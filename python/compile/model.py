"""L2 JAX model: the compute graph of one short-running simulation task.

`simulate` chains SCAN_STEPS kernel steps with `lax.scan` (no unrolling —
one fused HLO while-loop) and finishes with the checksum kernel. This is
the function `aot.py` lowers once per shape variant; the Rust runtime
invokes the compiled module repeatedly to scale task duration.
"""

import jax
import numpy as np

from compile.kernels.checksum import checksum
from compile.kernels.simstep import simstep

# Inner steps per module invocation. Rust chains invocations for longer
# tasks, so this only sets the granularity of one PJRT execute call.
SCAN_STEPS = 4


def simulate(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Run SCAN_STEPS simulation steps; return `(state, checksum)`."""

    def body(state, _):
        return simstep(state), None

    final, _ = jax.lax.scan(body, x, None, length=SCAN_STEPS)
    return final, checksum(final)


def initial_state(batch: int, h: int, w: int, task_id: int) -> np.ndarray:
    """Deterministic per-task initial state.

    Mirrors `rust/src/runtime/server.rs::initial_state` bit-for-bit: a
    SplitMix-style integer hash of `(element_index, task_id)` with u64
    wraparound, mapped to `[0, 1)` f32 (numpy, not jnp: JAX's default
    32-bit ints would break the wraparound semantics). Cross-language
    checksum tests depend on this.
    """
    n = batch * h * w
    with np.errstate(over="ignore"):
        i = np.arange(n, dtype=np.uint64)
        x = i + np.uint64(task_id) * np.uint64(7919)
        h64 = (x * np.uint64(0x9E3779B97F4A7C15)) >> np.uint64(40)
    vals = h64.astype(np.float32) / np.float32(1 << 24)
    return vals.reshape(batch, h, w)
