"""Pure-jnp oracles for the Pallas kernels (the build-time correctness
signal: pytest + hypothesis assert allclose between kernels and these)."""

import jax.numpy as jnp

from compile.kernels.simstep import ALPHA, BETA


def simstep_ref(x: jnp.ndarray, alpha: float = ALPHA, beta: float = BETA) -> jnp.ndarray:
    """Reference diffusion + cubic damping step, batched `[b, h, w]`."""
    lap = (
        jnp.roll(x, 1, axis=1)
        + jnp.roll(x, -1, axis=1)
        + jnp.roll(x, 1, axis=2)
        + jnp.roll(x, -1, axis=2)
        - 4.0 * x
    )
    y = x + alpha * lap
    return y - beta * y**3


def checksum_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Reference weighted-sum checksum; returns `[1, 1]`."""
    h = x.shape[1]
    weights = (1.0 + (jnp.arange(h, dtype=x.dtype) % 2.0)).reshape(1, h, 1)
    return jnp.sum(x * weights).reshape(1, 1)


def simulate_ref(x: jnp.ndarray, steps: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Reference for the L2 model: `steps` chained steps + checksum."""
    for _ in range(steps):
        x = simstep_ref(x)
    return x, checksum_ref(x)
