"""L1 Pallas kernel: state checksum (output-integrity reduction).

The coordinator verifies task outputs by a weighted sum over the final
state. The kernel iterates the batch as the Pallas grid and accumulates
into a single (1, 1) output block — the classic Pallas accumulation
pattern (`pl.when(first_program)` zero-init, `+=` on every step).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _checksum_kernel(x_ref, o_ref):
    b = pl.program_id(0)

    @pl.when(b == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    # Weighted fold: alternate-sign row weights defeat trivial
    # cancellation-symmetric errors.
    h = x.shape[1]
    weights = (1.0 + (jnp.arange(h, dtype=x.dtype) % 2.0)).reshape(1, h, 1)
    o_ref[...] += jnp.sum(x * weights).reshape(1, 1)


@functools.partial(jax.jit, static_argnames=())
def checksum(x: jax.Array) -> jax.Array:
    """Weighted-sum checksum of a batched state; returns `[1, 1] f32`."""
    batch, h, w = x.shape
    return pl.pallas_call(
        _checksum_kernel,
        out_shape=jax.ShapeDtypeStruct((1, 1), x.dtype),
        grid=(batch,),
        in_specs=[pl.BlockSpec((1, h, w), lambda b: (b, 0, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda b: (0, 0)),
        interpret=True,
    )(x)
