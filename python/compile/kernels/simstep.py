"""L1 Pallas kernel: one step of the synthetic short-running simulation.

The paper's benchmark tasks are "large scale simulations of short running
jobs" — constant-time science payloads (MATLAB/Octave simulations on MIT
SuperCloud). Our payload is a batched 2-D diffusion step fused with a
cubic damping update over a periodic domain:

    lap  = roll(x,+1,h) + roll(x,-1,h) + roll(x,+1,w) + roll(x,-1,w) - 4x
    y    = x + alpha * lap
    out  = y - beta * y**3

TPU mapping (DESIGN.md §Hardware-Adaptation): the batch dimension is the
Pallas grid; each program owns one (h, w) f32 tile in VMEM (<= 128x128 =
64 KiB, far under the ~16 MiB VMEM budget even with double buffering),
and the stencil + damping are fused so the tile makes exactly one
HBM->VMEM->HBM round trip per step. `interpret=True` everywhere: the CPU
PJRT client cannot execute Mosaic custom-calls, and interpret mode lowers
to plain HLO that the Rust runtime loads directly.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Physics constants of the synthetic simulation (shared with ref.py).
ALPHA = 0.05
BETA = 0.01


def _simstep_kernel(x_ref, o_ref, *, alpha: float, beta: float):
    """One fused stencil + damping step over a single (1, h, w) block."""
    x = x_ref[...]  # block shape (1, h, w): axis 1 = h, axis 2 = w
    lap = (
        jnp.roll(x, 1, axis=1)
        + jnp.roll(x, -1, axis=1)
        + jnp.roll(x, 1, axis=2)
        + jnp.roll(x, -1, axis=2)
        - 4.0 * x
    )
    y = x + alpha * lap
    o_ref[...] = y - beta * y * y * y


@functools.partial(jax.jit, static_argnames=())
def simstep(x: jax.Array) -> jax.Array:
    """Apply one simulation step to a batched state `[batch, h, w] f32`."""
    batch, h, w = x.shape
    return pl.pallas_call(
        functools.partial(_simstep_kernel, alpha=ALPHA, beta=BETA),
        out_shape=jax.ShapeDtypeStruct((batch, h, w), x.dtype),
        grid=(batch,),
        in_specs=[pl.BlockSpec((1, h, w), lambda b: (b, 0, 0))],
        out_specs=pl.BlockSpec((1, h, w), lambda b: (b, 0, 0)),
        interpret=True,
    )(x)


def vmem_bytes_per_program(h: int, w: int, dtype_bytes: int = 4) -> int:
    """Estimated VMEM footprint of one grid program (in + out tiles).

    Used by the DESIGN.md roofline notes; interpret-mode wallclock is not
    a TPU proxy, so we reason about footprint and arithmetic intensity.
    """
    return 2 * h * w * dtype_bytes


def flops_per_element() -> int:
    """FLOPs per element per step (4 adds + sub + axpy + cubic damping)."""
    # lap: 4 add + 1 mul/sub chain = 5; y = x + a*lap: 2; y^3 damping: 3.
    return 10
