"""AOT export: lower the L2 model to HLO *text* artifacts for the Rust
runtime.

HLO text (NOT `lowered.compile()` / serialized protos) is the interchange
format: the image's xla_extension 0.5.1 rejects jax>=0.5 protos with
64-bit instruction ids, while the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Also writes `expected_checksums.json`: reference checksums for a few
(variant, task_id, invocation-count) combinations, which the Rust
integration tests compare against PJRT results — the cross-language
correctness oracle.

Usage: python -m compile.aot --out-dir ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile.model import initial_state, simulate

# Shape variants exported as artifacts: (batch, h, w).
VARIANTS = [
    (8, 32, 32),   # "small"  — quick tasks, smoke tests
    (4, 64, 64),   # "medium"
    (1, 128, 128), # "large"  — one full VMEM-sized tile
]

# (variant index, task_id, chained invocations) for expected_checksums.
CHECKSUM_CASES = [(0, 0, 1), (0, 7, 3), (1, 42, 2), (2, 3, 1)]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_variant(batch: int, h: int, w: int, out_dir: str) -> str:
    spec = jax.ShapeDtypeStruct((batch, h, w), jnp.float32)
    lowered = jax.jit(simulate).lower(spec)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"simstep_{batch}x{h}x{w}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    return path


def expected_checksums() -> list[dict]:
    """Reference checksums via the jitted model (no Pallas bypass: this is
    the exact computation the artifact encodes)."""
    out = []
    for vi, task_id, invocations in CHECKSUM_CASES:
        batch, h, w = VARIANTS[vi]
        state = initial_state(batch, h, w, task_id)
        checksum = None
        for _ in range(invocations):
            state, checksum = simulate(state)
        out.append(
            {
                "artifact": f"simstep_{batch}x{h}x{w}",
                "task_id": task_id,
                "invocations": invocations,
                "checksum": float(checksum[0, 0]),
            }
        )
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    for batch, h, w in VARIANTS:
        path = export_variant(batch, h, w, args.out_dir)
        size = os.path.getsize(path)
        print(f"wrote {path} ({size} bytes)")
    cs_path = os.path.join(args.out_dir, "expected_checksums.json")
    with open(cs_path, "w") as f:
        json.dump(expected_checksums(), f, indent=2)
    print(f"wrote {cs_path}")


if __name__ == "__main__":
    main()
