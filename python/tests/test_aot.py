"""AOT path: HLO-text export sanity and the cross-language checksum
oracle file."""

import json
import os

import numpy as np
import pytest

from compile import aot
from compile.model import initial_state, simulate


@pytest.fixture(scope="module")
def out_dir(tmp_path_factory):
    d = tmp_path_factory.mktemp("artifacts")
    return str(d)


class TestExport:
    def test_variant_exports_hlo_text(self, out_dir):
        path = aot.export_variant(2, 8, 8, out_dir)
        assert path.endswith("simstep_2x8x8.hlo.txt")
        text = open(path).read()
        assert "ENTRY" in text, "must be HLO text, not a proto"
        assert "HloModule" in text
        # Tuple-returning module: (state, checksum).
        assert "tuple" in text.lower()

    def test_all_variants_have_distinct_shapes(self, out_dir):
        paths = [aot.export_variant(b, h, w, out_dir) for b, h, w in aot.VARIANTS]
        assert len(set(paths)) == len(aot.VARIANTS)
        for (b, h, w), p in zip(aot.VARIANTS, paths):
            assert f"{b}x{h}x{w}" in p
            assert os.path.getsize(p) > 500

    def test_hlo_text_mentions_shape(self, out_dir):
        path = aot.export_variant(2, 8, 8, out_dir)
        text = open(path).read()
        assert "f32[2,8,8]" in text


class TestChecksumOracle:
    def test_cases_cover_every_variant(self):
        cases = aot.expected_checksums()
        artifacts = {c["artifact"] for c in cases}
        for b, h, w in aot.VARIANTS:
            assert f"simstep_{b}x{h}x{w}" in artifacts

    def test_checksums_reproducible(self):
        a = aot.expected_checksums()
        b = aot.expected_checksums()
        for x, y in zip(a, b):
            assert x == y

    def test_checksum_matches_direct_model_run(self):
        case = aot.expected_checksums()[0]
        b, h, w = aot.VARIANTS[0]
        state = initial_state(b, h, w, case["task_id"])
        cs = None
        for _ in range(case["invocations"]):
            state, cs = simulate(state)
        assert abs(float(cs[0, 0]) - case["checksum"]) < 1e-5

    def test_json_roundtrip(self, out_dir):
        cases = aot.expected_checksums()
        p = os.path.join(out_dir, "expected_checksums.json")
        with open(p, "w") as f:
            json.dump(cases, f)
        loaded = json.load(open(p))
        assert loaded == cases
        for c in loaded:
            assert np.isfinite(c["checksum"])
