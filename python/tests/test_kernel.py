"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes; every case asserts allclose between the
interpret-mode Pallas kernel and ref.py — the core build-time correctness
signal for the artifacts the Rust runtime executes.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.checksum import checksum
from compile.kernels.ref import checksum_ref, simstep_ref, simulate_ref
from compile.kernels.simstep import (
    ALPHA,
    flops_per_element,
    simstep,
    vmem_bytes_per_program,
)

shapes = st.tuples(
    st.integers(min_value=1, max_value=4),   # batch
    st.integers(min_value=2, max_value=24),  # h
    st.integers(min_value=2, max_value=24),  # w
)


def rand_state(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(-1.0, 1.0, size=shape).astype(np.float32))


class TestSimstepKernel:
    @settings(max_examples=25, deadline=None)
    @given(shape=shapes, seed=st.integers(min_value=0, max_value=2**31))
    def test_matches_reference(self, shape, seed):
        x = rand_state(shape, seed)
        got = simstep(x)
        want = simstep_ref(x)
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)

    def test_shape_and_dtype_preserved(self):
        x = rand_state((3, 8, 16), 0)
        y = simstep(x)
        assert y.shape == x.shape
        assert y.dtype == jnp.float32

    def test_constant_field_stays_constant_modulo_damping(self):
        # Uniform field: laplacian is zero, only damping acts.
        x = jnp.full((1, 8, 8), 0.5, dtype=jnp.float32)
        y = simstep(x)
        expected = 0.5 - 0.01 * 0.5**3
        np.testing.assert_allclose(y, expected, rtol=1e-6)

    def test_translation_equivariance(self):
        # Periodic stencil: rolling the input rolls the output.
        x = rand_state((1, 12, 12), 3)
        rolled = jnp.roll(x, 5, axis=1)
        np.testing.assert_allclose(
            simstep(rolled), jnp.roll(simstep(x), 5, axis=1), rtol=1e-6, atol=1e-6
        )

    def test_batch_elements_independent(self):
        x = rand_state((4, 8, 8), 4)
        full = simstep(x)
        for b in range(4):
            single = simstep(x[b : b + 1])
            np.testing.assert_allclose(full[b : b + 1], single, rtol=1e-6, atol=1e-6)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**31))
    def test_diffusion_conserves_mass_without_damping(self, seed):
        # With beta=0, periodic diffusion conserves the field sum.
        x = rand_state((2, 10, 10), seed)
        lap_only = simstep_ref(x, alpha=ALPHA, beta=0.0)
        np.testing.assert_allclose(
            jnp.sum(lap_only), jnp.sum(x), rtol=1e-4, atol=1e-4
        )

    def test_stability_many_steps(self):
        # Repeated application must not blow up (damping bounds it).
        x = rand_state((1, 16, 16), 9)
        for _ in range(50):
            x = simstep(x)
        assert bool(jnp.all(jnp.isfinite(x)))
        assert float(jnp.max(jnp.abs(x))) < 10.0


class TestChecksumKernel:
    @settings(max_examples=25, deadline=None)
    @given(shape=shapes, seed=st.integers(min_value=0, max_value=2**31))
    def test_matches_reference(self, shape, seed):
        x = rand_state(shape, seed)
        got = checksum(x)
        want = checksum_ref(x)
        assert got.shape == (1, 1)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_zero_state(self):
        x = jnp.zeros((3, 8, 8), dtype=jnp.float32)
        np.testing.assert_allclose(checksum(x), 0.0, atol=1e-7)

    def test_linearity(self):
        x = rand_state((2, 8, 8), 11)
        np.testing.assert_allclose(
            checksum(2.0 * x), 2.0 * checksum(x), rtol=1e-5, atol=1e-5
        )

    def test_batch_additivity(self):
        x = rand_state((4, 6, 6), 12)
        total = checksum(x)
        parts = sum(float(checksum(x[b : b + 1])[0, 0]) for b in range(4))
        np.testing.assert_allclose(float(total[0, 0]), parts, rtol=1e-5, atol=1e-5)

    def test_weights_not_uniform(self):
        # Moving mass between rows with different weights changes the sum.
        x = jnp.zeros((1, 4, 4), dtype=jnp.float32).at[0, 0, 0].set(1.0)
        y = jnp.zeros((1, 4, 4), dtype=jnp.float32).at[0, 1, 0].set(1.0)
        assert abs(float(checksum(x)[0, 0]) - float(checksum(y)[0, 0])) > 0.5


class TestRooflineEstimates:
    def test_vmem_footprint_within_budget(self):
        # Largest exported tile: 128x128 f32 in+out = 128 KiB << 16 MiB.
        assert vmem_bytes_per_program(128, 128) == 2 * 128 * 128 * 4
        assert vmem_bytes_per_program(128, 128) < 16 * 1024 * 1024 // 4

    def test_flops_estimate_positive(self):
        assert flops_per_element() >= 8


@pytest.mark.parametrize("steps", [1, 3])
def test_simulate_ref_chains_steps(steps):
    x = rand_state((2, 8, 8), 21)
    state, cs = simulate_ref(x, steps)
    expect = x
    for _ in range(steps):
        expect = simstep_ref(expect)
    np.testing.assert_allclose(state, expect, rtol=1e-6)
    np.testing.assert_allclose(cs, checksum_ref(expect), rtol=1e-6)
