"""L2 correctness: the simulate() model vs the reference, shapes, scan
semantics, and the deterministic initial-state hash."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import simulate_ref
from compile.model import SCAN_STEPS, initial_state, simulate


def rand_state(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(0.0, 1.0, size=shape).astype(np.float32))


class TestSimulate:
    def test_matches_reference(self):
        x = rand_state((2, 16, 16), 1)
        state, cs = simulate(x)
        want_state, want_cs = simulate_ref(x, SCAN_STEPS)
        np.testing.assert_allclose(state, want_state, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(cs, want_cs, rtol=1e-4, atol=1e-4)

    def test_output_shapes(self):
        x = rand_state((4, 8, 8), 2)
        state, cs = simulate(x)
        assert state.shape == (4, 8, 8)
        assert cs.shape == (1, 1)
        assert state.dtype == jnp.float32

    def test_jit_compiles_once_per_shape(self):
        f = jax.jit(simulate)
        x = rand_state((1, 8, 8), 3)
        f(x)
        before = f._cache_size()
        f(rand_state((1, 8, 8), 4))  # same shape: no retrace
        assert f._cache_size() == before

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(min_value=0, max_value=2**31))
    def test_chained_invocations_compose(self, seed):
        # Two module invocations == 2*SCAN_STEPS reference steps.
        x = rand_state((1, 12, 12), seed)
        s1, _ = simulate(x)
        s2, cs2 = simulate(s1)
        want, want_cs = simulate_ref(x, 2 * SCAN_STEPS)
        np.testing.assert_allclose(s2, want, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(cs2, want_cs, rtol=1e-3, atol=1e-3)


class TestInitialState:
    def test_deterministic(self):
        a = initial_state(2, 4, 4, 7)
        b = initial_state(2, 4, 4, 7)
        np.testing.assert_array_equal(a, b)

    def test_task_ids_differ(self):
        a = initial_state(2, 4, 4, 7)
        b = initial_state(2, 4, 4, 8)
        assert np.any(a != b)

    def test_range_and_shape(self):
        s = initial_state(3, 8, 8, 0)
        assert s.shape == (3, 8, 8)
        assert s.dtype == np.float32
        assert np.all((s >= 0.0) & (s < 1.0))

    def test_known_values_match_rust_hash(self):
        # First elements for task_id=0: hash(i) = (i * K) >> 40, K the
        # splitmix constant — spot values computed independently.
        s = initial_state(1, 2, 2, 0).ravel()
        K = 0x9E3779B97F4A7C15
        for i in range(4):
            expect = (((i * K) % (1 << 64)) >> 40) / float(1 << 24)
            assert abs(float(s[i]) - expect) < 1e-7, (i, float(s[i]), expect)
