//! Bench: regenerate Table III (summary of run times).
//!
//! One measured DES run per cell (the paper's 3-run medians come from
//! `examples/paper_tables.rs`); this bench also reports the simulator's
//! own throughput (DES events/second) per cell, which is the §Perf L3
//! metric.
//!
//! ```bash
//! cargo bench --bench bench_table3                      # all scales
//! cargo bench --bench bench_table3 -- --max-nodes 32    # CI smoke
//! ```
//!
//! Results land in `BENCH_table3.json` at the crate root.

use llsched::bench::{arg_value, bench, section, write_artifact, BenchOpts};
use llsched::config::presets::{is_paper_na, NODE_SCALES, TASK_CONFIGS};
use llsched::config::Mode;
use llsched::coordinator::experiment::run_cell;
use llsched::util::json::Json;
use llsched::workload::paper::PaperCell;
use std::time::Duration;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let max_nodes = arg_value(&args, "--max-nodes").map(|v| v as u32).unwrap_or(u32::MAX);
    section("Table III — runtime per cell (simulated) + DES throughput");
    println!(
        "{:<16} {:>10} {:>12} {:>12} {:>14}",
        "cell", "runtime", "overhead", "sim events", "events/sec"
    );
    let mut rows: Vec<Json> = Vec::new();
    for &nodes in NODE_SCALES.iter().filter(|&&n| n <= max_nodes) {
        for task in &TASK_CONFIGS {
            for mode in [Mode::MultiLevel, Mode::NodeBased] {
                let label = format!("{}n/{}s/{}", nodes, task.task_time, mode.short());
                if is_paper_na(nodes, task, mode) {
                    println!("{:<16} {:>10}", label, "N/A");
                    rows.push(Json::obj().set("cell", label.as_str()).set("na", true));
                    continue;
                }
                let cell = PaperCell::new(nodes, *task, mode, 0);
                let mut events = 0u64;
                let mut runtime = 0.0;
                let mut overhead = 0.0;
                let r = bench(
                    &cell.label(),
                    BenchOpts { warmup: 0, iters: 1, max_wall: Duration::from_secs(120) },
                    |_| {
                        let res = run_cell(&cell).expect("cell runs");
                        events = res.events;
                        runtime = res.runtime;
                        overhead = res.overhead;
                    },
                );
                let wall = r.summary.mean;
                let events_per_s = events as f64 / wall.max(1e-9);
                println!(
                    "{:<16} {:>9.0}s {:>11.0}s {:>12} {:>14.0}",
                    cell.label(),
                    runtime,
                    overhead,
                    events,
                    events_per_s
                );
                rows.push(
                    Json::obj()
                        .set("cell", cell.label())
                        .set("runtime_s", runtime)
                        .set("overhead_s", overhead)
                        .set("events", events)
                        .set("wall_s", wall)
                        .set("events_per_s", events_per_s),
                );
            }
        }
    }
    let artifact = Json::obj()
        .set("bench", "bench_table3")
        .set("command", std::env::args().collect::<Vec<_>>().join(" "))
        .set("cells", Json::Arr(rows))
        .set("passed", true);
    write_artifact("BENCH_table3.json", &artifact);
}
