//! Bench: regenerate Table III (summary of run times).
//!
//! One measured DES run per cell (the paper's 3-run medians come from
//! `examples/paper_tables.rs`); this bench also reports the simulator's
//! own throughput (DES events/second) per cell, which is the §Perf L3
//! metric.

use llsched::bench::{bench, section, BenchOpts};
use llsched::config::presets::{is_paper_na, NODE_SCALES, TASK_CONFIGS};
use llsched::config::Mode;
use llsched::coordinator::experiment::run_cell;
use llsched::workload::paper::PaperCell;
use std::time::Duration;

fn main() {
    section("Table III — runtime per cell (simulated) + DES throughput");
    println!(
        "{:<16} {:>10} {:>12} {:>12} {:>14}",
        "cell", "runtime", "overhead", "sim events", "events/sec"
    );
    for &nodes in &NODE_SCALES {
        for task in &TASK_CONFIGS {
            for mode in [Mode::MultiLevel, Mode::NodeBased] {
                if is_paper_na(nodes, task, mode) {
                    let label = format!("{}n/{}s/{}", nodes, task.task_time, mode.short());
                    println!("{:<16} {:>10}", label, "N/A");
                    continue;
                }
                let cell = PaperCell::new(nodes, *task, mode, 0);
                let mut events = 0u64;
                let mut runtime = 0.0;
                let mut overhead = 0.0;
                let r = bench(
                    &cell.label(),
                    BenchOpts { warmup: 0, iters: 1, max_wall: Duration::from_secs(120) },
                    |_| {
                        let res = run_cell(&cell).expect("cell runs");
                        events = res.events;
                        runtime = res.runtime;
                        overhead = res.overhead;
                    },
                );
                let wall = r.summary.mean;
                println!(
                    "{:<16} {:>9.0}s {:>11.0}s {:>12} {:>14.0}",
                    cell.label(),
                    runtime,
                    overhead,
                    events,
                    events as f64 / wall.max(1e-9)
                );
            }
        }
    }
}
