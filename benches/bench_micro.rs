//! Microbenchmarks of the hot paths (§Perf L3): DES event queue,
//! scheduler event throughput, aggregation planning, script generation,
//! pending-queue ops, and — when artifacts exist — PJRT step latency.
//!
//! ```bash
//! cargo bench --bench bench_micro             # full sweep
//! cargo bench --bench bench_micro -- --quick  # CI smoke: skip the
//!                                             # heavy DES cell + PJRT
//! ```
//!
//! Results land in `BENCH_micro.json` at the crate root (the uniform
//! bench artifact pattern; see `benches/bench_pool.rs`).

use llsched::aggregation::plan::{Aggregator, ClusterShape, Workload};
use llsched::aggregation::script::build_scripts;
use llsched::aggregation::{MultiLevel, NodeBased};
use llsched::bench::{bench, black_box, has_flag, result_row, section, write_artifact, BenchOpts};
use llsched::cluster::Cluster;
use llsched::config::presets::TASK_CONFIGS;
use llsched::config::Mode;
use llsched::coordinator::experiment::run_cell;
use llsched::scheduler::queue::PendingQueue;
use llsched::sim::EventQueue;
use llsched::util::json::Json;
use llsched::workload::paper::PaperCell;
use std::time::Duration;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = has_flag(&args, "--quick");
    let opts = BenchOpts { warmup: 1, iters: 5, max_wall: Duration::from_secs(30) };
    let mut rows: Vec<Json> = Vec::new();
    let mut extras = Json::obj();

    section("DES event queue");
    let r = bench("event_queue push+pop 1M", opts, |i| {
        let mut q: EventQueue<u64> = EventQueue::new();
        for k in 0..1_000_000u64 {
            q.at((k ^ (i as u64 * 7919)) as f64 % 1e6, k);
        }
        let mut sum = 0u64;
        while let Some(e) = q.pop() {
            sum = sum.wrapping_add(e.event);
        }
        sum
    });
    println!("{}", r.line());
    let m_events_per_s = 2.0 / r.summary.p50.max(1e-12); // 1M push + 1M pop
    println!("  → {m_events_per_s:.1} M events/s");
    rows.push(result_row("event_queue", &r));
    extras = extras.set("event_queue_m_events_per_s", m_events_per_s);

    if quick {
        section("scheduler DES throughput — skipped (--quick)");
    } else {
        section("scheduler DES throughput (512-node M* cell, the heaviest)");
        let cell = PaperCell::new(512, TASK_CONFIGS[3], Mode::MultiLevel, 0);
        let mut events = 0u64;
        let heavy_opts = BenchOpts { warmup: 0, iters: 3, max_wall: Duration::from_secs(60) };
        let r = bench("run_cell 512n/60s/M*", heavy_opts, |_| {
            let res = run_cell(&cell).expect("runs");
            events = res.events;
            res.runtime
        });
        println!("{}", r.line());
        let des_m_events_per_s = events as f64 / r.summary.p50.max(1e-12) / 1e6;
        println!("  → {events} events, {des_m_events_per_s:.2} M events/s");
        rows.push(result_row("scheduler_des", &r));
        extras = extras
            .set("scheduler_des_events", events)
            .set("scheduler_des_m_events_per_s", des_m_events_per_s);
    }

    section("aggregation planning (7.9M-task workload)");
    let shape = ClusterShape { nodes: 512, cores_per_node: 64, task_mem_mib: 256 };
    let w = Workload::paper(32_768, 1.0, 240.0);
    let r = bench("MultiLevel.plan 32768 tasks", opts, |_| {
        black_box(MultiLevel.plan("b", &w, &shape).unwrap().array_size())
    });
    println!("{}", r.line());
    rows.push(result_row("aggregation", &r));
    let r = bench("NodeBased.plan 512 tasks", opts, |_| {
        black_box(NodeBased::default().plan("b", &w, &shape).unwrap().array_size())
    });
    println!("{}", r.line());
    rows.push(result_row("aggregation", &r));

    section("script generation (512 nodes × 64 lanes)");
    let r = bench("build_scripts 7.9M tasks", opts, |_| {
        black_box(build_scripts(7_864_320, 512, 64, 1).len())
    });
    println!("{}", r.line());
    rows.push(result_row("scripts", &r));
    let scripts = build_scripts(7_864_320, 512, 64, 1);
    let r = bench("render one node script", opts, |_| {
        black_box(scripts[0].render("./sim_task").len())
    });
    println!("{}", r.line());
    rows.push(result_row("scripts", &r));

    section("pending queue (32768 tasks)");
    let r = bench("push+pop 32768", opts, |_| {
        let mut q = PendingQueue::new();
        for t in 0..32_768u64 {
            q.push(t, 0, 0.0);
        }
        let mut n = 0u64;
        while q.pop(0.0).is_some() {
            n += 1;
        }
        n
    });
    println!("{}", r.line());
    rows.push(result_row("pending_queue", &r));

    section("cluster placement search (512 nodes)");
    let cluster = Cluster::tx_green(512);
    let r = bench("find_idle_nodes(512)", opts, |_| {
        black_box(cluster.find_idle_nodes(512, None).len())
    });
    println!("{}", r.line());
    rows.push(result_row("placement", &r));
    let r = bench("find_core_slots(32768)", opts, |_| {
        black_box(cluster.find_core_slots(32_768, 64, None).len())
    });
    println!("{}", r.line());
    rows.push(result_row("placement", &r));

    if quick {
        section("PJRT runtime — skipped (--quick)");
    } else {
        section("PJRT runtime (requires `make artifacts`)");
        match llsched::runtime::find_artifacts_dir() {
            Some(dir) => {
                let rt =
                    llsched::runtime::Runtime::load(&dir.join("simstep_8x32x32.hlo.txt")).unwrap();
                let state = vec![0.5f32; rt.artifact.elements()];
                let rt_opts = BenchOpts { warmup: 3, iters: 20, max_wall: Duration::from_secs(20) };
                let r = bench("simstep_8x32x32 step (4 scan iters)", rt_opts, |_| {
                    black_box(rt.step(&state).unwrap().1)
                });
                println!("{}", r.line());
                rows.push(result_row("pjrt", &r));
                let rt = llsched::runtime::Runtime::load(&dir.join("simstep_1x128x128.hlo.txt"))
                    .unwrap();
                let state = vec![0.5f32; rt.artifact.elements()];
                let r = bench("simstep_1x128x128 step (4 scan iters)", rt_opts, |_| {
                    black_box(rt.step(&state).unwrap().1)
                });
                println!("{}", r.line());
                rows.push(result_row("pjrt", &r));
            }
            None => println!("  artifacts/ not found — skipped"),
        }
    }

    let report = Json::obj()
        .set("bench", "bench_micro")
        .set("command", std::env::args().collect::<Vec<_>>().join(" "))
        .set("quick", quick)
        .set("results", Json::Arr(rows))
        .set("derived", extras)
        .set("passed", true);
    write_artifact("BENCH_micro.json", &report);
}
