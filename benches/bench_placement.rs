//! Bench: indexed placement vs the linear-scan baseline.
//!
//! The acceptance bar for the placement subsystem: indexed placement
//! beats the O(N) scan by ≥10× for single-task dispatch at 4096 nodes.
//! Three measurements per scale (512 / 4096 / 16384 / 65536 nodes):
//!
//!  1. single-task core-level dispatch on a nearly-full cluster — the
//!     worst case for first-fit scans (the fitting node is the last);
//!  2. whole-node ("give me an idle node") lookup on the same cluster;
//!  3. a full node-based machine fill — N whole-node placements, the
//!     paper's interactive-launch hot loop (scan pays O(N²) total,
//!     the index O(N log N)).
//!
//! ```bash
//! cargo bench --bench bench_placement                      # full sweep
//! cargo bench --bench bench_placement -- --max-scale 512 --require 5
//! ```
//!
//! `--max-scale N` limits the sweep to scales ≤ N (the CI smoke lane
//! runs the 512-node size only); `--require X` additionally enforces a
//! ≥X× dispatch speedup at the *largest scale run*, so perf
//! regressions fail PRs even on the truncated sweep.

use llsched::bench::{bench, black_box, fmt_secs, section, BenchOpts};
use llsched::cluster::Cluster;
use llsched::placement::{FreeIndex, PlacementEngine, Strategy};
use llsched::util::json::Json;
use std::time::Duration;

const SCALES: [u32; 4] = [512, 4096, 16384, 65_536];

/// Above this scale the O(N²) scan-based machine fill is skipped (it
/// would take minutes at 65,536 nodes); the indexed fill still runs, so
/// the large-scale cells report absolute indexed throughput only.
const MAX_SCAN_FILL: u32 = 16_384;

/// Cluster with every node but the last fully allocated.
fn near_full(nodes: u32) -> Cluster {
    let mut c = Cluster::tx_green(nodes);
    for id in 0..nodes - 1 {
        c.node_mut(id).unwrap().allocate_whole().unwrap();
    }
    c
}

fn fill_scan(nodes: u32) -> usize {
    let mut cluster = Cluster::tx_green(nodes);
    let mut placed = 0usize;
    loop {
        let id = {
            let idle = cluster.find_idle_nodes(1, None);
            match idle.first() {
                Some(&id) => id,
                None => break,
            }
        };
        cluster.node_mut(id).unwrap().allocate_whole().unwrap();
        placed += 1;
    }
    placed
}

fn fill_indexed(nodes: u32) -> usize {
    let mut cluster = Cluster::tx_green(nodes);
    let mut engine = PlacementEngine::new(&cluster, Strategy::NodeBased, 1);
    let mut placed = 0usize;
    while engine.place_whole(&mut cluster, None).is_some() {
        placed += 1;
    }
    placed
}

/// Parse `--flag value` from argv (panics on malformed input: a bench
/// invocation error should fail loudly, not silently run the default).
fn arg_value(args: &[String], flag: &str) -> Option<f64> {
    args.iter().position(|a| a == flag).map(|i| {
        args.get(i + 1)
            .unwrap_or_else(|| panic!("{flag} needs a value"))
            .parse::<f64>()
            .unwrap_or_else(|_| panic!("{flag} needs a number"))
    })
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let max_scale = arg_value(&args, "--max-scale").map(|v| v as u32);
    let require = arg_value(&args, "--require");

    let opts = BenchOpts {
        warmup: 1,
        iters: 5,
        max_wall: Duration::from_secs(30),
    };
    let mut dispatch_speedups = Vec::new();
    let mut rows: Vec<Json> = Vec::new();

    let scales: Vec<u32> = SCALES
        .iter()
        .copied()
        .filter(|&n| max_scale.map(|m| n <= m).unwrap_or(true))
        .collect();
    assert!(!scales.is_empty(), "--max-scale below the smallest scale");

    for &nodes in &scales {
        section(&format!("{nodes} nodes"));
        let cluster = near_full(nodes);
        let index = FreeIndex::build(&cluster);
        let queries: usize = 1000;

        // 1. single-task core-level dispatch query.
        let scan = bench(&format!("scan  find_fit_node ×{queries}"), opts, |_| {
            let mut acc = 0u64;
            for _ in 0..queries {
                acc += black_box(cluster.find_fit_node(1, 0, None).unwrap()) as u64;
            }
            acc
        });
        println!("{}", scan.line());
        let indexed = bench(&format!("index first_fit      ×{queries}"), opts, |_| {
            let mut acc = 0u64;
            for _ in 0..queries {
                acc += black_box(index.first_fit(&cluster, 0, 1, 0).unwrap()) as u64;
            }
            acc
        });
        println!("{}", indexed.line());
        let speedup = scan.summary.p50 / indexed.summary.p50.max(1e-12);
        println!(
            "  → single-task dispatch: scan {}/op, index {}/op, speedup {speedup:.0}x",
            fmt_secs(scan.summary.p50 / queries as f64),
            fmt_secs(indexed.summary.p50 / queries as f64),
        );
        dispatch_speedups.push((nodes, speedup));

        // 2. whole-node (idle pool) lookup.
        let scan_idle = bench(&format!("scan  find_idle_nodes ×{queries}"), opts, |_| {
            let mut acc = 0u64;
            for _ in 0..queries {
                acc += black_box(cluster.find_idle_nodes(1, None).first().copied().unwrap())
                    as u64;
            }
            acc
        });
        println!("{}", scan_idle.line());
        let index_idle = bench(&format!("index idle_lowest     ×{queries}"), opts, |_| {
            let mut acc = 0u64;
            for _ in 0..queries {
                acc += black_box(index.idle_lowest(&cluster, 0).unwrap()) as u64;
            }
            acc
        });
        println!("{}", index_idle.line());
        println!(
            "  → whole-node lookup: speedup {:.0}x",
            scan_idle.summary.p50 / index_idle.summary.p50.max(1e-12)
        );

        // 3. full node-based machine fill (the interactive-launch loop).
        let fill_opts = BenchOpts {
            warmup: 0,
            iters: 3,
            max_wall: Duration::from_secs(30),
        };
        let scan_fill_p50 = if nodes <= MAX_SCAN_FILL {
            let scan_fill = bench(&format!("scan  fill {nodes} whole nodes"), fill_opts, |_| {
                black_box(fill_scan(nodes))
            });
            println!("{}", scan_fill.line());
            Some(scan_fill.summary.p50)
        } else {
            println!("scan  fill {nodes} whole nodes: skipped (O(N²) scan above {MAX_SCAN_FILL} nodes)");
            None
        };
        let index_fill = bench(&format!("index fill {nodes} whole nodes"), fill_opts, |_| {
            black_box(fill_indexed(nodes))
        });
        println!("{}", index_fill.line());
        let fill_rate = nodes as f64 / index_fill.summary.p50.max(1e-12);
        match scan_fill_p50 {
            Some(p50) => println!(
                "  → machine fill: speedup {:.0}x (indexed {fill_rate:.0} placements/s)",
                p50 / index_fill.summary.p50.max(1e-12)
            ),
            None => println!("  → machine fill: indexed {fill_rate:.0} placements/s"),
        }
        rows.push(
            Json::obj()
                .set("nodes", nodes)
                .set("dispatch_speedup", speedup)
                .set(
                    "whole_node_lookup_speedup",
                    scan_idle.summary.p50 / index_idle.summary.p50.max(1e-12),
                )
                .set("indexed_fill_placements_per_s", fill_rate)
                .set(
                    "scan_fill_wall_s",
                    scan_fill_p50.map(Json::Num).unwrap_or(Json::Null),
                ),
        );
    }

    section("acceptance");
    let mut failed = false;
    let largest = *scales.last().expect("non-empty scales");
    for (nodes, speedup) in &dispatch_speedups {
        // The historical ≥10x bar applies at 4096+ nodes; `--require`
        // additionally enforces the caller's floor at the largest scale
        // actually run (the stricter of the two wins when both apply).
        let baseline = if *nodes >= 4096 { Some(10.0) } else { None };
        let required = if *nodes == largest { require } else { None };
        let floor = match (baseline, required) {
            (Some(a), Some(b)) => Some(a.max(b)),
            (a, None) => a,
            (None, b) => b,
        };
        let verdict = match floor {
            None => "info".to_string(),
            Some(f) if *speedup >= f => format!("PASS (≥{f:.0}x required)"),
            Some(f) => {
                failed = true;
                format!("FAIL (≥{f:.0}x required)")
            }
        };
        println!("single-task dispatch at {nodes:>6} nodes: {speedup:>8.0}x  [{verdict}]");
    }

    let report = Json::obj()
        .set("bench", "bench_placement")
        .set("command", std::env::args().collect::<Vec<_>>().join(" "))
        .set("scales", Json::Arr(rows))
        .set("passed", !failed);
    if let Err(e) = std::fs::write("BENCH_placement.json", report.to_pretty()) {
        eprintln!("warning: could not write BENCH_placement.json: {e}");
    } else {
        println!("\nwrote BENCH_placement.json");
    }
    if failed {
        std::process::exit(1);
    }
}
