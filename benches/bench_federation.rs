//! Bench: federated-gateway saturation vs a single scheduler.
//!
//! The acceptance bar for the federation subsystem: a fleet of 4
//! independent schedulers (32 nodes each) behind the submission gateway
//! must sustain **≥ 3×** the submission rate of one scheduler of the
//! same per-partition size before its p95 launch latency crosses the
//! knee. "Sustain" and "knee" are exactly the `federate --compare`
//! definitions — this bench runs the same
//! [`run_federation`](llsched::coordinator::experiment::run_federation)
//! sweep and pins its `rate_gain` as the acceptance number.
//!
//! ```bash
//! cargo bench --bench bench_federation                  # full sweep
//! cargo bench --bench bench_federation -- --max-rate 16 --jobs 200 --require 0
//! ```
//!
//! `--max-rate R` / `--jobs J` truncate the sweep (CI smoke); `--require X`
//! overrides the ≥3× floor (0 disables it — the truncated grid cannot
//! resolve the knee). Results land in `BENCH_federation.json` at the
//! crate root.

use llsched::bench::section;
use llsched::coordinator::experiment::{run_federation, FederationSweepOpts};
use llsched::util::json::Json;

/// Parse `--flag value` from argv (panics on malformed input: a bench
/// invocation error should fail loudly, not silently run the default).
fn arg_value(args: &[String], flag: &str) -> Option<f64> {
    args.iter().position(|a| a == flag).map(|i| {
        args.get(i + 1)
            .unwrap_or_else(|| panic!("{flag} needs a value"))
            .parse::<f64>()
            .unwrap_or_else(|_| panic!("{flag} needs a number"))
    })
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let max_rate = arg_value(&args, "--max-rate");
    let jobs = arg_value(&args, "--jobs").map(|v| v as usize);
    let require = arg_value(&args, "--require").unwrap_or(3.0);

    let mut opts = FederationSweepOpts::default();
    if let Some(m) = max_rate {
        opts.rates.retain(|&r| r <= m);
        assert!(!opts.rates.is_empty(), "--max-rate below the smallest rate");
    }
    if let Some(j) = jobs {
        opts.jobs = j;
    }
    let (instances, nodes) = (opts.instances, opts.nodes);

    section(&format!(
        "federation saturation sweep: 1 x {nodes} nodes vs {instances} x {nodes} nodes, \
         {} jobs/point, task {}s, knee {}s",
        opts.jobs, opts.task_s, opts.knee_s
    ));
    let t0 = std::time::Instant::now();
    let sweep = run_federation(opts).expect("sweep runs");
    let wall = t0.elapsed().as_secs_f64();
    for pt in &sweep.points {
        println!(
            "rate {:>5.1} jobs/s: single p95 {:>8.2}s   federated p95 {:>8.2}s",
            pt.rate, pt.single_p95, pt.federated_p95
        );
    }
    println!(
        "  → single saturates at {} jobs/s, federated at {} jobs/s \
         (gain {:.1}x; sweep wall time {wall:.1}s)",
        sweep.single_saturation, sweep.federated_saturation, sweep.rate_gain
    );

    section("acceptance");
    let mut failed = false;
    let verdict = if require <= 0.0 {
        "info (no floor)".to_string()
    } else if sweep.rate_gain.is_finite() && sweep.rate_gain >= require {
        format!("PASS (≥{require:.0}x required)")
    } else {
        failed = true;
        format!("FAIL (≥{require:.0}x required)")
    };
    println!(
        "federated sustained-rate gain at {instances} x {nodes} nodes: {:.1}x  [{verdict}]",
        sweep.rate_gain
    );

    let report = Json::obj()
        .set("bench", "bench_federation")
        .set("command", std::env::args().collect::<Vec<_>>().join(" "))
        .set("instances", sweep.opts.instances)
        .set("nodes_per_instance", sweep.opts.nodes)
        .set("jobs_per_point", sweep.opts.jobs)
        .set("task_s", sweep.opts.task_s)
        .set("knee_s", sweep.opts.knee_s)
        .set(
            "points",
            Json::Arr(
                sweep
                    .points
                    .iter()
                    .map(|pt| {
                        Json::obj()
                            .set("rate_jobs_per_s", pt.rate)
                            .set("single_p95_s", pt.single_p95)
                            .set("federated_p95_s", pt.federated_p95)
                    })
                    .collect(),
            ),
        )
        .set("single_saturation_jobs_per_s", sweep.single_saturation)
        .set("federated_saturation_jobs_per_s", sweep.federated_saturation)
        .set("rate_gain", sweep.rate_gain)
        .set("sweep_wall_s", wall)
        .set("passed", !failed);
    if let Err(e) = std::fs::write("BENCH_federation.json", report.to_pretty()) {
        eprintln!("warning: could not write BENCH_federation.json: {e}");
    } else {
        println!("\nwrote BENCH_federation.json");
    }
    if failed {
        std::process::exit(1);
    }
}
