//! Ablation benches for the design choices DESIGN.md calls out:
//!
//!  A. Aggregation granularity sweep — per-task vs per-core vs per-node
//!     at a fixed scale (the paper only reports the last two).
//!  B. Cleanup-cost dependence — the array-size coefficient is the cliff
//!     knob; sweep it and watch the 512-node M* runtime.
//!  C. Cleanup/dispatch interleave ratio — bounded starvation policy.
//!  D. Task-duration skew — node-based max-lane duration under
//!     log-normal and bimodal distributions (where per-node aggregation
//!     pays an imbalance cost the constant-time benchmark hides).
//!
//! ```bash
//! cargo bench --bench bench_ablation             # all four sections
//! cargo bench --bench bench_ablation -- --quick  # CI smoke: skip the
//!                                                # 512-node sweeps (B, C)
//! ```
//!
//! Results land in `BENCH_ablation.json` at the crate root.

use llsched::aggregation::plan::{Aggregator, ClusterShape};
use llsched::aggregation::{for_mode, NodeBased};
use llsched::bench::{has_flag, section, write_artifact};
use llsched::cluster::Cluster;
use llsched::config::presets::TASK_CONFIGS;
use llsched::config::Mode;
use llsched::scheduler::core::{SchedulerSim, TaskModel};
use llsched::scheduler::costmodel::CostModel;
use llsched::scheduler::noise::NoiseModel;
use llsched::util::fmt::count;
use llsched::util::json::Json;
use llsched::workload::paper::PaperCell;
use llsched::workload::taskgen::TaskGen;

fn quiet_run(nodes: u32, cost: CostModel, job: llsched::scheduler::job::JobSpec) -> (f64, f64) {
    let sim = SchedulerSim::new(
        Cluster::tx_green(nodes),
        cost,
        NoiseModel::dedicated(),
        99,
    )
    .with_server_speed(1.0)
    .with_task_model(TaskModel {
        startup: 0.0,
        jitter_sigma: 0.0,
        p_node_late: 0.0,
        late_range: (0.0, 0.0),
    })
    .without_timeline();
    let (out, id) = sim.run_single(job);
    let stats = out.job_stats(id, 240.0).expect("finished");
    (stats.runtime, stats.release_span)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = has_flag(&args, "--quick");

    section("A. aggregation granularity (8 nodes, t=30s, T_job=240s)");
    let cell = PaperCell::new(8, TASK_CONFIGS[2], Mode::NodeBased, 0);
    println!(
        "{:<12} {:>16} {:>10} {:>14}",
        "mode", "sched tasks", "runtime", "release span"
    );
    let mut granularity: Vec<Json> = Vec::new();
    for mode in [Mode::PerTask, Mode::MultiLevel, Mode::NodeBased] {
        let shape = ClusterShape { nodes: 8, cores_per_node: 64, task_mem_mib: 256 };
        let job = for_mode(mode).plan("abl", &cell.workload(), &shape).unwrap();
        let n = job.array_size();
        let (runtime, release) = quiet_run(8, CostModel::slurm_like_tx_green(), job);
        println!(
            "{:<12} {:>16} {:>9.0}s {:>13.1}s",
            mode.to_string(),
            count(n),
            runtime,
            release
        );
        granularity.push(
            Json::obj()
                .set("mode", mode.short())
                .set("sched_tasks", n)
                .set("runtime_s", runtime)
                .set("release_span_s", release),
        );
    }

    let mut cleanup_coeff: Vec<Json> = Vec::new();
    let mut interleave_rows: Vec<Json> = Vec::new();
    if quick {
        section("B/C. 512-node M* sweeps — skipped (--quick)");
    } else {
        section("B. cleanup array-size coefficient sweep (512 nodes, M*, t=60)");
        println!("{:<16} {:>12} {:>12}", "coeff (µs/task)", "runtime", "vs paper 2768s");
        for coeff_us in [0.0, 1.0, 2.15, 4.0, 8.0] {
            let mut cost = CostModel::slurm_like_tx_green();
            cost.cleanup_per_array_task = coeff_us * 1e-6;
            let cell = PaperCell::new(512, TASK_CONFIGS[3], Mode::MultiLevel, 0);
            let shape = cell.shape();
            let job = for_mode(Mode::MultiLevel)
                .plan("abl", &cell.workload(), &shape)
                .unwrap();
            let (runtime, _) = quiet_run(512, cost, job);
            println!("{:<16} {:>11.0}s {:>12.2}x", coeff_us, runtime, runtime / 2768.0);
            cleanup_coeff.push(
                Json::obj()
                    .set("coeff_us_per_task", coeff_us)
                    .set("runtime_s", runtime)
                    .set("vs_paper_2768s", runtime / 2768.0),
            );
        }

        section("C. cleanup/dispatch interleave (512 nodes, M*, t=60)");
        println!("{:<14} {:>12} {:>18}", "interleave", "runtime", "dispatch starved?");
        for interleave in [1u32, 2, 8, 64, u32::MAX] {
            let mut cost = CostModel::slurm_like_tx_green();
            cost.cleanup_interleave = interleave;
            let cell = PaperCell::new(512, TASK_CONFIGS[3], Mode::MultiLevel, 0);
            let job = for_mode(Mode::MultiLevel)
                .plan("abl", &cell.workload(), &cell.shape())
                .unwrap();
            let (runtime, _) = quiet_run(512, cost, job);
            let label = if interleave == u32::MAX {
                "∞ (no cleanup pri)".to_string()
            } else {
                interleave.to_string()
            };
            println!(
                "{:<14} {:>11.0}s {:>18}",
                label,
                runtime,
                if runtime > 1000.0 { "yes" } else { "no" }
            );
            interleave_rows.push(
                Json::obj()
                    .set("interleave", label)
                    .set("runtime_s", runtime)
                    .set("starved", runtime > 1000.0),
            );
        }
    }

    section("D. task-duration skew and node-based lane imbalance (32 nodes)");
    println!(
        "{:<34} {:>14} {:>16}",
        "distribution", "mean lane (s)", "max-lane runtime"
    );
    let shape = ClusterShape { nodes: 32, cores_per_node: 64, task_mem_mib: 256 };
    let n_tasks = 32 * 64 * 8;
    let mut skew: Vec<Json> = Vec::new();
    for (name, gen) in [
        ("constant 30s", TaskGen::Constant { seconds: 30.0 }),
        ("lognormal median 30s σ=0.5", TaskGen::LogNormal { median: 30.0, sigma: 0.5 }),
        ("bimodal 5s/120s p=0.2", TaskGen::Bimodal { short: 5.0, long: 120.0, p_long: 0.2 }),
        ("exponential mean 30s", TaskGen::Exponential { mean: 30.0 }),
    ] {
        let w = gen.generate(n_tasks, 7);
        let job = NodeBased::default().plan("abl", &w, &shape).unwrap();
        let mean_work = w.total_work() / (32.0 * 64.0);
        let max_dur = job.tasks.iter().map(|t| t.duration).fold(0.0, f64::max);
        println!("{:<34} {:>13.1}s {:>15.1}s", name, mean_work, max_dur);
        skew.push(
            Json::obj()
                .set("distribution", name)
                .set("mean_lane_s", mean_work)
                .set("max_lane_runtime_s", max_dur),
        );
    }
    println!("\nconstant-time tasks (the paper's benchmark) have zero imbalance;");
    println!("skewed workloads pay a max-lane premium — the trade node-based");
    println!("scheduling accepts for its 64x scheduler-load reduction.");

    let artifact = Json::obj()
        .set("bench", "bench_ablation")
        .set("command", std::env::args().collect::<Vec<_>>().join(" "))
        .set("quick", quick)
        .set("granularity", Json::Arr(granularity))
        .set("cleanup_coeff", Json::Arr(cleanup_coeff))
        .set("interleave", Json::Arr(interleave_rows))
        .set("skew", Json::Arr(skew))
        .set("passed", true);
    write_artifact("BENCH_ablation.json", &artifact);
}
