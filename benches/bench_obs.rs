//! Bench: flight-recorder overhead + the bench-trajectory watchdog.
//!
//! Two acceptance bars for the observability layer:
//!
//! 1. **Recorder overhead** — running the burst contention scenario
//!    with the flight recorder on (`trace_cap = 1<<20`) must cost at
//!    most **15%** wall time over the identical recorder-off run. The
//!    recorder is a branch + ring push per decision; if that bar
//!    moves, an observation site grew a real cost.
//! 2. **Trajectory watchdog** — the headline ratios in the workspace
//!    `BENCH_*.json` artifacts (pool dispatch speedup at ≥4096 nodes,
//!    trace replay speedup at ≥65536 nodes, federation rate gain) must
//!    not regress past `--tolerance` against the pinned baselines in
//!    `--baseline-dir`, and must stay above their hard floors
//!    (10×/5×/3×) regardless.
//!
//! ```bash
//! cargo bench --bench bench_obs                       # full run
//! cargo bench --bench bench_obs -- --quick            # CI smoke
//! cargo bench --bench bench_obs -- --baseline-dir baseline --tolerance 0.25
//! cargo bench --bench bench_obs -- --bless            # report, never fail
//! ```
//!
//! `--bless` prints every verdict but exits 0 — use it when
//! intentionally re-pinning baselines (commit the fresh `BENCH_*.json`
//! files as the new baseline afterwards). Results land in
//! `BENCH_obs.json` at the crate root.

use llsched::bench::watchdog;
use llsched::bench::{arg_value, bench, fmt_secs, has_flag, section, write_artifact, BenchOpts};
use llsched::coordinator::experiment::{run_contention_with, ContentionOpts};
use llsched::pool::PoolConfig;
use llsched::util::json::Json;
use llsched::workload::contention::ContentionMix;
use std::path::Path;

/// Parse `--flag value` as a string from argv (panics on malformed
/// input: a bench invocation error should fail loudly).
fn arg_str<'a>(args: &'a [String], flag: &str) -> Option<&'a str> {
    args.iter().position(|a| a == flag).map(|i| {
        args.get(i + 1)
            .unwrap_or_else(|| panic!("{flag} needs a value"))
            .as_str()
    })
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = has_flag(&args, "--quick");
    let bless = has_flag(&args, "--bless");
    let nodes = arg_value(&args, "--nodes")
        .map(|v| v as u32)
        .unwrap_or(if quick { 32 } else { 64 });
    let iters = arg_value(&args, "--iters")
        .map(|v| v as usize)
        .unwrap_or(if quick { 5 } else { 7 });
    let bar_pct = arg_value(&args, "--overhead-bar").unwrap_or(15.0);
    let tolerance = arg_value(&args, "--tolerance").unwrap_or(0.25);
    let baseline_dir = arg_str(&args, "--baseline-dir").unwrap_or(".");

    section(&format!("recorder overhead at burst ({nodes} nodes, {iters} iters)"));
    let mix = ContentionMix::preset("burst", nodes).expect("burst preset");
    // The `trace`/`explain` pool-fleet defaults, so the traced run
    // exercises the pool observation sites too.
    let opts_for = |trace_cap: usize| {
        let n = nodes as usize;
        ContentionOpts {
            pool: PoolConfig {
                size: (n / 4).max(1),
                min: (n / 8).min((n / 4).max(1)),
                max: (3 * n / 4).max((n / 4).max(1)),
                ..PoolConfig::disabled()
            },
            trace_cap,
            ..ContentionOpts::classic(true, 7)
        }
    };
    let bench_opts = BenchOpts {
        warmup: 1,
        iters,
        max_wall: std::time::Duration::from_secs(120),
    };
    let untraced = bench("burst, recorder off (trace_cap 0)", bench_opts, |_| {
        run_contention_with(&mix, opts_for(0)).expect("untraced run")
    });
    println!("{}", untraced.line());
    let traced = bench("burst, recorder on (trace_cap 1<<20)", bench_opts, |_| {
        run_contention_with(&mix, opts_for(1 << 20)).expect("traced run")
    });
    println!("{}", traced.line());
    let overhead_pct = (traced.summary.p50 / untraced.summary.p50 - 1.0) * 100.0;
    let overhead_ok = overhead_pct <= bar_pct;
    println!(
        "recorder overhead: traced p50 {} vs untraced p50 {} → {overhead_pct:+.1}% \
         (bar {bar_pct:.0}%)  [{}]",
        fmt_secs(traced.summary.p50),
        fmt_secs(untraced.summary.p50),
        if overhead_ok { "PASS" } else { "FAIL" }
    );

    section(&format!("bench-trajectory watchdog (baselines: {baseline_dir})"));
    let rep = watchdog::run(Path::new("."), Path::new(baseline_dir), tolerance);
    for line in rep.lines() {
        println!("{line}");
    }

    let failed = !bless && (!overhead_ok || !rep.passed);
    if bless && (!overhead_ok || !rep.passed) {
        println!("(--bless: reporting only, not failing)");
    }
    let report = Json::obj()
        .set("bench", "bench_obs")
        .set("command", std::env::args().collect::<Vec<_>>().join(" "))
        .set(
            "overhead",
            Json::obj()
                .set("preset", "burst")
                .set("nodes", nodes)
                .set("iters", iters)
                .set("untraced_p50_s", untraced.summary.p50)
                .set("traced_p50_s", traced.summary.p50)
                .set("overhead_pct", overhead_pct)
                .set("bar_pct", bar_pct)
                .set("passed", overhead_ok),
        )
        .set("tolerance", tolerance)
        .set("watchdog", rep.to_json())
        .set("passed", !failed);
    write_artifact("BENCH_obs.json", &report);
    if failed {
        std::process::exit(1);
    }
}
