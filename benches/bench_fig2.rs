//! Bench: regenerate Fig 2 (system utilization over time, median runs)
//! and report the derived utilization metrics the paper discusses:
//! time-to-100%, peak utilization, and mean utilization while active.
//!
//! ```bash
//! cargo bench --bench bench_fig2                        # full matrix
//! cargo bench --bench bench_fig2 -- --max-nodes 32 --runs 1   # CI smoke
//! ```
//!
//! Results land in `BENCH_fig2.json` at the crate root: one row per
//! median run plus the figure's structural claims (evaluated over
//! whatever slice of the matrix actually ran).

use llsched::bench::{arg_value, write_artifact};
use llsched::coordinator::experiment::{fig2_label, median_runs, run_matrix, ExperimentOpts};
use llsched::metrics::report;
use llsched::util::json::Json;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let opts = ExperimentOpts {
        include_na: false,
        max_nodes: arg_value(&args, "--max-nodes").map(|v| v as u32).unwrap_or(512),
        runs: arg_value(&args, "--runs").map(|v| v as usize).unwrap_or(3),
        dt: 1.0,
    };
    let t0 = std::time::Instant::now();
    let (_, all) = run_matrix(&opts, |_| {}).expect("matrix runs");
    let med = median_runs(&all);
    println!(
        "Fig 2 — utilization over time, {} median runs ({:.1}s wall)\n",
        med.len(),
        t0.elapsed().as_secs_f64()
    );
    println!(
        "{:<14} {:>10} {:>14} {:>12} {:>12}",
        "run", "peak util", "t to 100%", "mean active", "area (s)"
    );
    let mut rows: Vec<Json> = Vec::new();
    for r in &med {
        let u = &r.utilization;
        println!(
            "{:<14} {:>9.1}% {:>14} {:>11.1}% {:>12.0}",
            fig2_label(&r.cell),
            u.peak() * 100.0,
            u.time_to_reach(1.0)
                .map(|t| format!("{t:.0}s"))
                .unwrap_or_else(|| "never".into()),
            u.mean_while_active() * 100.0,
            u.area()
        );
        rows.push(
            Json::obj()
                .set("run", fig2_label(&r.cell))
                .set("peak_util", u.peak())
                .set(
                    "t_to_full_s",
                    u.time_to_reach(1.0).map(Json::from).unwrap_or(Json::Null),
                )
                .set("mean_active_util", u.mean_while_active())
                .set("area_s", u.area()),
        );
    }
    // ASCII rendering for the headline cells (512 nodes, t=60).
    let series: Vec<(String, llsched::metrics::timeline::UtilizationSeries)> = med
        .iter()
        .filter(|r| r.cell.nodes == 512 && r.cell.task.task_time == 60.0)
        .map(|r| (fig2_label(&r.cell), r.utilization.clone()))
        .collect();
    if !series.is_empty() {
        println!("\n512-node, t=60 (the collapse vs the instant fill):\n");
        println!("{}", report::fig2_plot(&series));
    }
    // The structural claims:
    let m512_never_full = med
        .iter()
        .filter(|r| r.cell.nodes == 512 && r.cell.mode == llsched::config::Mode::MultiLevel)
        .all(|r| r.utilization.time_to_reach(1.0).is_none());
    println!("M* 512 never reaches 100% utilization: {m512_never_full} (paper: true)");
    let n_fast_fill = med
        .iter()
        .filter(|r| r.cell.mode == llsched::config::Mode::NodeBased)
        .filter(|r| r.utilization.time_to_reach(0.99).map(|t| t < 30.0).unwrap_or(false))
        .count();
    let n_total = med
        .iter()
        .filter(|r| r.cell.mode == llsched::config::Mode::NodeBased)
        .count();
    println!(
        "N* runs filling the machine in <30s: {n_fast_fill}/{n_total} (paper: 'almost instantly')"
    );

    let artifact = Json::obj()
        .set("bench", "bench_fig2")
        .set("command", std::env::args().collect::<Vec<_>>().join(" "))
        .set("max_nodes", opts.max_nodes)
        .set("runs", opts.runs)
        .set("median_runs", Json::Arr(rows))
        .set(
            "claims",
            Json::obj()
                .set("m512_never_full", m512_never_full)
                .set("n_fast_fill", n_fast_fill)
                .set("n_total", n_total),
        )
        .set("passed", true);
    write_artifact("BENCH_fig2.json", &artifact);
}
