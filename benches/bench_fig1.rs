//! Bench: regenerate Fig 1 (normalized overhead vs task time, median of
//! three runs per cell, both scheduling modes, all scales).

use llsched::coordinator::experiment::{run_matrix, ExperimentOpts};
use llsched::metrics::report;

fn main() {
    let opts = ExperimentOpts {
        include_na: false,
        max_nodes: 512,
        runs: 3,
        dt: 1.0,
    };
    let t0 = std::time::Instant::now();
    let (points, _) = run_matrix(&opts, |_| {}).expect("matrix runs");
    println!(
        "Fig 1 — normalized overhead (runtime - T_job)/T_job, medians of 3 ({} cells, {:.1}s wall)\n",
        points.len(),
        t0.elapsed().as_secs_f64()
    );
    println!(
        "{:<8} {:>8} {:>6} {:>16} {:>15}",
        "nodes", "t (s)", "mode", "median runtime", "norm overhead"
    );
    for p in &points {
        println!(
            "{:<8} {:>8} {:>6} {:>15.1}s {:>15.4}",
            p.nodes,
            p.task_time,
            p.mode.short(),
            p.median_runtime(),
            p.norm_overhead()
        );
    }
    println!("\n{}", report::fig1_plot(&points));
    // The paper's two structural claims about this figure:
    let node_based_under_10pct = points
        .iter()
        .filter(|p| p.mode == llsched::config::Mode::NodeBased)
        .filter(|p| p.norm_overhead() < 0.10)
        .count();
    let node_based_total = points
        .iter()
        .filter(|p| p.mode == llsched::config::Mode::NodeBased)
        .count();
    println!(
        "node-based cells under 10% overhead: {node_based_under_10pct}/{node_based_total} (paper: 'most')"
    );
    let multi_over_10pct = points
        .iter()
        .filter(|p| p.mode == llsched::config::Mode::MultiLevel)
        .all(|p| p.norm_overhead() > 0.10);
    println!("multi-level cells all above 10%: {multi_over_10pct} (paper: all)");
}
