//! Bench: regenerate Fig 1 (normalized overhead vs task time, median of
//! three runs per cell, both scheduling modes, all scales).
//!
//! ```bash
//! cargo bench --bench bench_fig1                        # full matrix
//! cargo bench --bench bench_fig1 -- --max-nodes 32 --runs 1   # CI smoke
//! ```
//!
//! Results land in `BENCH_fig1.json` at the crate root: one row per
//! matrix point plus the paper's two structural claims about the
//! figure (evaluated over whatever slice of the matrix actually ran).

use llsched::bench::{arg_value, write_artifact};
use llsched::coordinator::experiment::{run_matrix, ExperimentOpts};
use llsched::metrics::report;
use llsched::util::json::Json;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let opts = ExperimentOpts {
        include_na: false,
        max_nodes: arg_value(&args, "--max-nodes").map(|v| v as u32).unwrap_or(512),
        runs: arg_value(&args, "--runs").map(|v| v as usize).unwrap_or(3),
        dt: 1.0,
    };
    let t0 = std::time::Instant::now();
    let (points, _) = run_matrix(&opts, |_| {}).expect("matrix runs");
    println!(
        "Fig 1 — normalized overhead (runtime - T_job)/T_job, medians of {} ({} cells, {:.1}s wall)\n",
        opts.runs,
        points.len(),
        t0.elapsed().as_secs_f64()
    );
    println!(
        "{:<8} {:>8} {:>6} {:>16} {:>15}",
        "nodes", "t (s)", "mode", "median runtime", "norm overhead"
    );
    let mut rows: Vec<Json> = Vec::new();
    for p in &points {
        println!(
            "{:<8} {:>8} {:>6} {:>15.1}s {:>15.4}",
            p.nodes,
            p.task_time,
            p.mode.short(),
            p.median_runtime(),
            p.norm_overhead()
        );
        rows.push(
            Json::obj()
                .set("nodes", p.nodes)
                .set("task_time_s", p.task_time)
                .set("mode", p.mode.short())
                .set("median_runtime_s", p.median_runtime())
                .set("norm_overhead", p.norm_overhead()),
        );
    }
    println!("\n{}", report::fig1_plot(&points));
    // The paper's two structural claims about this figure:
    let node_based_under_10pct = points
        .iter()
        .filter(|p| p.mode == llsched::config::Mode::NodeBased)
        .filter(|p| p.norm_overhead() < 0.10)
        .count();
    let node_based_total = points
        .iter()
        .filter(|p| p.mode == llsched::config::Mode::NodeBased)
        .count();
    println!(
        "node-based cells under 10% overhead: {node_based_under_10pct}/{node_based_total} (paper: 'most')"
    );
    let multi_over_10pct = points
        .iter()
        .filter(|p| p.mode == llsched::config::Mode::MultiLevel)
        .all(|p| p.norm_overhead() > 0.10);
    println!("multi-level cells all above 10%: {multi_over_10pct} (paper: all)");

    let artifact = Json::obj()
        .set("bench", "bench_fig1")
        .set("command", std::env::args().collect::<Vec<_>>().join(" "))
        .set("max_nodes", opts.max_nodes)
        .set("runs", opts.runs)
        .set("points", Json::Arr(rows))
        .set(
            "claims",
            Json::obj()
                .set("node_based_under_10pct", node_based_under_10pct)
                .set("node_based_total", node_based_total)
                .set("multi_all_over_10pct", multi_over_10pct),
        )
        .set("passed", true);
    write_artifact("BENCH_fig1.json", &artifact);
}
