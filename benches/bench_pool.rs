//! Bench: node-based pool dispatch vs full placement for short jobs.
//!
//! The acceptance bar for the pool subsystem (the paper's Figure-1
//! speedup, measured as jobs-per-second): at 4096 nodes, dispatching a
//! fleet of short whole-node jobs through the pool's O(1) free list
//! must beat the full placement engine (index queries + per-core masks
//! + memory accounting) by ≥ 10×.
//!
//! Both paths run the same steady-state loop: half the cluster is kept
//! occupied, then each "job" acquires a node and releases the oldest
//! live one — the short-job churn the rapid-launch partition serves.
//!
//! ```bash
//! cargo bench --bench bench_pool                         # full sweep
//! cargo bench --bench bench_pool -- --max-scale 4096 --max-jobs 10000 --require 10
//! ```
//!
//! `--max-scale N` / `--max-jobs J` truncate the sweep (CI smoke);
//! `--require X` enforces a ≥X× jobs-per-second speedup at the largest
//! (scale, jobs) cell actually run, so perf regressions fail PRs.

use llsched::bench::{bench, black_box, section, BenchOpts};
use llsched::cluster::{Cluster, NodeId};
use llsched::placement::{PlacementEngine, Strategy};
use llsched::pool::{FleetConfig, NodeDispatcher, NodePool, PoolFleet, ShardConfig};
use llsched::scheduler::job::Placement;
use std::collections::VecDeque;
use std::time::Duration;

const SCALES: [u32; 2] = [512, 4096];
const JOB_COUNTS: [usize; 3] = [1_000, 10_000, 100_000];

/// Full-placement path: every job goes through the engine (index
/// query, whole-node core mask + memory allocation, index delta), the
/// general scheduler's cost structure.
fn churn_engine(nodes: u32, jobs: usize) -> usize {
    let mut cluster = Cluster::tx_green(nodes);
    let mut engine = PlacementEngine::new(&cluster, Strategy::NodeBased, 1);
    let mut live: VecDeque<Placement> = VecDeque::new();
    for _ in 0..nodes / 2 {
        live.push_back(engine.place_whole(&mut cluster, None).expect("capacity"));
    }
    let mut done = 0usize;
    for _ in 0..jobs {
        let p = engine.place_whole(&mut cluster, None).expect("capacity");
        live.push_back(p);
        let old = live.pop_front().expect("live set non-empty");
        engine.release(&mut cluster, &old).expect("release");
        done += 1;
    }
    for p in live {
        engine.release(&mut cluster, &p).expect("drain");
    }
    done
}

/// Two-shard fleet path: every job is routed by shape (general 0.5 s
/// vs large 45 s, alternating), then served by its shard's free list —
/// the sharded-fleet dispatch hot path, measuring what shape routing
/// and per-shard bookkeeping add over the single pool.
fn churn_fleet(nodes: u32, jobs: usize) -> usize {
    let half = (nodes as usize / 2).max(1);
    let cfg = FleetConfig {
        shards: vec![
            ShardConfig::named("general", half, 0, half).unwrap(),
            ShardConfig::named("large", half, 0, half).unwrap(),
        ],
    };
    let mut fleet = PoolFleet::new(vec![64; nodes as usize], &cfg);
    for id in 0..nodes as NodeId {
        let sid = if (id as usize) < half { 0 } else { 1 };
        assert!(fleet.shards[sid].nodes.lease(id));
    }
    let mut live: Vec<VecDeque<NodeId>> = vec![VecDeque::new(), VecDeque::new()];
    for i in 0..nodes / 2 {
        let sid = fleet.route(64, if i % 2 == 0 { 0.5 } else { 45.0 }).expect("routed");
        if let Some(n) = fleet.shards[sid].nodes.acquire() {
            live[sid].push_back(n);
        }
    }
    let mut done = 0usize;
    for i in 0..jobs {
        let est = if i % 2 == 0 { 0.5 } else { 45.0 };
        let sid = fleet.route(64, est).expect("routed");
        let sh = &mut fleet.shards[sid];
        let n = match sh.dispatcher.launch(&mut sh.nodes) {
            Some(n) => n,
            None => {
                let old = live[sid].pop_front().expect("live set non-empty");
                assert!(sh.dispatcher.release(&mut sh.nodes, old));
                sh.dispatcher.launch(&mut sh.nodes).expect("freed capacity")
            }
        };
        live[sid].push_back(n);
        if let Some(old) = live[sid].pop_front() {
            let sh = &mut fleet.shards[sid];
            assert!(sh.dispatcher.release(&mut sh.nodes, old));
        }
        done += 1;
    }
    done
}

/// Node-based pool path: every job is a free-list pop + push.
fn churn_pool(nodes: u32, jobs: usize) -> usize {
    let mut pool = NodePool::new(nodes as usize);
    for id in 0..nodes as NodeId {
        assert!(pool.lease(id));
    }
    let mut disp = NodeDispatcher::new();
    let mut live: VecDeque<NodeId> = VecDeque::new();
    for _ in 0..nodes / 2 {
        live.push_back(disp.launch(&mut pool).expect("capacity"));
    }
    let mut done = 0usize;
    for _ in 0..jobs {
        let n = disp.launch(&mut pool).expect("capacity");
        live.push_back(n);
        let old = live.pop_front().expect("live set non-empty");
        assert!(disp.release(&mut pool, old));
        done += 1;
    }
    done
}

/// Parse `--flag value` from argv (panics on malformed input: a bench
/// invocation error should fail loudly, not silently run the default).
fn arg_value(args: &[String], flag: &str) -> Option<f64> {
    args.iter().position(|a| a == flag).map(|i| {
        args.get(i + 1)
            .unwrap_or_else(|| panic!("{flag} needs a value"))
            .parse::<f64>()
            .unwrap_or_else(|_| panic!("{flag} needs a number"))
    })
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let max_scale = arg_value(&args, "--max-scale").map(|v| v as u32);
    let max_jobs = arg_value(&args, "--max-jobs").map(|v| v as usize);
    let require = arg_value(&args, "--require");

    let opts = BenchOpts {
        warmup: 1,
        iters: 5,
        max_wall: Duration::from_secs(30),
    };
    let scales: Vec<u32> = SCALES
        .iter()
        .copied()
        .filter(|&n| max_scale.map(|m| n <= m).unwrap_or(true))
        .collect();
    assert!(!scales.is_empty(), "--max-scale below the smallest scale");
    let job_counts: Vec<usize> = JOB_COUNTS
        .iter()
        .copied()
        .filter(|&j| max_jobs.map(|m| j <= m).unwrap_or(true))
        .collect();
    assert!(!job_counts.is_empty(), "--max-jobs below the smallest count");

    let mut speedups: Vec<(u32, usize, f64)> = Vec::new();
    for &nodes in &scales {
        section(&format!("{nodes} nodes"));
        for &jobs in &job_counts {
            let engine = bench(&format!("engine placement  {jobs} jobs"), opts, |_| {
                black_box(churn_engine(nodes, jobs))
            });
            println!("{}", engine.line());
            let pool = bench(&format!("pool   dispatch   {jobs} jobs"), opts, |_| {
                black_box(churn_pool(nodes, jobs))
            });
            println!("{}", pool.line());
            let fleet = bench(&format!("fleet  dispatch   {jobs} jobs (2 shards)"), opts, |_| {
                black_box(churn_fleet(nodes, jobs))
            });
            println!("{}", fleet.line());
            let engine_jps = jobs as f64 / engine.summary.p50.max(1e-12);
            let pool_jps = jobs as f64 / pool.summary.p50.max(1e-12);
            let fleet_jps = jobs as f64 / fleet.summary.p50.max(1e-12);
            let speedup = pool_jps / engine_jps.max(1e-12);
            let fleet_speedup = fleet_jps / engine_jps.max(1e-12);
            println!(
                "  → {jobs} short jobs: engine {engine_jps:.0} jobs/s, pool {pool_jps:.0} jobs/s \
                 ({speedup:.0}x), 2-shard fleet {fleet_jps:.0} jobs/s ({fleet_speedup:.0}x)"
            );
            speedups.push((nodes, jobs, speedup));
        }
    }

    section("acceptance");
    let largest_scale = *scales.last().expect("non-empty");
    let largest_jobs = *job_counts.last().expect("non-empty");
    let mut failed = false;
    for (nodes, jobs, speedup) in &speedups {
        // The headline ≥10× bar applies at 4096 nodes; `--require`
        // additionally enforces the caller's floor at the largest cell
        // actually run (the stricter of the two wins when both apply).
        let baseline = if *nodes >= 4096 { Some(10.0) } else { None };
        let required = if *nodes == largest_scale && *jobs == largest_jobs {
            require
        } else {
            None
        };
        let floor = match (baseline, required) {
            (Some(a), Some(b)) => Some(a.max(b)),
            (a, None) => a,
            (None, b) => b,
        };
        let verdict = match floor {
            None => "info".to_string(),
            Some(f) if *speedup >= f => format!("PASS (≥{f:.0}x required)"),
            Some(f) => {
                failed = true;
                format!("FAIL (≥{f:.0}x required)")
            }
        };
        println!(
            "node-based dispatch at {nodes:>5} nodes / {jobs:>6} jobs: {speedup:>7.0}x  [{verdict}]"
        );
    }
    if failed {
        std::process::exit(1);
    }
}
