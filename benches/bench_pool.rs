//! Bench: node-based pool dispatch vs full placement for short jobs.
//!
//! The acceptance bar for the pool subsystem (the paper's Figure-1
//! speedup, measured as jobs-per-second): at 4096 nodes, dispatching a
//! fleet of short whole-node jobs through the pool's O(1) free list
//! must beat the full placement engine (index queries + per-core masks
//! + memory accounting) by ≥ 10×.
//!
//! Both paths run the same steady-state loop: half the cluster is kept
//! occupied, then each "job" acquires a node and releases the oldest
//! live one — the short-job churn the rapid-launch partition serves.
//!
//! ```bash
//! cargo bench --bench bench_pool                         # full sweep
//! cargo bench --bench bench_pool -- --max-scale 4096 --max-jobs 10000 --require 10
//! ```
//!
//! `--max-scale N` / `--max-jobs J` truncate the sweep (CI smoke);
//! `--require X` enforces a ≥X× jobs-per-second speedup at the largest
//! (scale, jobs) cell actually run, so perf regressions fail PRs.
//!
//! On top of the dispatch microbenches, the **scheduler-trace section**
//! drives the full event-driven scheduler (submit → register → pool
//! dispatch → completion → release) over a short-job trace and measures
//! end-to-end simulated jobs per wall-clock second. The shipping
//! wake-driven hot path runs the full trace — up to 1M jobs × 10 tasks
//! (10M tasks) at 65,536 nodes on the untruncated sweep — against the
//! pre-PR hot path (polled dispatch loop + the O(arena) legacy register
//! scan). The legacy path is quadratic in trace length, so it is
//! measured at two capped sizes and projected to the full trace with an
//! exact `a·N + b·N²` fit; the reported speedup is *conservative* — the
//! quadratic term only grows with N, so the true legacy slowdown at the
//! full trace is at least the projected one. Results land in
//! `BENCH_pool.json` at the crate root.

use llsched::bench::{arg_value, bench, black_box, section, write_artifact, BenchOpts};
use llsched::cluster::{Cluster, NodeId};
use llsched::placement::{PlacementEngine, Strategy};
use llsched::pool::{FleetConfig, NodeDispatcher, NodePool, PoolConfig, PoolFleet, ShardConfig};
use llsched::scheduler::core::{SchedulerSim, TaskModel};
use llsched::scheduler::costmodel::CostModel;
use llsched::scheduler::job::{
    ComputeBatch, JobSpec, Placement, ResourceRequest, SchedTaskSpec, TaskState,
};
use llsched::scheduler::noise::NoiseModel;
use llsched::scheduler::HotPath;
use llsched::sim::EventQueue;
use llsched::util::json::Json;
use std::collections::VecDeque;
use std::time::Duration;

const SCALES: [u32; 2] = [512, 4096];
const JOB_COUNTS: [usize; 3] = [1_000, 10_000, 100_000];

/// Scheduler-trace cells: (cluster nodes, jobs). Each job is a 10-task
/// whole-node array of 0.5 s tasks, so the last cell is the 10M-task /
/// 65,536-node trace the event-calendar hot path is sized for.
const SIM_POINTS: [(u32, usize); 3] = [(512, 5_000), (4_096, 30_000), (65_536, 1_000_000)];

/// Tasks per trace job (whole-node, pool-routed).
const TRACE_TASKS_PER_JOB: usize = 10;

/// Largest trace the quadratic legacy path is actually run at; beyond
/// this its cost is projected from the fit (see the module docs).
const LEGACY_CAPS: [usize; 2] = [10_000, 30_000];

/// Full-placement path: every job goes through the engine (index
/// query, whole-node core mask + memory allocation, index delta), the
/// general scheduler's cost structure.
fn churn_engine(nodes: u32, jobs: usize) -> usize {
    let mut cluster = Cluster::tx_green(nodes);
    let mut engine = PlacementEngine::new(&cluster, Strategy::NodeBased, 1);
    let mut live: VecDeque<Placement> = VecDeque::new();
    for _ in 0..nodes / 2 {
        live.push_back(engine.place_whole(&mut cluster, None).expect("capacity"));
    }
    let mut done = 0usize;
    for _ in 0..jobs {
        let p = engine.place_whole(&mut cluster, None).expect("capacity");
        live.push_back(p);
        let old = live.pop_front().expect("live set non-empty");
        engine.release(&mut cluster, &old).expect("release");
        done += 1;
    }
    for p in live {
        engine.release(&mut cluster, &p).expect("drain");
    }
    done
}

/// Two-shard fleet path: every job is routed by shape (general 0.5 s
/// vs large 45 s, alternating), then served by its shard's free list —
/// the sharded-fleet dispatch hot path, measuring what shape routing
/// and per-shard bookkeeping add over the single pool.
fn churn_fleet(nodes: u32, jobs: usize) -> usize {
    let half = (nodes as usize / 2).max(1);
    let cfg = FleetConfig {
        shards: vec![
            ShardConfig::named("general", half, 0, half).unwrap(),
            ShardConfig::named("large", half, 0, half).unwrap(),
        ],
    };
    let mut fleet = PoolFleet::new(vec![64; nodes as usize], &cfg);
    for id in 0..nodes as NodeId {
        let sid = if (id as usize) < half { 0 } else { 1 };
        assert!(fleet.shards[sid].nodes.lease(id));
    }
    let mut live: Vec<VecDeque<NodeId>> = vec![VecDeque::new(), VecDeque::new()];
    for i in 0..nodes / 2 {
        let sid = fleet.route(64, if i % 2 == 0 { 0.5 } else { 45.0 }).expect("routed");
        if let Some(n) = fleet.shards[sid].nodes.acquire() {
            live[sid].push_back(n);
        }
    }
    let mut done = 0usize;
    for i in 0..jobs {
        let est = if i % 2 == 0 { 0.5 } else { 45.0 };
        let sid = fleet.route(64, est).expect("routed");
        let sh = &mut fleet.shards[sid];
        let n = match sh.dispatcher.launch(&mut sh.nodes) {
            Some(n) => n,
            None => {
                let old = live[sid].pop_front().expect("live set non-empty");
                assert!(sh.dispatcher.release(&mut sh.nodes, old));
                sh.dispatcher.launch(&mut sh.nodes).expect("freed capacity")
            }
        };
        live[sid].push_back(n);
        if let Some(old) = live[sid].pop_front() {
            let sh = &mut fleet.shards[sid];
            assert!(sh.dispatcher.release(&mut sh.nodes, old));
        }
        done += 1;
    }
    done
}

/// Node-based pool path: every job is a free-list pop + push.
fn churn_pool(nodes: u32, jobs: usize) -> usize {
    let mut pool = NodePool::new(nodes as usize);
    for id in 0..nodes as NodeId {
        assert!(pool.lease(id));
    }
    let mut disp = NodeDispatcher::new();
    let mut live: VecDeque<NodeId> = VecDeque::new();
    for _ in 0..nodes / 2 {
        live.push_back(disp.launch(&mut pool).expect("capacity"));
    }
    let mut done = 0usize;
    for _ in 0..jobs {
        let n = disp.launch(&mut pool).expect("capacity");
        live.push_back(n);
        let old = live.pop_front().expect("live set non-empty");
        assert!(disp.release(&mut pool, old));
        done += 1;
    }
    done
}

/// One trace job: a short whole-node array the fleet routes to the
/// rapid-launch pool (duration well under the 30 s short threshold).
fn trace_job() -> JobSpec {
    JobSpec {
        name: "trace".into(),
        tasks: vec![
            SchedTaskSpec {
                request: ResourceRequest::WholeNode,
                duration: 0.5,
                batch: ComputeBatch { count: 1, each: 0.5 },
                lanes: 64,
            };
            TRACE_TASKS_PER_JOB
        ],
        reservation: None,
        priority: 0,
        preemptable: false,
    }
}

/// Drive the full scheduler over `jobs` trace jobs and return completed
/// tasks. Arrivals every 0.6 s of virtual time stay just above the
/// per-job server cost (0.5 s registration + ~8 ms of pool ops), so the
/// server runs near saturation without unbounded queue growth — the
/// steady-state regime where hot-path cost per event dominates.
fn trace_sim(nodes: u32, jobs: usize, hp: HotPath, legacy: bool) -> usize {
    let mut sim = SchedulerSim::new(
        Cluster::tx_green(nodes),
        CostModel::slurm_like_tx_green(),
        NoiseModel::dedicated(),
        42,
    )
    .with_task_model(TaskModel {
        startup: 0.0,
        jitter_sigma: 0.0,
        p_node_late: 0.0,
        late_range: (0.0, 0.0),
    })
    .with_placement(Strategy::NodeBased)
    .with_backfill(true)
    .with_pool(PoolConfig { size: 64, min: 32, max: 256, ..PoolConfig::disabled() })
    .with_hot_path(hp)
    .with_legacy_register(legacy)
    .without_timeline();
    let mut q = EventQueue::new();
    for j in 0..jobs {
        sim.submit_at(&mut q, 0.1 + 0.6 * j as f64, trace_job());
    }
    let out = sim.run(&mut q);
    let done = out
        .records
        .iter()
        .filter(|r| r.state == TaskState::Done)
        .count();
    assert_eq!(done, jobs * TRACE_TASKS_PER_JOB, "trace did not drain");
    let pool = out.pool.expect("trace runs with the pool on");
    assert_eq!(pool.launches as usize, done, "every trace task is pool-routed");
    done
}

/// Project the legacy runtime at `n` jobs from two capped measurements
/// via an exact `t(N) = a·N + b·N²` fit (the legacy register scan is
/// linear in arena size per job, so total cost is quadratic in trace
/// length). `b` is clamped at 0 so noise can only make the projection
/// *kinder* to the legacy path.
fn project_quadratic(p1: (usize, f64), p2: (usize, f64), n: usize) -> f64 {
    let (n1, t1) = (p1.0 as f64, p1.1);
    let (n2, t2) = (p2.0 as f64, p2.1);
    if (n1 - n2).abs() < 0.5 {
        return t1 / n1 * n as f64;
    }
    let b = ((t2 / n2) - (t1 / n1)) / (n2 - n1);
    let b = b.max(0.0);
    let a = (t1 / n1 - b * n1).max(0.0);
    let x = n as f64;
    a * x + b * x * x
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let max_scale = arg_value(&args, "--max-scale").map(|v| v as u32);
    let max_jobs = arg_value(&args, "--max-jobs").map(|v| v as usize);
    let require = arg_value(&args, "--require");

    let opts = BenchOpts {
        warmup: 1,
        iters: 5,
        max_wall: Duration::from_secs(30),
    };
    let scales: Vec<u32> = SCALES
        .iter()
        .copied()
        .filter(|&n| max_scale.map(|m| n <= m).unwrap_or(true))
        .collect();
    assert!(!scales.is_empty(), "--max-scale below the smallest scale");
    let job_counts: Vec<usize> = JOB_COUNTS
        .iter()
        .copied()
        .filter(|&j| max_jobs.map(|m| j <= m).unwrap_or(true))
        .collect();
    assert!(!job_counts.is_empty(), "--max-jobs below the smallest count");

    let mut speedups: Vec<(u32, usize, f64)> = Vec::new();
    let mut dispatch_rows: Vec<Json> = Vec::new();
    for &nodes in &scales {
        section(&format!("{nodes} nodes"));
        for &jobs in &job_counts {
            let engine = bench(&format!("engine placement  {jobs} jobs"), opts, |_| {
                black_box(churn_engine(nodes, jobs))
            });
            println!("{}", engine.line());
            let pool = bench(&format!("pool   dispatch   {jobs} jobs"), opts, |_| {
                black_box(churn_pool(nodes, jobs))
            });
            println!("{}", pool.line());
            let fleet = bench(&format!("fleet  dispatch   {jobs} jobs (2 shards)"), opts, |_| {
                black_box(churn_fleet(nodes, jobs))
            });
            println!("{}", fleet.line());
            let engine_jps = jobs as f64 / engine.summary.p50.max(1e-12);
            let pool_jps = jobs as f64 / pool.summary.p50.max(1e-12);
            let fleet_jps = jobs as f64 / fleet.summary.p50.max(1e-12);
            let speedup = pool_jps / engine_jps.max(1e-12);
            let fleet_speedup = fleet_jps / engine_jps.max(1e-12);
            println!(
                "  → {jobs} short jobs: engine {engine_jps:.0} jobs/s, pool {pool_jps:.0} jobs/s \
                 ({speedup:.0}x), 2-shard fleet {fleet_jps:.0} jobs/s ({fleet_speedup:.0}x)"
            );
            speedups.push((nodes, jobs, speedup));
            dispatch_rows.push(
                Json::obj()
                    .set("nodes", nodes)
                    .set("jobs", jobs)
                    .set("engine_jobs_per_s", engine_jps)
                    .set("pool_jobs_per_s", pool_jps)
                    .set("fleet_jobs_per_s", fleet_jps)
                    .set("speedup", speedup),
            );
        }
    }

    // ── Scheduler-trace section: the event-calendar hot path end to
    // end, wake-driven vs the pre-PR (polled + legacy-register) loop.
    let mut trace_rows: Vec<Json> = Vec::new();
    let mut trace_checks: Vec<(u32, usize, f64, bool)> = Vec::new();
    for &(nodes, cell_jobs) in &SIM_POINTS {
        if max_scale.map(|m| nodes > m).unwrap_or(false) {
            continue;
        }
        let jobs = max_jobs.map(|m| cell_jobs.min(m)).unwrap_or(cell_jobs);
        let tasks = jobs * TRACE_TASKS_PER_JOB;
        section(&format!("scheduler trace: {nodes} nodes, {jobs} jobs ({tasks} tasks)"));
        let trace_opts = BenchOpts {
            warmup: 0,
            iters: if jobs >= 100_000 { 1 } else { 3 },
            max_wall: Duration::from_secs(600),
        };
        let wake = bench(&format!("wake-driven trace {jobs} jobs"), trace_opts, |_| {
            black_box(trace_sim(nodes, jobs, HotPath::WakeDriven, false))
        });
        println!("{}", wake.line());
        let wake_jps = jobs as f64 / wake.summary.p50.max(1e-12);

        // The legacy path at its caps (full trace when it fits).
        let mut caps: Vec<usize> = LEGACY_CAPS.iter().map(|&c| c.min(jobs)).collect();
        caps.dedup();
        let mut legacy_pts: Vec<(usize, f64)> = Vec::new();
        for &cap in &caps {
            let legacy = bench(
                &format!("legacy (polled+scan) trace {cap} jobs"),
                trace_opts,
                |_| black_box(trace_sim(nodes, cap, HotPath::Polled, true)),
            );
            println!("{}", legacy.line());
            legacy_pts.push((cap, legacy.summary.p50));
        }
        let projected = jobs > *caps.last().expect("non-empty caps");
        let legacy_time = if projected {
            project_quadratic(legacy_pts[0], *legacy_pts.last().expect("caps"), jobs)
        } else {
            legacy_pts.last().expect("caps").1
        };
        let legacy_jps = jobs as f64 / legacy_time.max(1e-12);
        let speedup = wake_jps / legacy_jps.max(1e-12);
        println!(
            "  → {jobs} jobs ({tasks} tasks): wake-driven {wake_jps:.0} jobs/s, \
             pre-PR {legacy_jps:.0} jobs/s{} ({speedup:.1}x)",
            if projected { " [projected]" } else { "" }
        );
        trace_rows.push(
            Json::obj()
                .set("nodes", nodes)
                .set("jobs", jobs)
                .set("tasks", tasks)
                .set("wake_driven_jobs_per_s", wake_jps)
                .set("legacy_jobs_per_s", legacy_jps)
                .set("legacy_projected", projected)
                .set(
                    "legacy_measured_points",
                    Json::Arr(
                        legacy_pts
                            .iter()
                            .map(|&(n, t)| {
                                Json::obj().set("jobs", n).set("wall_s", t)
                            })
                            .collect(),
                    ),
                )
                .set("speedup", speedup),
        );
        trace_checks.push((nodes, jobs, speedup, projected));
    }

    section("acceptance");
    let largest_scale = *scales.last().expect("non-empty");
    let largest_jobs = *job_counts.last().expect("non-empty");
    let mut failed = false;
    for (nodes, jobs, speedup) in &speedups {
        // The headline ≥10× bar applies at 4096 nodes; `--require`
        // additionally enforces the caller's floor at the largest cell
        // actually run (the stricter of the two wins when both apply).
        let baseline = if *nodes >= 4096 { Some(10.0) } else { None };
        let required = if *nodes == largest_scale && *jobs == largest_jobs {
            require
        } else {
            None
        };
        let floor = match (baseline, required) {
            (Some(a), Some(b)) => Some(a.max(b)),
            (a, None) => a,
            (None, b) => b,
        };
        let verdict = match floor {
            None => "info".to_string(),
            Some(f) if *speedup >= f => format!("PASS (≥{f:.0}x required)"),
            Some(f) => {
                failed = true;
                format!("FAIL (≥{f:.0}x required)")
            }
        };
        println!(
            "node-based dispatch at {nodes:>5} nodes / {jobs:>6} jobs: {speedup:>7.0}x  [{verdict}]"
        );
    }
    // The hot-path bar: at the 65,536-node / 10M-task trace the
    // wake-driven loop must beat the pre-PR hot path ≥ 5× on jobs/sec.
    // Smaller (CI-truncated) cells are informational — at those sizes
    // the legacy quadratic term barely shows.
    for (nodes, jobs, speedup, projected) in &trace_checks {
        let floor = if *nodes >= 65_536 { Some(5.0) } else { None };
        let verdict = match floor {
            None => "info".to_string(),
            Some(f) if *speedup >= f => format!("PASS (≥{f:.0}x required)"),
            Some(f) => {
                failed = true;
                format!("FAIL (≥{f:.0}x required)")
            }
        };
        println!(
            "wake-driven trace at {nodes:>5} nodes / {jobs:>7} jobs: {speedup:>7.1}x{}  [{verdict}]",
            if *projected { " (projected baseline)" } else { "" }
        );
    }

    let report = Json::obj()
        .set("bench", "bench_pool")
        .set("command", std::env::args().collect::<Vec<_>>().join(" "))
        .set("dispatch", Json::Arr(dispatch_rows))
        .set("trace", Json::Arr(trace_rows))
        .set("passed", !failed);
    write_artifact("BENCH_pool.json", &report);
    if failed {
        std::process::exit(1);
    }
}
