//! Formatting helpers: durations, counts, ASCII tables and ASCII plots.
//!
//! The paper reports everything as tables (Table I–III) and two figures
//! (overhead scatter, utilization-vs-time curves); the report layer renders
//! terminal-friendly versions of all of them through this module.

/// Format a duration in (virtual or real) seconds, e.g. `242.0s`, `1.2h`.
pub fn dur(seconds: f64) -> String {
    if seconds.is_nan() {
        return "N/A".to_string();
    }
    if seconds < 0.0 {
        return format!("-{}", dur(-seconds));
    }
    if seconds < 120.0 {
        format!("{seconds:.1}s")
    } else if seconds < 7200.0 {
        format!("{:.1}m", seconds / 60.0)
    } else {
        format!("{:.1}h", seconds / 3600.0)
    }
}

/// Format a count with thousands separators (`8,388,608`).
pub fn count(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

/// A simple right-padded ASCII table renderer.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with the given column headers.
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Table {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row; panics if the column count mismatches the header.
    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True if the table has no data rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render with `|`-separated columns and a dashed header rule.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for i in 0..ncol {
                line.push(' ');
                line.push_str(&format!("{:<w$}", cells[i], w = widths[i]));
                line.push_str(" |");
            }
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        let mut rule = String::from("|");
        for w in &widths {
            rule.push_str(&"-".repeat(w + 2));
            rule.push('|');
        }
        out.push_str(&rule);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

/// Render an ASCII line plot of one or more named series.
///
/// Used for the terminal rendering of Fig 2 (utilization vs time). Each
/// series is a list of `(x, y)` points; y is expected in `[0, y_max]`.
pub fn ascii_plot(
    series: &[(String, Vec<(f64, f64)>)],
    width: usize,
    height: usize,
    y_max: f64,
) -> String {
    let marks = ['*', '+', 'o', 'x', '#', '@', '%', '&'];
    let x_max = series
        .iter()
        .flat_map(|(_, pts)| pts.iter().map(|p| p.0))
        .fold(1e-9_f64, f64::max);
    let mut grid = vec![vec![' '; width]; height];
    for (si, (_, pts)) in series.iter().enumerate() {
        let mark = marks[si % marks.len()];
        for &(x, y) in pts {
            let xi = ((x / x_max) * (width - 1) as f64).round() as usize;
            let yi = ((y / y_max).clamp(0.0, 1.0) * (height - 1) as f64).round() as usize;
            let row = height - 1 - yi;
            grid[row][xi.min(width - 1)] = mark;
        }
    }
    let mut out = String::new();
    for (ri, row) in grid.iter().enumerate() {
        let ylabel = if ri == 0 {
            format!("{y_max:>7.1} ")
        } else if ri == height - 1 {
            format!("{:>7.1} ", 0.0)
        } else {
            " ".repeat(8)
        };
        out.push_str(&ylabel);
        out.push('|');
        out.push_str(&row.iter().collect::<String>());
        out.push('\n');
    }
    out.push_str(&" ".repeat(8));
    out.push('+');
    out.push_str(&"-".repeat(width));
    out.push('\n');
    out.push_str(&format!("{:>9}0{:>w$.0}\n", "", x_max, w = width - 1));
    for (si, (name, _)) in series.iter().enumerate() {
        out.push_str(&format!("  {} {}\n", marks[si % marks.len()], name));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dur_ranges() {
        assert_eq!(dur(12.34), "12.3s");
        assert_eq!(dur(242.0), "4.0m");
        assert_eq!(dur(7200.0), "2.0h");
        assert_eq!(dur(f64::NAN), "N/A");
        assert_eq!(dur(-5.0), "-5.0s");
    }

    #[test]
    fn count_separators() {
        assert_eq!(count(0), "0");
        assert_eq!(count(999), "999");
        assert_eq!(count(1000), "1,000");
        assert_eq!(count(8_388_608), "8,388,608");
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(vec!["a", "bb"]);
        t.row(vec!["1", "2"]);
        t.row(vec!["333", "4"]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        // All lines same width.
        assert!(lines.windows(2).all(|w| w[0].len() == w[1].len()));
        assert!(lines[0].contains("a") && lines[0].contains("bb"));
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn table_arity_checked() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }

    #[test]
    fn ascii_plot_smoke() {
        let s = vec![("up".to_string(), vec![(0.0, 0.0), (10.0, 1.0)])];
        let p = ascii_plot(&s, 20, 5, 1.0);
        assert!(p.contains('*'));
        assert!(p.contains("up"));
    }
}
