//! Minimal JSON value model and serializer (offline build: no serde).
//!
//! Only what the metrics/report layer needs: objects, arrays, strings,
//! numbers, booleans, null, with correct string escaping and stable key
//! order (insertion order) so emitted reports are diff-friendly.

use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Insertion-ordered object.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Convenience: object builder.
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Insert/overwrite a key on an object; panics on non-objects.
    pub fn set<S: Into<String>, V: Into<Json>>(mut self, key: S, value: V) -> Json {
        match &mut self {
            Json::Obj(pairs) => {
                let key = key.into();
                let value = value.into();
                if let Some(p) = pairs.iter_mut().find(|(k, _)| *k == key) {
                    p.1 = value;
                } else {
                    pairs.push((key, value));
                }
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    /// Get a key from an object.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Serialize with two-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_str(out, s),
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        let pad = "  ".repeat(indent);
        let pad_in = "  ".repeat(indent + 1);
        match self {
            Json::Arr(xs) if !xs.is_empty() => {
                out.push_str("[\n");
                for (i, x) in xs.iter().enumerate() {
                    out.push_str(&pad_in);
                    x.write_pretty(out, indent + 1);
                    if i + 1 < xs.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(pairs) if !pairs.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in pairs.iter().enumerate() {
                    out.push_str(&pad_in);
                    write_str(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                    if i + 1 < pairs.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&pad);
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.is_nan() || x.is_infinite() {
        out.push_str("null"); // JSON has no NaN; null is the conventional stand-in
    } else if x == x.trunc() && x.abs() < 1e15 {
        let _ = write!(out, "{}", x as i64);
    } else {
        let _ = write!(out, "{x}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::Null.to_string(), "null");
        assert_eq!(Json::Bool(true).to_string(), "true");
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn string_escaping() {
        assert_eq!(Json::Str("a\"b\\c\nd".into()).to_string(), r#""a\"b\\c\nd""#);
        assert_eq!(Json::Str("\u{1}".into()).to_string(), "\"\\u0001\"");
    }

    #[test]
    fn object_ordering_and_overwrite() {
        let j = Json::obj().set("b", 1u64).set("a", 2u64).set("b", 3u64);
        assert_eq!(j.to_string(), r#"{"b":3,"a":2}"#);
        assert_eq!(j.get("a"), Some(&Json::Num(2.0)));
    }

    #[test]
    fn nested_pretty_roundtrip_shape() {
        let j = Json::obj()
            .set("xs", vec![1u64, 2, 3])
            .set("inner", Json::obj().set("k", "v"));
        let pretty = j.to_pretty();
        assert!(pretty.contains("\"xs\": [\n"));
        assert!(pretty.contains("\"k\": \"v\""));
        assert_eq!(j.to_string(), r#"{"xs":[1,2,3],"inner":{"k":"v"}}"#);
    }

    #[test]
    fn empty_containers_compact() {
        assert_eq!(Json::Arr(vec![]).to_pretty().trim(), "[]");
        assert_eq!(Json::obj().to_pretty().trim(), "{}");
    }
}
