//! Minimal JSON value model, serializer, and parser (offline build:
//! no serde).
//!
//! Only what the metrics/report layer needs: objects, arrays, strings,
//! numbers, booleans, null, with correct string escaping and stable key
//! order (insertion order) so emitted reports are diff-friendly. The
//! parser exists for the consumers of our own emitted documents (the
//! bench watchdog reading pinned `BENCH_*.json` baselines), but accepts
//! any standard JSON text.

use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Insertion-ordered object.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Convenience: object builder.
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Insert/overwrite a key on an object; panics on non-objects.
    pub fn set<S: Into<String>, V: Into<Json>>(mut self, key: S, value: V) -> Json {
        match &mut self {
            Json::Obj(pairs) => {
                let key = key.into();
                let value = value.into();
                if let Some(p) = pairs.iter_mut().find(|(k, _)| *k == key) {
                    p.1 = value;
                } else {
                    pairs.push((key, value));
                }
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    /// Get a key from an object.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Number value of a `Num` node.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// String value of a `Str` node.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Elements of an `Arr` node.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(xs) => Some(xs),
            _ => None,
        }
    }

    /// Parse a JSON document. Round-trips everything [`Json::to_string`]
    /// and [`Json::to_pretty`] emit (objects keep insertion order), and
    /// accepts standard JSON in general; errors carry the byte offset.
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { s, i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != s.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Serialize with two-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_str(out, s),
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        let pad = "  ".repeat(indent);
        let pad_in = "  ".repeat(indent + 1);
        match self {
            Json::Arr(xs) if !xs.is_empty() => {
                out.push_str("[\n");
                for (i, x) in xs.iter().enumerate() {
                    out.push_str(&pad_in);
                    x.write_pretty(out, indent + 1);
                    if i + 1 < xs.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(pairs) if !pairs.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in pairs.iter().enumerate() {
                    out.push_str(&pad_in);
                    write_str(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                    if i + 1 < pairs.len() {
                        out.push(',');
                    }
                    out.push('\n');
                }
                out.push_str(&pad);
                out.push('}');
            }
            other => other.write(out),
        }
    }
}

/// Recursive-descent state over the input text; `i` is a byte offset
/// and always sits on a char boundary.
struct Parser<'a> {
    s: &'a str,
    i: usize,
}

impl Parser<'_> {
    fn peek(&self) -> Option<u8> {
        self.s.as_bytes().get(self.i).copied()
    }

    fn err(&self, msg: &str) -> String {
        format!("{msg} at byte {}", self.i)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            None => Err(self.err("unexpected end of input")),
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(_) => self.number(),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.s.as_bytes()[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            pairs.push((k, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(pairs));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(xs));
        }
        loop {
            self.skip_ws();
            xs.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(xs));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let esc = self.peek().ok_or_else(|| self.err("unterminated escape"))?;
                    self.i += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let c = self.unicode_escape()?;
                            out.push(c);
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                Some(c) if c < 0x80 => {
                    out.push(c as char);
                    self.i += 1;
                }
                Some(_) => {
                    let ch = self.s[self.i..].chars().next().expect("valid utf-8");
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    /// The code point of a `\uXXXX` escape whose `\u` is already
    /// consumed, combining UTF-16 surrogate pairs.
    fn unicode_escape(&mut self) -> Result<char, String> {
        let hi = self.hex4()?;
        if !(0xD800..0xDC00).contains(&hi) {
            return char::from_u32(hi).ok_or_else(|| self.err("bad \\u escape"));
        }
        let tail = self.s.as_bytes().get(self.i..self.i + 2);
        if tail != Some(b"\\u".as_slice()) {
            return Err(self.err("lone high surrogate"));
        }
        self.i += 2;
        let lo = self.hex4()?;
        if !(0xDC00..0xE000).contains(&lo) {
            return Err(self.err("bad low surrogate"));
        }
        let cp = 0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
        char::from_u32(cp).ok_or_else(|| self.err("bad surrogate pair"))
    }

    fn hex4(&mut self) -> Result<u32, String> {
        let hex = self
            .s
            .get(self.i..self.i + 4)
            .ok_or_else(|| self.err("truncated \\u escape"))?;
        let cp = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad \\u escape"))?;
        self.i += 4;
        Ok(cp)
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.i += 1;
            } else {
                break;
            }
        }
        match self.s[start..self.i].parse::<f64>() {
            Ok(x) => Ok(Json::Num(x)),
            Err(_) => Err(format!("bad number at byte {start}")),
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.is_nan() || x.is_infinite() {
        out.push_str("null"); // JSON has no NaN; null is the conventional stand-in
    } else if x == x.trunc() && x.abs() < 1e15 {
        let _ = write!(out, "{}", x as i64);
    } else {
        let _ = write!(out, "{x}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u32> for Json {
    fn from(x: u32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::Null.to_string(), "null");
        assert_eq!(Json::Bool(true).to_string(), "true");
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.5).to_string(), "3.5");
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn string_escaping() {
        assert_eq!(Json::Str("a\"b\\c\nd".into()).to_string(), r#""a\"b\\c\nd""#);
        assert_eq!(Json::Str("\u{1}".into()).to_string(), "\"\\u0001\"");
    }

    #[test]
    fn object_ordering_and_overwrite() {
        let j = Json::obj().set("b", 1u64).set("a", 2u64).set("b", 3u64);
        assert_eq!(j.to_string(), r#"{"b":3,"a":2}"#);
        assert_eq!(j.get("a"), Some(&Json::Num(2.0)));
    }

    #[test]
    fn nested_pretty_roundtrip_shape() {
        let j = Json::obj()
            .set("xs", vec![1u64, 2, 3])
            .set("inner", Json::obj().set("k", "v"));
        let pretty = j.to_pretty();
        assert!(pretty.contains("\"xs\": [\n"));
        assert!(pretty.contains("\"k\": \"v\""));
        assert_eq!(j.to_string(), r#"{"xs":[1,2,3],"inner":{"k":"v"}}"#);
    }

    #[test]
    fn empty_containers_compact() {
        assert_eq!(Json::Arr(vec![]).to_pretty().trim(), "[]");
        assert_eq!(Json::obj().to_pretty().trim(), "{}");
    }

    #[test]
    fn parse_roundtrips_emitted_documents() {
        let j = Json::obj()
            .set("name", "bench_obs")
            .set("ratio", 3.5)
            .set("n", 174u64)
            .set("ok", true)
            .set("none", Json::Null)
            .set("xs", vec![1u64, 2, 3])
            .set("inner", Json::obj().set("k", "v"))
            .set("empty", Json::Arr(vec![]));
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
        assert_eq!(Json::parse(&j.to_pretty()).unwrap(), j, "pretty form parses too");
    }

    #[test]
    fn parse_handles_escapes_and_numbers() {
        let j = Json::parse(r#"{"s":"a\"b\\c\ndA😀é","x":-1.5e-3}"#).unwrap();
        assert_eq!(j.get("s").and_then(Json::as_str), Some("a\"b\\c\ndA\u{1f600}é"));
        assert_eq!(j.get("x").and_then(Json::as_f64), Some(-0.0015));
        assert_eq!(Json::parse(" [ 1 , 2.5 ] ").unwrap().as_arr().map(<[Json]>::len), Some(2));
        let u = Json::parse("\"\\u0041\\ud83d\\ude00\"").unwrap();
        assert_eq!(u.as_str(), Some("A\u{1f600}"), "\\u escapes incl. surrogate pairs");
    }

    #[test]
    fn parse_rejects_malformed_input() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("treu").is_err());
        assert!(Json::parse("{}extra").is_err());
        assert!(Json::parse(r#""\ud800""#).is_err(), "lone surrogate");
    }
}
