//! Deterministic pseudo-random number generation.
//!
//! The vendored crate set has no `rand`, so we implement SplitMix64 (for
//! seeding) and xoshiro256** (the workhorse generator, Blackman/Vigna 2018).
//! Determinism matters: every experiment in EXPERIMENTS.md is reproducible
//! from its seed.

/// SplitMix64 — used to expand a single `u64` seed into generator state.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a new SplitMix64 stream from `seed`.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — fast, high-quality, 256-bit state PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed the generator. Any seed (including 0) is valid: state is
    /// expanded through SplitMix64 so it is never all-zero.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent child generator (for per-entity streams).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in `[0, 1)`. Uses the top 53 bits.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform u64 in `[0, n)` via Lemire's multiply-shift (unbiased enough
    /// for simulation purposes; n is tiny relative to 2^64 here).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Bernoulli trial with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (polar form avoided for simplicity;
    /// this path is not hot).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal sample with the given log-space mean and sigma.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential sample with rate `lambda`.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        -self.f64().max(f64::MIN_POSITIVE).ln() / lambda
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn xoshiro_known_stream_differs_by_seed() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4, "streams with different seeds should diverge");
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets should be hit");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let mean = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut parent = Rng::new(21);
        let mut c1 = parent.fork();
        let mut c2 = parent.fork();
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 4);
    }
}
