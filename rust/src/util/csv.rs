//! Tiny CSV writer (RFC 4180 quoting) for the figure/benchmark data dumps.

use std::io::Write;
use std::path::Path;

/// In-memory CSV document builder.
#[derive(Debug, Default)]
pub struct Csv {
    buf: String,
    ncol: Option<usize>,
}

impl Csv {
    /// Start a CSV with a header row.
    pub fn with_header<S: AsRef<str>>(cols: &[S]) -> Csv {
        let mut c = Csv::default();
        c.row(cols);
        c
    }

    /// Append a row of string-ish cells; enforces constant arity.
    pub fn row<S: AsRef<str>>(&mut self, cells: &[S]) -> &mut Self {
        match self.ncol {
            None => self.ncol = Some(cells.len()),
            Some(n) => assert_eq!(n, cells.len(), "CSV arity mismatch"),
        }
        for (i, c) in cells.iter().enumerate() {
            if i > 0 {
                self.buf.push(',');
            }
            self.buf.push_str(&quote(c.as_ref()));
        }
        self.buf.push('\n');
        self
    }

    /// Append a row of f64s, formatted with up to 6 significant decimals.
    pub fn row_f64(&mut self, cells: &[f64]) -> &mut Self {
        let strs: Vec<String> = cells.iter().map(|x| trim_f64(*x)).collect();
        self.row(&strs)
    }

    /// The document text.
    pub fn as_str(&self) -> &str {
        &self.buf
    }

    /// Write the document to `path`, creating parent directories.
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.buf.as_bytes())
    }
}

fn quote(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

fn trim_f64(x: f64) -> String {
    if x.is_nan() {
        return String::new();
    }
    if x == x.trunc() && x.abs() < 1e15 {
        format!("{}", x as i64)
    } else {
        format!("{x:.6}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_rows() {
        let mut c = Csv::with_header(&["a", "b"]);
        c.row(&["1", "x,y"]).row(&["2", "q\"t"]);
        assert_eq!(c.as_str(), "a,b\n1,\"x,y\"\n2,\"q\"\"t\"\n");
    }

    #[test]
    fn f64_rows() {
        let mut c = Csv::with_header(&["v", "w"]);
        c.row_f64(&[2.0, 2.5]);
        c.row_f64(&[f64::NAN, 1.0]);
        assert_eq!(c.as_str(), "v,w\n2,2.500000\n,1\n");
    }

    #[test]
    #[should_panic(expected = "CSV arity mismatch")]
    fn arity_enforced() {
        let mut c = Csv::with_header(&["a", "b"]);
        c.row(&["only"]);
    }

    #[test]
    fn save_roundtrip() {
        let mut c = Csv::with_header(&["x"]);
        c.row(&["1"]);
        let p = std::env::temp_dir().join("llsched_csv_test/out.csv");
        c.save(&p).unwrap();
        assert_eq!(std::fs::read_to_string(&p).unwrap(), "x\n1\n");
        let _ = std::fs::remove_file(&p);
    }
}
