//! Small self-contained utilities (PRNG, statistics, formatting, JSON/CSV
//! emitters). Hand-rolled because the build environment is offline and the
//! vendored crate set has no `rand`, `serde` or table-formatting crates.

pub mod csv;
pub mod fmt;
pub mod json;
pub mod rng;
pub mod slab;
pub mod stats;
