//! Descriptive statistics used by the metrics and bench layers.

/// Median of a slice (interpolated for even lengths). Returns `NaN` on empty
/// input, matching the "no data" semantics used in reports.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Linear-interpolation percentile (p in `[0, 100]`).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).expect("NaN in percentile input"));
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Arithmetic mean (`NaN` on empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Minimum (`NaN` on empty input).
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NAN, |a, b| if a.is_nan() || b < a { b } else { a })
}

/// Maximum (`NaN` on empty input).
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NAN, |a, b| if a.is_nan() || b > a { b } else { a })
}

/// Summary bundle used by the bench harness.
#[derive(Debug, Clone, Copy)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub max: f64,
}

impl Summary {
    /// Compute all summary statistics for `xs`.
    pub fn of(xs: &[f64]) -> Summary {
        Summary {
            n: xs.len(),
            mean: mean(xs),
            stddev: stddev(xs),
            min: min(xs),
            p50: percentile(xs, 50.0),
            p95: percentile(xs, 95.0),
            max: max(xs),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn median_single_and_empty() {
        assert_eq!(median(&[7.0]), 7.0);
        assert!(median(&[]).is_nan());
    }

    #[test]
    fn percentile_endpoints() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 25.0), 2.0);
    }

    #[test]
    fn mean_stddev_known() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert_eq!(mean(&xs), 5.0);
        assert_eq!(stddev(&xs), 2.0);
    }

    #[test]
    fn min_max_known() {
        let xs = [3.0, -1.0, 9.0];
        assert_eq!(min(&xs), -1.0);
        assert_eq!(max(&xs), 9.0);
    }

    #[test]
    fn summary_consistent() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.n, 100);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert_eq!(s.p50, 50.5);
        assert!((s.mean - 50.5).abs() < 1e-12);
    }
}
