//! A generation-checked slab: stable integer keys, O(1) insert/remove,
//! and ABA-safe key validation.
//!
//! Slots are recycled through a free list, but every recycle bumps the
//! slot's generation, so a key that outlives its value is *detected*
//! (`get`/`remove` return `None`) instead of silently aliasing the new
//! occupant. This is the storage discipline the event calendar's wake
//! tokens ride on ([`crate::sim::EventQueue`]): a timer handle held past
//! its firing is a stale generation, never a dangling index.

/// A generation-checked handle into a [`Slab`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SlabKey {
    index: u32,
    gen: u32,
}

impl SlabKey {
    /// The dense slot index (stable for the key's lifetime).
    pub fn index(&self) -> u32 {
        self.index
    }

    /// The slot generation this key was minted under.
    pub fn generation(&self) -> u32 {
        self.gen
    }
}

#[derive(Debug)]
enum Entry<T> {
    /// Free slot; `gen` is the generation the *next* occupant will get.
    Vacant { gen: u32 },
    Occupied { gen: u32, value: T },
}

/// The slab arena.
#[derive(Debug)]
pub struct Slab<T> {
    entries: Vec<Entry<T>>,
    free: Vec<u32>,
    len: usize,
}

impl<T> Default for Slab<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> Slab<T> {
    /// An empty slab.
    pub fn new() -> Slab<T> {
        Slab { entries: Vec::new(), free: Vec::new(), len: 0 }
    }

    /// An empty slab with room for `cap` values before reallocating.
    pub fn with_capacity(cap: usize) -> Slab<T> {
        Slab { entries: Vec::with_capacity(cap), free: Vec::new(), len: 0 }
    }

    /// Number of live values.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True if no values are live.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Insert a value, reusing a free slot when one exists.
    pub fn insert(&mut self, value: T) -> SlabKey {
        self.len += 1;
        if let Some(index) = self.free.pop() {
            let gen = match self.entries[index as usize] {
                Entry::Vacant { gen } => gen,
                Entry::Occupied { .. } => unreachable!("free list points at occupied slot"),
            };
            self.entries[index as usize] = Entry::Occupied { gen, value };
            return SlabKey { index, gen };
        }
        let index = self.entries.len() as u32;
        self.entries.push(Entry::Occupied { gen: 0, value });
        SlabKey { index, gen: 0 }
    }

    /// Whether `key` still addresses a live value (same slot *and* same
    /// generation).
    pub fn contains(&self, key: SlabKey) -> bool {
        matches!(
            self.entries.get(key.index as usize),
            Some(Entry::Occupied { gen, .. }) if *gen == key.gen
        )
    }

    /// Borrow the value behind `key`, if the key is still live.
    pub fn get(&self, key: SlabKey) -> Option<&T> {
        match self.entries.get(key.index as usize) {
            Some(Entry::Occupied { gen, value }) if *gen == key.gen => Some(value),
            _ => None,
        }
    }

    /// Mutably borrow the value behind `key`, if the key is still live.
    pub fn get_mut(&mut self, key: SlabKey) -> Option<&mut T> {
        match self.entries.get_mut(key.index as usize) {
            Some(Entry::Occupied { gen, value }) if *gen == key.gen => Some(value),
            _ => None,
        }
    }

    /// Remove and return the value behind `key`. A stale key (already
    /// removed, or its slot recycled) returns `None` and changes
    /// nothing — double-free becomes a visible no-op.
    pub fn remove(&mut self, key: SlabKey) -> Option<T> {
        match self.entries.get_mut(key.index as usize) {
            Some(entry @ Entry::Occupied { .. }) => {
                let matches = matches!(entry, Entry::Occupied { gen, .. } if *gen == key.gen);
                if !matches {
                    return None;
                }
                // Bump the generation on vacancy so every old key to
                // this slot is dead from here on.
                let next_gen = key.gen.wrapping_add(1);
                let old = std::mem::replace(entry, Entry::Vacant { gen: next_gen });
                self.free.push(key.index);
                self.len -= 1;
                match old {
                    Entry::Occupied { value, .. } => Some(value),
                    Entry::Vacant { .. } => unreachable!("matched occupied above"),
                }
            }
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_get_remove_roundtrip() {
        let mut s = Slab::new();
        let a = s.insert("a");
        let b = s.insert("b");
        assert_eq!(s.len(), 2);
        assert_eq!(s.get(a), Some(&"a"));
        assert_eq!(s.get(b), Some(&"b"));
        assert_eq!(s.remove(a), Some("a"));
        assert_eq!(s.len(), 1);
        assert_eq!(s.get(a), None);
        assert!(s.contains(b));
        assert!(!s.contains(a));
    }

    #[test]
    fn double_remove_is_a_no_op() {
        let mut s = Slab::new();
        let k = s.insert(7);
        assert_eq!(s.remove(k), Some(7));
        assert_eq!(s.remove(k), None, "second remove is detected, not UB");
        assert_eq!(s.len(), 0);
    }

    #[test]
    fn recycled_slot_kills_the_old_key() {
        let mut s = Slab::new();
        let old = s.insert("old");
        assert_eq!(s.remove(old), Some("old"));
        let new = s.insert("new");
        // Same slot, new generation: the stale key must not alias.
        assert_eq!(new.index(), old.index());
        assert_ne!(new.generation(), old.generation());
        assert_eq!(s.get(old), None);
        assert_eq!(s.remove(old), None);
        assert_eq!(s.get(new), Some(&"new"));
    }

    #[test]
    fn get_mut_updates_in_place() {
        let mut s = Slab::with_capacity(4);
        let k = s.insert(1u64);
        *s.get_mut(k).unwrap() += 41;
        assert_eq!(s.get(k), Some(&42));
    }

    #[test]
    fn heavy_churn_keeps_len_consistent() {
        let mut s = Slab::new();
        let mut keys = Vec::new();
        for round in 0..10 {
            for i in 0..100u32 {
                keys.push(s.insert(round * 1000 + i));
            }
            // Remove every other key; all survivors stay addressable.
            let mut kept = Vec::new();
            for (i, k) in keys.drain(..).enumerate() {
                if i % 2 == 0 {
                    assert!(s.remove(k).is_some());
                } else {
                    kept.push(k);
                }
            }
            for &k in &kept {
                assert!(s.contains(k));
            }
            keys = kept;
        }
        assert_eq!(s.len(), keys.len());
    }
}
