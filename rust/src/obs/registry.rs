//! The metrics registry: named monotonic counters (one per
//! [`TraceKind`], qualified by subsystem) and fixed-bucket histograms
//! for the quantities the paper's overhead story turns on — queue
//! depth at decision time, per-decision simulated latency, and
//! steal-hop counts in federated runs.
//!
//! Everything here is plain integer/float arithmetic over
//! pre-allocated fixed-size storage: no strings on the hot path, no
//! hashing, no allocation after construction.

use super::trace::{Subsystem, TraceKind};

/// Upper bounds for the queue-depth histogram (pending batch tasks at
/// each `pick_next` decision); the last bucket is implicit +inf.
pub const QUEUE_DEPTH_BOUNDS: &[f64] =
    &[0.0, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0, 512.0, 1024.0];

/// Upper bounds (seconds of simulated server charge) for the
/// decision-latency histogram.
pub const DECISION_LATENCY_BOUNDS: &[f64] = &[1e-6, 1e-5, 1e-4, 1e-3, 1e-2, 1e-1, 1.0];

/// Upper bounds for the steal-hops histogram (times a federated job
/// migrated before starting).
pub const STEAL_HOPS_BOUNDS: &[f64] = &[0.0, 1.0, 2.0, 3.0, 4.0, 8.0];

/// A fixed-bucket histogram: `bounds` are inclusive upper edges, with
/// one extra overflow bucket for values above the last edge.
#[derive(Debug, Clone)]
pub struct Histogram {
    pub name: &'static str,
    bounds: &'static [f64],
    counts: Vec<u64>,
    /// Observations recorded.
    pub n: u64,
    /// Sum of observed values (for means).
    pub sum: f64,
}

impl Histogram {
    /// A zeroed histogram over `bounds` (plus the overflow bucket).
    pub fn new(name: &'static str, bounds: &'static [f64]) -> Histogram {
        Histogram { name, bounds, counts: vec![0; bounds.len() + 1], n: 0, sum: 0.0 }
    }

    /// Record one observation.
    #[inline]
    pub fn observe(&mut self, v: f64) {
        self.n += 1;
        self.sum += v;
        let i = self.bounds.iter().position(|&b| v <= b).unwrap_or(self.bounds.len());
        self.counts[i] += 1;
    }

    /// `(upper_edge, count)` per bucket, overflow edge = +inf.
    pub fn buckets(&self) -> impl Iterator<Item = (f64, u64)> + '_ {
        self.bounds
            .iter()
            .copied()
            .chain(std::iter::once(f64::INFINITY))
            .zip(self.counts.iter().copied())
    }

    /// Fold another histogram (same bounds) into this one.
    pub fn merge_from(&mut self, other: &Histogram) {
        debug_assert_eq!(self.bounds.len(), other.bounds.len(), "merging mismatched histograms");
        for (mine, theirs) in self.counts.iter_mut().zip(other.counts.iter()) {
            *mine += theirs;
        }
        self.n += other.n;
        self.sum += other.sum;
    }
}

/// The per-recorder registry: one monotonic counter per trace kind
/// (auto-bumped by `Obs::record`) plus the three standing histograms.
#[derive(Debug, Clone)]
pub struct Registry {
    kind_counts: [u64; TraceKind::COUNT],
    /// Pending batch-queue depth at each traced `pick_next` decision.
    pub queue_depth: Histogram,
    /// Simulated server charge (seconds) per traced decision.
    pub decision_latency: Histogram,
    /// Steal migrations per federated job (observed at rollup).
    pub steal_hops: Histogram,
}

impl Registry {
    pub fn new() -> Registry {
        Registry {
            kind_counts: [0; TraceKind::COUNT],
            queue_depth: Histogram::new("queue_depth", QUEUE_DEPTH_BOUNDS),
            decision_latency: Histogram::new("decision_latency_s", DECISION_LATENCY_BOUNDS),
            steal_hops: Histogram::new("steal_hops", STEAL_HOPS_BOUNDS),
        }
    }

    /// Bump the counter for one recorded kind.
    #[inline]
    pub(crate) fn note_kind(&mut self, kind: TraceKind) {
        self.kind_counts[kind.index()] += 1;
    }

    /// Events recorded for one kind.
    pub fn counter(&self, kind: TraceKind) -> u64 {
        self.kind_counts[kind.index()]
    }

    /// Events recorded across all kinds.
    pub fn total(&self) -> u64 {
        self.kind_counts.iter().sum()
    }

    /// Events recorded for one subsystem.
    pub fn subsystem_total(&self, sub: Subsystem) -> u64 {
        TraceKind::ALL
            .into_iter()
            .filter(|k| k.subsystem() == sub)
            .map(|k| self.counter(k))
            .sum()
    }

    /// Every non-zero counter as `("subsystem.kind", value)`, in
    /// declaration order (deterministic for export).
    pub fn counters(&self) -> Vec<(String, u64)> {
        TraceKind::ALL
            .into_iter()
            .filter(|k| self.counter(*k) > 0)
            .map(|k| (format!("{}.{}", k.subsystem().name(), k.name()), self.counter(k)))
            .collect()
    }

    /// The standing histograms, in declaration order.
    pub fn histograms(&self) -> [&Histogram; 3] {
        [&self.queue_depth, &self.decision_latency, &self.steal_hops]
    }

    /// Fold another registry into this one (federated rollups).
    pub fn merge_from(&mut self, other: &Registry) {
        for (mine, theirs) in self.kind_counts.iter_mut().zip(other.kind_counts.iter()) {
            *mine += theirs;
        }
        self.queue_depth.merge_from(&other.queue_depth);
        self.decision_latency.merge_from(&other.decision_latency);
        self.steal_hops.merge_from(&other.steal_hops);
    }
}

impl Default for Registry {
    fn default() -> Registry {
        Registry::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_buckets_and_overflow() {
        let mut h = Histogram::new("t", &[1.0, 10.0]);
        for v in [0.5, 1.0, 5.0, 100.0] {
            h.observe(v);
        }
        let b: Vec<(f64, u64)> = h.buckets().collect();
        assert_eq!(b.len(), 3);
        assert_eq!(b[0], (1.0, 2), "0.5 and the inclusive edge 1.0");
        assert_eq!(b[1], (10.0, 1));
        assert_eq!(b[2].1, 1, "100.0 lands in the overflow bucket");
        assert!(b[2].0.is_infinite());
        assert_eq!(h.n, 4);
    }

    #[test]
    fn registry_counters_roll_up_by_subsystem() {
        let mut r = Registry::new();
        r.note_kind(TraceKind::Pick);
        r.note_kind(TraceKind::Pick);
        r.note_kind(TraceKind::PoolDispatch);
        assert_eq!(r.counter(TraceKind::Pick), 2);
        assert_eq!(r.subsystem_total(Subsystem::Scheduler), 2);
        assert_eq!(r.subsystem_total(Subsystem::Pool), 1);
        assert_eq!(r.total(), 3);
        let names: Vec<String> = r.counters().into_iter().map(|(n, _)| n).collect();
        assert_eq!(names, vec!["scheduler.pick".to_string(), "pool.pool_dispatch".to_string()]);
    }

    #[test]
    fn merge_sums_everything() {
        let mut a = Registry::new();
        let mut b = Registry::new();
        a.note_kind(TraceKind::StealAttempt);
        b.note_kind(TraceKind::StealAttempt);
        a.steal_hops.observe(2.0);
        b.steal_hops.observe(3.0);
        a.merge_from(&b);
        assert_eq!(a.counter(TraceKind::StealAttempt), 2);
        assert_eq!(a.steal_hops.n, 2);
        assert_eq!(a.steal_hops.sum, 5.0);
    }
}
