//! Span reconstruction and wait attribution: fold the flight
//! recorder's event stream back into per-job lifecycle spans and
//! decompose each job's queue wait into named causes.
//!
//! The recorder (PR 9) answers *what happened*; this layer answers
//! *why a given job waited*. It is a pure function of an
//! [`ObsSnapshot`] — no scheduler state, no randomness — so spans are
//! deterministic and federated snapshots merged with
//! [`ObsSnapshot::merge`] reconstruct identically every run.
//!
//! ## Anchors
//!
//! A job's span is stitched from the trace vocabulary:
//!
//! * **submit** — the `Pick` branch-0 record emitted at `Submit`
//!   (standalone), or the `GatewayRoute` record (federated).
//! * **queued** — the `JobQueued` record, which also carries the
//!   job's contiguous task-arena range (`unit` = task count,
//!   `detail` = first task id): the job→task join key.
//! * **launch** — the first task to start. Pool tasks anchor on
//!   `PoolDispatch`, backfilled tasks on `BackfillAdmit`, held tasks
//!   on `HoldClear`, and plain dispatches on a *resolved* `Pick`
//!   branch-2 attempt: an attempt whose next same-task event is a
//!   `WaitCause` fence/capacity marker failed; any other resolution
//!   means the task started at `t + detail/1e9` (the pick's service
//!   charge).
//! * **finish** — the last `Pick` branch-4 (cleanup) record.
//! * **steal hops** — `JobLink` records chain a gateway job index
//!   through every instance that ever owned it; the last link is the
//!   instance whose local span finishes the job.
//!
//! ## Blame
//!
//! The wait window (submit → first launch, plus one re-wait window
//! per fault requeue) is tiled by *cause segments*: the current cause
//! starts as head-of-line and flips at each `WaitCause` marker
//! recorded for one of the job's tasks. Because the segments
//! telescope, the per-cause totals sum to the job's total wait to
//! float rounding — the invariant pinned by
//! `rust/tests/obs_spans_properties.rs`.
//!
//! When the ring dropped records (`snapshot.dropped > 0`) anchors may
//! be missing, so every span — and the [`SpanSet`] itself — is marked
//! `partial` and the sum invariant is not claimed.

use std::collections::BTreeMap;

use super::{ObsSnapshot, TraceKind};

/// Names of the wait-blame causes, indexed by the `WaitBlame` part
/// order: head-of-line capacity blocking, backfill-fence/hold
/// rejection, pool cold-start (resize cooldown), fault-requeue retry
/// backoff, gateway batching delay, federation steal hops.
pub const BLAME_CAUSES: [&str; 6] =
    ["hol", "fence", "cold_start", "requeue_backoff", "gateway_batch", "steal"];

/// `BLAME_CAUSES` indices, named.
pub const CAUSE_HOL: usize = 0;
pub const CAUSE_FENCE: usize = 1;
pub const CAUSE_COLD_START: usize = 2;
pub const CAUSE_REQUEUE: usize = 3;
pub const CAUSE_GATEWAY: usize = 4;
pub const CAUSE_STEAL: usize = 5;

/// Map a `WaitCause` marker's `unit` (the on-wire cause code) to a
/// `BLAME_CAUSES` index. Codes: 0 hold-park/head-of-line, 1
/// cooldown-block, 2 fence-reject, 3 requeue-backoff.
fn marker_cause(code: u32) -> usize {
    match code {
        1 => CAUSE_COLD_START,
        2 => CAUSE_FENCE,
        3 => CAUSE_REQUEUE,
        _ => CAUSE_HOL,
    }
}

/// Per-cause seconds of attributed queue wait for one job (or an
/// aggregate over many).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct WaitBlame {
    /// Seconds per cause, in `BLAME_CAUSES` order.
    pub parts: [f64; 6],
}

impl WaitBlame {
    /// Attribute `dt` seconds to one cause (negative clamps to zero).
    pub fn add(&mut self, cause: usize, dt: f64) {
        if dt > 0.0 {
            self.parts[cause] += dt;
        }
    }

    /// Seconds attributed to one cause, by `BLAME_CAUSES` index.
    pub fn get(&self, cause: usize) -> f64 {
        self.parts[cause]
    }

    /// Total attributed wait across every cause.
    pub fn total(&self) -> f64 {
        self.parts.iter().sum()
    }

    /// The largest cause, as `(BLAME_CAUSES index, seconds)`.
    /// Ties break toward the lower index; all-zero blame reports
    /// `(CAUSE_HOL, 0.0)`.
    pub fn dominant(&self) -> (usize, f64) {
        let mut best = (0, self.parts[0]);
        for (i, &v) in self.parts.iter().enumerate().skip(1) {
            if v > best.1 {
                best = (i, v);
            }
        }
        best
    }

    /// Sum another blame vector into this one.
    pub fn merge(&mut self, other: &WaitBlame) {
        for (a, b) in self.parts.iter_mut().zip(other.parts.iter()) {
            *a += b;
        }
    }
}

/// One reconstructed job lifecycle span with its wait attribution.
#[derive(Debug, Clone)]
pub struct JobSpan {
    /// Job key: the local job id (standalone) or the gateway job
    /// index (federated).
    pub job: u64,
    /// Final owning instance (the gateway's routing target, after any
    /// steals).
    pub pid: u32,
    /// Task count, from the `JobQueued` arena range.
    pub tasks: u32,
    /// Submission time: local `Submit` (standalone) or gateway
    /// arrival (federated).
    pub submit_t: f64,
    /// Local queue-entry time on the final owner (NaN when the
    /// anchor was dropped).
    pub queued_t: f64,
    /// First task launch (NaN when the job never launched in the
    /// traced window).
    pub launch_t: f64,
    /// Last task cleanup (NaN when not observed).
    pub finish_t: f64,
    /// Whether any task of the job was observed launching.
    pub launched: bool,
    /// Federation steal hops the job took before launching.
    pub steal_hops: u32,
    /// Total attributed queue wait: submit → first launch, plus one
    /// re-wait window per observed fault requeue.
    pub wait_s: f64,
    /// The wait, decomposed by cause. `blame.total()` equals
    /// `wait_s` to float rounding on non-partial spans.
    pub blame: WaitBlame,
    /// True when anchors may be missing (ring drops, or a span whose
    /// submit/queued record was not observed).
    pub partial: bool,
}

/// Every job span reconstructed from one snapshot.
#[derive(Debug, Clone)]
pub struct SpanSet {
    /// Spans, sorted by job key.
    pub spans: Vec<JobSpan>,
    /// True when the ring dropped records: every span is then partial
    /// and the blame-sums-to-wait invariant is not claimed.
    pub partial: bool,
}

impl SpanSet {
    /// The span for one job key, if reconstructed.
    pub fn get(&self, job: u64) -> Option<&JobSpan> {
        self.spans.binary_search_by(|s| s.job.cmp(&job)).ok().map(|i| &self.spans[i])
    }

    /// The `k` launched jobs with the largest attributed wait,
    /// longest first (ties break toward the lower job key).
    pub fn worst(&self, k: usize) -> Vec<&JobSpan> {
        let mut launched: Vec<&JobSpan> = self.spans.iter().filter(|s| s.launched).collect();
        launched.sort_by(|a, b| b.wait_s.total_cmp(&a.wait_s).then(a.job.cmp(&b.job)));
        launched.truncate(k);
        launched
    }

    /// Sum of every span's blame vector.
    pub fn total_blame(&self) -> WaitBlame {
        let mut acc = WaitBlame::default();
        for s in &self.spans {
            acc.merge(&s.blame);
        }
        acc
    }

    /// Mean attributed wait over launched jobs (NaN when none).
    pub fn mean_wait_s(&self) -> f64 {
        let launched: Vec<f64> =
            self.spans.iter().filter(|s| s.launched).map(|s| s.wait_s).collect();
        if launched.is_empty() {
            f64::NAN
        } else {
            launched.iter().sum::<f64>() / launched.len() as f64
        }
    }
}

/// Local (per-instance) job bookkeeping built from the stream.
#[derive(Debug, Clone)]
struct LocalJob {
    submit_t: f64,
    queued_t: f64,
    first_task: u64,
    count: u32,
}

/// Per-task reconstruction state: the online state machine that
/// resolves dispatch attempts and collects launch/requeue/marker
/// timelines.
#[derive(Debug, Clone, Default)]
struct TaskTrack {
    /// An unresolved `Pick` branch-2 attempt: `(pick t, cost s)`.
    pending: Option<(f64, f64)>,
    /// Whether the task is currently between queue entry (or a
    /// requeue) and its next launch.
    waiting: bool,
    /// Observed launch times, oldest first.
    launches: Vec<f64>,
    /// Fault requeues: `(requeue t, retry backoff s)`.
    requeues: Vec<(f64, f64)>,
    /// Wait-cause markers: `(t, on-wire cause code)`.
    markers: Vec<(f64, u32)>,
    /// Last observed cleanup time (NaN until seen).
    finish: f64,
}

impl TaskTrack {
    fn new() -> TaskTrack {
        TaskTrack { waiting: true, finish: f64::NAN, ..TaskTrack::default() }
    }

    /// Resolve an open dispatch attempt as successful: the attempt's
    /// op completed without a failure marker, so the task started at
    /// pick time plus the service charge.
    fn resolve_pending(&mut self) {
        if let Some((at, cost)) = self.pending.take() {
            self.launch(at + cost);
        }
    }

    fn launch(&mut self, t: f64) {
        if self.waiting {
            self.launches.push(t);
            self.waiting = false;
        }
    }

    fn on_attempt(&mut self, t: f64, cost_s: f64) {
        self.resolve_pending();
        self.pending = Some((t, cost_s));
    }

    /// A launch anchor with an explicit start time (`HoldClear`,
    /// `BackfillAdmit`, `PoolDispatch`). Supersedes any open attempt:
    /// both describe the same start.
    fn on_anchor(&mut self, t: f64) {
        self.pending = None;
        self.launch(t);
    }

    fn on_marker(&mut self, t: f64, code: u32) {
        // A capacity/fence marker is the failure resolution of an
        // open dispatch attempt; either way the marker flips the
        // job's current wait cause.
        if matches!(code, 0 | 2) {
            self.pending = None;
        }
        self.markers.push((t, code));
    }

    fn on_requeue(&mut self, t: f64, backoff_s: f64) {
        self.resolve_pending();
        self.requeues.push((t, backoff_s));
        self.waiting = true;
    }

    fn on_cleanup(&mut self, t: f64) {
        self.resolve_pending();
        if self.finish.is_nan() || t > self.finish {
            self.finish = t;
        }
    }
}

/// A gateway-side job: arrival plus its chain of ownership links.
#[derive(Debug, Clone, Default)]
struct GatewayJob {
    submit_t: f64,
    /// `(t, owning instance, instance-local job id)`, oldest first.
    links: Vec<(f64, u32, u64)>,
}

fn blank_job() -> LocalJob {
    LocalJob { submit_t: f64::NAN, queued_t: f64::NAN, first_task: 0, count: 0 }
}

/// What `local_blame` reconstructs for one local job.
struct LocalSpanOut {
    launch_t: f64,
    finish_t: f64,
    wait_s: f64,
    blame: WaitBlame,
}

fn nan_min(a: f64, b: f64) -> f64 {
    if a.is_nan() || b < a {
        b
    } else {
        a
    }
}

fn nan_max(a: f64, b: f64) -> f64 {
    if a.is_nan() || b > a {
        b
    } else {
        a
    }
}

/// Tile the window `[start, launch]` with cause segments flipped by
/// the given markers (sorted by time), starting from head-of-line.
fn tile_window(blame: &mut WaitBlame, start: f64, launch: f64, markers: &[(f64, u32)]) {
    let mut cur_t = start;
    let mut cur_cause = CAUSE_HOL;
    for &(mt, code) in markers {
        if mt <= start || mt >= launch {
            continue;
        }
        blame.add(cur_cause, mt - cur_t);
        cur_t = mt;
        cur_cause = marker_cause(code);
    }
    blame.add(cur_cause, launch - cur_t);
}

/// Reconstruct the local part of a job's span: first launch, finish,
/// and the blame tiling of `[start, first launch]` plus one re-wait
/// window per requeue that relaunched.
fn local_blame(
    start: f64,
    pid: u32,
    lj: &LocalJob,
    tracks: &BTreeMap<(u32, u64), TaskTrack>,
) -> Option<LocalSpanOut> {
    let tids = lj.first_task..lj.first_task + u64::from(lj.count);

    let mut first_launch = f64::NAN;
    let mut finish = f64::NAN;
    for tid in tids.clone() {
        if let Some(tr) = tracks.get(&(pid, tid)) {
            if let Some(&l0) = tr.launches.first() {
                first_launch = nan_min(first_launch, l0);
            }
            if !tr.finish.is_nan() {
                finish = nan_max(finish, tr.finish);
            }
        }
    }
    if first_launch.is_nan() {
        return None;
    }

    let mut blame = WaitBlame::default();
    let mut wait = first_launch - start;

    // Window 0: submit → first launch, flipped by markers from any of
    // the job's tasks (the job waits as a unit until its head task
    // starts).
    let mut markers: Vec<(f64, u32)> = Vec::new();
    for tid in tids.clone() {
        if let Some(tr) = tracks.get(&(pid, tid)) {
            markers.extend(tr.markers.iter().filter(|&&(_, c)| c != 3).copied());
        }
    }
    markers.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    tile_window(&mut blame, start, first_launch, &markers);

    // Re-wait windows: a fault requeue reopens the wait at the
    // requeue time; the retry backoff itself is blamed first, the
    // remainder tiles from head-of-line using the task's own markers.
    for tid in tids {
        let Some(tr) = tracks.get(&(pid, tid)) else { continue };
        for &(rt, backoff) in &tr.requeues {
            let Some(&relaunch) = tr.launches.iter().find(|&&l| l > rt) else { continue };
            let backoff_end = (rt + backoff).min(relaunch);
            blame.add(CAUSE_REQUEUE, backoff_end - rt);
            tile_window(&mut blame, backoff_end, relaunch, &tr.markers);
            wait += relaunch - rt;
        }
    }

    Some(LocalSpanOut { launch_t: first_launch, finish_t: finish, wait_s: wait, blame })
}

/// Fold a snapshot's event stream into per-job spans with wait
/// attribution. Pure and deterministic: same snapshot, same spans.
pub fn reconstruct_spans(snap: &ObsSnapshot) -> SpanSet {
    let dropped = snap.dropped > 0;

    let mut jobs: BTreeMap<(u32, u64), LocalJob> = BTreeMap::new();
    let mut tracks: BTreeMap<(u32, u64), TaskTrack> = BTreeMap::new();
    let mut gateway: BTreeMap<u64, GatewayJob> = BTreeMap::new();
    let mut steal_hops: BTreeMap<u64, u32> = BTreeMap::new();

    // Pass 1: per-pid online reconstruction. The merged stream is
    // sorted by (t, pid, host_ns, seq); within one pid that order is
    // the recording order, so each per-task state machine sees its
    // events chronologically.
    for ev in &snap.events {
        match ev.kind {
            TraceKind::Pick => {
                let key = (ev.pid, ev.id);
                match ev.unit {
                    0 => {
                        let lj = jobs.entry(key).or_insert_with(blank_job);
                        if lj.submit_t.is_nan() {
                            lj.submit_t = ev.t;
                        }
                    }
                    2 => {
                        let tr = tracks.entry(key).or_insert_with(TaskTrack::new);
                        tr.on_attempt(ev.t, ev.detail as f64 / 1e9);
                    }
                    4 => {
                        let tr = tracks.entry(key).or_insert_with(TaskTrack::new);
                        tr.on_cleanup(ev.t);
                    }
                    _ => {}
                }
            }
            TraceKind::JobQueued => {
                let lj = jobs.entry((ev.pid, ev.id)).or_insert_with(blank_job);
                lj.queued_t = ev.t;
                lj.first_task = ev.detail as u64;
                lj.count = ev.unit;
            }
            TraceKind::WaitCause => {
                let tr = tracks.entry((ev.pid, ev.id)).or_insert_with(TaskTrack::new);
                if ev.unit == 3 {
                    tr.on_requeue(ev.t, ev.detail as f64 / 1e9);
                } else {
                    tr.on_marker(ev.t, ev.unit);
                }
            }
            TraceKind::HoldClear | TraceKind::BackfillAdmit | TraceKind::PoolDispatch => {
                let tr = tracks.entry((ev.pid, ev.id)).or_insert_with(TaskTrack::new);
                tr.on_anchor(ev.t);
            }
            TraceKind::GatewayRoute => {
                let gw = gateway.entry(ev.id).or_default();
                if gw.links.is_empty() && gw.submit_t == 0.0 {
                    gw.submit_t = ev.t;
                }
            }
            TraceKind::JobLink => {
                let gw = gateway.entry(ev.id).or_default();
                gw.links.push((ev.t, ev.unit, ev.detail as u64));
            }
            TraceKind::StealAttempt => {
                *steal_hops.entry(ev.id).or_insert(0) += 1;
            }
            _ => {}
        }
    }
    for tr in tracks.values_mut() {
        tr.resolve_pending();
    }

    let federated = !gateway.is_empty();
    let mut spans: Vec<JobSpan> = Vec::new();

    if federated {
        for (&idx, gw) in &gateway {
            let hops = steal_hops.get(&idx).copied().unwrap_or(0);
            let Some(&(last_t, owner, local_id)) = gw.links.last() else {
                // Routed but never flushed to an instance: still
                // backlogged when the trace ended.
                spans.push(JobSpan {
                    job: idx,
                    pid: u32::MAX,
                    tasks: 0,
                    submit_t: gw.submit_t,
                    queued_t: f64::NAN,
                    launch_t: f64::NAN,
                    finish_t: f64::NAN,
                    launched: false,
                    steal_hops: hops,
                    wait_s: 0.0,
                    blame: WaitBlame::default(),
                    partial: dropped,
                });
                continue;
            };
            let lj = jobs.get(&(owner, local_id)).cloned().unwrap_or_else(blank_job);
            let anchors_missing = lj.submit_t.is_nan() || lj.queued_t.is_nan();
            let local_start = if lj.submit_t.is_nan() { last_t } else { lj.submit_t };
            let mut blame = WaitBlame::default();
            // Gateway batching: arrival → first flush to an instance.
            // Steal hops: first flush → the final owner's local
            // submission. The three segments telescope with the local
            // window so blame still sums to the total wait.
            let first_link_t = gw.links[0].0;
            blame.add(CAUSE_GATEWAY, first_link_t - gw.submit_t);
            blame.add(CAUSE_STEAL, local_start - first_link_t);
            let gw_wait =
                (first_link_t - gw.submit_t).max(0.0) + (local_start - first_link_t).max(0.0);
            match local_blame(local_start, owner, &lj, &tracks) {
                Some(out) => {
                    blame.merge(&out.blame);
                    spans.push(JobSpan {
                        job: idx,
                        pid: owner,
                        tasks: lj.count,
                        submit_t: gw.submit_t,
                        queued_t: lj.queued_t,
                        launch_t: out.launch_t,
                        finish_t: out.finish_t,
                        launched: true,
                        steal_hops: hops,
                        wait_s: gw_wait + out.wait_s,
                        blame,
                        partial: dropped || anchors_missing,
                    });
                }
                None => spans.push(JobSpan {
                    job: idx,
                    pid: owner,
                    tasks: lj.count,
                    submit_t: gw.submit_t,
                    queued_t: lj.queued_t,
                    launch_t: f64::NAN,
                    finish_t: f64::NAN,
                    launched: false,
                    steal_hops: hops,
                    wait_s: 0.0,
                    blame: WaitBlame::default(),
                    partial: dropped || anchors_missing,
                }),
            }
        }
    } else {
        for (&(pid, job), lj) in &jobs {
            let anchors_missing = lj.submit_t.is_nan() || lj.queued_t.is_nan();
            let start = if lj.submit_t.is_nan() {
                if lj.queued_t.is_nan() {
                    continue;
                }
                lj.queued_t
            } else {
                lj.submit_t
            };
            match local_blame(start, pid, lj, &tracks) {
                Some(out) => spans.push(JobSpan {
                    job,
                    pid,
                    tasks: lj.count,
                    submit_t: start,
                    queued_t: lj.queued_t,
                    launch_t: out.launch_t,
                    finish_t: out.finish_t,
                    launched: true,
                    steal_hops: 0,
                    wait_s: out.wait_s,
                    blame: out.blame,
                    partial: dropped || anchors_missing,
                }),
                None => spans.push(JobSpan {
                    job,
                    pid,
                    tasks: lj.count,
                    submit_t: start,
                    queued_t: lj.queued_t,
                    launch_t: f64::NAN,
                    finish_t: f64::NAN,
                    launched: false,
                    steal_hops: 0,
                    wait_s: 0.0,
                    blame: WaitBlame::default(),
                    partial: dropped || anchors_missing,
                }),
            }
        }
    }

    spans.sort_by(|a, b| a.job.cmp(&b.job).then(a.pid.cmp(&b.pid)));
    SpanSet { spans, partial: dropped }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::Obs;

    const EPS: f64 = 1e-9;

    #[test]
    fn single_job_dispatch_retry_attributes_to_hol() {
        let mut o = Obs::new(64);
        // Submit at t=0 (1 ms registration charge), queued, then a
        // failed dispatch attempt resolved by a capacity marker, then
        // a successful one: launch at 2.0 + 0.5 = 2.5.
        o.record(TraceKind::Pick, 0, 7, 0.0, 1_000_000);
        o.record(TraceKind::JobQueued, 1, 7, 0.001, 40);
        o.record(TraceKind::Pick, 2, 40, 1.0, 500_000_000);
        o.record(TraceKind::WaitCause, 0, 40, 1.5, 0);
        o.record(TraceKind::Pick, 2, 40, 2.0, 500_000_000);
        o.record(TraceKind::Pick, 4, 40, 5.0, 0);
        let set = reconstruct_spans(&o.snapshot());
        assert!(!set.partial);
        assert_eq!(set.spans.len(), 1);
        let s = set.get(7).expect("span for job 7");
        assert!(s.launched && !s.partial);
        assert_eq!((s.tasks, s.steal_hops), (1, 0));
        assert!((s.submit_t - 0.0).abs() < EPS && (s.launch_t - 2.5).abs() < EPS);
        assert!((s.finish_t - 5.0).abs() < EPS);
        assert!((s.wait_s - 2.5).abs() < EPS);
        // Both segments carry the head-of-line cause.
        assert!((s.blame.get(CAUSE_HOL) - 2.5).abs() < EPS);
        assert!((s.blame.total() - s.wait_s).abs() < EPS, "blame tiles the wait");
    }

    #[test]
    fn fence_and_cooldown_markers_flip_the_cause() {
        let mut o = Obs::new(64);
        o.record(TraceKind::Pick, 0, 1, 0.0, 0);
        o.record(TraceKind::JobQueued, 1, 1, 0.0, 9);
        // Fence-reject at 1.0, cooldown-block at 3.0, launch (pool)
        // at 4.0: hol [0,1), fence [1,3), cold_start [3,4).
        o.record(TraceKind::WaitCause, 2, 9, 1.0, 0);
        o.record(TraceKind::WaitCause, 1, 9, 3.0, 0);
        o.record(TraceKind::PoolDispatch, 0, 9, 4.0, 5);
        let set = reconstruct_spans(&o.snapshot());
        let s = set.get(1).expect("span");
        assert!((s.blame.get(CAUSE_HOL) - 1.0).abs() < EPS);
        assert!((s.blame.get(CAUSE_FENCE) - 2.0).abs() < EPS);
        assert!((s.blame.get(CAUSE_COLD_START) - 1.0).abs() < EPS);
        assert!((s.blame.total() - s.wait_s).abs() < EPS);
        assert_eq!(s.blame.dominant().0, CAUSE_FENCE);
    }

    #[test]
    fn requeue_opens_a_rewait_window_blamed_on_backoff() {
        let mut o = Obs::new(64);
        o.record(TraceKind::Pick, 0, 2, 0.0, 0);
        o.record(TraceKind::JobQueued, 1, 2, 0.0, 3);
        o.record(TraceKind::BackfillAdmit, 0, 3, 1.0, 0);
        // Killed by a fault at t=4 with a 2 s retry backoff, then
        // relaunched at t=7: requeue_backoff 2 s + hol 1 s on top of
        // the 1 s first-launch wait.
        o.record(TraceKind::WaitCause, 3, 3, 4.0, 2_000_000_000);
        o.record(TraceKind::BackfillAdmit, 0, 3, 7.0, 0);
        o.record(TraceKind::Pick, 4, 3, 9.0, 0);
        let set = reconstruct_spans(&o.snapshot());
        let s = set.get(2).expect("span");
        assert!((s.wait_s - 4.0).abs() < EPS, "1 s first wait + 3 s re-wait");
        assert!((s.blame.get(CAUSE_REQUEUE) - 2.0).abs() < EPS);
        assert!((s.blame.get(CAUSE_HOL) - 2.0).abs() < EPS);
        assert!((s.blame.total() - s.wait_s).abs() < EPS);
    }

    #[test]
    fn federated_span_chains_gateway_and_steal_segments() {
        // Gateway (pid 2) routes job idx 0, flushes it to instance 0
        // at t=1, instance 1 steals it at t=2, instance 1 launches it
        // at t=3: gateway_batch 1 s, steal 1 s, hol 1 s.
        let mut gw = Obs::new(64).with_pid(2);
        gw.record(TraceKind::GatewayRoute, 0, 0, 0.0, 0);
        gw.record(TraceKind::JobLink, 0, 0, 1.0, 5);
        gw.record(TraceKind::StealAttempt, 0, 0, 2.0, 1);
        gw.record(TraceKind::JobLink, 1, 0, 2.0, 8);
        let mut inst = Obs::new(64).with_pid(1);
        inst.record(TraceKind::Pick, 0, 8, 2.0, 0);
        inst.record(TraceKind::JobQueued, 1, 8, 2.0, 17);
        inst.record(TraceKind::PoolDispatch, 0, 17, 3.0, 4);
        let (a, b) = (gw.snapshot(), inst.snapshot());
        let merged = ObsSnapshot::merge([&a, &b]);
        let set = reconstruct_spans(&merged);
        assert_eq!(set.spans.len(), 1, "one gateway job, no standalone double-count");
        let s = set.get(0).expect("gateway span");
        assert_eq!((s.pid, s.steal_hops, s.tasks), (1, 1, 1));
        assert!((s.wait_s - 3.0).abs() < EPS);
        assert!((s.blame.get(CAUSE_GATEWAY) - 1.0).abs() < EPS);
        assert!((s.blame.get(CAUSE_STEAL) - 1.0).abs() < EPS);
        assert!((s.blame.get(CAUSE_HOL) - 1.0).abs() < EPS);
        assert!((s.blame.total() - s.wait_s).abs() < EPS);
    }

    #[test]
    fn ring_drops_mark_every_span_partial() {
        let mut o = Obs::new(2);
        o.record(TraceKind::Pick, 0, 1, 0.0, 0);
        o.record(TraceKind::JobQueued, 1, 1, 0.0, 0);
        o.record(TraceKind::Pick, 2, 0, 1.0, 0);
        let snap = o.snapshot();
        assert!(snap.dropped > 0);
        let set = reconstruct_spans(&snap);
        assert!(set.partial);
        assert!(set.spans.iter().all(|s| s.partial));
    }

    #[test]
    fn worst_ranks_launched_jobs_by_wait() {
        let mut o = Obs::new(64);
        for (job, tid, launch) in [(0u64, 10u64, 4.0), (1, 11, 9.0), (2, 12, 1.0)] {
            o.record(TraceKind::Pick, 0, job, 0.0, 0);
            o.record(TraceKind::JobQueued, 1, job, 0.0, tid as i64);
            o.record(TraceKind::PoolDispatch, 0, tid, launch, 0);
        }
        let set = reconstruct_spans(&o.snapshot());
        let worst: Vec<u64> = set.worst(2).iter().map(|s| s.job).collect();
        assert_eq!(worst, vec![1, 0]);
        assert!((set.mean_wait_s() - (4.0 + 9.0 + 1.0) / 3.0).abs() < EPS);
    }
}
