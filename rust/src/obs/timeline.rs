//! Fleet timeseries: fold a flight-recorder snapshot into
//! fixed-interval buckets of queue and fleet state, per federation
//! instance and fleet-wide, with CSV / JSON / Perfetto exporters.
//!
//! Like the span layer this is a pure, deterministic function of an
//! [`ObsSnapshot`]. Gauges are reconstructed by replaying the event
//! stream — queue entries from `JobQueued`, task starts from the
//! launch anchors (`PoolDispatch`, `BackfillAdmit`, `HoldClear`, and
//! resolved `Pick` branch-2 attempts), completions from `Pick`
//! branch-4 cleanups, pool lease level from `PoolResize` deltas,
//! pool in-flight from `PoolDispatch`/`PoolRelease`, and active-fault
//! nodes from `FaultCascade` fail/drain/recover steps — and sampling
//! the counters at each bucket boundary.
//!
//! Two documented approximations: gauges are *bucket-end samples*
//! (intra-bucket excursions are invisible), and `utilization` is the
//! running-task count normalized by the run's observed peak (the
//! trace does not carry per-node core occupancy). Both are noted in
//! `docs/observability.md`.

use std::collections::BTreeMap;

use super::spans::SpanSet;
use super::{ObsSnapshot, TraceKind};
use crate::util::csv::Csv;
use crate::util::json::Json;

/// The pid used for the fleet-aggregate rows (sorts after every real
/// federation instance).
pub const FLEET_PID: u32 = u32::MAX;

/// One fixed-interval sample of one instance (or the fleet).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct TimelineBucket {
    /// Bucket start, seconds of simulated time.
    pub t0: f64,
    /// Federation instance, or [`FLEET_PID`] for the aggregate row.
    pub pid: u32,
    /// Tasks queued but not yet launched, sampled at bucket end.
    pub pending: f64,
    /// Tasks running at bucket end.
    pub running: f64,
    /// `running` normalized by the run's peak running count for this
    /// row's pid (0 when the peak is 0).
    pub utilization: f64,
    /// Net pool lease level (grow minus shrink) at bucket end.
    pub pool_leased: f64,
    /// Tasks in flight on pool nodes at bucket end.
    pub pool_inflight: f64,
    /// Nodes failed or draining (not yet recovered) at bucket end.
    pub faults_active: f64,
    /// Task launches inside the bucket.
    pub launches: f64,
    /// Task cleanups inside the bucket.
    pub completions: f64,
}

/// A bucketed fleet timeseries.
#[derive(Debug, Clone)]
pub struct Timeline {
    /// Bucket width, seconds.
    pub interval_s: f64,
    /// Rows sorted by `(bucket, pid)`; the fleet row of each bucket
    /// sorts last. Empty when the snapshot held no events.
    pub buckets: Vec<TimelineBucket>,
    /// True when the source ring dropped records (gauges may start
    /// mid-stream and drift).
    pub partial: bool,
}

impl Timeline {
    /// Rows for one pid, in time order.
    pub fn for_pid(&self, pid: u32) -> Vec<&TimelineBucket> {
        self.buckets.iter().filter(|b| b.pid == pid).collect()
    }

    /// The fleet-aggregate rows, in time order.
    pub fn fleet(&self) -> Vec<&TimelineBucket> {
        self.for_pid(FLEET_PID)
    }
}

/// Instantaneous counter deltas replayed during the sweep.
#[derive(Debug, Clone, Copy)]
enum Delta {
    Queued(f64),
    Launch,
    Unlaunch,
    Complete,
    Leased(f64),
    Inflight(f64),
    Fault(f64),
}

/// Minimal per-task attempt resolver (the span layer's rule): a
/// `Pick` branch-2 attempt launches at `t + cost` unless its next
/// same-task event is a capacity/fence `WaitCause` marker.
#[derive(Debug, Clone, Copy, Default)]
struct Mini {
    pending: Option<(f64, f64)>,
    running: bool,
}

fn fmt_cell(x: f64) -> String {
    if x.is_nan() {
        String::new()
    } else if x == x.trunc() && x.abs() < 1e15 {
        format!("{}", x as i64)
    } else {
        format!("{x:.6}")
    }
}

/// Fold a snapshot into fixed-interval buckets. `interval_s` is
/// clamped below at 1 µs and widened when it would produce more than
/// 200 000 buckets.
pub fn build_timeline(snap: &ObsSnapshot, interval_s: f64) -> Timeline {
    fn push(map: &mut BTreeMap<u32, Vec<(f64, Delta)>>, pid: u32, t: f64, d: Delta) {
        map.entry(pid).or_default().push((t, d));
    }
    let mut deltas: BTreeMap<u32, Vec<(f64, Delta)>> = BTreeMap::new();
    let mut minis: BTreeMap<(u32, u64), Mini> = BTreeMap::new();

    for ev in &snap.events {
        // Every pid seen gets a delta stream, even if it stays empty
        // (a gateway's rows sample as zero rather than vanishing).
        deltas.entry(ev.pid).or_default();
        match ev.kind {
            TraceKind::JobQueued => {
                push(&mut deltas, ev.pid, ev.t, Delta::Queued(f64::from(ev.unit)));
            }
            TraceKind::Pick => match ev.unit {
                2 => {
                    let m = minis.entry((ev.pid, ev.id)).or_default();
                    if let Some((at, c)) = m.pending.take() {
                        if !m.running {
                            m.running = true;
                            push(&mut deltas, ev.pid, at + c, Delta::Launch);
                        }
                    }
                    m.pending = Some((ev.t, ev.detail as f64 / 1e9));
                }
                4 => {
                    let m = minis.entry((ev.pid, ev.id)).or_default();
                    if let Some((at, c)) = m.pending.take() {
                        if !m.running {
                            m.running = true;
                            push(&mut deltas, ev.pid, at + c, Delta::Launch);
                        }
                    }
                    if m.running {
                        m.running = false;
                        push(&mut deltas, ev.pid, ev.t, Delta::Complete);
                    }
                }
                _ => {}
            },
            TraceKind::HoldClear | TraceKind::BackfillAdmit | TraceKind::PoolDispatch => {
                let m = minis.entry((ev.pid, ev.id)).or_default();
                m.pending = None;
                if !m.running {
                    m.running = true;
                    push(&mut deltas, ev.pid, ev.t, Delta::Launch);
                }
                if ev.kind == TraceKind::PoolDispatch {
                    push(&mut deltas, ev.pid, ev.t, Delta::Inflight(1.0));
                }
            }
            TraceKind::PoolRelease => {
                push(&mut deltas, ev.pid, ev.t, Delta::Inflight(-1.0));
            }
            TraceKind::WaitCause => {
                let m = minis.entry((ev.pid, ev.id)).or_default();
                match ev.unit {
                    3 => {
                        // Fault requeue: the task stopped running and
                        // is queued again (pending for the next
                        // launch).
                        m.pending = None;
                        if m.running {
                            m.running = false;
                            push(&mut deltas, ev.pid, ev.t, Delta::Unlaunch);
                        }
                    }
                    _ => {
                        m.pending = None;
                    }
                }
            }
            TraceKind::PoolResize => {
                push(&mut deltas, ev.pid, ev.t, Delta::Leased(ev.detail as f64));
            }
            TraceKind::FaultCascade => {
                let d = match ev.detail {
                    0 | 3 => 1.0,
                    1 => -1.0,
                    _ => 0.0,
                };
                if d != 0.0 {
                    push(&mut deltas, ev.pid, ev.t, Delta::Fault(d));
                }
            }
            _ => {}
        }
    }
    for ((pid, _), m) in &mut minis {
        if let Some((at, c)) = m.pending.take() {
            if !m.running {
                m.running = true;
                push(&mut deltas, *pid, at + c, Delta::Launch);
            }
        }
    }

    let mut t_end: f64 = 0.0;
    for stream in deltas.values_mut() {
        stream.sort_by(|a, b| a.0.total_cmp(&b.0));
        if let Some(&(t, _)) = stream.last() {
            if t > t_end {
                t_end = t;
            }
        }
    }
    if deltas.is_empty() {
        return Timeline { interval_s, buckets: Vec::new(), partial: snap.dropped > 0 };
    }

    let mut dt = interval_s.max(1e-6);
    if t_end / dt > 200_000.0 {
        dt = t_end / 200_000.0;
    }
    let nbuckets = (t_end / dt).floor() as usize + 1;

    let mut rows: Vec<TimelineBucket> = Vec::new();
    let mut peaks: BTreeMap<u32, f64> = BTreeMap::new();
    for (&pid, stream) in &deltas {
        let mut cursor = 0usize;
        let (mut pending, mut running) = (0.0f64, 0.0f64);
        let (mut leased, mut inflight, mut faults) = (0.0f64, 0.0f64, 0.0f64);
        let mut peak = 0.0f64;
        for k in 0..nbuckets {
            let bucket_end = (k + 1) as f64 * dt;
            let (mut launches, mut completions) = (0.0f64, 0.0f64);
            while cursor < stream.len() && stream[cursor].0 < bucket_end {
                match stream[cursor].1 {
                    Delta::Queued(n) => pending += n,
                    Delta::Launch => {
                        pending -= 1.0;
                        running += 1.0;
                        launches += 1.0;
                    }
                    Delta::Unlaunch => {
                        pending += 1.0;
                        running -= 1.0;
                    }
                    Delta::Complete => {
                        running -= 1.0;
                        completions += 1.0;
                    }
                    Delta::Leased(n) => leased += n,
                    Delta::Inflight(n) => inflight += n,
                    Delta::Fault(n) => faults += n,
                }
                cursor += 1;
            }
            if running > peak {
                peak = running;
            }
            rows.push(TimelineBucket {
                t0: k as f64 * dt,
                pid,
                pending: pending.max(0.0),
                running: running.max(0.0),
                utilization: 0.0,
                pool_leased: leased.max(0.0),
                pool_inflight: inflight.max(0.0),
                faults_active: faults.max(0.0),
                launches,
                completions,
            });
        }
        peaks.insert(pid, peak);
    }

    // Fleet aggregate: the per-bucket sum over instances. With the
    // per-pid rows grouped contiguously above, bucket k of pid i is
    // row i * nbuckets + k.
    let npids = deltas.len();
    let mut fleet_peak = 0.0f64;
    let mut fleet_rows: Vec<TimelineBucket> = Vec::with_capacity(nbuckets);
    for k in 0..nbuckets {
        let mut agg = TimelineBucket { t0: k as f64 * dt, pid: FLEET_PID, ..Default::default() };
        for i in 0..npids {
            let r = &rows[i * nbuckets + k];
            agg.pending += r.pending;
            agg.running += r.running;
            agg.pool_leased += r.pool_leased;
            agg.pool_inflight += r.pool_inflight;
            agg.faults_active += r.faults_active;
            agg.launches += r.launches;
            agg.completions += r.completions;
        }
        if agg.running > fleet_peak {
            fleet_peak = agg.running;
        }
        fleet_rows.push(agg);
    }
    peaks.insert(FLEET_PID, fleet_peak);
    rows.append(&mut fleet_rows);

    for r in &mut rows {
        let peak = peaks.get(&r.pid).copied().unwrap_or(0.0);
        r.utilization = if peak > 0.0 { r.running / peak } else { 0.0 };
    }
    rows.sort_by(|a, b| a.t0.total_cmp(&b.t0).then(a.pid.cmp(&b.pid)));

    Timeline { interval_s: dt, buckets: rows, partial: snap.dropped > 0 }
}

/// Timeline column names, in row order after `t_s` and `pid`.
pub const TIMELINE_COLS: [&str; 8] = [
    "pending",
    "running",
    "utilization",
    "pool_leased",
    "pool_inflight",
    "faults_active",
    "launches",
    "completions",
];

/// Render a timeline as CSV: one row per `(bucket, pid)`, the fleet
/// row labelled `fleet`.
pub fn timeline_csv(tl: &Timeline) -> Csv {
    let mut cols = vec!["t_s".to_string(), "pid".to_string()];
    cols.extend(TIMELINE_COLS.iter().map(|c| c.to_string()));
    let mut csv = Csv::with_header(&cols);
    for b in &tl.buckets {
        let pid = if b.pid == FLEET_PID { "fleet".to_string() } else { b.pid.to_string() };
        let cells = vec![
            fmt_cell(b.t0),
            pid,
            fmt_cell(b.pending),
            fmt_cell(b.running),
            fmt_cell(b.utilization),
            fmt_cell(b.pool_leased),
            fmt_cell(b.pool_inflight),
            fmt_cell(b.faults_active),
            fmt_cell(b.launches),
            fmt_cell(b.completions),
        ];
        csv.row(&cells);
    }
    csv
}

/// Render a timeline as JSON (same rows as the CSV).
pub fn timeline_json(tl: &Timeline) -> Json {
    let rows: Vec<Json> = tl
        .buckets
        .iter()
        .map(|b| {
            let pid: Json = if b.pid == FLEET_PID { "fleet".into() } else { u64::from(b.pid).into() };
            Json::obj()
                .set("t_s", b.t0)
                .set("pid", pid)
                .set("pending", b.pending)
                .set("running", b.running)
                .set("utilization", b.utilization)
                .set("pool_leased", b.pool_leased)
                .set("pool_inflight", b.pool_inflight)
                .set("faults_active", b.faults_active)
                .set("launches", b.launches)
                .set("completions", b.completions)
        })
        .collect();
    Json::obj()
        .set("interval_s", tl.interval_s)
        .set("partial", tl.partial)
        .set("buckets", Json::Arr(rows))
}

/// Render a span set as Perfetto *complete* events (`ph: "X"`,
/// duration spans) alongside PR 9's instant stream: one wait span per
/// launched job (submit → first launch, blame in `args`) on track 99
/// and one run span (launch → finish, when observed) on track 98.
pub fn perfetto_spans(set: &SpanSet) -> Json {
    use super::spans::BLAME_CAUSES;
    let mut pids: Vec<u32> = set.spans.iter().filter(|s| s.launched).map(|s| s.pid).collect();
    pids.sort_unstable();
    pids.dedup();

    let mut events: Vec<Json> = Vec::new();
    for pid in &pids {
        events.push(
            Json::obj()
                .set("name", "process_name")
                .set("ph", "M")
                .set("pid", u64::from(*pid))
                .set("args", Json::obj().set("name", format!("instance {pid}"))),
        );
        for (tid, label) in [(99u64, "job wait"), (98, "job run")] {
            events.push(
                Json::obj()
                    .set("name", "thread_name")
                    .set("ph", "M")
                    .set("pid", u64::from(*pid))
                    .set("tid", tid)
                    .set("args", Json::obj().set("name", label)),
            );
        }
    }
    for s in set.spans.iter().filter(|s| s.launched) {
        let mut args = Json::obj()
            .set("job", s.job)
            .set("steal_hops", u64::from(s.steal_hops))
            .set("partial", s.partial);
        for (i, name) in BLAME_CAUSES.iter().enumerate() {
            args = args.set(format!("blame_{name}_s"), s.blame.get(i));
        }
        events.push(
            Json::obj()
                .set("name", format!("wait job {}", s.job))
                .set("ph", "X")
                .set("ts", s.submit_t * 1e6)
                .set("dur", s.wait_s * 1e6)
                .set("pid", u64::from(s.pid))
                .set("tid", 99u64)
                .set("args", args),
        );
        if !s.finish_t.is_nan() && !s.launch_t.is_nan() {
            events.push(
                Json::obj()
                    .set("name", format!("run job {}", s.job))
                    .set("ph", "X")
                    .set("ts", s.launch_t * 1e6)
                    .set("dur", (s.finish_t - s.launch_t).max(0.0) * 1e6)
                    .set("pid", u64::from(s.pid))
                    .set("tid", 98u64),
            );
        }
    }
    Json::obj().set("displayTimeUnit", "ms").set("traceEvents", Json::Arr(events))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{reconstruct_spans, Obs};

    const EPS: f64 = 1e-9;

    fn sample() -> ObsSnapshot {
        let mut o = Obs::new(64);
        // Job 0 with two tasks queued at 0.2; one launches via the
        // pool at 1.2 and cleans up at 2.2; the other never starts.
        o.record(TraceKind::Pick, 0, 0, 0.2, 0);
        o.record(TraceKind::JobQueued, 2, 0, 0.2, 10);
        o.record(TraceKind::PoolDispatch, 0, 10, 1.2, 4);
        o.record(TraceKind::PoolRelease, 0, 10, 2.0, 4);
        o.record(TraceKind::Pick, 4, 10, 2.2, 0);
        o.snapshot()
    }

    #[test]
    fn buckets_sample_pending_running_and_counts() {
        let tl = build_timeline(&sample(), 1.0);
        assert!(!tl.partial);
        let p0 = tl.for_pid(0);
        assert_eq!(p0.len(), 3, "t_end 2.2 at 1 s interval gives 3 buckets");
        assert!((p0[0].pending - 2.0).abs() < EPS && p0[0].running == 0.0);
        assert!((p0[1].pending - 1.0).abs() < EPS);
        assert!((p0[1].running - 1.0).abs() < EPS);
        assert!((p0[1].launches - 1.0).abs() < EPS);
        assert!((p0[1].pool_inflight - 1.0).abs() < EPS);
        assert!((p0[2].running - 0.0).abs() < EPS);
        assert!((p0[2].completions - 1.0).abs() < EPS);
        // Utilization normalizes against the run's peak (1 task).
        assert!((p0[1].utilization - 1.0).abs() < EPS);
        // The fleet aggregate mirrors the single instance.
        let fleet = tl.fleet();
        assert_eq!(fleet.len(), 3);
        assert!((fleet[1].running - 1.0).abs() < EPS);
    }

    #[test]
    fn resize_and_fault_deltas_are_gauges() {
        let mut o = Obs::new(64);
        o.record(TraceKind::PoolResize, 0, 4, 0.5, 4);
        o.record(TraceKind::FaultCascade, 3, 2, 0.6, 0);
        o.record(TraceKind::FaultCascade, 3, 0, 1.5, 1);
        o.record(TraceKind::PoolResize, 0, 2, 2.5, -2);
        let tl = build_timeline(&o.snapshot(), 1.0);
        let p0 = tl.for_pid(0);
        assert!((p0[0].pool_leased - 4.0).abs() < EPS);
        assert!((p0[0].faults_active - 1.0).abs() < EPS);
        assert!((p0[1].faults_active - 0.0).abs() < EPS);
        assert!((p0[2].pool_leased - 2.0).abs() < EPS);
    }

    #[test]
    fn csv_and_json_exports_are_deterministic() {
        let tl = build_timeline(&sample(), 1.0);
        let csv = timeline_csv(&tl);
        let head = csv.as_str().lines().next().unwrap();
        assert_eq!(
            head,
            "t_s,pid,pending,running,utilization,pool_leased,pool_inflight,\
             faults_active,launches,completions"
        );
        assert_eq!(csv.as_str().lines().count(), 1 + 6, "3 buckets x (pid 0 + fleet)");
        assert!(csv.as_str().contains("fleet"));
        let j1 = timeline_json(&tl).to_pretty();
        let j2 = timeline_json(&build_timeline(&sample(), 1.0)).to_pretty();
        assert_eq!(j1, j2);
    }

    #[test]
    fn perfetto_spans_emit_complete_events() {
        let set = reconstruct_spans(&sample());
        let text = perfetto_spans(&set).to_pretty();
        assert!(text.contains("\"ph\": \"X\""));
        assert!(text.contains("wait job 0"));
        assert!(text.contains("run job 0"));
        assert!(text.contains("blame_hol_s"));
        assert!(text.contains("\"dur\""));
    }

    #[test]
    fn empty_snapshot_yields_empty_timeline() {
        let o = Obs::new(4);
        let tl = build_timeline(&o.snapshot(), 1.0);
        assert!(tl.buckets.is_empty());
    }
}
