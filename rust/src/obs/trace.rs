//! The structured trace ring: typed decision records in a bounded,
//! pre-allocated buffer.
//!
//! Every record carries the decision kind, the federation instance it
//! came from (`pid`), a subsystem-specific unit (shard, node, or
//! `pick_next` branch code), the job/task id, the simulated time, and
//! a host-side timestamp drawn from a monotonic counter injected at
//! construction — never the wall clock — so same-seed traces are
//! byte-identical across runs (pinned by
//! `rust/tests/obs_properties.rs`). The opt-in self-profiling mode is
//! the one place wall-clock time exists, and it stays outside the ring.

/// The subsystem a trace event belongs to (the Perfetto "thread").
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Subsystem {
    /// The server op loop: `pick_next` branches and register routing.
    Scheduler,
    /// EASY backfill: admissions, rejections, hold planning/clearing,
    /// and overdue-backfill preemptions.
    Backfill,
    /// The rapid-launch pool fleet: dispatches, releases, resizes.
    Pool,
    /// The churn layer: failure / recovery / reclaim / drain cascades.
    Fault,
    /// The submission gateway: routing, flushes, work stealing.
    Federation,
}

impl Subsystem {
    /// Every subsystem, in display order.
    pub const ALL: [Subsystem; 5] = [
        Subsystem::Scheduler,
        Subsystem::Backfill,
        Subsystem::Pool,
        Subsystem::Fault,
        Subsystem::Federation,
    ];

    /// Stable lowercase name (the `--trace-filter` vocabulary).
    pub fn name(self) -> &'static str {
        match self {
            Subsystem::Scheduler => "scheduler",
            Subsystem::Backfill => "backfill",
            Subsystem::Pool => "pool",
            Subsystem::Fault => "fault",
            Subsystem::Federation => "federation",
        }
    }

    /// Position in [`Self::ALL`] (the Perfetto thread id).
    pub fn index(self) -> usize {
        self as usize
    }

    /// Parse a `--trace-filter` value.
    pub fn parse(s: &str) -> Option<Subsystem> {
        Subsystem::ALL.into_iter().find(|sub| sub.name() == s)
    }

    /// Parse a comma-separated `--trace-filter` list. Unknown names are
    /// an error naming the bad token (not silently dropped); empty
    /// tokens are ignored so trailing commas are harmless.
    pub fn parse_list(s: &str) -> Result<Vec<Subsystem>, String> {
        let mut subs = Vec::new();
        for tok in s.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            match Subsystem::parse(tok) {
                Some(sub) => {
                    if !subs.contains(&sub) {
                        subs.push(sub);
                    }
                }
                None => {
                    return Err(format!(
                        "unknown subsystem {tok:?} (one of \
                         scheduler|backfill|pool|fault|federation)"
                    ))
                }
            }
        }
        if subs.is_empty() {
            return Err("empty subsystem list".into());
        }
        Ok(subs)
    }
}

/// The decision vocabulary: every kind of record the flight recorder
/// can hold, each belonging to exactly one [`Subsystem`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceKind {
    /// One `pick_next` decision (or a `Submit`-scheduled `Register`):
    /// `unit` is the branch code in service-discipline order
    /// (0 register, 1 cycle, 2 dispatch, 3 backfill, 4 cleanup,
    /// 5 noise, 6 preempt-signal, 7 pool-dispatch, 8 pool-release,
    /// 9 pool-resize, 10–13 fault ops), `detail` the simulated charge
    /// in nanoseconds.
    Pick,
    /// A registered task routed to its queue: `unit` is the shard id
    /// (`u32::MAX` = the batch pending queue), `detail` 1 for pool,
    /// 0 for batch.
    RegisterRoute,
    /// A backfill admission placed: `unit` the node, `detail` the hold
    /// task fencing that node (−1 when unheld).
    BackfillAdmit,
    /// A backfill admission failed at placement: `detail` 0 = raced a
    /// hold change, 1 = whole-node task reached the backfill path.
    BackfillReject,
    /// An earliest-start reservation planted: `unit` the fenced node,
    /// `detail` 0 = planned from the free-time table, 1 = borrowed
    /// from the pool fleet's drain forecast.
    HoldPlan,
    /// A hold released because its task started: `unit` the node.
    HoldClear,
    /// A preemption signal landed on a running task: `unit` the fault
    /// node (`u32::MAX` for non-fault preemptions), `detail` 1 when it
    /// was an overdue-backfill kill.
    Preempt,
    /// An O(1) pool launch: `unit` the shard, `detail` the node.
    PoolDispatch,
    /// An O(1) pool release: `unit` the shard, `detail` the node
    /// (−1 when the lease was already gone).
    PoolRelease,
    /// A shard resize applied: `detail` is +grown / −shrunk / 0 hold.
    PoolResize,
    /// One step of a fault cascade: `unit` the node (or wave), `id`
    /// the kill count (or shard), `detail` 0 fail / 1 recover /
    /// 2 reclaim-wave / 3 drain / 4 pool-evict.
    FaultCascade,
    /// The gateway routed a submission: `unit` the chosen instance,
    /// `id` the gateway job index, `detail` the winning backlog depth.
    GatewayRoute,
    /// The gateway flushed one instance's buffer: `unit` the instance,
    /// `id` its batch ordinal, `detail` the jobs injected.
    GatewayFlush,
    /// A work-steal migrated a job: `unit` the donor instance, `id`
    /// the gateway job index, `detail` the receiving instance.
    StealAttempt,
    /// A steal candidate refused withdrawal (already started): `unit`
    /// the donor, `id` the gateway job index, `detail` the receiver.
    StealRefused,
    /// A job's tasks entered the local queues at Register: `unit` the
    /// task count, `id` the job, `detail` the first task id of the
    /// job's contiguous arena range. The span layer's queue-entry
    /// anchor and job→task mapping.
    JobQueued,
    /// A wait-cause marker: a decision point explained *why* pending
    /// work did not start. `unit` is the cause code (0 hold-park,
    /// 1 cooldown-block, 2 fence-reject, 3 requeue-backoff), `id` the
    /// task held up, `detail` a cause-specific payload (the backoff
    /// delay in nanoseconds for code 3, else 0).
    WaitCause,
    /// The gateway bound a gateway job to an instance-local job id at
    /// flush or steal: `unit` the owning instance, `id` the gateway
    /// job index, `detail` the instance-local job id. The span layer's
    /// cross-process join key.
    JobLink,
}

impl TraceKind {
    /// Number of kinds (sizing for per-kind counters).
    pub const COUNT: usize = 18;

    /// Every kind, in declaration order (indexable by [`Self::index`]).
    pub const ALL: [TraceKind; TraceKind::COUNT] = [
        TraceKind::Pick,
        TraceKind::RegisterRoute,
        TraceKind::BackfillAdmit,
        TraceKind::BackfillReject,
        TraceKind::HoldPlan,
        TraceKind::HoldClear,
        TraceKind::Preempt,
        TraceKind::PoolDispatch,
        TraceKind::PoolRelease,
        TraceKind::PoolResize,
        TraceKind::FaultCascade,
        TraceKind::GatewayRoute,
        TraceKind::GatewayFlush,
        TraceKind::StealAttempt,
        TraceKind::StealRefused,
        TraceKind::JobQueued,
        TraceKind::WaitCause,
        TraceKind::JobLink,
    ];

    /// Position in [`Self::ALL`].
    pub fn index(self) -> usize {
        self as usize
    }

    /// Stable snake_case name (the exporter vocabulary).
    pub fn name(self) -> &'static str {
        match self {
            TraceKind::Pick => "pick",
            TraceKind::RegisterRoute => "register_route",
            TraceKind::BackfillAdmit => "backfill_admit",
            TraceKind::BackfillReject => "backfill_reject",
            TraceKind::HoldPlan => "hold_plan",
            TraceKind::HoldClear => "hold_clear",
            TraceKind::Preempt => "preempt",
            TraceKind::PoolDispatch => "pool_dispatch",
            TraceKind::PoolRelease => "pool_release",
            TraceKind::PoolResize => "pool_resize",
            TraceKind::FaultCascade => "fault_cascade",
            TraceKind::GatewayRoute => "gateway_route",
            TraceKind::GatewayFlush => "gateway_flush",
            TraceKind::StealAttempt => "steal_attempt",
            TraceKind::StealRefused => "steal_refused",
            TraceKind::JobQueued => "job_queued",
            TraceKind::WaitCause => "wait_cause",
            TraceKind::JobLink => "job_link",
        }
    }

    /// The subsystem this kind belongs to.
    pub fn subsystem(self) -> Subsystem {
        match self {
            TraceKind::Pick
            | TraceKind::RegisterRoute
            | TraceKind::JobQueued
            | TraceKind::WaitCause => Subsystem::Scheduler,
            TraceKind::BackfillAdmit
            | TraceKind::BackfillReject
            | TraceKind::HoldPlan
            | TraceKind::HoldClear
            | TraceKind::Preempt => Subsystem::Backfill,
            TraceKind::PoolDispatch | TraceKind::PoolRelease | TraceKind::PoolResize => {
                Subsystem::Pool
            }
            TraceKind::FaultCascade => Subsystem::Fault,
            TraceKind::GatewayRoute
            | TraceKind::GatewayFlush
            | TraceKind::StealAttempt
            | TraceKind::StealRefused
            | TraceKind::JobLink => Subsystem::Federation,
        }
    }
}

/// One flight-recorder record. 48 bytes, `Copy`, no heap.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceEvent {
    pub kind: TraceKind,
    /// Federation instance (0 for standalone runs; the gateway records
    /// under `instances` — one past the last instance).
    pub pid: u32,
    /// Subsystem-specific unit: shard, node, instance, or branch code
    /// (`u32::MAX` = not applicable / the batch queue).
    pub unit: u32,
    /// The job/task the decision was about (kind-specific).
    pub id: u64,
    /// Simulated time of the decision.
    pub t: f64,
    /// Deterministic host-side timestamp from the injected
    /// [`MonoClock`] — a per-recorder sequence, not the wall clock.
    pub host_ns: u64,
    /// Kind-specific payload (see [`TraceKind`]).
    pub detail: i64,
}

/// The deterministic "host clock": a monotonic counter advanced by a
/// fixed step per recorded event. Injected at recorder construction so
/// trace bytes never depend on the machine the sim ran on.
#[derive(Debug, Clone, Copy)]
pub struct MonoClock {
    next: u64,
    step: u64,
}

impl MonoClock {
    /// A clock starting at `start` nanoseconds, advancing `step`
    /// nanoseconds per tick.
    pub fn new(start: u64, step: u64) -> MonoClock {
        MonoClock { next: start, step: step.max(1) }
    }

    /// The next timestamp (and advance).
    pub fn tick(&mut self) -> u64 {
        let t = self.next;
        self.next = self.next.wrapping_add(self.step);
        t
    }
}

/// A bounded, pre-allocated ring of trace records. Overwrites the
/// oldest record when full and counts what it dropped — a flight
/// recorder keeps the *latest* window, and the drop counter makes
/// truncation visible instead of silent.
#[derive(Debug, Clone)]
pub struct TraceRing {
    buf: Vec<TraceEvent>,
    /// Oldest record's index once the ring has wrapped.
    head: usize,
    cap: usize,
    dropped: u64,
}

impl TraceRing {
    /// A ring holding at most `cap` records (clamped to ≥ 1), with the
    /// full capacity allocated up front so recording never reallocates.
    pub fn new(cap: usize) -> TraceRing {
        let cap = cap.max(1);
        TraceRing { buf: Vec::with_capacity(cap), head: 0, cap, dropped: 0 }
    }

    /// Append a record, overwriting the oldest when full.
    #[inline]
    pub fn push(&mut self, ev: TraceEvent) {
        if self.buf.len() < self.cap {
            self.buf.push(ev);
        } else {
            self.buf[self.head] = ev;
            self.head = (self.head + 1) % self.cap;
            self.dropped += 1;
        }
    }

    /// Records currently held (≤ capacity).
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// Whether nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Records overwritten because the ring was full.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Consume the ring into oldest-first order.
    pub fn into_ordered(self) -> Vec<TraceEvent> {
        let TraceRing { mut buf, head, .. } = self;
        buf.rotate_left(head);
        buf
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(i: u64) -> TraceEvent {
        TraceEvent {
            kind: TraceKind::Pick,
            pid: 0,
            unit: 0,
            id: i,
            t: i as f64,
            host_ns: i,
            detail: 0,
        }
    }

    #[test]
    fn ring_overwrites_oldest_and_counts_drops() {
        let mut r = TraceRing::new(3);
        for i in 0..5 {
            r.push(ev(i));
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.dropped(), 2);
        let ids: Vec<u64> = r.into_ordered().iter().map(|e| e.id).collect();
        assert_eq!(ids, vec![2, 3, 4], "oldest-first, latest window kept");
    }

    #[test]
    fn every_kind_has_a_distinct_index_and_a_subsystem() {
        for (i, k) in TraceKind::ALL.into_iter().enumerate() {
            assert_eq!(k.index(), i);
            assert!(Subsystem::ALL.contains(&k.subsystem()));
        }
        for s in Subsystem::ALL {
            assert_eq!(Subsystem::parse(s.name()), Some(s));
        }
        assert_eq!(Subsystem::parse("nope"), None);
    }

    #[test]
    fn parse_list_accepts_commas_and_rejects_unknowns() {
        assert_eq!(
            Subsystem::parse_list("pool,federation").unwrap(),
            vec![Subsystem::Pool, Subsystem::Federation]
        );
        assert_eq!(
            Subsystem::parse_list(" scheduler , pool ,").unwrap(),
            vec![Subsystem::Scheduler, Subsystem::Pool],
            "whitespace and trailing commas are harmless"
        );
        assert_eq!(
            Subsystem::parse_list("pool,pool").unwrap(),
            vec![Subsystem::Pool],
            "duplicates collapse"
        );
        let err = Subsystem::parse_list("pool,bogus").unwrap_err();
        assert!(err.contains("bogus"), "error names the bad token: {err}");
        assert!(Subsystem::parse_list("").is_err(), "an empty list is an error");
    }

    #[test]
    fn mono_clock_is_a_pure_counter() {
        let mut c = MonoClock::new(100, 50);
        assert_eq!((c.tick(), c.tick(), c.tick()), (100, 150, 200));
    }
}
