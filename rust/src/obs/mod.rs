//! The scheduler flight recorder: structured decision tracing, a
//! metrics registry, and dispatch-loop self-profiling.
//!
//! The paper's headline quantity is *scheduler overhead* — the latency
//! the scheduler itself adds — but outcome quantiles can't say *why* a
//! given job waited. This layer records the individual decisions: the
//! `pick_next` branch taken, where a register routed, each backfill
//! admission/rejection with its reason, hold planning and clearing,
//! preemptions, pool dispatch/release/resize, fault-cascade steps, and
//! gateway routing/flush/steal traffic — into a bounded pre-allocated
//! ring ([`TraceRing`]) with per-kind counters and fixed-bucket
//! histograms ([`Registry`]) alongside.
//!
//! Design constraints, in order:
//!
//! 1. **Off is free.** The recorder lives behind `Option<Box<Obs>>`;
//!    with `None` every observation site is a single branch on the
//!    option, so recorder-off schedules and hot-path timings are the
//!    pre-PR ones (pinned by `event_equivalence` and the PR 6 bench
//!    bars).
//! 2. **On is invisible.** The recorder only observes — it draws no
//!    randomness and feeds nothing back — so recorder-on schedules are
//!    bit-for-bit the recorder-off ones (pinned by
//!    `rust/tests/obs_properties.rs`).
//! 3. **Deterministic bytes.** Host timestamps come from an injected
//!    [`MonoClock`] counter, never the wall clock, so same-seed trace
//!    exports are byte-identical. The only wall-clock numbers live in
//!    the opt-in self-profiling mode ([`ProfileAccum`]) and stay out
//!    of the ring and the determinism-pinned exports.

mod export;
mod registry;
pub mod spans;
pub mod timeline;
mod trace;

pub use export::{decision_log, perfetto_json, profile_lines};
pub use registry::{
    Histogram, Registry, DECISION_LATENCY_BOUNDS, QUEUE_DEPTH_BOUNDS, STEAL_HOPS_BOUNDS,
};
pub use spans::{reconstruct_spans, JobSpan, SpanSet, WaitBlame, BLAME_CAUSES};
pub use timeline::{
    build_timeline, perfetto_spans, timeline_csv, timeline_json, Timeline, TimelineBucket,
    FLEET_PID,
};
pub use trace::{MonoClock, Subsystem, TraceEvent, TraceKind, TraceRing};

use crate::sim::Time;

/// Self-profiling accumulator: host-side wall time spent inside
/// `pick_next` against the cost model's simulated charge for the same
/// decisions. Opt-in (`--profile`) because wall-clock numbers are the
/// one thing that may differ between same-seed runs.
#[derive(Debug, Clone, Copy, Default)]
pub struct ProfileAccum {
    /// `pick_next` invocations timed (including empty picks).
    pub picks: u64,
    /// Total host nanoseconds inside `pick_next`.
    pub host_ns: u64,
    /// Total simulated server charge (seconds) for the picked ops.
    pub sim_cost_s: f64,
}

impl ProfileAccum {
    /// Mean host nanoseconds per `pick_next` invocation.
    pub fn mean_host_ns(&self) -> f64 {
        if self.picks == 0 {
            0.0
        } else {
            self.host_ns as f64 / self.picks as f64
        }
    }
}

/// The flight recorder attached to one scheduler (or one gateway).
#[derive(Debug, Clone)]
pub struct Obs {
    ring: TraceRing,
    /// Counters + histograms, bumped alongside the ring.
    pub registry: Registry,
    clock: MonoClock,
    pid: u32,
    profile: Option<ProfileAccum>,
}

impl Obs {
    /// A recorder whose ring holds at most `cap` records, stamped with
    /// a fresh deterministic clock (1 µs per recorded event).
    pub fn new(cap: usize) -> Obs {
        Obs {
            ring: TraceRing::new(cap),
            registry: Registry::new(),
            clock: MonoClock::new(0, 1_000),
            pid: 0,
            profile: None,
        }
    }

    /// Tag every record with a federation instance id (the Perfetto
    /// process; gateways record under `instances`, one past the last).
    pub fn with_pid(mut self, pid: u32) -> Obs {
        self.pid = pid;
        self
    }

    /// Enable dispatch-loop self-profiling (wall-clock; opt-in).
    pub fn with_profiling(mut self) -> Obs {
        self.profile = Some(ProfileAccum::default());
        self
    }

    /// Whether self-profiling is on.
    pub fn profiling(&self) -> bool {
        self.profile.is_some()
    }

    /// Record one decision: bump its counter, stamp it with the
    /// deterministic host clock, append to the ring.
    #[inline]
    pub fn record(&mut self, kind: TraceKind, unit: u32, id: u64, t: Time, detail: i64) {
        let host_ns = self.clock.tick();
        self.registry.note_kind(kind);
        self.ring.push(TraceEvent { kind, pid: self.pid, unit, id, t, host_ns, detail });
    }

    /// Accumulate one timed `pick_next` invocation (no-op unless
    /// profiling is on).
    #[inline]
    pub fn profile_pick(&mut self, host_ns: u64, sim_cost_s: f64) {
        if let Some(p) = self.profile.as_mut() {
            p.picks += 1;
            p.host_ns += host_ns;
            p.sim_cost_s += sim_cost_s;
        }
    }

    /// Freeze the recorder into an immutable snapshot.
    pub fn snapshot(self) -> ObsSnapshot {
        let Obs { ring, registry, profile, .. } = self;
        let dropped = ring.dropped();
        ObsSnapshot { events: ring.into_ordered(), dropped, registry, profile }
    }
}

/// An immutable recorder snapshot: the surviving ring window (oldest
/// first), the drop counter, and the metrics registry. This is what
/// `SimOutcome` carries and what the exporters consume.
#[derive(Debug, Clone)]
pub struct ObsSnapshot {
    /// The ring's surviving window, oldest first.
    pub events: Vec<TraceEvent>,
    /// Records overwritten because the ring was full.
    pub dropped: u64,
    /// Counters + histograms for everything recorded (including
    /// overwritten records).
    pub registry: Registry,
    /// Self-profiling totals, when profiling was on.
    pub profile: Option<ProfileAccum>,
}

impl ObsSnapshot {
    /// Total decisions recorded (ring window + dropped).
    pub fn total_events(&self) -> u64 {
        self.registry.total()
    }

    /// Decisions recorded for one subsystem.
    pub fn subsystem_events(&self, sub: Subsystem) -> u64 {
        self.registry.subsystem_total(sub)
    }

    /// Subsystems with at least one recorded decision.
    pub fn subsystems_seen(&self) -> Vec<Subsystem> {
        Subsystem::ALL.into_iter().filter(|s| self.subsystem_events(*s) > 0).collect()
    }

    /// Merge per-instance snapshots (already pid-tagged at recorder
    /// construction) into one fleet snapshot: events interleaved in
    /// the total, documented `(sim time, pid, host_ns, seq)` order —
    /// `seq` being each event's position in the concatenation of the
    /// parts in iteration order — registries summed, profiles summed
    /// when any part carried one.
    ///
    /// The final `seq` tie-break matters: every recorder's injected
    /// [`MonoClock`] starts at the same origin, so two *different*
    /// parts carrying the same pid (e.g. re-merged snapshots) can
    /// collide on `(t, pid, host_ns)`. Without a total order the sort
    /// would be free to reorder such events between runs, breaking the
    /// byte-identical-exports pin and deterministic federated span
    /// reconstruction.
    pub fn merge<'a>(parts: impl IntoIterator<Item = &'a ObsSnapshot>) -> ObsSnapshot {
        let mut events: Vec<TraceEvent> = Vec::new();
        let mut dropped = 0;
        let mut registry = Registry::new();
        let mut profile: Option<ProfileAccum> = None;
        for part in parts {
            events.extend_from_slice(&part.events);
            dropped += part.dropped;
            registry.merge_from(&part.registry);
            if let Some(p) = part.profile {
                let acc = profile.get_or_insert_with(ProfileAccum::default);
                acc.picks += p.picks;
                acc.host_ns += p.host_ns;
                acc.sim_cost_s += p.sim_cost_s;
            }
        }
        let mut order: Vec<(usize, &TraceEvent)> = events.iter().enumerate().collect();
        order.sort_by(|(sa, a), (sb, b)| {
            a.t.total_cmp(&b.t)
                .then(a.pid.cmp(&b.pid))
                .then(a.host_ns.cmp(&b.host_ns))
                .then(sa.cmp(sb))
        });
        let events = order.into_iter().map(|(_, e)| *e).collect();
        ObsSnapshot { events, dropped, registry, profile }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_bumps_ring_and_registry_together() {
        let mut o = Obs::new(8);
        o.record(TraceKind::Pick, 2, 17, 1.5, 42);
        o.record(TraceKind::PoolDispatch, 0, 18, 2.0, 3);
        let s = o.snapshot();
        assert_eq!(s.events.len(), 2);
        assert_eq!(s.total_events(), 2);
        assert_eq!(s.subsystem_events(Subsystem::Scheduler), 1);
        assert_eq!(s.subsystem_events(Subsystem::Pool), 1);
        assert_eq!(s.events[0].host_ns, 0);
        assert_eq!(s.events[1].host_ns, 1_000, "injected clock, not wall time");
        assert_eq!(s.subsystems_seen(), vec![Subsystem::Scheduler, Subsystem::Pool]);
    }

    #[test]
    fn dropped_records_still_count() {
        let mut o = Obs::new(2);
        for i in 0..5 {
            o.record(TraceKind::Pick, 0, i, i as f64, 0);
        }
        let s = o.snapshot();
        assert_eq!(s.events.len(), 2);
        assert_eq!(s.dropped, 3);
        assert_eq!(s.total_events(), 5, "counters survive ring overwrites");
        assert_eq!(s.events.len() as u64 + s.dropped, s.total_events());
    }

    #[test]
    fn merge_order_is_total_when_events_share_a_timestamp() {
        // Two parts tagged with the same pid whose injected clocks both
        // start at 0: every event pair collides on (t, pid, host_ns),
        // so only the concatenation-index tie-break orders them. The
        // documented order is (t, pid, host_ns, seq) — part A's events
        // strictly before part B's — and it must be stable across
        // merges (the federated determinism regression).
        let mut a = Obs::new(8).with_pid(3);
        let mut b = Obs::new(8).with_pid(3);
        a.record(TraceKind::Pick, 0, 100, 1.0, 0);
        b.record(TraceKind::PoolDispatch, 0, 200, 1.0, 0);
        let (sa, sb) = (a.snapshot(), b.snapshot());
        let m1 = ObsSnapshot::merge([&sa, &sb]);
        let m2 = ObsSnapshot::merge([&sa, &sb]);
        let ids: Vec<u64> = m1.events.iter().map(|e| e.id).collect();
        assert_eq!(ids, vec![100, 200], "concatenation order breaks the tie");
        assert_eq!(
            m1.events, m2.events,
            "same parts, same order — merge is deterministic"
        );
        // And NaN-free totality: total_cmp never panics and never
        // reports Equal for distinct times.
        let order: Vec<f64> = m1.events.iter().map(|e| e.t).collect();
        assert!(order.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn merge_interleaves_by_time_then_pid() {
        let mut a = Obs::new(8).with_pid(1);
        let mut b = Obs::new(8).with_pid(0);
        a.record(TraceKind::Pick, 0, 1, 2.0, 0);
        a.record(TraceKind::Pick, 0, 2, 5.0, 0);
        b.record(TraceKind::GatewayRoute, 1, 3, 2.0, 0);
        let (sa, sb) = (a.snapshot(), b.snapshot());
        let m = ObsSnapshot::merge([&sa, &sb]);
        let order: Vec<(f64, u32)> = m.events.iter().map(|e| (e.t, e.pid)).collect();
        assert_eq!(order, vec![(2.0, 0), (2.0, 1), (5.0, 1)]);
        assert_eq!(m.total_events(), 3);
    }
}
