//! Exporters for flight-recorder snapshots: Chrome-trace/Perfetto
//! JSON, a plain-text decision log, and the self-profiling report.
//!
//! Both exports are deterministic functions of the snapshot — stable
//! field order, stable event order, no wall-clock anywhere — so
//! same-seed runs produce byte-identical files (pinned by
//! `rust/tests/obs_properties.rs`).

use super::{ObsSnapshot, ProfileAccum, Subsystem, TraceEvent};
use crate::util::json::Json;

fn keep(ev: &TraceEvent, filter: Option<&[Subsystem]>) -> bool {
    filter.is_none_or(|f| f.contains(&ev.kind.subsystem()))
}

/// Render a snapshot as Chrome-trace/Perfetto JSON (the "JSON object
/// format"): one instant event per record (`ph: "i"`, thread-scoped),
/// `ts` in microseconds of simulated time, `pid` = federation
/// instance, `tid` = subsystem, with unit/id/detail/host_ns in `args`.
/// Process/thread-name metadata events come first so Perfetto labels
/// the tracks.
pub fn perfetto_json(snap: &ObsSnapshot, filter: Option<&[Subsystem]>) -> Json {
    let kept: Vec<&TraceEvent> = snap.events.iter().filter(|e| keep(e, filter)).collect();

    let mut pids: Vec<u32> = kept.iter().map(|e| e.pid).collect();
    pids.sort_unstable();
    pids.dedup();
    let mut tracks: Vec<(u32, Subsystem)> =
        kept.iter().map(|e| (e.pid, e.kind.subsystem())).collect();
    tracks.sort_unstable_by_key(|&(pid, sub)| (pid, sub.index()));
    tracks.dedup();

    let mut events: Vec<Json> = Vec::with_capacity(kept.len() + pids.len() + tracks.len());
    for pid in &pids {
        events.push(
            Json::obj()
                .set("name", "process_name")
                .set("ph", "M")
                .set("pid", u64::from(*pid))
                .set("args", Json::obj().set("name", format!("instance {pid}"))),
        );
    }
    for (pid, sub) in &tracks {
        events.push(
            Json::obj()
                .set("name", "thread_name")
                .set("ph", "M")
                .set("pid", u64::from(*pid))
                .set("tid", sub.index() as u64)
                .set("args", Json::obj().set("name", sub.name())),
        );
    }
    for ev in kept {
        events.push(
            Json::obj()
                .set("name", ev.kind.name())
                .set("ph", "i")
                .set("s", "t")
                .set("ts", ev.t * 1e6)
                .set("pid", u64::from(ev.pid))
                .set("tid", ev.kind.subsystem().index() as u64)
                .set(
                    "args",
                    Json::obj()
                        .set("unit", u64::from(ev.unit))
                        .set("id", ev.id)
                        .set("detail", ev.detail)
                        .set("host_ns", ev.host_ns),
                ),
        );
    }

    Json::obj()
        .set("displayTimeUnit", "ms")
        .set("traceEvents", Json::Arr(events))
        .set(
            "metadata",
            Json::obj()
                .set("recorded", snap.total_events())
                .set("dropped", snap.dropped)
                .set("exported", kept_count(snap, filter)),
        )
}

fn kept_count(snap: &ObsSnapshot, filter: Option<&[Subsystem]>) -> u64 {
    snap.events.iter().filter(|e| keep(e, filter)).count() as u64
}

/// Render a snapshot as a human-readable decision log, one line per
/// record, oldest first:
///
/// ```text
/// [    1.234567] p0  pool       pool_dispatch   unit=3          id=1042     detail=17 host_ns=52000
/// ```
pub fn decision_log(snap: &ObsSnapshot, filter: Option<&[Subsystem]>) -> String {
    let mut out = String::new();
    for ev in snap.events.iter().filter(|e| keep(e, filter)) {
        let unit =
            if ev.unit == u32::MAX { "-".to_string() } else { ev.unit.to_string() };
        out.push_str(&format!(
            "[{:>12.6}] p{:<2} {:<10} {:<15} unit={:<10} id={:<8} detail={} host_ns={}\n",
            ev.t,
            ev.pid,
            ev.kind.subsystem().name(),
            ev.kind.name(),
            unit,
            ev.id,
            ev.detail,
            ev.host_ns,
        ));
    }
    if snap.dropped > 0 {
        out.push_str(&format!(
            "# ring dropped {} older record(s); raise --trace-cap to keep more\n",
            snap.dropped
        ));
    }
    out
}

/// The self-profiling report: host-side `pick_next` cost against the
/// cost model's simulated charge for the same decisions.
pub fn profile_lines(p: &ProfileAccum) -> Vec<String> {
    let host_s = p.host_ns as f64 / 1e9;
    let ratio = if host_s > 0.0 { p.sim_cost_s / host_s } else { f64::NAN };
    vec![
        format!("pick_next invocations     {}", p.picks),
        format!("host time in pick_next    {:.3} ms total, {:.0} ns mean", host_s * 1e3, p.mean_host_ns()),
        format!("simulated charge picked   {:.6} s", p.sim_cost_s),
        format!("simulated-vs-host ratio   {:.1}x", ratio),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{Obs, TraceKind};

    fn sample() -> ObsSnapshot {
        let mut o = Obs::new(16);
        o.record(TraceKind::Pick, 2, 7, 0.5, 1200);
        o.record(TraceKind::PoolDispatch, 0, 8, 1.0, 3);
        o.record(TraceKind::GatewayFlush, 1, 1, 1.5, 4);
        o.snapshot()
    }

    #[test]
    fn perfetto_export_has_metadata_and_instants() {
        let s = sample();
        let text = perfetto_json(&s, None).to_pretty();
        assert!(text.contains("\"traceEvents\""));
        assert!(text.contains("\"process_name\""));
        assert!(text.contains("\"thread_name\""));
        assert!(text.contains("\"pool_dispatch\""));
        assert!(text.contains("\"host_ns\""));
        // Deterministic: same snapshot renders the same bytes.
        assert_eq!(text, perfetto_json(&s, None).to_pretty());
    }

    #[test]
    fn filter_keeps_listed_subsystems() {
        let s = sample();
        let text = perfetto_json(&s, Some(&[Subsystem::Pool])).to_pretty();
        assert!(text.contains("pool_dispatch"));
        assert!(!text.contains("gateway_flush"));
        let log = decision_log(&s, Some(&[Subsystem::Federation]));
        assert_eq!(log.lines().count(), 1);
        assert!(log.contains("gateway_flush"));
        // A two-subsystem list keeps both and drops the rest.
        let both = decision_log(&s, Some(&[Subsystem::Pool, Subsystem::Federation]));
        assert_eq!(both.lines().count(), 2);
        assert!(both.contains("pool_dispatch") && both.contains("gateway_flush"));
        assert!(!both.contains(" pick "));
    }

    #[test]
    fn decision_log_reports_drops() {
        let mut o = Obs::new(1);
        o.record(TraceKind::Pick, 0, 1, 0.0, 0);
        o.record(TraceKind::Pick, 0, 2, 1.0, 0);
        let log = decision_log(&o.snapshot(), None);
        assert!(log.contains("dropped 1 older record"));
    }
}
