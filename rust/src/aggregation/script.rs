//! Per-node execution-script generation.
//!
//! "This node-based scheduling approach generates a job execution script
//! per each node on the fly in such a way that all of the compute tasks to
//! be executed on the same node are aggregated as a single scheduling task
//! … we have also implemented explicit control of the process affinity and
//! the number of threads of all the compute tasks" (§II).
//!
//! The generator emits real POSIX shell: one worker loop per core, pinned
//! with `taskset -c`, thread counts exported, tasks consumed from a
//! contiguous global index range. The same script structure drives the
//! real executor ([`crate::exec`]), which parses the plan (not the shell)
//! and applies the identical pinning with `sched_setaffinity`.

use crate::cluster::affinity::CoreMask;

/// The per-core lane of a node script: which core, which task range.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Lane {
    /// Core index on the node this lane is pinned to.
    pub core: u32,
    /// Global compute-task index range `[start, end)` for this lane.
    pub start: u64,
    pub end: u64,
}

impl Lane {
    pub fn count(&self) -> u64 {
        self.end - self.start
    }
}

/// A generated node script: structured plan + rendered shell text.
#[derive(Debug, Clone)]
pub struct NodeScript {
    /// Node-local sequence number within the job (array index).
    pub node_index: u32,
    /// Threads each compute process may use (triples mode's third knob).
    pub threads_per_process: u32,
    /// Per-core lanes.
    pub lanes: Vec<Lane>,
}

impl NodeScript {
    /// Total compute tasks this node runs.
    pub fn total_tasks(&self) -> u64 {
        self.lanes.iter().map(Lane::count).sum()
    }

    /// The affinity mask covering all lanes.
    pub fn mask(&self, cores_per_node: u32) -> CoreMask {
        let mut m = CoreMask::empty(cores_per_node);
        for l in &self.lanes {
            m.set(l.core);
        }
        m
    }

    /// Render the actual shell script (what would be submitted to Slurm as
    /// the array task's batch script).
    pub fn render(&self, task_cmd: &str) -> String {
        let mut s = String::new();
        s.push_str("#!/bin/bash\n");
        s.push_str(&format!(
            "# llsched node-based execution script — array index {}\n",
            self.node_index
        ));
        s.push_str("# generated on the fly: one pinned worker loop per core\n");
        s.push_str(&format!(
            "export OMP_NUM_THREADS={}\n",
            self.threads_per_process
        ));
        s.push_str(&format!(
            "export LLSCHED_NODE_INDEX={}\n\n",
            self.node_index
        ));
        for lane in &self.lanes {
            if lane.count() == 0 {
                continue;
            }
            s.push_str(&format!(
                "( for TASK_ID in $(seq {} {}); do\n",
                lane.start,
                lane.end - 1
            ));
            s.push_str(&format!(
                "    taskset -c {} {} \"$TASK_ID\" || echo \"task $TASK_ID failed\" >&2\n",
                lane.core, task_cmd
            ));
            s.push_str("  done ) &\n");
        }
        s.push_str("\nwait\n");
        s
    }
}

/// Build the node scripts for a job of `total` compute tasks over
/// `nodes` × `cores_per_node`, assigning contiguous index ranges
/// core-major within each node (node 0 gets the first block, etc.).
pub fn build_scripts(
    total: u64,
    nodes: u32,
    cores_per_node: u32,
    threads_per_process: u32,
) -> Vec<NodeScript> {
    let per_node = crate::aggregation::plan::split_even(total, nodes as u64);
    let mut scripts = Vec::with_capacity(nodes as usize);
    let mut next = 0u64;
    for (ni, &n_tasks) in per_node.iter().enumerate() {
        let per_core = crate::aggregation::plan::split_even(n_tasks, cores_per_node as u64);
        let mut lanes = Vec::with_capacity(cores_per_node as usize);
        for (ci, &c_tasks) in per_core.iter().enumerate() {
            lanes.push(Lane {
                core: ci as u32,
                start: next,
                end: next + c_tasks,
            });
            next += c_tasks;
        }
        scripts.push(NodeScript {
            node_index: ni as u32,
            threads_per_process,
            lanes,
        });
    }
    scripts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scripts_cover_all_tasks_without_overlap() {
        let scripts = build_scripts(1000, 4, 64, 1);
        assert_eq!(scripts.len(), 4);
        let mut seen = vec![false; 1000];
        for s in &scripts {
            for l in &s.lanes {
                for t in l.start..l.end {
                    assert!(!seen[t as usize], "task {t} double-assigned");
                    seen[t as usize] = true;
                }
            }
        }
        assert!(seen.iter().all(|&x| x), "every task assigned");
    }

    #[test]
    fn lanes_balanced_within_one() {
        let scripts = build_scripts(7_864_320, 512, 64, 1);
        for s in &scripts {
            let counts: Vec<u64> = s.lanes.iter().map(Lane::count).collect();
            let min = *counts.iter().min().unwrap();
            let max = *counts.iter().max().unwrap();
            assert!(max - min <= 1, "unbalanced lanes {min}..{max}");
            assert_eq!(s.total_tasks(), 15360); // 240 × 64
        }
    }

    #[test]
    fn mask_covers_used_cores_only() {
        // 10 tasks over 1 node × 64 cores: 10 lanes used, 54 empty.
        let scripts = build_scripts(10, 1, 64, 1);
        let m = scripts[0].mask(64);
        // All 64 lanes exist but empty ones still list a core; the mask
        // includes every lane's core — empty lanes have count 0.
        assert_eq!(m.count(), 64);
        let busy: u64 = scripts[0].lanes.iter().filter(|l| l.count() > 0).count() as u64;
        assert_eq!(busy, 10);
    }

    #[test]
    fn render_contains_pinning_and_wait() {
        let scripts = build_scripts(8, 1, 4, 2);
        let text = scripts[0].render("./sim_task");
        assert!(text.starts_with("#!/bin/bash"));
        assert!(text.contains("OMP_NUM_THREADS=2"));
        assert!(text.contains("taskset -c 0 ./sim_task"));
        assert!(text.contains("taskset -c 3 ./sim_task"));
        assert!(text.contains("seq 0 1"), "lane 0 runs tasks 0..2: {text}");
        assert!(text.trim_end().ends_with("wait"));
    }

    #[test]
    fn empty_lanes_render_no_loops() {
        let scripts = build_scripts(2, 1, 4, 1);
        let text = scripts[0].render("cmd");
        // Only two worker loops.
        assert_eq!(text.matches("for TASK_ID").count(), 2);
    }

    #[test]
    fn node_index_stamped() {
        let scripts = build_scripts(100, 3, 4, 1);
        for (i, s) in scripts.iter().enumerate() {
            assert_eq!(s.node_index, i as u32);
            assert!(s.render("c").contains(&format!("LLSCHED_NODE_INDEX={i}")));
        }
    }
}
