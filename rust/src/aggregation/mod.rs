//! Task aggregation — the paper's contribution.
//!
//! A user workload is a set of *compute tasks*. An aggregator decides how
//! they are packed into *scheduling tasks*, which is what the scheduler
//! places, tracks and cleans up:
//!
//! * [`per_task::PerTask`] — 1 scheduling task per compute task (naive
//!   baseline; what a plain array job does),
//! * [`multi_level::MultiLevel`] — 1 scheduling task per physical core;
//!   all compute tasks bound for that core run in a loop inside it
//!   (LLMapReduce MIMO, the paper's "M*" comparison point),
//! * [`node_based::NodeBased`] — 1 scheduling task per *node*; all compute
//!   tasks bound for the node's cores are wrapped in a generated
//!   execution script with explicit per-process core pinning and thread
//!   counts (the paper's "N*" contribution, a.k.a. triples mode).
//!
//! The aggregation is explicit and algorithmic ("because this aggregation
//! is done explicitly and algorithmically, we can design how we want to
//! manage the compute tasks" — §II), so the same plans drive both the DES
//! (virtual time) and the real executor (actual processes, real pinning).

pub mod multi_level;
pub mod node_based;
pub mod per_task;
pub mod plan;
pub mod script;
pub mod triples;

pub use multi_level::MultiLevel;
pub use node_based::NodeBased;
pub use per_task::PerTask;
pub use plan::{Aggregator, ClusterShape, Workload};
pub use script::NodeScript;
pub use triples::Triple;

use crate::config::Mode;

/// Construct the aggregator for a mode.
pub fn for_mode(mode: Mode) -> Box<dyn Aggregator> {
    match mode {
        Mode::PerTask => Box::new(PerTask),
        Mode::MultiLevel => Box::new(MultiLevel),
        Mode::NodeBased => Box::new(NodeBased::default()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn factory_maps_modes() {
        assert_eq!(for_mode(Mode::PerTask).mode(), Mode::PerTask);
        assert_eq!(for_mode(Mode::MultiLevel).mode(), Mode::MultiLevel);
        assert_eq!(for_mode(Mode::NodeBased).mode(), Mode::NodeBased);
    }

    #[test]
    fn modes_name_their_placement_defaults() {
        use crate::placement::Strategy;
        assert_eq!(for_mode(Mode::PerTask).default_strategy(), Strategy::FirstFit);
        assert_eq!(for_mode(Mode::MultiLevel).default_strategy(), Strategy::FirstFit);
        assert_eq!(for_mode(Mode::NodeBased).default_strategy(), Strategy::NodeBased);
    }
}
