//! Naive baseline: one scheduling task per compute task.
//!
//! This is what a plain Slurm array job does and the reason short-running
//! jobs are "inefficient due to the overhead associated with the life
//! cycles of the jobs" (paper §I). With 1 s tasks at 512-node scale this
//! means ~7.9 M scheduling tasks — the ablation benches show the scheduler
//! drowning long before that.

use crate::aggregation::plan::{Aggregator, ClusterShape, Workload};
use crate::config::Mode;
use crate::error::Result;
use crate::placement::Strategy;
use crate::scheduler::job::{ComputeBatch, JobSpec, ResourceRequest, SchedTaskSpec};

/// The 1:1 aggregator.
#[derive(Debug, Default, Clone, Copy)]
pub struct PerTask;

impl Aggregator for PerTask {
    fn mode(&self) -> Mode {
        Mode::PerTask
    }

    /// One single-core request per compute task: indexed first-fit,
    /// matching what the naive array job got from the linear scan.
    fn default_strategy(&self) -> Strategy {
        Strategy::FirstFit
    }

    fn plan(&self, name: &str, workload: &Workload, shape: &ClusterShape) -> Result<JobSpec> {
        workload.validate()?;
        let tasks = (0..workload.count())
            .map(|i| {
                let d = workload.duration(i);
                SchedTaskSpec {
                    request: ResourceRequest::Cores {
                        cores: 1,
                        mem_mib: shape.task_mem_mib,
                    },
                    duration: d,
                    batch: ComputeBatch { count: 1, each: d },
                    lanes: 1,
                }
            })
            .collect();
        Ok(JobSpec {
            name: name.to_string(),
            tasks,
            reservation: None,
            priority: 0,
            preemptable: false,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape() -> ClusterShape {
        ClusterShape { nodes: 2, cores_per_node: 64, task_mem_mib: 512 }
    }

    #[test]
    fn one_sched_task_per_compute_task() {
        let w = Workload::Uniform { count: 100, duration: 5.0 };
        let job = PerTask.plan("naive", &w, &shape()).unwrap();
        assert_eq!(job.array_size(), 100);
        assert_eq!(job.total_compute_tasks(), 100);
        for t in &job.tasks {
            assert_eq!(t.duration, 5.0);
            assert_eq!(t.request, ResourceRequest::Cores { cores: 1, mem_mib: 512 });
        }
    }

    #[test]
    fn explicit_durations_pass_through() {
        let w = Workload::Explicit(vec![1.0, 2.0, 4.0]);
        let job = PerTask.plan("naive", &w, &shape()).unwrap();
        let durs: Vec<f64> = job.tasks.iter().map(|t| t.duration).collect();
        assert_eq!(durs, vec![1.0, 2.0, 4.0]);
    }

    #[test]
    fn empty_rejected() {
        assert!(PerTask
            .plan("naive", &Workload::Explicit(vec![]), &shape())
            .is_err());
    }
}
