//! The triples-mode resource specification.
//!
//! Node-based scheduling is "also termed 'triples mode'" (§I): the user
//! gives `(n_nodes, processes_per_node, threads_per_process)` and the
//! launch tools translate it into whole-node scheduling tasks with
//! explicit affinity. This module is the typed form of that triple.

use crate::error::{Error, Result};

/// `(nodes, ppn, tpp)` — the LLsub/LLMapReduce triples-mode argument.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Triple {
    /// Number of whole nodes to allocate.
    pub nodes: u32,
    /// Processes (compute-task workers) per node.
    pub processes_per_node: u32,
    /// Threads each process may use.
    pub threads_per_process: u32,
}

impl Triple {
    /// Parse the `[N,P,T]` / `N,P,T` / `NxPxT` forms used on the CLI.
    pub fn parse(s: &str) -> Result<Triple> {
        let cleaned = s.trim().trim_start_matches('[').trim_end_matches(']');
        let parts: Vec<&str> = if cleaned.contains(',') {
            cleaned.split(',').collect()
        } else {
            cleaned.split('x').collect()
        };
        if parts.len() != 3 {
            return Err(Error::Config(format!(
                "triple {s:?}: expected three comma- or x-separated fields"
            )));
        }
        let nums: Result<Vec<u32>> = parts
            .iter()
            .map(|p| {
                p.trim()
                    .parse::<u32>()
                    .map_err(|_| Error::Config(format!("triple {s:?}: bad number {p:?}")))
            })
            .collect();
        let n = nums?;
        let t = Triple {
            nodes: n[0],
            processes_per_node: n[1],
            threads_per_process: n[2],
        };
        t.validate(u32::MAX)?;
        Ok(t)
    }

    /// Check the triple fits a node with `cores_per_node` cores
    /// (ppn × tpp must not oversubscribe the node).
    pub fn validate(&self, cores_per_node: u32) -> Result<()> {
        if self.nodes == 0 || self.processes_per_node == 0 || self.threads_per_process == 0 {
            return Err(Error::Config("triple fields must be positive".into()));
        }
        let per_node = self.processes_per_node as u64 * self.threads_per_process as u64;
        if per_node > cores_per_node as u64 {
            return Err(Error::Config(format!(
                "triple oversubscribes node: {} procs × {} threads > {} cores",
                self.processes_per_node, self.threads_per_process, cores_per_node
            )));
        }
        Ok(())
    }

    /// Total worker processes across the allocation.
    pub fn total_processes(&self) -> u64 {
        self.nodes as u64 * self.processes_per_node as u64
    }

    /// The canonical triples mode for the paper's benchmarks: fill every
    /// core with a single-threaded worker.
    pub fn fill(nodes: u32, cores_per_node: u32) -> Triple {
        Triple {
            nodes,
            processes_per_node: cores_per_node,
            threads_per_process: 1,
        }
    }
}

impl std::fmt::Display for Triple {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "[{},{},{}]",
            self.nodes, self.processes_per_node, self.threads_per_process
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_forms() {
        let want = Triple { nodes: 32, processes_per_node: 64, threads_per_process: 1 };
        assert_eq!(Triple::parse("[32,64,1]").unwrap(), want);
        assert_eq!(Triple::parse("32,64,1").unwrap(), want);
        assert_eq!(Triple::parse("32x64x1").unwrap(), want);
        assert_eq!(Triple::parse(" [ 32 , 64 , 1 ] ").unwrap(), want);
    }

    #[test]
    fn parse_errors() {
        assert!(Triple::parse("32,64").is_err());
        assert!(Triple::parse("a,b,c").is_err());
        assert!(Triple::parse("0,1,1").is_err());
        assert!(Triple::parse("").is_err());
    }

    #[test]
    fn oversubscription_rejected() {
        let t = Triple { nodes: 1, processes_per_node: 32, threads_per_process: 4 };
        assert!(t.validate(64).is_err());
        assert!(t.validate(128).is_ok());
    }

    #[test]
    fn fill_and_totals() {
        let t = Triple::fill(512, 64);
        assert_eq!(t.total_processes(), 32_768);
        assert_eq!(t.to_string(), "[512,64,1]");
        t.validate(64).unwrap();
    }

    #[test]
    fn threads_trade_against_processes() {
        // 16 procs × 4 threads fills a 64-core node exactly.
        let t = Triple { nodes: 2, processes_per_node: 16, threads_per_process: 4 };
        t.validate(64).unwrap();
        assert_eq!(t.total_processes(), 32);
    }
}
