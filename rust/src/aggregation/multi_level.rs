//! Multi-level scheduling (LLMapReduce MIMO) — the paper's comparison
//! point, "M*".
//!
//! "Aggregates all the compute tasks to be executed on the same physical
//! core as a single scheduling task by packing all individual compute
//! tasks in a loop" (§II). The scheduler therefore sees one scheduling
//! task per *processor*: P = nodes × cores_per_node tasks (Table II:
//! 2048 … 32768).

use crate::aggregation::plan::{split_even, Aggregator, ClusterShape, Workload};
use crate::config::Mode;
use crate::error::Result;
use crate::placement::Strategy;
use crate::scheduler::job::{ComputeBatch, JobSpec, ResourceRequest, SchedTaskSpec};

/// The per-core aggregator.
#[derive(Debug, Default, Clone, Copy)]
pub struct MultiLevel;

impl Aggregator for MultiLevel {
    fn mode(&self) -> Mode {
        Mode::MultiLevel
    }

    /// Per-core requests go through the index's first-fit query: the
    /// same lowest-node-first packing as the historical scan, answered
    /// from the free-core buckets instead of an O(N) walk.
    fn default_strategy(&self) -> Strategy {
        Strategy::FirstFit
    }

    fn plan(&self, name: &str, workload: &Workload, shape: &ClusterShape) -> Result<JobSpec> {
        workload.validate()?;
        let processors = shape.processors();
        let counts = split_even(workload.count(), processors);
        let mut tasks = Vec::with_capacity(processors as usize);
        let mut next = 0u64; // contiguous block assignment, like MIMO's loop
        for &n in &counts {
            if n == 0 {
                continue; // fewer tasks than processors: idle cores get none
            }
            let duration: f64 = match workload {
                Workload::Uniform { duration, .. } => n as f64 * duration,
                Workload::Explicit(v) => {
                    v[next as usize..(next + n) as usize].iter().sum()
                }
            };
            let each = duration / n as f64;
            tasks.push(SchedTaskSpec {
                request: ResourceRequest::Cores {
                    cores: 1,
                    mem_mib: shape.task_mem_mib,
                },
                duration,
                batch: ComputeBatch { count: n, each },
                lanes: 1,
            });
            next += n;
        }
        Ok(JobSpec {
            name: name.to_string(),
            tasks,
            reservation: None,
            priority: 0,
            preemptable: false,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape(nodes: u32) -> ClusterShape {
        ClusterShape { nodes, cores_per_node: 64, task_mem_mib: 512 }
    }

    #[test]
    fn one_sched_task_per_processor() {
        // Paper Table I long config on 32 nodes: 2048 processors × 4 tasks.
        let w = Workload::paper(2048, 60.0, 240.0);
        let job = MultiLevel.plan("mimo", &w, &shape(32)).unwrap();
        assert_eq!(job.array_size(), 2048);
        assert_eq!(job.total_compute_tasks(), 8192);
        for t in &job.tasks {
            assert_eq!(t.duration, 240.0, "each core does T_job of work");
            assert_eq!(t.batch.count, 4);
        }
    }

    #[test]
    fn rapid_config_packs_240_per_core() {
        let w = Workload::paper(2048, 1.0, 240.0);
        let job = MultiLevel.plan("mimo", &w, &shape(32)).unwrap();
        assert_eq!(job.array_size(), 2048);
        assert!(job.tasks.iter().all(|t| t.batch.count == 240));
        assert!(job.tasks.iter().all(|t| (t.duration - 240.0).abs() < 1e-9));
    }

    #[test]
    fn work_is_conserved() {
        let w = Workload::Uniform { count: 10_000, duration: 3.0 };
        let job = MultiLevel.plan("mimo", &w, &shape(2)).unwrap();
        let total: f64 = job.tasks.iter().map(|t| t.duration).sum();
        assert!((total - 30_000.0).abs() < 1e-6);
        assert_eq!(job.total_compute_tasks(), 10_000);
    }

    #[test]
    fn explicit_workload_contiguous_blocks() {
        let durs: Vec<f64> = (1..=8).map(|i| i as f64).collect();
        let tiny = ClusterShape { nodes: 1, cores_per_node: 4, task_mem_mib: 0 };
        let job = MultiLevel.plan("mimo", &Workload::Explicit(durs), &tiny).unwrap();
        assert_eq!(job.array_size(), 4);
        // blocks [1,2], [3,4], [5,6], [7,8] → sums 3, 7, 11, 15
        let sums: Vec<f64> = job.tasks.iter().map(|t| t.duration).collect();
        assert_eq!(sums, vec![3.0, 7.0, 11.0, 15.0]);
    }

    #[test]
    fn fewer_tasks_than_processors_drops_empty_slots() {
        let w = Workload::Uniform { count: 10, duration: 1.0 };
        let job = MultiLevel.plan("mimo", &w, &shape(32)).unwrap();
        assert_eq!(job.array_size(), 10, "only non-empty scheduling tasks");
    }
}
