//! Node-based scheduling ("triples mode") — the paper's contribution, "N*".
//!
//! All compute tasks bound for one node become a *single* scheduling task
//! requesting the whole node; a generated execution script (see
//! [`crate::aggregation::script`]) runs one pinned worker loop per core.
//! The scheduler therefore sees `nodes` scheduling tasks instead of
//! `nodes × cores` (multi-level) or `total_tasks` (naive): at the paper's
//! largest scale this is 512 instead of 32768 or 7.9 M.

use crate::aggregation::plan::{split_even, Aggregator, ClusterShape, Workload};
use crate::aggregation::script::{build_scripts, NodeScript};
use crate::aggregation::triples::Triple;
use crate::config::Mode;
use crate::error::Result;
use crate::placement::Strategy;
use crate::scheduler::job::{ComputeBatch, JobSpec, ResourceRequest, SchedTaskSpec};

/// The per-node aggregator.
#[derive(Debug, Clone, Copy)]
pub struct NodeBased {
    /// Threads per worker process (triples-mode third knob).
    pub threads_per_process: u32,
}

impl Default for NodeBased {
    fn default() -> Self {
        NodeBased { threads_per_process: 1 }
    }
}

impl NodeBased {
    /// Construct from a triple; `threads_per_process` is carried into the
    /// generated scripts.
    pub fn from_triple(t: &Triple) -> NodeBased {
        NodeBased { threads_per_process: t.threads_per_process }
    }

    /// Generate the node scripts for a workload (exposed for the launch
    /// tools, the real executor and the examples).
    pub fn scripts(&self, workload: &Workload, shape: &ClusterShape) -> Vec<NodeScript> {
        build_scripts(
            workload.count(),
            shape.nodes,
            shape.cores_per_node,
            self.threads_per_process,
        )
    }
}

impl Aggregator for NodeBased {
    fn mode(&self) -> Mode {
        Mode::NodeBased
    }

    /// Whole-node requests route through the placement index's idle
    /// pool — the O(log n) pop that gives the simulator's own dispatch
    /// the paper's node-vs-task asymptotics.
    fn default_strategy(&self) -> Strategy {
        Strategy::NodeBased
    }

    fn plan(&self, name: &str, workload: &Workload, shape: &ClusterShape) -> Result<JobSpec> {
        workload.validate()?;
        let per_node = split_even(workload.count(), shape.nodes as u64);
        let mut tasks = Vec::with_capacity(shape.nodes as usize);
        let mut next = 0u64;
        for &n_tasks in &per_node {
            if n_tasks == 0 {
                continue;
            }
            // The node task occupies the node until its slowest core lane
            // drains: duration = max over lanes of the lane's serial work.
            let lane_counts = split_even(n_tasks, shape.cores_per_node as u64);
            let duration = match workload {
                Workload::Uniform { duration, .. } => {
                    lane_counts.iter().copied().max().unwrap_or(0) as f64 * duration
                }
                Workload::Explicit(v) => {
                    // Contiguous assignment lane by lane, mirroring
                    // build_scripts.
                    let mut lane_start = next;
                    let mut max_lane = 0.0f64;
                    for &c in &lane_counts {
                        let sum: f64 =
                            v[lane_start as usize..(lane_start + c) as usize].iter().sum();
                        max_lane = max_lane.max(sum);
                        lane_start += c;
                    }
                    max_lane
                }
            };
            let each = if n_tasks > 0 {
                workload_mean(workload, next, n_tasks)
            } else {
                0.0
            };
            tasks.push(SchedTaskSpec {
                request: ResourceRequest::WholeNode,
                duration,
                batch: ComputeBatch {
                    count: n_tasks / shape.cores_per_node as u64,
                    each,
                },
                lanes: shape.cores_per_node,
            });
            next += n_tasks;
        }
        Ok(JobSpec {
            name: name.to_string(),
            tasks,
            reservation: None,
            priority: 0,
            preemptable: false,
        })
    }
}

fn workload_mean(w: &Workload, start: u64, count: u64) -> f64 {
    match w {
        Workload::Uniform { duration, .. } => *duration,
        Workload::Explicit(v) => {
            v[start as usize..(start + count) as usize].iter().sum::<f64>() / count as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape(nodes: u32) -> ClusterShape {
        ClusterShape { nodes, cores_per_node: 64, task_mem_mib: 512 }
    }

    #[test]
    fn one_sched_task_per_node() {
        // Paper: 512 nodes, rapid tasks → 512 scheduling tasks, not 7.9 M.
        let w = Workload::paper(32_768, 1.0, 240.0);
        let job = NodeBased::default().plan("triples", &w, &shape(512)).unwrap();
        assert_eq!(job.array_size(), 512);
        assert_eq!(job.total_compute_tasks(), 512 * 64 * 240);
        for t in &job.tasks {
            assert_eq!(t.request, ResourceRequest::WholeNode);
            assert!((t.duration - 240.0).abs() < 1e-9, "balanced lanes run T_job");
            assert_eq!(t.lanes, 64);
        }
    }

    #[test]
    fn duration_is_max_lane_not_sum() {
        // 65 tasks of 10 s on one 64-core node: one lane gets 2 tasks.
        let w = Workload::Uniform { count: 65, duration: 10.0 };
        let job = NodeBased::default().plan("t", &w, &shape(1)).unwrap();
        assert_eq!(job.array_size(), 1);
        assert_eq!(job.tasks[0].duration, 20.0);
    }

    #[test]
    fn explicit_durations_use_lane_assignment() {
        // 4-core node, 8 tasks: lanes get [10,1],[1,1],[1,1],[1,1] → max 11.
        let tiny = ClusterShape { nodes: 1, cores_per_node: 4, task_mem_mib: 0 };
        let w = Workload::Explicit(vec![10.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0]);
        let job = NodeBased::default().plan("t", &w, &tiny).unwrap();
        assert_eq!(job.tasks[0].duration, 11.0);
    }

    #[test]
    fn scripts_match_plan() {
        let w = Workload::paper(2048, 5.0, 240.0);
        let nb = NodeBased::default();
        let job = nb.plan("t", &w, &shape(32)).unwrap();
        let scripts = nb.scripts(&w, &shape(32));
        assert_eq!(scripts.len(), job.tasks.len());
        let total: u64 = scripts.iter().map(|s| s.total_tasks()).sum();
        assert_eq!(total, w.count());
    }

    #[test]
    fn threads_from_triple() {
        let t = Triple { nodes: 4, processes_per_node: 16, threads_per_process: 4 };
        let nb = NodeBased::from_triple(&t);
        let w = Workload::Uniform { count: 100, duration: 1.0 };
        let scripts = nb.scripts(&w, &shape(4));
        assert!(scripts.iter().all(|s| s.threads_per_process == 4));
    }

    #[test]
    fn fewer_tasks_than_nodes() {
        let w = Workload::Uniform { count: 3, duration: 2.0 };
        let job = NodeBased::default().plan("t", &w, &shape(8)).unwrap();
        assert_eq!(job.array_size(), 3, "empty nodes get no scheduling task");
        assert!(job.tasks.iter().all(|t| t.duration == 2.0));
    }
}
