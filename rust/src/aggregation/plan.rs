//! Workload descriptions and the [`Aggregator`] interface.

use crate::config::Mode;
use crate::error::{Error, Result};
use crate::placement::Strategy;
use crate::scheduler::job::JobSpec;

/// The compute tasks a user wants run.
#[derive(Debug, Clone)]
pub enum Workload {
    /// `count` identical tasks of `duration` seconds — the paper's
    /// constant-time benchmark tasks. Kept symbolic so 8-million-task
    /// workloads never materialize per-task state.
    Uniform { count: u64, duration: f64 },
    /// Explicit per-task durations (traces, real workloads).
    Explicit(Vec<f64>),
}

impl Workload {
    /// Number of compute tasks.
    pub fn count(&self) -> u64 {
        match self {
            Workload::Uniform { count, .. } => *count,
            Workload::Explicit(v) => v.len() as u64,
        }
    }

    /// Total serial work, seconds.
    pub fn total_work(&self) -> f64 {
        match self {
            Workload::Uniform { count, duration } => *count as f64 * duration,
            Workload::Explicit(v) => v.iter().sum(),
        }
    }

    /// Duration of task `i`.
    pub fn duration(&self, i: u64) -> f64 {
        match self {
            Workload::Uniform { duration, .. } => *duration,
            Workload::Explicit(v) => v[i as usize],
        }
    }

    /// The paper's Table I/II workload: fill `processors` cores with
    /// `t_job / task_time` tasks each.
    pub fn paper(processors: u64, task_time: f64, t_job: f64) -> Workload {
        let per_proc = (t_job / task_time).round() as u64;
        Workload::Uniform {
            count: processors * per_proc,
            duration: task_time,
        }
    }

    /// Validate.
    pub fn validate(&self) -> Result<()> {
        if self.count() == 0 {
            return Err(Error::Infeasible("empty workload".into()));
        }
        match self {
            Workload::Uniform { duration, .. } if *duration <= 0.0 => {
                Err(Error::Infeasible("non-positive task duration".into()))
            }
            Workload::Explicit(v) if v.iter().any(|d| *d <= 0.0) => {
                Err(Error::Infeasible("non-positive task duration".into()))
            }
            _ => Ok(()),
        }
    }
}

/// The slice of machine the job will be packed onto.
#[derive(Debug, Clone, Copy)]
pub struct ClusterShape {
    pub nodes: u32,
    pub cores_per_node: u32,
    /// Memory per compute task, MiB (per-core requests carry it; node
    /// requests take the whole node's memory — the paper notes node-based
    /// scheduling "allows for better usage of memory").
    pub task_mem_mib: u64,
}

impl ClusterShape {
    pub fn processors(&self) -> u64 {
        self.nodes as u64 * self.cores_per_node as u64
    }
}

/// An aggregation strategy: maps a workload onto scheduling tasks.
pub trait Aggregator {
    /// Which mode this implements.
    fn mode(&self) -> Mode;

    /// Build the job. The returned spec's scheduling tasks carry both the
    /// DES representation (durations, batch counts) and — for node-based —
    /// the generated execution script.
    fn plan(&self, name: &str, workload: &Workload, shape: &ClusterShape) -> Result<JobSpec>;

    /// The placement strategy this mode's jobs route through by default
    /// (used when the run config sets no explicit `placement`). The
    /// core-level modes keep the historical first-fit scan order;
    /// node-based overrides this with the idle-pool fast path.
    fn default_strategy(&self) -> Strategy {
        Strategy::FirstFit
    }
}

/// Split `count` items as evenly as possible over `bins` bins
/// (first `count % bins` bins get one extra). Returns per-bin counts.
pub fn split_even(count: u64, bins: u64) -> Vec<u64> {
    assert!(bins > 0);
    let base = count / bins;
    let extra = count % bins;
    (0..bins)
        .map(|i| base + if i < extra { 1 } else { 0 })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_workload_counts() {
        // 512 nodes × 64 cores, 1 s tasks, 240 s per processor.
        let w = Workload::paper(32_768, 1.0, 240.0);
        assert_eq!(w.count(), 7_864_320);
        assert_eq!(w.total_work(), 7_864_320.0);
        assert_eq!(w.duration(123), 1.0);
    }

    #[test]
    fn explicit_workload() {
        let w = Workload::Explicit(vec![1.0, 2.0, 3.0]);
        assert_eq!(w.count(), 3);
        assert_eq!(w.total_work(), 6.0);
        assert_eq!(w.duration(2), 3.0);
    }

    #[test]
    fn validation() {
        assert!(Workload::Uniform { count: 0, duration: 1.0 }.validate().is_err());
        assert!(Workload::Uniform { count: 1, duration: 0.0 }.validate().is_err());
        assert!(Workload::Explicit(vec![1.0, -2.0]).validate().is_err());
        assert!(Workload::Explicit(vec![]).validate().is_err());
        assert!(Workload::Uniform { count: 5, duration: 2.0 }.validate().is_ok());
    }

    #[test]
    fn split_even_distributes_remainder() {
        assert_eq!(split_even(10, 3), vec![4, 3, 3]);
        assert_eq!(split_even(9, 3), vec![3, 3, 3]);
        assert_eq!(split_even(2, 4), vec![1, 1, 0, 0]);
        let s = split_even(7_864_320, 32_768);
        assert!(s.iter().all(|&c| c == 240));
    }

    #[test]
    fn shape_processors() {
        let s = ClusterShape { nodes: 512, cores_per_node: 64, task_mem_mib: 512 };
        assert_eq!(s.processors(), 32_768);
    }
}
