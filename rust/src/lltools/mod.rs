//! User-facing launch tools, mirroring the MIT SuperCloud stack the paper
//! integrates node-based scheduling into:
//!
//! * [`llsub::LLsub`] — submit a command at a given scale, either as a
//!   classic array job or in triples mode (`LLsub cmd [Nnodes,PPN,TPP]`),
//! * [`llmapreduce::LLMapReduce`] — map a task list over the machine with
//!   MIMO (multi-level, per-core) aggregation, optionally with the
//!   `--triples` flag for node-based aggregation.
//!
//! Both tools produce ordinary [`crate::scheduler::job::JobSpec`]s, so they run unchanged against
//! the DES scheduler and the real executor.

pub mod llmapreduce;
pub mod llsub;

pub use llmapreduce::LLMapReduce;
pub use llsub::LLsub;
