//! `LLsub` — the general-purpose launcher.
//!
//! Classic mode submits an array of single-core scheduling tasks; triples
//! mode (`LLsub cmd [N,PPN,TPP]`) submits whole-node scheduling tasks with
//! generated pinning scripts — the paper's node-based path.

use crate::aggregation::plan::{Aggregator, ClusterShape, Workload};
use crate::aggregation::script::NodeScript;
use crate::aggregation::triples::Triple;
use crate::aggregation::{NodeBased, PerTask};
use crate::error::Result;
use crate::scheduler::job::JobSpec;

/// A prepared LLsub submission.
#[derive(Debug)]
pub struct Submission {
    pub job: JobSpec,
    /// Generated node scripts (triples mode only).
    pub scripts: Vec<NodeScript>,
}

/// The LLsub front end.
#[derive(Debug, Clone)]
pub struct LLsub {
    /// Command the workers run (recorded into generated scripts).
    pub command: String,
    /// Estimated duration of one invocation, seconds (used by the DES;
    /// the real executor measures actual durations).
    pub task_seconds: f64,
    /// Submit into a reservation.
    pub reservation: Option<String>,
    /// Job priority.
    pub priority: i32,
}

impl LLsub {
    pub fn new(command: &str, task_seconds: f64) -> LLsub {
        LLsub {
            command: command.to_string(),
            task_seconds,
            reservation: None,
            priority: 0,
        }
    }

    /// Classic array submission: `count` single-core tasks.
    pub fn array(&self, count: u64, shape: &ClusterShape) -> Result<Submission> {
        let w = Workload::Uniform { count, duration: self.task_seconds };
        let mut job = PerTask.plan(&format!("LLsub:{}", self.command), &w, shape)?;
        job.reservation = self.reservation.clone();
        job.priority = self.priority;
        Ok(Submission { job, scripts: vec![] })
    }

    /// Triples-mode submission: `[N,PPN,TPP]` → N whole-node scheduling
    /// tasks running N×PPN workers, with generated pinned scripts.
    pub fn triples(&self, triple: &Triple, shape: &ClusterShape) -> Result<Submission> {
        triple.validate(shape.cores_per_node)?;
        let count = triple.total_processes();
        let w = Workload::Uniform { count, duration: self.task_seconds };
        let run_shape = ClusterShape {
            nodes: triple.nodes,
            // PPN workers per node; each lane is one worker process.
            cores_per_node: triple.processes_per_node,
            task_mem_mib: shape.task_mem_mib,
        };
        let nb = NodeBased::from_triple(triple);
        let mut job = nb.plan(
            &format!("LLsub:{}:{}", self.command, triple),
            &w,
            &run_shape,
        )?;
        job.reservation = self.reservation.clone();
        job.priority = self.priority;
        let scripts = nb.scripts(&w, &run_shape);
        Ok(Submission { job, scripts })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::job::ResourceRequest;

    fn shape() -> ClusterShape {
        ClusterShape { nodes: 8, cores_per_node: 64, task_mem_mib: 256 }
    }

    #[test]
    fn array_mode_is_per_task() {
        let sub = LLsub::new("./sim", 5.0).array(100, &shape()).unwrap();
        assert_eq!(sub.job.array_size(), 100);
        assert!(sub.scripts.is_empty());
        assert!(sub.job.name.contains("./sim"));
    }

    #[test]
    fn triples_mode_is_node_based() {
        let t = Triple::fill(8, 64);
        let sub = LLsub::new("./sim", 5.0).triples(&t, &shape()).unwrap();
        assert_eq!(sub.job.array_size(), 8);
        assert_eq!(sub.scripts.len(), 8);
        assert!(sub
            .job
            .tasks
            .iter()
            .all(|x| x.request == ResourceRequest::WholeNode));
        // One worker per core, one task per worker.
        assert_eq!(sub.job.total_compute_tasks(), 512);
    }

    #[test]
    fn triples_respects_ppn() {
        // 2 nodes × 4 workers × 8 threads on 64-core nodes.
        let t = Triple { nodes: 2, processes_per_node: 4, threads_per_process: 8 };
        let sub = LLsub::new("cmd", 1.0).triples(&t, &shape()).unwrap();
        assert_eq!(sub.scripts.len(), 2);
        assert!(sub.scripts.iter().all(|s| s.threads_per_process == 8));
        assert_eq!(sub.scripts[0].lanes.len(), 4, "one lane per worker");
    }

    #[test]
    fn oversubscribed_triple_rejected() {
        let t = Triple { nodes: 1, processes_per_node: 64, threads_per_process: 2 };
        assert!(LLsub::new("c", 1.0).triples(&t, &shape()).is_err());
    }

    #[test]
    fn reservation_and_priority_carried() {
        let mut ll = LLsub::new("c", 1.0);
        ll.reservation = Some("bench".into());
        ll.priority = 7;
        let sub = ll.triples(&Triple::fill(2, 64), &shape()).unwrap();
        assert_eq!(sub.job.reservation.as_deref(), Some("bench"));
        assert_eq!(sub.job.priority, 7);
    }
}
