//! `LLMapReduce` — map a list of inputs over the machine.
//!
//! MIMO mode ("Multi-Input, Multi-Output") aggregates per core — the
//! multi-level scheduling the paper compares against. The `triples` flag
//! switches to node-based aggregation on top of the same MIMO packing
//! ("the node-based scheduling approach is an expansion of aggregation by
//! node on top of the core-based aggregation done by the multi-level
//! scheduling implementation in LLMapReduce MIMO" — §III).

use crate::aggregation::plan::{Aggregator, ClusterShape, Workload};
use crate::aggregation::script::NodeScript;
use crate::aggregation::{MultiLevel, NodeBased};
use crate::config::Mode;
use crate::error::Result;
use crate::scheduler::job::JobSpec;

/// A prepared LLMapReduce submission.
#[derive(Debug)]
pub struct MapJob {
    pub job: JobSpec,
    pub scripts: Vec<NodeScript>,
    pub mode: Mode,
}

/// The LLMapReduce front end.
#[derive(Debug, Clone)]
pub struct LLMapReduce {
    /// The mapper command (recorded in scripts / run by the executor).
    pub mapper: String,
    /// Use node-based aggregation (the paper's triples mode).
    pub triples: bool,
    /// Threads per worker process in triples mode.
    pub threads_per_process: u32,
    pub reservation: Option<String>,
    pub priority: i32,
}

impl LLMapReduce {
    pub fn new(mapper: &str) -> LLMapReduce {
        LLMapReduce {
            mapper: mapper.to_string(),
            triples: false,
            threads_per_process: 1,
            reservation: None,
            priority: 0,
        }
    }

    /// Enable triples (node-based) mode.
    pub fn with_triples(mut self) -> Self {
        self.triples = true;
        self
    }

    /// Map a workload over the machine slice.
    pub fn map(&self, workload: &Workload, shape: &ClusterShape) -> Result<MapJob> {
        let name = format!(
            "LLMapReduce:{}{}",
            self.mapper,
            if self.triples { ":triples" } else { ":mimo" }
        );
        let (mut job, scripts, mode) = if self.triples {
            let nb = NodeBased { threads_per_process: self.threads_per_process };
            let job = nb.plan(&name, workload, shape)?;
            let scripts = nb.scripts(workload, shape);
            (job, scripts, Mode::NodeBased)
        } else {
            (MultiLevel.plan(&name, workload, shape)?, vec![], Mode::MultiLevel)
        };
        job.reservation = self.reservation.clone();
        job.priority = self.priority;
        Ok(MapJob { job, scripts, mode })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape() -> ClusterShape {
        ClusterShape { nodes: 4, cores_per_node: 64, task_mem_mib: 128 }
    }

    #[test]
    fn mimo_maps_per_core() {
        let w = Workload::Uniform { count: 1024, duration: 30.0 };
        let m = LLMapReduce::new("proc.sh").map(&w, &shape()).unwrap();
        assert_eq!(m.mode, Mode::MultiLevel);
        assert_eq!(m.job.array_size(), 256, "4 × 64 processors");
        assert!(m.scripts.is_empty());
        assert!(m.job.name.contains("mimo"));
    }

    #[test]
    fn triples_maps_per_node() {
        let w = Workload::Uniform { count: 1024, duration: 30.0 };
        let m = LLMapReduce::new("proc.sh")
            .with_triples()
            .map(&w, &shape())
            .unwrap();
        assert_eq!(m.mode, Mode::NodeBased);
        assert_eq!(m.job.array_size(), 4);
        assert_eq!(m.scripts.len(), 4);
        assert!(m.job.name.contains("triples"));
    }

    #[test]
    fn both_modes_conserve_compute_tasks() {
        let w = Workload::Uniform { count: 1000, duration: 1.0 };
        let mimo = LLMapReduce::new("m").map(&w, &shape()).unwrap();
        let trip = LLMapReduce::new("m").with_triples().map(&w, &shape()).unwrap();
        assert_eq!(mimo.job.total_compute_tasks(), 1000);
        // Node-based batch counts are per-lane approximations for the DES;
        // the scripts are the ground truth for task coverage.
        let script_total: u64 = trip.scripts.iter().map(|s| s.total_tasks()).sum();
        assert_eq!(script_total, 1000);
    }

    #[test]
    fn reservation_priority_flow_through() {
        let mut ll = LLMapReduce::new("m").with_triples();
        ll.reservation = Some("slice".into());
        ll.priority = -5;
        let w = Workload::Uniform { count: 10, duration: 1.0 };
        let m = ll.map(&w, &shape()).unwrap();
        assert_eq!(m.job.reservation.as_deref(), Some("slice"));
        assert_eq!(m.job.priority, -5);
    }
}
