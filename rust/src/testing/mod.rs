//! Test support: a miniature property-testing toolkit (the vendored crate
//! set has no `proptest`), used by the coordinator-invariant test suites.

pub mod prop;

pub use prop::{forall, Gen};
