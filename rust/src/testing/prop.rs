//! Mini property-based testing: seeded random case generation with
//! first-failure shrinking over a scalar "size" knob.
//!
//! Not a proptest replacement — just enough to express the coordinator
//! invariants ("for any workload and any cluster shape, aggregation
//! conserves tasks", "the scheduler always drains", …) as randomized
//! properties with reproducible failures.

use crate::util::rng::Rng;

/// A generation context handed to property closures.
pub struct Gen {
    rng: Rng,
    /// Current size bound; shrinking retries the property at smaller sizes.
    pub size: usize,
}

impl Gen {
    /// Integer in `[lo, hi]`, additionally capped by the current size.
    pub fn int(&mut self, lo: u64, hi: u64) -> u64 {
        let hi_capped = hi.min(lo.saturating_add(self.size as u64));
        lo + self.rng.below(hi_capped - lo + 1)
    }

    /// usize in `[lo, hi]` (size-capped).
    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.int(lo as u64, hi as u64) as usize
    }

    /// f64 in `[lo, hi)`.
    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.range_f64(lo, hi)
    }

    /// Boolean with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.rng.chance(p)
    }

    /// Pick one of the slice's elements.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        let i = self.rng.range(0, xs.len());
        &xs[i]
    }

    /// A vector of `n` items built by `f`.
    pub fn vec<T>(&mut self, n: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        (0..n).map(|_| f(self)).collect()
    }
}

/// Run `cases` random cases of a property. On failure, retry with smaller
/// sizes to report the smallest failing seed/size, then panic with a
/// reproduction line.
pub fn forall(name: &str, cases: usize, prop: impl Fn(&mut Gen) -> Result<(), String>) {
    forall_seeded(name, 0xC0FFEE, cases, prop)
}

/// [`forall`] with an explicit base seed (for reproducing failures).
pub fn forall_seeded(
    name: &str,
    base_seed: u64,
    cases: usize,
    prop: impl Fn(&mut Gen) -> Result<(), String>,
) {
    for case in 0..cases {
        let seed = base_seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        // Sizes ramp up so early cases are small.
        let size = 1 + (case * 97) % 1000;
        if let Err(msg) = run_case(&prop, seed, size) {
            // Shrink: halve the size until the property passes again.
            let (mut fail_size, mut fail_msg) = (size, msg);
            let mut s = size / 2;
            while s >= 1 {
                match run_case(&prop, seed, s) {
                    Err(m) => {
                        fail_size = s;
                        fail_msg = m;
                        if s == 1 {
                            break;
                        }
                        s /= 2;
                    }
                    Ok(()) => break,
                }
            }
            panic!(
                "property {name:?} failed (case {case}, seed {seed:#x}, shrunk size {fail_size}): {fail_msg}\n\
                 reproduce with forall_seeded({name:?}, {seed:#x}, 1, ..) at size {fail_size}"
            );
        }
    }
}

fn run_case(
    prop: &impl Fn(&mut Gen) -> Result<(), String>,
    seed: u64,
    size: usize,
) -> Result<(), String> {
    let mut g = Gen {
        rng: Rng::new(seed),
        size,
    };
    prop(&mut g)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        forall("reverse twice is identity", 50, |g| {
            let v = g.vec(g.size.min(64), |g| g.int(0, 100));
            let mut r = v.clone();
            r.reverse();
            r.reverse();
            if r == v {
                Ok(())
            } else {
                Err("mismatch".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property \"always fails\"")]
    fn failing_property_panics_with_repro() {
        forall("always fails", 10, |_| Err("nope".into()));
    }

    #[test]
    fn shrinking_reports_small_size() {
        let res = std::panic::catch_unwind(|| {
            forall("fails above 10", 100, |g| {
                if g.size > 10 {
                    Err(format!("size {}", g.size))
                } else {
                    Ok(())
                }
            });
        });
        let msg = *res.unwrap_err().downcast::<String>().unwrap();
        // Shrinker halves until ≤10 passes again, so the reported failing
        // size should be ≤ 2× the threshold.
        assert!(msg.contains("shrunk size"), "{msg}");
    }

    #[test]
    fn gen_ranges_respected() {
        let mut g = Gen { rng: Rng::new(1), size: 1000 };
        for _ in 0..1000 {
            let x = g.int(5, 10);
            assert!((5..=10).contains(&x));
            let f = g.f64(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
        }
    }

    #[test]
    fn size_caps_ranges() {
        let mut g = Gen { rng: Rng::new(2), size: 3 };
        for _ in 0..100 {
            assert!(g.int(0, 1_000_000) <= 3);
        }
    }
}
