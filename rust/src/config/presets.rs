//! Paper benchmark presets: Table I task configurations and Table II
//! cluster configurations, plus the full Table III run matrix,
//! placement-policy sweeps, and interactive-vs-batch contention sweeps.

use crate::config::{Mode, RunConfig};
use crate::placement::ALL_STRATEGIES;
use crate::workload::contention::ContentionMix;

/// A Table I column: a named task-time configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TaskConfig {
    pub name: &'static str,
    /// Task time `t`, seconds.
    pub task_time: f64,
    /// Job time per processor `T_job`, seconds.
    pub job_time: f64,
}

impl TaskConfig {
    /// Tasks per processor, n = T_job / t.
    pub fn tasks_per_processor(&self) -> u64 {
        (self.job_time / self.task_time).round() as u64
    }
}

/// Table I: rapid (1 s), fast (5 s), medium (30 s), long (60 s); T_job=240 s.
pub const TASK_CONFIGS: [TaskConfig; 4] = [
    TaskConfig { name: "rapid", task_time: 1.0, job_time: 240.0 },
    TaskConfig { name: "fast", task_time: 5.0, job_time: 240.0 },
    TaskConfig { name: "medium", task_time: 30.0, job_time: 240.0 },
    TaskConfig { name: "long", task_time: 60.0, job_time: 240.0 },
];

/// Table II node-count scaling points.
pub const NODE_SCALES: [u32; 5] = [32, 64, 128, 256, 512];

/// Cores per node on the paper's testbed.
pub const CORES_PER_NODE: u32 = 64;

/// Runs per cell in Table III.
pub const RUNS_PER_CELL: usize = 3;

/// Build the `RunConfig` for one Table III cell.
pub fn cell(nodes: u32, task: &TaskConfig, mode: Mode, run_idx: usize) -> RunConfig {
    RunConfig {
        nodes,
        cores_per_node: CORES_PER_NODE,
        task_time: task.task_time,
        job_time: task.job_time,
        mode,
        // Seed is a stable function of the cell so each of the 3 runs is
        // reproducible but distinct.
        seed: (nodes as u64) << 32
            | (task.task_time as u64) << 16
            | (mode as u64) << 8
            | run_idx as u64,
        // The paper needed a dedicated system for multi-level at ≥256
        // nodes (scheduler unresponsive under production load).
        dedicated: mode == Mode::MultiLevel && nodes >= 256,
        task_mem_mib: 512,
        // Per-mode default (node-based fast path for N*, first-fit for
        // the core-level modes); sweeps override it explicitly.
        placement: None,
        // The paper's single-job matrix has no contention to backfill
        // around; contention runs opt in explicitly. The fairness knobs
        // keep their config defaults (top-4 holds, aging off, exact
        // walltime estimates) — all inert while backfill is off.
        backfill: false,
        holds: 4,
        aging: 0.0,
        aging_cap: 1000,
        walltime_error: 0.0,
        // The rapid-launch pool and preemptive backfill are contention-
        // era features; the paper's single-job matrix leaves them off.
        pool_size: 0,
        pool_min: 0,
        pool_max: 0,
        pool_hysteresis: 0.25,
        preempt_overdue: false,
        pools: Vec::new(),
        // Fault injection stays off in the paper matrix; the churn
        // presets ([`crate::fault::scenario`]) opt in explicitly.
        fault_mtbf: 0.0,
        fault_mttr: 30.0,
        fault_straggler_prob: 0.0,
        fault_straggler_factor: 1.0,
        // The flight recorder is opt-in tooling; the paper matrix runs
        // with the recorder (and its exporters) fully absent.
        trace_cap: 0,
    }
}

/// One entry of the contention sweep: a mix plus a backfill setting.
#[derive(Debug, Clone)]
pub struct ContentionCell {
    pub mix: ContentionMix,
    pub backfill: bool,
}

impl ContentionCell {
    /// Human label like `default/32n/backfill`.
    pub fn label(&self) -> String {
        format!(
            "{}/{}n/{}",
            self.mix.name,
            self.mix.nodes,
            if self.backfill { "backfill" } else { "no-backfill" }
        )
    }
}

/// The interactive-vs-batch contention sweep at one cluster size:
/// every named mix, with backfill off and on, so the `contention`
/// CLI subcommand (and CI) can compare per-class launch latency and
/// utilization across the policy flip.
pub fn contention_sweep(nodes: u32) -> Vec<ContentionCell> {
    let mut out = Vec::new();
    for name in ["tiny", "default", "heavy"] {
        let mix = ContentionMix::preset(name, nodes).expect("known preset name");
        for backfill in [false, true] {
            out.push(ContentionCell {
                mix: mix.clone(),
                backfill,
            });
        }
    }
    out
}

/// One cell replicated across every placement strategy — the
/// policy-comparison scenario the placement subsystem opens up.
pub fn placement_sweep(nodes: u32, task: &TaskConfig, mode: Mode) -> Vec<RunConfig> {
    ALL_STRATEGIES
        .iter()
        .map(|&s| RunConfig {
            placement: Some(s),
            ..cell(nodes, task, mode, 0)
        })
        .collect()
}

/// The paper ran multi-level at 512 nodes only for long (60 s) tasks; the
/// other cells are N/A ("takes too long to release the completed tasks").
pub fn is_paper_na(nodes: u32, task: &TaskConfig, mode: Mode) -> bool {
    mode == Mode::MultiLevel && nodes == 512 && task.task_time < 60.0
}

/// The full Table III matrix (both modes, all scales, all task types,
/// 3 runs per cell), excluding the paper's N/A cells unless `include_na`.
pub fn table3_matrix(include_na: bool) -> Vec<RunConfig> {
    let mut out = Vec::new();
    for &nodes in &NODE_SCALES {
        for task in &TASK_CONFIGS {
            for mode in [Mode::MultiLevel, Mode::NodeBased] {
                if !include_na && is_paper_na(nodes, task, mode) {
                    continue;
                }
                for run in 0..RUNS_PER_CELL {
                    out.push(cell(nodes, task, mode, run));
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_tasks_per_processor() {
        let n: Vec<u64> = TASK_CONFIGS.iter().map(|t| t.tasks_per_processor()).collect();
        assert_eq!(n, vec![240, 48, 8, 4]); // Table I row 3
    }

    #[test]
    fn table2_total_processor_time() {
        // Table II: total processor time = P × T_job; 32 nodes → 136.5 h.
        for (&nodes, hours) in NODE_SCALES.iter().zip([136.5, 273.1, 546.1, 1092.3, 2184.5]) {
            let p = nodes as f64 * CORES_PER_NODE as f64;
            let h = p * 240.0 / 3600.0;
            assert!((h - hours).abs() < 0.06, "{nodes} nodes: {h} vs {hours}");
        }
    }

    #[test]
    fn matrix_size_matches_paper() {
        // Full grid: 5 scales × 4 tasks × 2 modes × 3 runs = 120.
        assert_eq!(table3_matrix(true).len(), 120);
        // Paper's N/A: M* at 512 for t ∈ {1,5,30} → 3 cells × 3 runs = 9 fewer.
        assert_eq!(table3_matrix(false).len(), 111);
    }

    #[test]
    fn na_cells_are_multilevel_512_short() {
        assert!(is_paper_na(512, &TASK_CONFIGS[0], Mode::MultiLevel));
        assert!(!is_paper_na(512, &TASK_CONFIGS[3], Mode::MultiLevel));
        assert!(!is_paper_na(512, &TASK_CONFIGS[0], Mode::NodeBased));
        assert!(!is_paper_na(256, &TASK_CONFIGS[0], Mode::MultiLevel));
    }

    #[test]
    fn cell_seeds_distinct_and_stable() {
        let a = cell(32, &TASK_CONFIGS[0], Mode::NodeBased, 0);
        let b = cell(32, &TASK_CONFIGS[0], Mode::NodeBased, 1);
        let a2 = cell(32, &TASK_CONFIGS[0], Mode::NodeBased, 0);
        assert_ne!(a.seed, b.seed);
        assert_eq!(a.seed, a2.seed);
    }

    #[test]
    fn placement_sweep_covers_all_strategies() {
        use crate::placement::Strategy;
        let sweep = placement_sweep(32, &TASK_CONFIGS[3], Mode::MultiLevel);
        assert_eq!(sweep.len(), 5);
        let strategies: Vec<Strategy> =
            sweep.iter().map(|c| c.placement.unwrap()).collect();
        for s in ALL_STRATEGIES {
            assert!(strategies.contains(&s), "{s} missing from sweep");
        }
        // Everything else matches the base cell.
        assert!(sweep.iter().all(|c| c.nodes == 32 && c.mode == Mode::MultiLevel));
    }

    #[test]
    fn contention_sweep_pairs_mixes_with_backfill_flip() {
        let sweep = contention_sweep(16);
        assert_eq!(sweep.len(), 6, "3 mixes × backfill off/on");
        for pair in sweep.chunks(2) {
            assert_eq!(pair[0].mix.name, pair[1].mix.name);
            assert!(!pair[0].backfill && pair[1].backfill);
            assert_eq!(pair[0].mix.nodes, 16);
        }
        assert_eq!(sweep[0].label(), "tiny/16n/no-backfill");
        assert_eq!(sweep[1].label(), "tiny/16n/backfill");
    }

    #[test]
    fn dedicated_rule() {
        assert!(cell(256, &TASK_CONFIGS[0], Mode::MultiLevel, 0).dedicated);
        assert!(cell(512, &TASK_CONFIGS[3], Mode::MultiLevel, 0).dedicated);
        assert!(!cell(128, &TASK_CONFIGS[0], Mode::MultiLevel, 0).dedicated);
        assert!(!cell(512, &TASK_CONFIGS[0], Mode::NodeBased, 0).dedicated);
    }
}
