//! Configuration system: a TOML-subset parser (offline build: no serde /
//! toml crates) plus typed experiment configuration and paper presets.

pub mod parser;
pub mod presets;

use crate::error::{Error, Result};
use crate::placement::Strategy;
use crate::pool::{FleetConfig, JobShape, PoolConfig, ShardConfig};
use crate::scheduler::queue::AgingPolicy;
use parser::Value;

/// Which aggregation mode a run uses (paper §II).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Mode {
    /// One scheduling task per compute task (naive baseline).
    PerTask,
    /// One scheduling task per physical core — multi-level scheduling,
    /// LLMapReduce MIMO (the paper's comparison point, "M*").
    MultiLevel,
    /// One scheduling task per node — node-based scheduling, "triples
    /// mode" (the paper's contribution, "N*").
    NodeBased,
}

impl Mode {
    /// Parse from the names used in configs and CLI flags.
    pub fn parse(s: &str) -> Result<Mode> {
        match s {
            "per-task" | "per_task" | "naive" => Ok(Mode::PerTask),
            "multi-level" | "multi_level" | "mimo" | "M" => Ok(Mode::MultiLevel),
            "node-based" | "node_based" | "triples" | "N" => Ok(Mode::NodeBased),
            other => Err(Error::Config(format!("unknown mode {other:?}"))),
        }
    }

    /// The paper's shorthand (M* / N*).
    pub fn short(&self) -> &'static str {
        match self {
            Mode::PerTask => "P*",
            Mode::MultiLevel => "M*",
            Mode::NodeBased => "N*",
        }
    }
}

impl std::fmt::Display for Mode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Mode::PerTask => "per-task",
            Mode::MultiLevel => "multi-level",
            Mode::NodeBased => "node-based",
        };
        write!(f, "{s}")
    }
}

/// Fully-resolved configuration for one benchmark run.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Number of nodes in the benchmark slice (Table II: 32…512).
    pub nodes: u32,
    /// Cores per node (Table II: 64).
    pub cores_per_node: u32,
    /// Task time `t` in seconds (Table I: 1, 5, 30, 60).
    pub task_time: f64,
    /// Job time per processor `T_job` (Table I: 240 s).
    pub job_time: f64,
    /// Aggregation mode.
    pub mode: Mode,
    /// RNG seed for jitter.
    pub seed: u64,
    /// Dedicated system (no background noise) — the paper needed this for
    /// multi-level at 256/512 nodes.
    pub dedicated: bool,
    /// Memory per compute task, MiB.
    pub task_mem_mib: u64,
    /// Placement strategy (`placement = "best-fit"` in config files);
    /// `None` defers to the aggregation mode's default
    /// ([`crate::aggregation::plan::Aggregator::default_strategy`]).
    pub placement: Option<Strategy>,
    /// Enable backfill scheduling (`backfill = true`): blocked
    /// whole-node heads hold earliest-start reservations while small
    /// core-level tasks fill gaps ([`crate::placement::backfill`]).
    pub backfill: bool,
    /// Max simultaneous backfill holds (`holds = 4`): earliest-start
    /// reservations for the top-K blocked whole-node tasks. `1` is the
    /// original EASY single-hold discipline; only meaningful with
    /// `backfill = true`.
    pub holds: usize,
    /// Queue-aging slope (`aging = 0.5`), in priority points per second
    /// of pending wait; `0` disables aging (static priorities).
    pub aging: f64,
    /// Cap on the aging boost (`aging_cap = 1000`).
    pub aging_cap: i32,
    /// Walltime-estimate error sigma (`walltime_error = 0.3`):
    /// log-normal multiplicative error on the estimates backfill plans
    /// from; `0` keeps the DES's exact-oracle estimates.
    pub walltime_error: f64,
    /// Initial rapid-launch pool size (`pool_size = 8`); `0` disables
    /// the pool entirely ([`crate::pool`]).
    pub pool_size: u32,
    /// Elastic lower bound on the pool (`pool_min = 2`).
    pub pool_min: u32,
    /// Elastic upper bound on the pool (`pool_max = 16`); `0` pins the
    /// pool at `pool_size`.
    pub pool_max: u32,
    /// Resize dead-band fraction in `[0, 1)` (`pool_hysteresis = 0.25`).
    pub pool_hysteresis: f64,
    /// Preemptive backfill (`preempt_overdue = true`): kill backfilled
    /// tasks that overstay their walltime estimate once their node's
    /// hold comes due, instead of waiting for them to vacate.
    pub preempt_overdue: bool,
    /// Shape-sharded pool fleet
    /// (`pools = [{shape = "general", size = 8}, ...]`): one
    /// rapid-launch shard per entry, keyed by a job-shape classifier.
    /// Mutually exclusive with the legacy `pool_size` keys, which map
    /// to a one-shard fleet.
    pub pools: Vec<ShardConfig>,
    /// Per-node mean time between failures in seconds
    /// (`fault_mtbf = 7200`); `0` disables MTBF node churn
    /// ([`crate::fault`]).
    pub fault_mtbf: f64,
    /// Mean time to recovery once a node fails (`fault_mttr = 30`).
    pub fault_mttr: f64,
    /// Probability a task is a straggler (`fault_straggler_prob = 0.05`);
    /// `0` disables straggler slowdowns.
    pub fault_straggler_prob: f64,
    /// Actual-runtime multiplier on stragglers
    /// (`fault_straggler_factor = 4.0`).
    pub fault_straggler_factor: f64,
    /// Scheduler federation
    /// (`federation = {instances = 4, batch = 8, steal_threshold = 64}`):
    /// run the workload through a gateway over N independent scheduler
    /// instances, each owning a disjoint cluster partition
    /// ([`crate::federation`]). `None` = the classic single scheduler.
    pub federation: Option<crate::federation::FederationConfig>,
    /// Flight-recorder ring capacity in events (`trace_cap = 65536`):
    /// trace scheduler decisions into a bounded ring for the Perfetto /
    /// decision-log exporters ([`crate::obs`]). `0` (the default)
    /// leaves the recorder out entirely — zero overhead.
    pub trace_cap: usize,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            nodes: 32,
            cores_per_node: 64,
            task_time: 60.0,
            job_time: 240.0,
            mode: Mode::NodeBased,
            seed: 1,
            dedicated: false,
            task_mem_mib: 512,
            placement: None,
            backfill: false,
            holds: 4,
            aging: 0.0,
            aging_cap: 1000,
            walltime_error: 0.0,
            pool_size: 0,
            pool_min: 0,
            pool_max: 0,
            pool_hysteresis: 0.25,
            preempt_overdue: false,
            pools: Vec::new(),
            fault_mtbf: 0.0,
            fault_mttr: 30.0,
            fault_straggler_prob: 0.0,
            fault_straggler_factor: 1.0,
            federation: None,
            trace_cap: 0,
        }
    }
}

impl RunConfig {
    /// Total processors P = nodes × cores_per_node (Table II).
    pub fn processors(&self) -> u64 {
        self.nodes as u64 * self.cores_per_node as u64
    }

    /// Tasks per processor n = T_job / t (Table I).
    pub fn tasks_per_processor(&self) -> u64 {
        (self.job_time / self.task_time).round() as u64
    }

    /// Total compute tasks in the job (≈8M at 512 nodes / 1 s tasks).
    pub fn total_tasks(&self) -> u64 {
        self.processors() * self.tasks_per_processor()
    }

    /// Validate ranges.
    pub fn validate(&self) -> Result<()> {
        if self.nodes == 0 || self.cores_per_node == 0 {
            return Err(Error::Config("nodes and cores_per_node must be > 0".into()));
        }
        if self.task_time <= 0.0 || self.job_time <= 0.0 {
            return Err(Error::Config("task_time and job_time must be > 0".into()));
        }
        if self.task_time > self.job_time {
            return Err(Error::Config(format!(
                "task_time {} exceeds job_time {}",
                self.task_time, self.job_time
            )));
        }
        if self.holds == 0 {
            return Err(Error::Config("holds must be >= 1".into()));
        }
        if self.aging < 0.0 || self.aging_cap < 0 {
            return Err(Error::Config("aging slope and cap must be >= 0".into()));
        }
        if self.walltime_error < 0.0 {
            return Err(Error::Config("walltime_error must be >= 0".into()));
        }
        if !self.pools.is_empty() && (self.pool_size > 0 || self.pool_min > 0 || self.pool_max > 0)
        {
            return Err(Error::Config(
                "pools = [...] and the legacy pool_size/pool_min/pool_max keys are \
                 mutually exclusive (set per-shard bounds inside the list)"
                    .into(),
            ));
        }
        self.pool_config().validate().map_err(Error::Config)?;
        self.fleet_config().validate().map_err(Error::Config)?;
        self.fault_config().validate().map_err(Error::Config)?;
        if let Some(fed) = &self.federation {
            fed.validate().map_err(Error::Config)?;
            if self.nodes as usize % fed.instances != 0 {
                return Err(Error::Config(format!(
                    "federation.instances ({}) must divide nodes ({}) into equal partitions",
                    fed.instances, self.nodes
                )));
            }
        }
        Ok(())
    }

    /// Build from a parsed config file (`[run]` section).
    pub fn from_value(root: &Value) -> Result<RunConfig> {
        let mut c = RunConfig::default();
        let run = root.get("run").unwrap_or(root);
        if let Some(v) = run.get("nodes") {
            c.nodes = v.as_int()? as u32;
        }
        if let Some(v) = run.get("cores_per_node") {
            c.cores_per_node = v.as_int()? as u32;
        }
        if let Some(v) = run.get("task_time") {
            c.task_time = v.as_float()?;
        }
        if let Some(v) = run.get("job_time") {
            c.job_time = v.as_float()?;
        }
        if let Some(v) = run.get("mode") {
            c.mode = Mode::parse(v.as_str()?)?;
        }
        if let Some(v) = run.get("seed") {
            c.seed = v.as_int()? as u64;
        }
        if let Some(v) = run.get("dedicated") {
            c.dedicated = v.as_bool()?;
        }
        if let Some(v) = run.get("task_mem_mib") {
            c.task_mem_mib = v.as_int()? as u64;
        }
        if let Some(v) = run.get("placement") {
            c.placement = Some(Strategy::parse(v.as_str()?)?);
        }
        if let Some(v) = run.get("backfill") {
            c.backfill = v.as_bool()?;
        }
        if let Some(v) = run.get("holds") {
            // Range-check before the usize cast: a negative value must
            // be a config error, not a wrap to a huge hold capacity.
            let holds = v.as_int()?;
            if holds < 1 {
                return Err(Error::Config(format!("holds must be >= 1, got {holds}")));
            }
            c.holds = holds as usize;
        }
        if let Some(v) = run.get("aging") {
            c.aging = v.as_float()?;
        }
        if let Some(v) = run.get("aging_cap") {
            let cap = v.as_int()?;
            if !(0..=i32::MAX as i64).contains(&cap) {
                return Err(Error::Config(format!(
                    "aging_cap must be in 0..={}, got {cap}",
                    i32::MAX
                )));
            }
            c.aging_cap = cap as i32;
        }
        if let Some(v) = run.get("walltime_error") {
            c.walltime_error = v.as_float()?;
        }
        // Pool keys: negative values must be config errors, not wraps.
        for (key, field) in [
            ("pool_size", &mut c.pool_size as &mut u32),
            ("pool_min", &mut c.pool_min),
            ("pool_max", &mut c.pool_max),
        ] {
            if let Some(v) = run.get(key) {
                let x = v.as_int()?;
                if !(0..=u32::MAX as i64).contains(&x) {
                    return Err(Error::Config(format!(
                        "{key} must be in 0..={}, got {x}",
                        u32::MAX
                    )));
                }
                *field = x as u32;
            }
        }
        if let Some(v) = run.get("pool_hysteresis") {
            c.pool_hysteresis = v.as_float()?;
        }
        if let Some(v) = run.get("preempt_overdue") {
            c.preempt_overdue = v.as_bool()?;
        }
        if let Some(v) = run.get("fault_mtbf") {
            c.fault_mtbf = v.as_float()?;
        }
        if let Some(v) = run.get("fault_mttr") {
            c.fault_mttr = v.as_float()?;
        }
        if let Some(v) = run.get("fault_straggler_prob") {
            c.fault_straggler_prob = v.as_float()?;
        }
        if let Some(v) = run.get("fault_straggler_factor") {
            c.fault_straggler_factor = v.as_float()?;
        }
        if let Some(v) = run.get("federation") {
            c.federation = Some(federation_from_value(v)?);
        }
        if let Some(v) = run.get("trace_cap") {
            // Range-check before the usize cast: a negative capacity
            // must be a config error, not a wrap to a huge ring.
            let cap = v.as_int()?;
            if cap < 0 {
                return Err(Error::Config(format!("trace_cap must be >= 0, got {cap}")));
            }
            c.trace_cap = cap as usize;
        }
        if let Some(v) = run.get("pools") {
            // Key *presence* is what conflicts — an explicitly written
            // legacy knob next to the list must error even when it
            // restates a default, or it would be silently ignored.
            for key in ["pool_size", "pool_min", "pool_max", "pool_hysteresis"] {
                if run.get(key).is_some() {
                    return Err(Error::Config(format!(
                        "pools = [...] and the legacy {key} key are mutually exclusive \
                         (set per-shard bounds inside the list)"
                    )));
                }
            }
            let Value::Arr(items) = v else {
                return Err(Error::Config(
                    "pools must be a list of inline tables: \
                     pools = [{shape = \"general\", size = 8}, ...]"
                        .into(),
                ));
            };
            for (i, item) in items.iter().enumerate() {
                c.pools.push(shard_from_value(item, i)?);
            }
        }
        c.validate()?;
        Ok(c)
    }

    /// The queue-aging policy this run uses (`None` when the slope is
    /// zero: static priorities).
    pub fn aging_policy(&self) -> Option<AgingPolicy> {
        if self.aging > 0.0 {
            Some(AgingPolicy::new(self.aging, self.aging_cap))
        } else {
            None
        }
    }

    /// The rapid-launch pool configuration this run uses (disabled when
    /// `pool_size` is 0) — the legacy single-pool knobs.
    pub fn pool_config(&self) -> PoolConfig {
        PoolConfig {
            size: self.pool_size as usize,
            min: self.pool_min as usize,
            max: self.pool_max as usize,
            hysteresis: self.pool_hysteresis,
            ..PoolConfig::disabled()
        }
    }

    /// The pool fleet this run uses: the explicit `pools = [...]` list
    /// when present, else the legacy `pool_size` keys mapped to a
    /// one-shard fleet (disabled when `pool_size` is 0 too).
    pub fn fleet_config(&self) -> FleetConfig {
        FleetConfig::from_parts(&self.pools, self.pool_config())
    }

    /// The fault-injection config this run uses (disabled when every
    /// `fault_*` key is at its default). The planning horizon is a
    /// generous multiple of `T_job` so churn covers the whole run even
    /// under heavy scheduler overhead.
    pub fn fault_config(&self) -> crate::fault::FaultConfig {
        crate::fault::FaultConfig {
            mtbf: self.fault_mtbf,
            mttr: self.fault_mttr,
            straggler_prob: self.fault_straggler_prob,
            straggler_factor: self.fault_straggler_factor,
            horizon: self.job_time * 20.0,
            ..crate::fault::FaultConfig::disabled()
        }
    }

    /// The placement strategy this run uses: the explicit `placement`
    /// key if set, else the aggregation mode's default.
    pub fn placement_strategy(&self) -> Strategy {
        self.placement
            .unwrap_or_else(|| crate::aggregation::for_mode(self.mode).default_strategy())
    }

    /// Parse a config file from disk.
    pub fn from_file(path: &std::path::Path) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path)?;
        let v = parser::parse(&text)?;
        RunConfig::from_value(&v)
    }
}

/// One `pools = [...]` entry: a named shape (`shape = "general"`) with
/// optional explicit band overrides (`min_lanes` / `max_lanes` /
/// `min_walltime` / `max_walltime`), plus the per-shard elastic knobs
/// (`size` required; `min` / `max` / `hysteresis` optional with the
/// legacy defaults). With no `shape` key the bands start from the
/// legacy short-threshold classifier.
fn shard_from_value(item: &Value, idx: usize) -> Result<ShardConfig> {
    if !matches!(item, Value::Table(_)) {
        return Err(Error::Config(format!(
            "pools[{idx}] must be an inline table like {{shape = \"general\", size = 8}}"
        )));
    }
    let (name, mut shape) = match item.get("shape") {
        Some(v) => {
            let s = v.as_str()?;
            let shape = JobShape::named(s).ok_or_else(|| {
                Error::Config(format!(
                    "pools[{idx}]: unknown shape {s:?} (known: general, large, wide, short)"
                ))
            })?;
            (s.to_string(), shape)
        }
        None => (
            format!("shard{idx}"),
            JobShape::up_to(crate::pool::DEFAULT_SHORT_THRESHOLD),
        ),
    };
    if let Some(v) = item.get("min_lanes") {
        shape.min_lanes = int_in_range(v, "min_lanes", idx)?;
    }
    if let Some(v) = item.get("max_lanes") {
        shape.max_lanes = int_in_range(v, "max_lanes", idx)?;
    }
    if let Some(v) = item.get("min_walltime") {
        shape.min_walltime = v.as_float()?;
    }
    if let Some(v) = item.get("max_walltime") {
        shape.max_walltime = v.as_float()?;
    }
    let size = item
        .get("size")
        .ok_or_else(|| Error::Config(format!("pools[{idx}] ({name}): size is required")))?;
    let pool = PoolConfig {
        size: int_in_range::<u32>(size, "size", idx)? as usize,
        min: item
            .get("min")
            .map(|v| int_in_range::<u32>(v, "min", idx))
            .transpose()?
            .unwrap_or(0) as usize,
        max: item
            .get("max")
            .map(|v| int_in_range::<u32>(v, "max", idx))
            .transpose()?
            .unwrap_or(0) as usize,
        hysteresis: item
            .get("hysteresis")
            .map(|v| v.as_float())
            .transpose()?
            .unwrap_or(0.25),
        short_threshold: shape.max_walltime,
    };
    Ok(ShardConfig { name, shape, pool })
}

/// The `federation = {instances = 4, batch = 8, steal_threshold = 64}`
/// inline table: all keys optional, defaults from
/// [`crate::federation::FederationConfig`]. `flush` (seconds) tunes the
/// gateway's flush/steal cadence.
fn federation_from_value(v: &Value) -> Result<crate::federation::FederationConfig> {
    if !matches!(v, Value::Table(_)) {
        return Err(Error::Config(
            "federation must be an inline table like \
             federation = {instances = 4, batch = 8, steal_threshold = 64}"
                .into(),
        ));
    }
    let mut fed = crate::federation::FederationConfig::default();
    for (key, field) in [
        ("instances", &mut fed.instances as &mut usize),
        ("batch", &mut fed.batch),
        ("steal_threshold", &mut fed.steal_threshold),
    ] {
        if let Some(x) = v.get(key) {
            let x = x.as_int()?;
            *field = usize::try_from(x).map_err(|_| {
                Error::Config(format!(
                    "federation.{key} must be a non-negative integer, got {x}"
                ))
            })?;
        }
    }
    if let Some(x) = v.get("flush") {
        fed.flush_interval = x.as_float()?;
    }
    Ok(fed)
}

/// A non-negative integer that fits the target width — negative config
/// values must be errors, not wraps.
fn int_in_range<T: TryFrom<i64>>(v: &Value, key: &str, idx: usize) -> Result<T> {
    let x = v.as_int()?;
    T::try_from(x).map_err(|_| {
        Error::Config(format!("pools[{idx}]: {key} must be a non-negative integer, got {x}"))
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_parse_aliases() {
        assert_eq!(Mode::parse("triples").unwrap(), Mode::NodeBased);
        assert_eq!(Mode::parse("mimo").unwrap(), Mode::MultiLevel);
        assert_eq!(Mode::parse("naive").unwrap(), Mode::PerTask);
        assert!(Mode::parse("bogus").is_err());
    }

    #[test]
    fn derived_quantities_match_paper_tables() {
        // Table I/II: 512 nodes × 64 cores, 1 s tasks → ~8M tasks.
        let c = RunConfig {
            nodes: 512,
            task_time: 1.0,
            ..Default::default()
        };
        assert_eq!(c.processors(), 32_768);
        assert_eq!(c.tasks_per_processor(), 240);
        assert_eq!(c.total_tasks(), 7_864_320);
    }

    #[test]
    fn validation_catches_bad_values() {
        let mut c = RunConfig::default();
        c.task_time = 0.0;
        assert!(c.validate().is_err());
        let mut c2 = RunConfig::default();
        c2.task_time = 500.0; // > job_time
        assert!(c2.validate().is_err());
        let mut c3 = RunConfig::default();
        c3.nodes = 0;
        assert!(c3.validate().is_err());
    }

    #[test]
    fn from_value_reads_run_section() {
        let v = parser::parse(
            "[run]\nnodes = 64\ntask_time = 5.0\nmode = \"multi-level\"\ndedicated = true\n",
        )
        .unwrap();
        let c = RunConfig::from_value(&v).unwrap();
        assert_eq!(c.nodes, 64);
        assert_eq!(c.task_time, 5.0);
        assert_eq!(c.mode, Mode::MultiLevel);
        assert!(c.dedicated);
        // Defaults preserved.
        assert_eq!(c.cores_per_node, 64);
        assert_eq!(c.placement, None);
        assert!(!c.backfill);
    }

    #[test]
    fn backfill_key_parses() {
        let v = parser::parse("[run]\nbackfill = true\n").unwrap();
        let c = RunConfig::from_value(&v).unwrap();
        assert!(c.backfill);
        let bad = parser::parse("[run]\nbackfill = \"yes\"\n").unwrap();
        assert!(RunConfig::from_value(&bad).is_err());
    }

    #[test]
    fn fairness_keys_parse_with_defaults() {
        let v = parser::parse("[run]\n").unwrap();
        let c = RunConfig::from_value(&v).unwrap();
        assert_eq!(c.holds, 4);
        assert_eq!(c.aging, 0.0);
        assert_eq!(c.aging_cap, 1000);
        assert_eq!(c.walltime_error, 0.0);
        assert!(c.aging_policy().is_none(), "zero slope = static priorities");
        let v = parser::parse(
            "[run]\nholds = 2\naging = 0.5\naging_cap = 64\nwalltime_error = 0.3\n",
        )
        .unwrap();
        let c = RunConfig::from_value(&v).unwrap();
        assert_eq!(c.holds, 2);
        assert_eq!(c.aging, 0.5);
        assert_eq!(c.aging_cap, 64);
        assert_eq!(c.walltime_error, 0.3);
        let policy = c.aging_policy().expect("positive slope enables aging");
        assert_eq!(policy.slope, 0.5);
        assert_eq!(policy.cap, 64);
    }

    #[test]
    fn fairness_keys_validated() {
        let mut c = RunConfig::default();
        c.holds = 0;
        assert!(c.validate().is_err(), "zero holds rejected");
        let mut c = RunConfig::default();
        c.aging = -0.1;
        assert!(c.validate().is_err(), "negative slope rejected");
        let mut c = RunConfig::default();
        c.walltime_error = -0.5;
        assert!(c.validate().is_err(), "negative sigma rejected");
        let bad = parser::parse("[run]\nholds = 0\n").unwrap();
        assert!(RunConfig::from_value(&bad).is_err());
        // Negative values must error, not wrap through the casts.
        let bad = parser::parse("[run]\nholds = -3\n").unwrap();
        assert!(RunConfig::from_value(&bad).is_err());
        let bad = parser::parse("[run]\naging_cap = -1\n").unwrap();
        assert!(RunConfig::from_value(&bad).is_err());
        let bad = parser::parse("[run]\naging_cap = 5000000000\n").unwrap();
        assert!(RunConfig::from_value(&bad).is_err(), "out of i32 range");
    }

    #[test]
    fn pool_keys_parse_with_defaults() {
        let c = RunConfig::from_value(&parser::parse("[run]\n").unwrap()).unwrap();
        assert_eq!(c.pool_size, 0);
        assert_eq!(c.pool_min, 0);
        assert_eq!(c.pool_max, 0);
        assert_eq!(c.pool_hysteresis, 0.25);
        assert!(!c.preempt_overdue);
        assert!(!c.pool_config().enabled(), "pool off by default");
        let v = parser::parse(
            "[run]\npool_size = 8\npool_min = 2\npool_max = 16\n\
             pool_hysteresis = 0.5\npreempt_overdue = true\n",
        )
        .unwrap();
        let c = RunConfig::from_value(&v).unwrap();
        assert_eq!(c.pool_size, 8);
        assert_eq!(c.pool_min, 2);
        assert_eq!(c.pool_max, 16);
        assert_eq!(c.pool_hysteresis, 0.5);
        assert!(c.preempt_overdue);
        let pc = c.pool_config();
        assert!(pc.enabled());
        assert_eq!(pc.effective_max(), 16);
        assert_eq!(pc.effective_min(), 2);
    }

    #[test]
    fn fault_keys_parse_and_validate() {
        let c = RunConfig::from_value(&parser::parse("[run]\n").unwrap()).unwrap();
        assert_eq!(c.fault_mtbf, 0.0);
        assert_eq!(c.fault_mttr, 30.0);
        assert_eq!(c.fault_straggler_prob, 0.0);
        assert!(!c.fault_config().enabled(), "faults off by default");
        let v = parser::parse(
            "[run]\nfault_mtbf = 7200\nfault_mttr = 45\n\
             fault_straggler_prob = 0.05\nfault_straggler_factor = 4.0\n",
        )
        .unwrap();
        let c = RunConfig::from_value(&v).unwrap();
        let fc = c.fault_config();
        assert!(fc.enabled());
        assert_eq!(fc.mtbf, 7200.0);
        assert_eq!(fc.mttr, 45.0);
        assert_eq!(fc.straggler_prob, 0.05);
        assert_eq!(fc.straggler_factor, 4.0);
        assert!(fc.horizon > c.job_time, "horizon covers the run");
        let bad = parser::parse("[run]\nfault_mtbf = -1\n").unwrap();
        assert!(RunConfig::from_value(&bad).is_err(), "negative mtbf rejected");
        let bad = parser::parse("[run]\nfault_mtbf = 100\nfault_mttr = 0\n").unwrap();
        assert!(RunConfig::from_value(&bad).is_err(), "zero mttr rejected");
        let bad = parser::parse("[run]\nfault_straggler_prob = 1.5\n").unwrap();
        assert!(RunConfig::from_value(&bad).is_err(), "prob > 1 rejected");
    }

    #[test]
    fn trace_cap_key_parses_and_validates() {
        let c = RunConfig::from_value(&parser::parse("[run]\n").unwrap()).unwrap();
        assert_eq!(c.trace_cap, 0, "recorder off by default");
        let v = parser::parse("[run]\ntrace_cap = 65536\n").unwrap();
        assert_eq!(RunConfig::from_value(&v).unwrap().trace_cap, 65536);
        let bad = parser::parse("[run]\ntrace_cap = -1\n").unwrap();
        assert!(RunConfig::from_value(&bad).is_err(), "negative cap rejected");
    }

    #[test]
    fn pool_keys_validated() {
        let bad = parser::parse("[run]\npool_size = -1\n").unwrap();
        assert!(RunConfig::from_value(&bad).is_err(), "negative size rejected");
        let bad = parser::parse("[run]\npool_hysteresis = 1.0\n").unwrap();
        assert!(RunConfig::from_value(&bad).is_err(), "hysteresis < 1 required");
        let bad = parser::parse("[run]\npool_size = 4\npool_min = 9\npool_max = 8\n").unwrap();
        assert!(RunConfig::from_value(&bad).is_err(), "min above max rejected");
        // min/max nonsense is tolerated while the pool is disabled.
        let ok = parser::parse("[run]\npool_min = 9\npool_max = 8\n").unwrap();
        assert!(RunConfig::from_value(&ok).is_ok());
    }

    #[test]
    fn pools_list_parses_into_a_fleet() {
        let v = parser::parse(
            "[run]\npools = [{shape = \"general\", size = 8, min = 2, max = 16}, \
             {shape = \"large\", size = 4, hysteresis = 0.5}]\n",
        )
        .unwrap();
        let c = RunConfig::from_value(&v).unwrap();
        assert_eq!(c.pools.len(), 2);
        assert_eq!(c.pools[0].name, "general");
        assert_eq!(c.pools[0].pool.size, 8);
        assert_eq!(c.pools[0].pool.min, 2);
        assert_eq!(c.pools[0].pool.max, 16);
        assert_eq!(c.pools[1].name, "large");
        assert_eq!(c.pools[1].pool.hysteresis, 0.5);
        assert_eq!(c.pools[1].shape, JobShape::named("large").unwrap());
        let fleet = c.fleet_config();
        assert_eq!(fleet.shards.len(), 2);
        assert!(fleet.validate().is_ok());
        // Explicit band overrides compose a custom shape.
        let v = parser::parse(
            "[run]\npools = [{size = 4, min_lanes = 65, max_walltime = 120}]\n",
        )
        .unwrap();
        let c = RunConfig::from_value(&v).unwrap();
        assert_eq!(c.pools[0].name, "shard0");
        assert_eq!(c.pools[0].shape.min_lanes, 65);
        assert_eq!(c.pools[0].shape.max_walltime, 120.0);
    }

    #[test]
    fn pools_list_validated() {
        // The satellite bug guard end-to-end: overlapping shard shapes
        // are a config error, not a silent routing ambiguity.
        let v = parser::parse(
            "[run]\npools = [{shape = \"general\", size = 4}, {shape = \"general\", size = 2}]\n",
        )
        .unwrap();
        let err = RunConfig::from_value(&v).unwrap_err().to_string();
        assert!(err.contains("overlap"), "{err}");
        // Legacy keys and the list are mutually exclusive — all of
        // them, so no knob is ever silently ignored.
        // Presence conflicts, not values: even a legacy knob restating
        // its default is rejected rather than silently ignored.
        for legacy in [
            "pool_size = 4",
            "pool_min = 2",
            "pool_max = 8",
            "pool_hysteresis = 0.5",
            "pool_hysteresis = 0.25",
        ] {
            let v = parser::parse(&format!(
                "[run]\n{legacy}\npools = [{{shape = \"general\", size = 4}}]\n"
            ))
            .unwrap();
            assert!(
                RunConfig::from_value(&v).is_err(),
                "{legacy} must conflict with pools = [...]"
            );
        }
        // Missing size, unknown shape, negative size: all errors.
        let v = parser::parse("[run]\npools = [{shape = \"general\"}]\n").unwrap();
        assert!(RunConfig::from_value(&v).is_err(), "size required");
        let v = parser::parse("[run]\npools = [{shape = \"bogus\", size = 2}]\n").unwrap();
        assert!(RunConfig::from_value(&v).is_err(), "unknown shape");
        let v = parser::parse("[run]\npools = [{shape = \"general\", size = -1}]\n").unwrap();
        assert!(RunConfig::from_value(&v).is_err(), "negative size");
        let v = parser::parse("[run]\npools = [3]\n").unwrap();
        assert!(RunConfig::from_value(&v).is_err(), "non-table entry");
        // The legacy keys still map to a one-shard fleet.
        let v = parser::parse("[run]\npool_size = 4\n").unwrap();
        let c = RunConfig::from_value(&v).unwrap();
        let fleet = c.fleet_config();
        assert_eq!(fleet.shards.len(), 1);
        assert_eq!(fleet.shards[0].pool.size, 4);
        assert_eq!(fleet.total_size(), 4);
    }

    #[test]
    fn federation_table_parses_and_validates() {
        let c = RunConfig::from_value(&parser::parse("[run]\n").unwrap()).unwrap();
        assert!(c.federation.is_none(), "federation off by default");
        let v = parser::parse(
            "[run]\nnodes = 128\n\
             federation = {instances = 4, batch = 16, steal_threshold = 32}\n",
        )
        .unwrap();
        let c = RunConfig::from_value(&v).unwrap();
        let fed = c.federation.expect("federation table parsed");
        assert_eq!(fed.instances, 4);
        assert_eq!(fed.batch, 16);
        assert_eq!(fed.steal_threshold, 32);
        assert_eq!(fed.flush_interval, 1.0, "default cadence");
        // Partial tables keep the remaining defaults.
        let v = parser::parse("[run]\nnodes = 64\nfederation = {instances = 2}\n").unwrap();
        let fed = RunConfig::from_value(&v).unwrap().federation.unwrap();
        assert_eq!(fed.instances, 2);
        assert_eq!(fed.batch, crate::federation::FederationConfig::default().batch);
        // Bad values are config errors, not wraps or panics.
        let bad = parser::parse("[run]\nfederation = {instances = 0}\n").unwrap();
        assert!(RunConfig::from_value(&bad).is_err(), "zero instances rejected");
        let bad = parser::parse("[run]\nfederation = {instances = -2}\n").unwrap();
        assert!(RunConfig::from_value(&bad).is_err(), "negative rejected");
        let bad = parser::parse("[run]\nfederation = 4\n").unwrap();
        assert!(RunConfig::from_value(&bad).is_err(), "non-table rejected");
        let bad =
            parser::parse("[run]\nnodes = 30\nfederation = {instances = 4}\n").unwrap();
        assert!(
            RunConfig::from_value(&bad).is_err(),
            "instances must divide nodes into equal partitions"
        );
    }

    #[test]
    fn placement_key_parses_and_defaults_by_mode() {
        let v = parser::parse("[run]\nplacement = \"best-fit\"\n").unwrap();
        let c = RunConfig::from_value(&v).unwrap();
        assert_eq!(c.placement, Some(Strategy::BestFit));
        assert_eq!(c.placement_strategy(), Strategy::BestFit);
        // Unset: node-based mode uses the fast path, core-level modes
        // the first-fit scan order.
        let node = RunConfig { mode: Mode::NodeBased, ..Default::default() };
        assert_eq!(node.placement_strategy(), Strategy::NodeBased);
        let multi = RunConfig { mode: Mode::MultiLevel, ..Default::default() };
        assert_eq!(multi.placement_strategy(), Strategy::FirstFit);
        // Bad values are config errors.
        let bad = parser::parse("[run]\nplacement = \"bogus\"\n").unwrap();
        assert!(RunConfig::from_value(&bad).is_err());
    }

    #[test]
    fn from_value_flat_file_also_works() {
        let v = parser::parse("nodes = 128\n").unwrap();
        let c = RunConfig::from_value(&v).unwrap();
        assert_eq!(c.nodes, 128);
    }
}
