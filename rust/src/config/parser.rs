//! A TOML-subset parser.
//!
//! Supports what llsched config files use: `[section]` and
//! `[section.sub]` headers, `key = value` pairs with string / integer /
//! float / boolean / array / inline-table (`{k = v, ...}`) values, `#`
//! comments, and blank lines. Arrays of inline tables give the pool
//! fleet its `pools = [{shape = "general", size = 8}, ...]` list
//! syntax. Unsupported TOML (dates, multi-line strings) is rejected
//! with a line-numbered error rather than silently misparsed.

use crate::error::{Error, Result};

/// A parsed config value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
    /// A table (section); insertion-ordered.
    Table(Vec<(String, Value)>),
}

impl Value {
    /// Get a child of a table.
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Table(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            other => Err(Error::Config(format!("expected string, got {other:?}"))),
        }
    }

    pub fn as_int(&self) -> Result<i64> {
        match self {
            Value::Int(i) => Ok(*i),
            other => Err(Error::Config(format!("expected integer, got {other:?}"))),
        }
    }

    /// Accepts both ints and floats.
    pub fn as_float(&self) -> Result<f64> {
        match self {
            Value::Float(x) => Ok(*x),
            Value::Int(i) => Ok(*i as f64),
            other => Err(Error::Config(format!("expected number, got {other:?}"))),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            other => Err(Error::Config(format!("expected bool, got {other:?}"))),
        }
    }

    fn table_mut(&mut self) -> &mut Vec<(String, Value)> {
        match self {
            Value::Table(pairs) => pairs,
            _ => unreachable!("internal: non-table in section path"),
        }
    }
}

/// Parse a config document into a root [`Value::Table`].
pub fn parse(text: &str) -> Result<Value> {
    let mut root = Value::Table(Vec::new());
    let mut section_path: Vec<String> = Vec::new();

    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim().to_string();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') {
            if !line.ends_with(']') {
                return Err(err(lineno, "unterminated section header"));
            }
            let inner = &line[1..line.len() - 1];
            if inner.is_empty() {
                return Err(err(lineno, "empty section header"));
            }
            section_path = inner.split('.').map(|s| s.trim().to_string()).collect();
            if section_path.iter().any(|s| s.is_empty()) {
                return Err(err(lineno, "empty section path component"));
            }
            ensure_section(&mut root, &section_path, lineno)?;
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| err(lineno, "expected `key = value`"))?;
        let key = line[..eq].trim().to_string();
        if key.is_empty() {
            return Err(err(lineno, "empty key"));
        }
        let value = parse_value(line[eq + 1..].trim(), lineno)?;
        let table = section_table(&mut root, &section_path);
        if table.iter().any(|(k, _)| *k == key) {
            return Err(err(lineno, &format!("duplicate key {key:?}")));
        }
        table.push((key, value));
    }
    Ok(root)
}

fn err(lineno: usize, msg: &str) -> Error {
    Error::Config(format!("line {}: {}", lineno + 1, msg))
}

fn strip_comment(line: &str) -> &str {
    // `#` starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn ensure_section(root: &mut Value, path: &[String], lineno: usize) -> Result<()> {
    let mut cur = root;
    for part in path {
        let exists = cur.get(part).is_some();
        if !exists {
            cur.table_mut()
                .push((part.clone(), Value::Table(Vec::new())));
        }
        let pairs = cur.table_mut();
        let slot = pairs
            .iter_mut()
            .find(|(k, _)| k == part)
            .map(|(_, v)| v)
            .expect("just ensured");
        if !matches!(slot, Value::Table(_)) {
            return Err(err(lineno, &format!("{part:?} is a value, not a section")));
        }
        cur = slot;
    }
    Ok(())
}

fn section_table<'a>(root: &'a mut Value, path: &[String]) -> &'a mut Vec<(String, Value)> {
    let mut cur = root;
    for part in path {
        let pairs = cur.table_mut();
        let idx = pairs
            .iter()
            .position(|(k, _)| k == part)
            .expect("section pre-created by ensure_section");
        cur = &mut pairs[idx].1;
    }
    cur.table_mut()
}

fn parse_value(s: &str, lineno: usize) -> Result<Value> {
    if s.is_empty() {
        return Err(err(lineno, "missing value"));
    }
    if s.starts_with('"') {
        if s.len() < 2 || !s.ends_with('"') {
            return Err(err(lineno, "unterminated string"));
        }
        let inner = &s[1..s.len() - 1];
        if inner.contains('"') {
            return Err(err(lineno, "embedded quotes not supported"));
        }
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if s.starts_with('[') {
        if !s.ends_with(']') {
            return Err(err(lineno, "unterminated array"));
        }
        let inner = s[1..s.len() - 1].trim();
        if inner.is_empty() {
            return Ok(Value::Arr(Vec::new()));
        }
        let items: Result<Vec<Value>> = split_top_level(inner, lineno)?
            .into_iter()
            .map(|it| parse_value(it.trim(), lineno))
            .collect();
        return Ok(Value::Arr(items?));
    }
    if s.starts_with('{') {
        if !s.ends_with('}') {
            return Err(err(lineno, "unterminated inline table"));
        }
        let inner = s[1..s.len() - 1].trim();
        let mut pairs: Vec<(String, Value)> = Vec::new();
        if inner.is_empty() {
            return Ok(Value::Table(pairs));
        }
        for part in split_top_level(inner, lineno)? {
            let part = part.trim();
            let eq = part
                .find('=')
                .ok_or_else(|| err(lineno, "inline table entries are `key = value`"))?;
            let key = part[..eq].trim().to_string();
            if key.is_empty() {
                return Err(err(lineno, "empty key in inline table"));
            }
            if pairs.iter().any(|(k, _)| *k == key) {
                return Err(err(lineno, &format!("duplicate key {key:?} in inline table")));
            }
            let value = parse_value(part[eq + 1..].trim(), lineno)?;
            pairs.push((key, value));
        }
        return Ok(Value::Table(pairs));
    }
    if let Ok(i) = s.replace('_', "").parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(x) = s.parse::<f64>() {
        return Ok(Value::Float(x));
    }
    Err(err(lineno, &format!("cannot parse value {s:?}")))
}

/// Split on commas at bracket/brace depth zero (outside strings), so
/// arrays of inline tables — `[{a = 1, b = 2}, {a = 3}]` — split into
/// whole elements rather than at every comma.
fn split_top_level(s: &str, lineno: usize) -> Result<Vec<&str>> {
    let mut parts = Vec::new();
    let mut depth = 0i32;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' | '{' if !in_str => depth += 1,
            ']' | '}' if !in_str => depth -= 1,
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
        if depth < 0 {
            return Err(err(lineno, "unbalanced brackets"));
        }
    }
    if depth != 0 || in_str {
        return Err(err(lineno, "unbalanced brackets or string"));
    }
    parts.push(&s[start..]);
    Ok(parts)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_and_sections() {
        let v = parse(
            "top = 1\n[a]\nx = \"hi\"  # comment\ny = 2.5\n[a.b]\nz = true\narr = [1, 2, 3]\n",
        )
        .unwrap();
        assert_eq!(v.get("top").unwrap().as_int().unwrap(), 1);
        let a = v.get("a").unwrap();
        assert_eq!(a.get("x").unwrap().as_str().unwrap(), "hi");
        assert_eq!(a.get("y").unwrap().as_float().unwrap(), 2.5);
        let b = a.get("b").unwrap();
        assert_eq!(b.get("z").unwrap().as_bool().unwrap(), true);
        assert_eq!(
            b.get("arr").unwrap(),
            &Value::Arr(vec![Value::Int(1), Value::Int(2), Value::Int(3)])
        );
    }

    #[test]
    fn comments_respect_strings() {
        let v = parse("s = \"a # not comment\"\n").unwrap();
        assert_eq!(v.get("s").unwrap().as_str().unwrap(), "a # not comment");
    }

    #[test]
    fn underscored_ints() {
        let v = parse("n = 32_768\n").unwrap();
        assert_eq!(v.get("n").unwrap().as_int().unwrap(), 32_768);
    }

    #[test]
    fn duplicate_key_rejected() {
        assert!(parse("a = 1\na = 2\n").is_err());
    }

    #[test]
    fn bad_syntax_has_line_numbers() {
        let e = parse("ok = 1\nbroken\n").unwrap_err().to_string();
        assert!(e.contains("line 2"), "{e}");
    }

    #[test]
    fn section_vs_value_conflict() {
        assert!(parse("[a]\nb = 1\n[a.b]\nc = 2\n").is_err());
    }

    #[test]
    fn unterminated_constructs() {
        assert!(parse("[sec\n").is_err());
        assert!(parse("s = \"oops\n").is_err());
        assert!(parse("a = [1, 2\n").is_err());
        assert!(parse("a =\n").is_err());
    }

    #[test]
    fn inline_tables_and_table_arrays() {
        let v = parse(
            "pools = [{shape = \"general\", size = 8, min = 2}, {shape = \"large\", size = 4}]\n",
        )
        .unwrap();
        let Value::Arr(items) = v.get("pools").unwrap() else {
            panic!("pools is an array");
        };
        assert_eq!(items.len(), 2, "commas inside braces do not split elements");
        assert_eq!(items[0].get("shape").unwrap().as_str().unwrap(), "general");
        assert_eq!(items[0].get("size").unwrap().as_int().unwrap(), 8);
        assert_eq!(items[0].get("min").unwrap().as_int().unwrap(), 2);
        assert_eq!(items[1].get("shape").unwrap().as_str().unwrap(), "large");
        assert!(items[1].get("min").is_none());
        // Bare inline tables and empty ones parse too.
        let v = parse("t = {a = 1, s = \"x, y\"}\ne = {}\n").unwrap();
        assert_eq!(v.get("t").unwrap().get("a").unwrap().as_int().unwrap(), 1);
        assert_eq!(
            v.get("t").unwrap().get("s").unwrap().as_str().unwrap(),
            "x, y",
            "commas inside strings do not split"
        );
        assert_eq!(v.get("e").unwrap(), &Value::Table(vec![]));
    }

    #[test]
    fn malformed_inline_tables_rejected() {
        assert!(parse("t = {a = 1\n").is_err(), "unterminated");
        assert!(parse("t = {a}\n").is_err(), "missing `=`");
        assert!(parse("t = {a = 1, a = 2}\n").is_err(), "duplicate key");
        assert!(parse("t = [{a = 1}, {b = 2]\n").is_err(), "unbalanced braces");
    }

    #[test]
    fn empty_array_and_floats() {
        let v = parse("e = []\nf = -3.5\ni = -7\n").unwrap();
        assert_eq!(v.get("e").unwrap(), &Value::Arr(vec![]));
        assert_eq!(v.get("f").unwrap().as_float().unwrap(), -3.5);
        assert_eq!(v.get("i").unwrap().as_int().unwrap(), -7);
        // int coerces to float but not vice versa
        assert_eq!(v.get("i").unwrap().as_float().unwrap(), -7.0);
        assert!(v.get("f").unwrap().as_int().is_err());
    }
}
