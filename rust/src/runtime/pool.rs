//! Executable pool: one compiled PJRT executable shared by worker lanes.
//!
//! PJRT loaded executables are internally synchronized; workers clone the
//! `Arc` and execute concurrently. The pool also caches by artifact name
//! so examples can grab "the small simstep" without tracking paths.

use crate::error::{Error, Result};
use crate::runtime::executable::Runtime;
use crate::runtime::{find_artifacts_dir, is_hlo_artifact};
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::Arc;

/// A cache of loaded artifacts keyed by artifact name.
pub struct ExecPool {
    dir: PathBuf,
    loaded: HashMap<String, Arc<Runtime>>,
}

impl ExecPool {
    /// Open the pool over an explicit artifacts directory.
    pub fn open(dir: PathBuf) -> ExecPool {
        ExecPool {
            dir,
            loaded: HashMap::new(),
        }
    }

    /// Open the pool by discovering `artifacts/` from the cwd upwards.
    pub fn discover() -> Result<ExecPool> {
        let dir = find_artifacts_dir().ok_or_else(|| {
            Error::Runtime(
                "artifacts/ not found — run `make artifacts` first".to_string(),
            )
        })?;
        Ok(ExecPool::open(dir))
    }

    /// List artifact files available in the directory.
    pub fn list(&self) -> Result<Vec<PathBuf>> {
        let mut out = Vec::new();
        for entry in std::fs::read_dir(&self.dir)? {
            let p = entry?.path();
            if is_hlo_artifact(&p) {
                out.push(p);
            }
        }
        out.sort();
        Ok(out)
    }

    /// Load (or fetch cached) an artifact by name, e.g. `simstep_8x32x32`.
    pub fn get(&mut self, name: &str) -> Result<Arc<Runtime>> {
        if let Some(r) = self.loaded.get(name) {
            return Ok(r.clone());
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        if !path.exists() {
            return Err(Error::Runtime(format!(
                "artifact {name:?} not found in {:?} (run `make artifacts`)",
                self.dir
            )));
        }
        let rt = Arc::new(Runtime::load(&path)?);
        self.loaded.insert(name.to_string(), rt.clone());
        Ok(rt)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn missing_artifact_is_a_clear_error() {
        let mut pool = ExecPool::open(std::env::temp_dir().join("no_such_dir_llsched"));
        let err = match pool.get("simstep_8x32x32") {
            Err(e) => e.to_string(),
            Ok(_) => panic!("expected missing-artifact error"),
        };
        assert!(err.contains("make artifacts"), "{err}");
    }
    // Positive-path tests live in rust/tests/runtime_integration.rs.
}
