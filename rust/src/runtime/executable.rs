//! Loading and executing one AOT artifact.
//!
//! The offline build links the PJRT stub bindings; swap the `use` below
//! for the real `xla` crate to re-enable live execution (the call
//! surface is identical).

use crate::error::{Error, Result};
use crate::runtime::stub as xla;
use std::path::Path;

/// Metadata of a loaded artifact (parsed from its filename:
/// `<name>_<batch>x<h>x<w>.hlo.txt`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Artifact {
    pub name: String,
    /// State shape the module expects: `[batch, h, w]` f32.
    pub batch: usize,
    pub h: usize,
    pub w: usize,
}

impl Artifact {
    /// Parse `simstep_8x32x32.hlo.txt` → name `simstep`, shape 8×32×32.
    pub fn parse(path: &Path) -> Result<Artifact> {
        let stem = path
            .file_name()
            .and_then(|n| n.to_str())
            .and_then(|n| n.strip_suffix(".hlo.txt"))
            .ok_or_else(|| Error::Runtime(format!("not an HLO artifact: {path:?}")))?;
        let (name, dims) = stem
            .rsplit_once('_')
            .ok_or_else(|| Error::Runtime(format!("no shape suffix in {stem:?}")))?;
        let parts: Vec<usize> = dims
            .split('x')
            .map(|d| d.parse::<usize>())
            .collect::<std::result::Result<_, _>>()
            .map_err(|_| Error::Runtime(format!("bad shape suffix {dims:?}")))?;
        if parts.len() != 3 {
            return Err(Error::Runtime(format!("expected 3 dims in {dims:?}")));
        }
        Ok(Artifact {
            name: name.to_string(),
            batch: parts[0],
            h: parts[1],
            w: parts[2],
        })
    }

    /// Number of f32 elements in the state tensor.
    pub fn elements(&self) -> usize {
        self.batch * self.h * self.w
    }
}

/// A PJRT CPU runtime holding one compiled executable.
pub struct Runtime {
    client: xla::PjRtClient,
    exe: xla::PjRtLoadedExecutable,
    pub artifact: Artifact,
}

impl Runtime {
    /// Load an HLO-text artifact and compile it on the CPU PJRT client.
    pub fn load(path: &Path) -> Result<Runtime> {
        let artifact = Artifact::parse(path)?;
        let client = xla::PjRtClient::cpu()?;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| Error::Runtime("non-UTF-8 path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        Ok(Runtime { client, exe, artifact })
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Execute the simulation-step module: `state: [batch, h, w] f32`
    /// (row-major) → `(new_state, checksum)`.
    ///
    /// The module was lowered with `return_tuple=True`, so the single
    /// output is a 2-tuple.
    pub fn step(&self, state: &[f32]) -> Result<(Vec<f32>, f32)> {
        let a = &self.artifact;
        if state.len() != a.elements() {
            return Err(Error::Runtime(format!(
                "state has {} elements, artifact {} wants {}",
                state.len(),
                a.name,
                a.elements()
            )));
        }
        let lit = xla::Literal::vec1(state).reshape(&[
            a.batch as i64,
            a.h as i64,
            a.w as i64,
        ])?;
        let result = self.exe.execute(&[lit])?[0][0].to_literal_sync()?;
        let (new_state_l, checksum_l) = result.to_tuple2()?;
        let new_state = new_state_l.to_vec::<f32>()?;
        let checksum = checksum_l.to_vec::<f32>()?[0];
        Ok((new_state, checksum))
    }

    /// Run `iters` chained steps, feeding each output into the next input
    /// (the "short-running simulation" payload of one compute task).
    pub fn run_task(&self, state: &[f32], iters: usize) -> Result<(Vec<f32>, f32)> {
        let mut s = state.to_vec();
        let mut checksum = 0.0;
        for _ in 0..iters {
            let (ns, c) = self.step(&s)?;
            s = ns;
            checksum = c;
        }
        Ok((s, checksum))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_parse_ok() {
        let a = Artifact::parse(Path::new("artifacts/simstep_8x32x32.hlo.txt")).unwrap();
        assert_eq!(a.name, "simstep");
        assert_eq!((a.batch, a.h, a.w), (8, 32, 32));
        assert_eq!(a.elements(), 8 * 32 * 32);
    }

    #[test]
    fn artifact_parse_errors() {
        assert!(Artifact::parse(Path::new("x.pb")).is_err());
        assert!(Artifact::parse(Path::new("noshape.hlo.txt")).is_err());
        assert!(Artifact::parse(Path::new("bad_1x2.hlo.txt")).is_err());
        assert!(Artifact::parse(Path::new("bad_axbxc.hlo.txt")).is_err());
    }
    // Execution tests live in rust/tests/runtime_integration.rs (they
    // need `make artifacts` to have run).
}
