//! PJRT runtime: load AOT-compiled JAX/Pallas artifacts (HLO text) and
//! execute them from Rust.
//!
//! Python runs only at build time (`make artifacts`); this module is the
//! request-path bridge: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! HLO *text* is the interchange format: the crate's xla_extension 0.5.1
//! rejects jax≥0.5's 64-bit-instruction-id protos, while the text parser
//! reassigns ids (see /opt/xla-example/README.md).

pub mod executable;
pub mod pool;
pub mod server;
pub mod stub;

pub use executable::{Artifact, Runtime};
pub use pool::ExecPool;
pub use server::RuntimeServer;

use std::path::{Path, PathBuf};

/// Whether this build can actually execute PJRT artifacts. The offline
/// build links the [`stub`] bindings and returns `false`; integration
/// tests and examples use this to skip live-execution paths gracefully.
pub fn pjrt_available() -> bool {
    stub::AVAILABLE
}

/// Default artifacts directory (relative to the repo root).
pub const ARTIFACTS_DIR: &str = "artifacts";

/// Locate the artifacts directory from the current working directory or
/// its ancestors (so examples/tests work from any cwd inside the repo).
pub fn find_artifacts_dir() -> Option<PathBuf> {
    let mut dir = std::env::current_dir().ok()?;
    loop {
        let cand = dir.join(ARTIFACTS_DIR);
        if cand.join("simstep_8x32x32.hlo.txt").exists() || cand.join(".stamp").exists() {
            return Some(cand);
        }
        if !dir.pop() {
            return None;
        }
    }
}

/// True if `path` looks like an HLO text artifact.
pub fn is_hlo_artifact(path: &Path) -> bool {
    path.extension().map(|e| e == "txt").unwrap_or(false)
        && path
            .file_name()
            .and_then(|n| n.to_str())
            .map(|n| n.ends_with(".hlo.txt"))
            .unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_name_filter() {
        assert!(is_hlo_artifact(Path::new("artifacts/simstep_8x32x32.hlo.txt")));
        assert!(!is_hlo_artifact(Path::new("artifacts/simstep.pb")));
        assert!(!is_hlo_artifact(Path::new("artifacts/notes.txt")));
    }
}
