//! Node-local runtime server.
//!
//! The `xla` crate's PJRT handles are `!Send` (`Rc` internals), so they
//! cannot be shared across worker lanes directly. Mirroring how a real
//! node agent would host one model instance, [`RuntimeServer`] owns the
//! compiled executable on a dedicated thread and serves execution
//! requests from the pinned worker lanes over channels.

use crate::error::{Error, Result};
use crate::runtime::executable::{Artifact, Runtime};
use std::path::PathBuf;
use std::sync::mpsc::{channel, Sender};
use std::thread::JoinHandle;

enum Request {
    /// Run `iters` chained simulation steps for compute task `task_id`;
    /// reply with the final checksum.
    RunTask {
        task_id: u64,
        iters: usize,
        reply: Sender<Result<f32>>,
    },
    Shutdown,
}

/// A handle to the runtime thread. Cloneable across lanes via `Arc`.
pub struct RuntimeServer {
    tx: Sender<Request>,
    handle: Option<JoinHandle<()>>,
    artifact: Artifact,
}

impl RuntimeServer {
    /// Spawn the server: loads + compiles the artifact on its own thread.
    /// Fails fast if the artifact cannot be loaded.
    pub fn spawn(path: PathBuf) -> Result<RuntimeServer> {
        let artifact = Artifact::parse(&path)?;
        let (tx, rx) = channel::<Request>();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let handle = std::thread::Builder::new()
            .name(format!("pjrt-{}", artifact.name))
            .spawn(move || {
                let rt = match Runtime::load(&path) {
                    Ok(rt) => {
                        let _ = ready_tx.send(Ok(()));
                        rt
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    match req {
                        Request::RunTask { task_id, iters, reply } => {
                            let state = initial_state(&rt.artifact, task_id);
                            let res = rt.run_task(&state, iters).map(|(_, c)| c);
                            let _ = reply.send(res);
                        }
                        Request::Shutdown => break,
                    }
                }
            })
            .map_err(|e| Error::Runtime(format!("spawn runtime thread: {e}")))?;
        ready_rx
            .recv()
            .map_err(|_| Error::Runtime("runtime thread died during load".into()))??;
        Ok(RuntimeServer {
            tx,
            handle: Some(handle),
            artifact,
        })
    }

    /// The artifact this server hosts.
    pub fn artifact(&self) -> &Artifact {
        &self.artifact
    }

    /// Execute one compute task (blocking until the runtime thread
    /// replies). Thread-safe; callable from any lane.
    pub fn run_task(&self, task_id: u64, iters: usize) -> Result<f32> {
        let (reply, rx) = channel();
        self.tx
            .send(Request::RunTask { task_id, iters, reply })
            .map_err(|_| Error::Runtime("runtime thread gone".into()))?;
        rx.recv()
            .map_err(|_| Error::Runtime("runtime thread dropped reply".into()))?
    }
}

impl Drop for RuntimeServer {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Deterministic per-task initial state: a cheap hash of `(element,
/// task_id)` mapped into `[0, 1)`. Mirrored exactly by the Python oracle
/// (`python/tests/test_aot.py::initial_state`) so checksums can be
/// compared across the language boundary.
pub fn initial_state(artifact: &Artifact, task_id: u64) -> Vec<f32> {
    (0..artifact.elements())
        .map(|i| {
            let x = (i as u64).wrapping_add(task_id.wrapping_mul(7919));
            let h = x.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 40;
            (h as f32) / (1u64 << 24) as f32
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn initial_state_deterministic_and_bounded() {
        let a = Artifact {
            name: "simstep".into(),
            batch: 2,
            h: 4,
            w: 4,
        };
        let s1 = initial_state(&a, 7);
        let s2 = initial_state(&a, 7);
        let s3 = initial_state(&a, 8);
        assert_eq!(s1, s2);
        assert_ne!(s1, s3);
        assert_eq!(s1.len(), 32);
        assert!(s1.iter().all(|&x| (0.0..1.0).contains(&x)));
    }

    #[test]
    fn spawn_missing_artifact_fails_fast() {
        let err = RuntimeServer::spawn(PathBuf::from("/nonexistent/simstep_1x4x4.hlo.txt"));
        assert!(err.is_err());
    }
    // Live-execution tests in rust/tests/runtime_integration.rs.
}
