//! Offline stand-in for the `xla` PJRT bindings.
//!
//! The original runtime layer linked the `xla` crate (xla_extension
//! 0.5.1) to compile and execute the AOT-exported HLO-text artifacts.
//! The offline build vendors no external crates, so this module mirrors
//! the small slice of the `xla` API surface the runtime layer uses and
//! fails fast at client construction. The rest of the crate (DES
//! scheduler, aggregation, placement, launch tools) is unaffected; code
//! that needs live PJRT checks [`AVAILABLE`] / `runtime::pjrt_available`
//! and skips gracefully.
//!
//! Re-enabling real execution is a one-line change in
//! [`crate::runtime::executable`]: swap `use crate::runtime::stub as
//! xla;` for the real crate import.

use std::fmt;

/// Whether this build carries a live PJRT runtime.
pub const AVAILABLE: bool = false;

/// Error type mirroring `xla::Error`.
#[derive(Debug)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

fn unavailable<T>() -> Result<T, XlaError> {
    Err(XlaError(
        "PJRT runtime not available in this build (offline stub; see runtime::stub)".to_string(),
    ))
}

/// Stand-in for `xla::PjRtClient`.
pub struct PjRtClient;

impl PjRtClient {
    /// The real binding constructs a CPU PJRT client; the stub fails fast.
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        unavailable()
    }
}

/// Stand-in for `xla::HloModuleProto`.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, XlaError> {
        unavailable()
    }
}

/// Stand-in for `xla::XlaComputation`.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Stand-in for `xla::PjRtLoadedExecutable`.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        unavailable()
    }
}

/// Stand-in for `xla::PjRtBuffer`.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        unavailable()
    }
}

/// Stand-in for `xla::Literal`.
pub struct Literal;

impl Literal {
    pub fn vec1(_xs: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, XlaError> {
        unavailable()
    }

    pub fn to_tuple2(&self) -> Result<(Literal, Literal), XlaError> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_fast_with_clear_message() {
        let err = PjRtClient::cpu().err().expect("stub has no client");
        assert!(err.to_string().contains("offline stub"), "{err}");
        assert!(!AVAILABLE);
    }
}
