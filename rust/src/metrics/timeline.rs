//! Utilization-over-time series (the paper's Fig 2).
//!
//! The scheduler sim emits `(time, running_cores)` step points; this
//! module normalizes them against the slice's processor count, shifts
//! time zero to the first scheduling event (the paper does the same:
//! "we shifted the time in such a way that the initial time zero is to be
//! the first scheduling event"), and resamples onto a regular grid for
//! plotting / CSV export.

use crate::sim::Time;

/// A utilization series for one run.
#[derive(Debug, Clone)]
pub struct UtilizationSeries {
    /// Regular-grid samples `(t, utilization in [0,1])`, t starting at 0.
    pub samples: Vec<(Time, f64)>,
    /// Grid step, seconds.
    pub dt: Time,
    /// Processors the utilization is normalized against.
    pub processors: u64,
}

impl UtilizationSeries {
    /// Build from raw step points. `processors` is P for the run;
    /// `dt` the sampling step.
    pub fn from_steps(steps: &[(Time, u64)], processors: u64, dt: Time) -> UtilizationSeries {
        assert!(dt > 0.0 && processors > 0);
        if steps.is_empty() {
            return UtilizationSeries { samples: vec![], dt, processors };
        }
        let t0 = steps[0].0; // first scheduling event = time zero
        let t_end = steps.last().expect("non-empty").0;
        let n = ((t_end - t0) / dt).ceil() as usize + 1;
        let mut samples = Vec::with_capacity(n);
        let mut idx = 0;
        let mut current: u64 = 0;
        for k in 0..n {
            let t = t0 + k as f64 * dt;
            while idx < steps.len() && steps[idx].0 <= t {
                current = steps[idx].1;
                idx += 1;
            }
            samples.push((t - t0, current as f64 / processors as f64));
        }
        UtilizationSeries { samples, dt, processors }
    }

    /// Peak utilization reached.
    pub fn peak(&self) -> f64 {
        self.samples.iter().map(|s| s.1).fold(0.0, f64::max)
    }

    /// First time utilization reaches `level` (None if never).
    pub fn time_to_reach(&self, level: f64) -> Option<Time> {
        self.samples.iter().find(|s| s.1 >= level).map(|s| s.0)
    }

    /// Integral of utilization over time (≈ delivered processor-seconds /
    /// P). For a perfect run this equals T_job.
    pub fn area(&self) -> f64 {
        self.samples.iter().map(|s| s.1 * self.dt).sum()
    }

    /// Mean utilization over the span where the job is active.
    pub fn mean_while_active(&self) -> f64 {
        let active: Vec<f64> = self
            .samples
            .iter()
            .map(|s| s.1)
            .filter(|&u| u > 0.0)
            .collect();
        if active.is_empty() {
            0.0
        } else {
            active.iter().sum::<f64>() / active.len() as f64
        }
    }

    /// Downsample to at most `max_points` for plotting.
    pub fn thin(&self, max_points: usize) -> Vec<(Time, f64)> {
        if self.samples.len() <= max_points {
            return self.samples.clone();
        }
        let stride = self.samples.len() as f64 / max_points as f64;
        (0..max_points)
            .map(|i| self.samples[(i as f64 * stride) as usize])
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_resampling() {
        // 4 cores: 2 busy at t=10, 4 at t=11, 0 at t=20.
        let steps = vec![(10.0, 2), (11.0, 4), (20.0, 0)];
        let s = UtilizationSeries::from_steps(&steps, 4, 1.0);
        assert_eq!(s.samples[0], (0.0, 0.5), "time shifted to first event");
        assert_eq!(s.samples[1], (1.0, 1.0));
        assert_eq!(s.samples.last().unwrap().1, 0.0);
        assert_eq!(s.peak(), 1.0);
    }

    #[test]
    fn time_to_reach_full() {
        let steps = vec![(0.0, 1), (5.0, 2), (9.0, 4)];
        let s = UtilizationSeries::from_steps(&steps, 4, 1.0);
        assert_eq!(s.time_to_reach(1.0), Some(9.0));
        assert_eq!(s.time_to_reach(0.25), Some(0.0));
        let never = UtilizationSeries::from_steps(&[(0.0, 1), (2.0, 0)], 4, 1.0);
        assert_eq!(never.time_to_reach(0.9), None);
    }

    #[test]
    fn area_approximates_work() {
        // 4 cores fully busy for 100 s → area ≈ 100.
        let steps = vec![(0.0, 4), (100.0, 0)];
        let s = UtilizationSeries::from_steps(&steps, 4, 0.5);
        assert!((s.area() - 100.0).abs() < 1.0, "area {}", s.area());
    }

    #[test]
    fn empty_steps() {
        let s = UtilizationSeries::from_steps(&[], 4, 1.0);
        assert!(s.samples.is_empty());
        assert_eq!(s.peak(), 0.0);
        assert_eq!(s.area(), 0.0);
    }

    #[test]
    fn thinning_preserves_endpoints_shape() {
        let steps: Vec<(f64, u64)> = (0..1000).map(|i| (i as f64, (i % 5) as u64)).collect();
        let s = UtilizationSeries::from_steps(&steps, 4, 1.0);
        let thin = s.thin(100);
        assert_eq!(thin.len(), 100);
        assert_eq!(thin[0].0, 0.0);
    }

    #[test]
    fn mean_while_active_ignores_idle_tail() {
        let steps = vec![(0.0, 4), (10.0, 0), (100.0, 0)];
        let s = UtilizationSeries::from_steps(&steps, 4, 1.0);
        assert!(s.mean_while_active() > 0.9);
    }
}
