//! Per-class contention metrics: launch latency and utilization split
//! by job class (interactive vs batch).
//!
//! The paper's pitch is "interactive jobs launch fast while batch keeps
//! the machine utilized"; these metrics make both halves measurable for
//! one contention run. Launch latency is the scheduler-log convention
//! (task start − job submit); utilization is delivered core-seconds as
//! a share of cluster capacity over the run's span.

use crate::scheduler::accounting::TaskRecord;
use crate::scheduler::core::{PoolOutcome, ShardOutcome};
use crate::sim::Time;
use crate::util::stats;
use crate::workload::contention::{JobClass, JOB_CLASSES};

/// Per-class summary of one contention run.
#[derive(Debug, Clone)]
pub struct ClassReport {
    pub class: JobClass,
    /// Jobs submitted in this class.
    pub jobs: usize,
    /// Scheduling tasks across those jobs.
    pub tasks: usize,
    /// Tasks that finished (reached DONE).
    pub completed: usize,
    /// Median of task start − job submit, seconds.
    pub median_launch_latency: Time,
    /// 95th percentile launch latency, seconds.
    pub p95_launch_latency: Time,
    /// Worst launch latency in the class (max start − submit), seconds;
    /// NaN when nothing started. The fairness-bound metric: aging caps
    /// it, static priorities let it grow with the opposing stream.
    pub max_launch_latency: Time,
    /// Oldest never-started task's age at the end of the run, seconds
    /// (0 when every task started) — the outright-starvation indicator.
    pub starvation_age: Time,
    /// Delivered core-seconds by this class.
    pub core_seconds: f64,
    /// Share of cluster capacity over the run span, in `[0, 1]`.
    pub utilization: f64,
}

/// Compute per-class reports. `classes[job]` maps dense job ids to
/// their class; `total_cores` is cluster capacity. Returns the reports
/// (one per class, [`JOB_CLASSES`] order) and the run span used for
/// utilization (first submit → last cleanup).
pub fn per_class(
    records: &[TaskRecord],
    classes: &[JobClass],
    total_cores: u64,
) -> (Vec<ClassReport>, Time) {
    let mut first_submit = f64::INFINITY;
    let mut last_cleanup: f64 = 0.0;
    // The run's horizon for starvation ages: the latest timestamp any
    // record carries. Unlike `last_cleanup` it stays meaningful when a
    // run is truncated before anything finishes — the exact situation
    // a starvation metric must not report as zero.
    let mut run_end: f64 = 0.0;
    for r in records {
        first_submit = first_submit.min(r.submit_t);
        run_end = run_end.max(r.submit_t);
        if let Some(t) = r.start_t {
            run_end = run_end.max(t);
        }
        if let Some(t) = r.end_t {
            run_end = run_end.max(t);
        }
        if let Some(c) = r.cleanup_t {
            last_cleanup = last_cleanup.max(c);
            run_end = run_end.max(c);
        }
    }
    let span = if first_submit.is_finite() && last_cleanup > first_submit {
        last_cleanup - first_submit
    } else {
        0.0
    };
    let capacity = total_cores as f64 * span;
    let reports = JOB_CLASSES
        .iter()
        .map(|&class| {
            let mut latencies = Vec::new();
            let mut core_seconds = 0.0;
            let mut tasks = 0usize;
            let mut completed = 0usize;
            let mut starvation_age: f64 = 0.0;
            for r in records {
                if classes.get(r.job as usize).copied() != Some(class) {
                    continue;
                }
                tasks += 1;
                match r.start_t {
                    Some(start) => {
                        latencies.push(start - r.submit_t);
                        if let Some(end) = r.end_t {
                            core_seconds += r.cores as f64 * (end - start).max(0.0);
                        }
                    }
                    // Never started: its age keeps growing until the
                    // run's end.
                    None => {
                        starvation_age = starvation_age.max((run_end - r.submit_t).max(0.0));
                    }
                }
                if r.cleanup_t.is_some() {
                    completed += 1;
                }
            }
            let jobs = classes.iter().filter(|&&c| c == class).count();
            let max_launch_latency = if latencies.is_empty() {
                f64::NAN
            } else {
                latencies.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
            };
            ClassReport {
                class,
                jobs,
                tasks,
                completed,
                median_launch_latency: stats::median(&latencies),
                p95_launch_latency: stats::percentile(&latencies, 95.0),
                max_launch_latency,
                starvation_age,
                core_seconds,
                utilization: if capacity > 0.0 {
                    core_seconds / capacity
                } else {
                    0.0
                },
            }
        })
        .collect();
    (reports, span)
}

/// Pool-side summary of one contention run: how the rapid-launch
/// subsystem performed next to the per-class batch metrics. Scalar
/// fields aggregate over the fleet; [`Self::shards`] carries the
/// per-shard split (one entry per shard, in shard-config order).
#[derive(Debug, Clone)]
pub struct PoolReport {
    /// Tasks launched through the fleet's node-based dispatch path.
    pub launches: u64,
    /// Nodes taken from batch (leases + drains) across all resizes.
    pub grows: u64,
    /// Nodes returned to batch across all resizes.
    pub shrinks: u64,
    /// True fleet-wide peak of simultaneous leases (shards peaking at
    /// different times do not add up).
    pub peak_leased: usize,
    /// Free nodes transferred between sibling shards by the rebalancer.
    pub borrows: u64,
    /// Median launch latency of pooled tasks (start − submit), seconds.
    pub median_launch_latency: Time,
    /// 95th percentile pooled launch latency, seconds.
    pub p95_launch_latency: Time,
    /// Core-seconds delivered by pooled tasks as a share of cluster
    /// capacity over the run span.
    pub utilization: f64,
    /// Per-shard reports (the v3 export's `shard:` rows).
    pub shards: Vec<ShardReport>,
}

/// One shard's slice of a [`PoolReport`].
#[derive(Debug, Clone)]
pub struct ShardReport {
    /// Shard name from the fleet config.
    pub name: String,
    /// Tasks launched through this shard.
    pub launches: u64,
    /// Launched tasks that reached DONE.
    pub completed: usize,
    /// Nodes this shard took from batch across all resizes.
    pub grows: u64,
    /// Nodes this shard returned to batch across all resizes.
    pub shrinks: u64,
    /// Peak simultaneous lease count of this shard.
    pub peak_leased: usize,
    /// Median launch latency of this shard's tasks, seconds.
    pub median_launch_latency: Time,
    /// 95th percentile launch latency of this shard's tasks, seconds.
    pub p95_launch_latency: Time,
    /// Core-seconds this shard's tasks delivered.
    pub core_seconds: f64,
    /// Those core-seconds as a share of cluster capacity over the span.
    pub utilization: f64,
}

/// Latency/throughput join over the pool-launched records: every record
/// tagged with a `pool_shard` matching `shard` (`None` = any shard).
/// The per-task attribution lives on the records themselves — the fleet
/// keeps only counters and a bounded recent-launch ring.
fn join_launches(records: &[TaskRecord], shard: Option<u32>) -> (Vec<Time>, f64, usize) {
    let mut latencies = Vec::new();
    let mut core_seconds = 0.0;
    let mut completed = 0usize;
    for r in records {
        let Some(s) = r.pool_shard else { continue };
        if shard.is_some_and(|want| want != s) {
            continue;
        }
        if let Some(start) = r.start_t {
            latencies.push(start - r.submit_t);
            if let Some(end) = r.end_t {
                core_seconds += r.cores as f64 * (end - start).max(0.0);
            }
        }
        if r.cleanup_t.is_some() {
            completed += 1;
        }
    }
    (latencies, core_seconds, completed)
}

/// Compute one shard's report (`sid` is the shard's dense fleet index,
/// matching the `pool_shard` record tags).
fn shard_report(
    records: &[TaskRecord],
    shard: &ShardOutcome,
    sid: u32,
    total_cores: u64,
    span: Time,
) -> ShardReport {
    let (latencies, core_seconds, completed) = join_launches(records, Some(sid));
    let capacity = total_cores as f64 * span;
    ShardReport {
        name: shard.name.clone(),
        launches: shard.launches,
        completed,
        grows: shard.grows,
        shrinks: shard.shrinks,
        peak_leased: shard.peak_leased,
        median_launch_latency: stats::median(&latencies),
        p95_launch_latency: stats::percentile(&latencies, 95.0),
        core_seconds,
        utilization: if capacity > 0.0 {
            core_seconds / capacity
        } else {
            0.0
        },
    }
}

/// Compute the pool report for one run: joins the records' `pool_shard`
/// launch tags against the fleet counters. `span` is the same
/// first-submit → last-cleanup window [`per_class`] returns, so pool
/// utilization is directly comparable to the class shares.
pub fn pool_report(
    records: &[TaskRecord],
    pool: &PoolOutcome,
    total_cores: u64,
    span: Time,
) -> PoolReport {
    let (latencies, core_seconds, _) = join_launches(records, None);
    let capacity = total_cores as f64 * span;
    PoolReport {
        launches: pool.launches,
        grows: pool.grows,
        shrinks: pool.shrinks,
        peak_leased: pool.peak_leased,
        borrows: pool.borrows,
        median_launch_latency: stats::median(&latencies),
        p95_launch_latency: stats::percentile(&latencies, 95.0),
        utilization: if capacity > 0.0 {
            core_seconds / capacity
        } else {
            0.0
        },
        shards: pool
            .shards
            .iter()
            .enumerate()
            .map(|(sid, s)| shard_report(records, s, sid as u32, total_cores, span))
            .collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::job::TaskState;

    fn rec(job: u64, submit: f64, start: f64, end: f64, cores: u32) -> TaskRecord {
        TaskRecord {
            task: 0,
            job,
            state: TaskState::Done,
            submit_t: submit,
            start_t: Some(start),
            end_t: Some(end),
            cleanup_t: Some(end + 1.0),
            cores,
            pool_shard: None,
        }
    }

    /// `rec` tagged as launched through pool shard `sid`.
    fn pooled(sid: u32, job: u64, submit: f64, start: f64, end: f64, cores: u32) -> TaskRecord {
        TaskRecord {
            pool_shard: Some(sid),
            ..rec(job, submit, start, end, cores)
        }
    }

    #[test]
    fn latency_and_utilization_split_by_class() {
        // Job 0 interactive (2 tasks), job 1 batch (1 task).
        let classes = vec![JobClass::Interactive, JobClass::Batch];
        let records = vec![
            rec(0, 0.0, 1.0, 11.0, 2),  // latency 1, 20 core-s
            rec(0, 0.0, 3.0, 13.0, 2),  // latency 3, 20 core-s
            rec(1, 0.0, 10.0, 110.0, 64), // latency 10, 6400 core-s
        ];
        let (reports, span) = per_class(&records, &classes, 128);
        assert_eq!(span, 111.0, "first submit 0 → last cleanup 111");
        let inter = &reports[0];
        assert_eq!(inter.class, JobClass::Interactive);
        assert_eq!(inter.jobs, 1);
        assert_eq!(inter.tasks, 2);
        assert_eq!(inter.completed, 2);
        assert!((inter.median_launch_latency - 2.0).abs() < 1e-9);
        assert!((inter.core_seconds - 40.0).abs() < 1e-9);
        let batch = &reports[1];
        assert_eq!(batch.tasks, 1);
        assert!((batch.median_launch_latency - 10.0).abs() < 1e-9);
        assert!((batch.utilization - 6400.0 / (128.0 * 111.0)).abs() < 1e-9);
    }

    #[test]
    fn unstarted_tasks_count_but_do_not_skew_latency() {
        let classes = vec![JobClass::Batch];
        let mut unfinished = rec(0, 5.0, 0.0, 0.0, 0);
        unfinished.start_t = None;
        unfinished.end_t = None;
        unfinished.cleanup_t = None;
        let records = vec![rec(0, 5.0, 8.0, 18.0, 4), unfinished];
        let (reports, _) = per_class(&records, &classes, 64);
        let batch = &reports[1];
        assert_eq!(batch.tasks, 2);
        assert_eq!(batch.completed, 1);
        assert!((batch.median_launch_latency - 3.0).abs() < 1e-9);
    }

    #[test]
    fn empty_records_are_safe() {
        let (reports, span) = per_class(&[], &[], 64);
        assert_eq!(span, 0.0);
        assert_eq!(reports.len(), 2);
        assert!(reports[0].median_launch_latency.is_nan());
        assert!(reports[0].max_launch_latency.is_nan());
        assert_eq!(reports[0].starvation_age, 0.0);
        assert_eq!(reports[0].utilization, 0.0);
    }

    #[test]
    fn pool_report_joins_launches_against_records() {
        // Three records; two carry pool-launch tags, the middle one is a
        // batch-path task and stays out of the join.
        let records = vec![
            pooled(0, 0, 0.0, 1.0, 3.0, 64), // latency 1, 128 core-s
            rec(0, 0.0, 50.0, 60.0, 64),     // batch-path task, ignored
            pooled(0, 1, 2.0, 5.0, 7.0, 64), // latency 3, 128 core-s
        ];
        let pool = PoolOutcome {
            launches: 2,
            recent_launches: vec![0, 2],
            grows: 3,
            shrinks: 1,
            peak_leased: 2,
            final_leased: 1,
            borrows: 0,
            shards: vec![],
            invariant_violated: false,
        };
        let r = pool_report(&records, &pool, 128, 10.0);
        assert_eq!(r.launches, 2);
        assert_eq!(r.grows, 3);
        assert_eq!(r.shrinks, 1);
        assert_eq!(r.peak_leased, 2);
        assert!((r.median_launch_latency - 2.0).abs() < 1e-9, "median of 1 and 3");
        assert!((r.utilization - 256.0 / 1280.0).abs() < 1e-9);
        assert!(r.shards.is_empty());
        // Zero-span runs stay safe.
        let empty = pool_report(&records, &pool, 128, 0.0);
        assert_eq!(empty.utilization, 0.0);
    }

    #[test]
    fn shard_reports_split_the_fleet_join() {
        let records = vec![
            pooled(0, 0, 0.0, 1.0, 3.0, 64),  // general: latency 1
            pooled(0, 0, 0.0, 3.0, 5.0, 64),  // general: latency 3
            pooled(1, 1, 2.0, 7.0, 17.0, 64), // large: latency 5
        ];
        let pool = PoolOutcome {
            launches: 3,
            recent_launches: vec![0, 1, 2],
            grows: 2,
            shrinks: 1,
            peak_leased: 3,
            final_leased: 2,
            borrows: 1,
            shards: vec![
                ShardOutcome {
                    name: "general".into(),
                    launches: 2,
                    grows: 1,
                    shrinks: 1,
                    peak_leased: 2,
                    final_leased: 1,
                },
                ShardOutcome {
                    name: "large".into(),
                    launches: 1,
                    grows: 1,
                    shrinks: 0,
                    peak_leased: 1,
                    final_leased: 1,
                },
            ],
            invariant_violated: false,
        };
        let r = pool_report(&records, &pool, 128, 20.0);
        assert_eq!(r.borrows, 1);
        assert_eq!(r.shards.len(), 2);
        let g = &r.shards[0];
        assert_eq!(g.name, "general");
        assert_eq!(g.launches, 2);
        assert_eq!(g.completed, 2);
        assert!((g.median_launch_latency - 2.0).abs() < 1e-9);
        assert!((g.core_seconds - 2.0 * 2.0 * 64.0).abs() < 1e-9);
        let l = &r.shards[1];
        assert_eq!(l.launches, 1);
        assert!((l.median_launch_latency - 5.0).abs() < 1e-9);
        assert!((l.core_seconds - 640.0).abs() < 1e-9);
        assert!((l.utilization - 640.0 / (128.0 * 20.0)).abs() < 1e-9);
        // Aggregate latency covers both shards' tasks.
        assert!((r.median_launch_latency - 3.0).abs() < 1e-9, "median of 1, 3, 5");
    }

    #[test]
    fn max_wait_and_starvation_age() {
        let classes = vec![JobClass::Interactive, JobClass::Batch];
        let mut starved = rec(1, 2.0, 0.0, 0.0, 0);
        starved.start_t = None;
        starved.end_t = None;
        starved.cleanup_t = None;
        let records = vec![
            rec(0, 0.0, 1.0, 5.0, 2),   // latency 1
            rec(0, 0.0, 9.0, 15.0, 2),  // latency 9 (the class max)
            rec(1, 3.0, 50.0, 90.0, 64), // latency 47; cleanup at 91
            starved,                    // batch task never started
        ];
        let (reports, span) = per_class(&records, &classes, 128);
        assert_eq!(span, 91.0);
        let inter = &reports[0];
        assert!((inter.max_launch_latency - 9.0).abs() < 1e-9);
        assert_eq!(inter.starvation_age, 0.0, "everything started");
        let batch = &reports[1];
        assert!((batch.max_launch_latency - 47.0).abs() < 1e-9);
        // The starved task was submitted at 2 and the run ended at 91.
        assert!((batch.starvation_age - 89.0).abs() < 1e-9);
    }

    #[test]
    fn starvation_age_survives_truncated_runs() {
        // No task ever reached cleanup; the starved task's age must be
        // measured against the latest timestamp seen, not cleanups
        // (which would clamp it to zero in the worst starvation case).
        let classes = vec![JobClass::Batch];
        let mut running = rec(0, 0.0, 5.0, 0.0, 4);
        running.end_t = None;
        running.cleanup_t = None;
        let mut starved = rec(0, 1.0, 0.0, 0.0, 0);
        starved.start_t = None;
        starved.end_t = None;
        starved.cleanup_t = None;
        let (reports, span) = per_class(&[running, starved], &classes, 64);
        assert_eq!(span, 0.0, "no cleanups: utilization span stays empty");
        let batch = &reports[1];
        assert!(
            (batch.starvation_age - 4.0).abs() < 1e-9,
            "latest start (5) minus submit (1), got {}",
            batch.starvation_age
        );
    }
}
