//! Overhead analysis (the paper's Fig 1 and headline speedup).
//!
//! Overhead = runtime − T_job; Fig 1 plots it normalized by T_job per
//! `(task time, scale, mode)` using the median of three runs. The
//! headline claim compares multi-level vs node-based overhead at 512
//! nodes: ~57× on medians, ~100× on best runtimes.

use crate::config::Mode;
use crate::util::stats;

/// One Fig 1 point: a `(scale, task time, mode)` cell with its three runs.
#[derive(Debug, Clone)]
pub struct OverheadPoint {
    pub nodes: u32,
    pub task_time: f64,
    pub mode: Mode,
    /// Measured runtimes of the (usually three) runs, seconds.
    pub runtimes: Vec<f64>,
    /// Job time per processor T_job.
    pub t_job: f64,
}

impl OverheadPoint {
    /// Median runtime (the paper's reported statistic).
    pub fn median_runtime(&self) -> f64 {
        stats::median(&self.runtimes)
    }

    /// Best (minimum) runtime.
    pub fn best_runtime(&self) -> f64 {
        stats::min(&self.runtimes)
    }

    /// Median overhead, seconds.
    pub fn overhead(&self) -> f64 {
        self.median_runtime() - self.t_job
    }

    /// Fig 1's vertical axis: median overhead normalized by T_job.
    pub fn norm_overhead(&self) -> f64 {
        self.overhead() / self.t_job
    }

    /// Best-run overhead.
    pub fn best_overhead(&self) -> f64 {
        self.best_runtime() - self.t_job
    }
}

/// Normalized overhead for a single runtime.
pub fn norm_overhead(runtime: f64, t_job: f64) -> f64 {
    (runtime - t_job) / t_job
}

/// Overhead ratio between two points (e.g. M* / N* at the same cell) —
/// the paper's "up to 100 times faster scheduler performance".
/// `best` selects best-runtime basis instead of median.
pub fn speedup(multi: &OverheadPoint, node: &OverheadPoint, best: bool) -> f64 {
    let (m, n) = if best {
        (multi.best_overhead(), node.best_overhead())
    } else {
        (multi.overhead(), node.overhead())
    };
    if n <= 0.0 {
        f64::INFINITY
    } else {
        m / n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn point(mode: Mode, runtimes: &[f64]) -> OverheadPoint {
        OverheadPoint {
            nodes: 512,
            task_time: 60.0,
            mode,
            runtimes: runtimes.to_vec(),
            t_job: 240.0,
        }
    }

    #[test]
    fn paper_512_node_long_cell() {
        // Table III, 512 nodes, t=60: M* 2644,2768,2791; N* 266,487,312.
        let m = point(Mode::MultiLevel, &[2644.0, 2768.0, 2791.0]);
        let n = point(Mode::NodeBased, &[266.0, 487.0, 312.0]);
        assert_eq!(m.median_runtime(), 2768.0);
        assert_eq!(n.median_runtime(), 312.0);
        let med = speedup(&m, &n, false);
        let best = speedup(&m, &n, true);
        // Paper: "about 57x (median) and 100x (best)".
        assert!((30.0..80.0).contains(&med), "median speedup {med}");
        assert!((80.0..120.0).contains(&best), "best speedup {best}");
    }

    #[test]
    fn norm_overhead_axis() {
        assert!((norm_overhead(242.0, 240.0) - 2.0 / 240.0).abs() < 1e-12);
        assert!((norm_overhead(480.0, 240.0) - 1.0).abs() < 1e-12);
        let p = point(Mode::NodeBased, &[241.0, 242.0, 243.0]);
        assert!(p.norm_overhead() < 0.1, "node-based under 10% (paper)");
    }

    #[test]
    fn zero_or_negative_node_overhead_is_infinite_speedup() {
        let m = point(Mode::MultiLevel, &[300.0]);
        let n = point(Mode::NodeBased, &[240.0]);
        assert!(speedup(&m, &n, false).is_infinite());
    }

    #[test]
    fn best_vs_median_basis() {
        let p = point(Mode::MultiLevel, &[250.0, 300.0, 350.0]);
        assert_eq!(p.median_runtime(), 300.0);
        assert_eq!(p.best_runtime(), 250.0);
        assert_eq!(p.overhead(), 60.0);
        assert_eq!(p.best_overhead(), 10.0);
    }
}
