//! Metrics: utilization timelines (Fig 2), overhead analysis (Fig 1),
//! per-class contention metrics (launch latency / utilization by job
//! class), and paper-style report rendering (Tables I–III).

pub mod contention;
pub mod overhead;
pub mod report;
pub mod timeline;

pub use contention::{per_class, pool_report, ClassReport, PoolReport};
pub use overhead::{norm_overhead, speedup, OverheadPoint};
pub use timeline::UtilizationSeries;
