//! Metrics: utilization timelines (Fig 2), overhead analysis (Fig 1),
//! and paper-style report rendering (Tables I–III).

pub mod overhead;
pub mod report;
pub mod timeline;

pub use overhead::{norm_overhead, speedup, OverheadPoint};
pub use timeline::UtilizationSeries;
