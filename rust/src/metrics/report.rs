//! Paper-style report rendering: Tables I–III, Fig 1 data, Fig 2 series,
//! as ASCII tables/plots, CSV files and JSON documents.

use crate::config::presets::{TaskConfig, CORES_PER_NODE, NODE_SCALES, TASK_CONFIGS};
use crate::config::Mode;
use crate::metrics::overhead::OverheadPoint;
use crate::metrics::timeline::UtilizationSeries;
use crate::util::csv::Csv;
use crate::util::fmt::{ascii_plot, count, Table};
use crate::util::json::Json;

/// Render Table I (parameter sets).
pub fn table1() -> String {
    let mut t = Table::new(vec!["Configuration", "Rapid", "Fast", "Medium", "Long"]);
    let row = |name: &str, f: &dyn Fn(&TaskConfig) -> String| {
        let mut cells = vec![name.to_string()];
        cells.extend(TASK_CONFIGS.iter().map(f));
        cells
    };
    t.row(row("Task time, t", &|c| format!("{}s", c.task_time)));
    t.row(row("Job time per processor, T_job", &|c| {
        format!("{}s", c.job_time)
    }));
    t.row(row("Tasks per processor, n", &|c| {
        format!("{}", c.tasks_per_processor())
    }));
    t.render()
}

/// Render Table II (benchmark configurations).
pub fn table2() -> String {
    let mut t = Table::new(vec!["Nodes", "Cores/node", "Processors P", "Total processor time"]);
    for &n in &NODE_SCALES {
        let p = n as u64 * CORES_PER_NODE as u64;
        let hours = p as f64 * 240.0 / 3600.0;
        t.row(vec![
            n.to_string(),
            CORES_PER_NODE.to_string(),
            count(p),
            format!("{hours:.1} h"),
        ]);
    }
    t.render()
}

/// Render Table III (run times) from measured points. Points are keyed by
/// `(nodes, task_time, mode)`; missing cells render as N/A, matching the
/// paper's 512-node multi-level gaps.
pub fn table3(points: &[OverheadPoint]) -> String {
    let mut t = Table::new(vec!["Config", "Mode", "t=1", "t=5", "t=30", "t=60"]);
    for &nodes in &NODE_SCALES {
        for mode in [Mode::MultiLevel, Mode::NodeBased] {
            let mut cells = vec![format!("{nodes} nodes"), mode.short().to_string()];
            for tc in &TASK_CONFIGS {
                let cell = points.iter().find(|p| {
                    p.nodes == nodes && p.mode == mode && p.task_time == tc.task_time
                });
                cells.push(match cell {
                    Some(p) => p
                        .runtimes
                        .iter()
                        .map(|r| format!("{r:.0}"))
                        .collect::<Vec<_>>()
                        .join(", "),
                    None => "N/A".to_string(),
                });
            }
            t.row(cells);
        }
    }
    t.render()
}

/// Fig 1 as CSV: one row per `(nodes, task_time, mode)` with the median
/// normalized overhead.
pub fn fig1_csv(points: &[OverheadPoint]) -> Csv {
    let mut c = Csv::with_header(&[
        "nodes",
        "task_time_s",
        "mode",
        "median_runtime_s",
        "overhead_s",
        "norm_overhead",
    ]);
    for p in points {
        c.row(&[
            p.nodes.to_string(),
            format!("{}", p.task_time),
            p.mode.short().to_string(),
            format!("{:.1}", p.median_runtime()),
            format!("{:.1}", p.overhead()),
            format!("{:.4}", p.norm_overhead()),
        ]);
    }
    c
}

/// Fig 1 as an ASCII scatter: normalized overhead vs task time, one series
/// per `(scale, mode)`.
pub fn fig1_plot(points: &[OverheadPoint]) -> String {
    let mut series: Vec<(String, Vec<(f64, f64)>)> = Vec::new();
    for &nodes in &NODE_SCALES {
        for mode in [Mode::MultiLevel, Mode::NodeBased] {
            let pts: Vec<(f64, f64)> = points
                .iter()
                .filter(|p| p.nodes == nodes && p.mode == mode)
                .map(|p| (p.task_time, p.norm_overhead().max(0.0)))
                .collect();
            if !pts.is_empty() {
                series.push((format!("{} {}n", mode.short(), nodes), pts));
            }
        }
    }
    let y_max = series
        .iter()
        .flat_map(|(_, pts)| pts.iter().map(|p| p.1))
        .fold(0.1_f64, f64::max);
    ascii_plot(&series, 64, 20, y_max * 1.05)
}

/// Fig 2 as CSV: long format `(label, t, utilization)`.
pub fn fig2_csv(series: &[(String, UtilizationSeries)]) -> Csv {
    let mut c = Csv::with_header(&["run", "t_s", "utilization"]);
    for (label, s) in series {
        for &(t, u) in &s.thin(400) {
            c.row(&[label.clone(), format!("{t:.1}"), format!("{u:.4}")]);
        }
    }
    c
}

/// Fig 2 as an ASCII plot (utilization vs time).
pub fn fig2_plot(series: &[(String, UtilizationSeries)]) -> String {
    let plot_series: Vec<(String, Vec<(f64, f64)>)> = series
        .iter()
        .map(|(label, s)| (label.clone(), s.thin(64)))
        .collect();
    ascii_plot(&plot_series, 72, 22, 1.0)
}

/// Full results document (for `results/*.json`).
pub fn results_json(points: &[OverheadPoint]) -> Json {
    let mut arr = Vec::new();
    for p in points {
        arr.push(
            Json::obj()
                .set("nodes", p.nodes as u64)
                .set("task_time_s", p.task_time)
                .set("mode", p.mode.short())
                .set("runtimes_s", p.runtimes.clone())
                .set("median_runtime_s", p.median_runtime())
                .set("overhead_s", p.overhead())
                .set("norm_overhead", p.norm_overhead()),
        );
    }
    Json::obj()
        .set("t_job_s", 240.0)
        .set("cells", Json::Arr(arr))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_points() -> Vec<OverheadPoint> {
        vec![
            OverheadPoint {
                nodes: 32,
                task_time: 1.0,
                mode: Mode::MultiLevel,
                runtimes: vec![305.0, 284.0, 291.0],
                t_job: 240.0,
            },
            OverheadPoint {
                nodes: 32,
                task_time: 1.0,
                mode: Mode::NodeBased,
                runtimes: vec![241.0, 242.0, 243.0],
                t_job: 240.0,
            },
        ]
    }

    #[test]
    fn table1_matches_paper_numbers() {
        let t = table1();
        assert!(t.contains("240"), "rapid tasks per processor");
        assert!(t.contains("1s") && t.contains("60s"));
    }

    #[test]
    fn table2_totals() {
        let t = table2();
        assert!(t.contains("32,768"));
        assert!(t.contains("2184.5 h"));
        assert!(t.contains("136.5 h"));
    }

    #[test]
    fn table3_renders_measured_and_na() {
        let t = table3(&sample_points());
        assert!(t.contains("305, 284, 291"));
        assert!(t.contains("241, 242, 243"));
        assert!(t.contains("N/A"), "unmeasured cells are N/A");
        assert!(t.contains("M*") && t.contains("N*"));
    }

    #[test]
    fn fig1_csv_and_plot() {
        let pts = sample_points();
        let c = fig1_csv(&pts);
        assert!(c.as_str().contains("nodes,task_time_s,mode"));
        assert!(c.as_str().lines().count() == 3);
        let plot = fig1_plot(&pts);
        assert!(plot.contains("M* 32n"));
        assert!(plot.contains("N* 32n"));
    }

    #[test]
    fn fig2_csv_shape() {
        let s = UtilizationSeries::from_steps(&[(0.0, 64), (100.0, 0)], 64, 1.0);
        let c = fig2_csv(&[("M-S1-A".to_string(), s)]);
        let lines: Vec<&str> = c.as_str().lines().collect();
        assert_eq!(lines[0], "run,t_s,utilization");
        assert!(lines.len() > 50);
        assert!(lines[1].starts_with("M-S1-A,0.0,1.0000"));
    }

    #[test]
    fn json_document() {
        let j = results_json(&sample_points());
        let s = j.to_string();
        assert!(s.contains("\"cells\""));
        assert!(s.contains("\"median_runtime_s\":291"));
    }
}
