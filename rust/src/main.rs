//! `llsched` — the leader binary: runs the paper's benchmarks, renders
//! tables/figures, and drives demo workloads.
//!
//! ```text
//! llsched table1                       # Table I (parameter sets)
//! llsched table2                       # Table II (benchmark configs)
//! llsched table3 [--quick] [--runs N] [--include-na] [--out DIR]
//! llsched fig1   [--quick] [--out DIR] # overhead scatter (CSV + ASCII)
//! llsched fig2   [--quick] [--out DIR] # utilization curves (CSV + ASCII)
//! llsched speedup                      # headline 57×/100× numbers
//! llsched run CONFIG.toml              # one run from a config file
//! llsched spot [--nodes N]             # spot release latency demo
//! llsched artifacts                    # check PJRT artifacts load
//! ```

use llsched::coordinator::cli::Args;
use llsched::coordinator::experiment::{
    contention_csv, contention_json, fig2_label, median_runs, run_contention_federated,
    run_contention_with, run_federation, run_matrix, run_placement_sweep, ContentionOpts,
    ContentionResult, ExperimentOpts, FederationSweepOpts,
};
use llsched::config::{Mode, RunConfig};
use llsched::error::Result;
use llsched::federation::FederationConfig;
use llsched::fault::audit::AuditLog;
use llsched::fault::scenario::ChurnScenario;
use llsched::fault::FaultConfig;
use llsched::metrics::overhead::speedup;
use llsched::metrics::report;
use llsched::obs::{
    build_timeline, decision_log, perfetto_json, perfetto_spans, profile_lines,
    reconstruct_spans, timeline_csv, timeline_json, JobSpan, SpanSet, Subsystem, WaitBlame,
    BLAME_CAUSES,
};
use llsched::placement::Strategy;
use llsched::pool::{PoolConfig, ShardConfig};
use llsched::scheduler::queue::AgingPolicy;
use llsched::util::csv::Csv;
use llsched::util::fmt::dur;
use llsched::util::json::Json;
use llsched::util::stats::percentile;
use llsched::workload::contention::{ContentionMix, JobClass, WalltimeError, JOB_CLASSES};
use std::path::PathBuf;

fn main() {
    let args = match Args::parse(std::env::args().skip(1)) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let code = match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn dispatch(args: &Args) -> Result<()> {
    match args.command.as_str() {
        "" | "help" | "--help" => {
            print!("{}", HELP);
            Ok(())
        }
        "table1" => {
            println!("Table I — parameter sets (task time vs tasks per processor)\n");
            println!("{}", report::table1());
            Ok(())
        }
        "table2" => {
            println!("Table II — benchmark configurations\n");
            println!("{}", report::table2());
            Ok(())
        }
        "table3" => cmd_table3(args),
        "fig1" => cmd_fig1(args),
        "fig2" => cmd_fig2(args),
        "speedup" => cmd_speedup(args),
        "run" => cmd_run(args),
        "placement" => cmd_placement(args),
        "contention" => cmd_contention(args),
        "pool" => cmd_pool(args),
        "churn" => cmd_churn(args),
        "federate" => cmd_federate(args),
        "trace" => cmd_trace(args),
        "explain" => cmd_explain(args),
        "spot" => cmd_spot(args),
        "artifacts" => cmd_artifacts(args),
        other => {
            eprint!("{}", HELP);
            Err(llsched::Error::Config(format!("unknown command {other:?}")))
        }
    }
}

const HELP: &str = "\
llsched — node-based job scheduling (HPEC 2021 reproduction)

commands:
  table1                    print Table I (parameter sets)
  table2                    print Table II (benchmark configurations)
  table3 [--quick] [--runs N] [--include-na] [--out DIR]
                            run the benchmark matrix, print Table III
  fig1   [--quick] [--out DIR]   overhead scatter (Fig 1) as CSV + ASCII
  fig2   [--quick] [--out DIR]   utilization curves (Fig 2) as CSV + ASCII
  speedup                   headline M*/N* overhead ratios at 512 nodes
  run CONFIG.toml [--seed N] [--placement P]
                            run one configuration; P is one of
                            first-fit|best-fit|spread|random|node-based
  placement [--nodes N] [--mode M] [--task-time T]
                            compare all placement policies on one cell
  contention [--preset P] [--nodes N] [--seed S] [--no-backfill]
             [--compare] [--sweep] [--holds K] [--aging SLOPE]
             [--aging-cap CAP] [--walltime-error SIGMA] [--out DIR]
                            run an interactive-vs-batch contention mix
                            (P: tiny|default|heavy|burst|burst_mixed)
                            and report per-class launch latency +
                            utilization; --compare runs backfill off vs
                            on; --sweep runs every mix; --holds reserves
                            for the top-K blocked whole-node jobs
                            (default 4), --aging boosts priority by
                            SLOPE points per second waited (0 = off,
                            capped at CAP), --walltime-error plans
                            backfill from log-normal noisy estimates;
                            --pool-size K leases K nodes into the
                            rapid-launch pool (0 = off) with
                            --pool-min/--pool-max/--pool-hysteresis
                            elastic bounds; --pools
                            shape:size[:min[:max[:hyst]]],... runs a
                            shape-sharded fleet instead (shapes:
                            general|large|wide|short); --preempt-overdue
                            kills backfilled tasks that overstay their
                            walltime once their hold is due;
                            --out writes per-class CSV + JSON
  pool [--preset P] [--nodes N] [--seed S] [--pool-size K]
       [--pool-min LO] [--pool-max HI] [--pool-hysteresis H]
       [--pools SPEC] [--preempt-overdue] [--compare] [--out DIR]
                            run a rapid-launch pool scenario (default
                            preset: burst — periodic 1000-task short-job
                            volleys over a batch stream; burst_mixed
                            interleaves general and large-capacity
                            volleys for the sharded fleet); --compare
                            runs backfill-only vs pooled/fleet and
                            reports the launch-latency speedup
  churn [--preset P] [--nodes N] [--seed S] [--no-pool] [--replay]
        [--out DIR]
                            run a failure & churn scenario (P:
                            churn_mtbf|churn_reclaim|churn_drain|
                            churn_full, default churn_full): node
                            failures, spot reclamation waves,
                            maintenance drains, and stragglers over a
                            contention mix, with the rapid-launch pool
                            fleet on by default (--no-pool for the
                            batch-only path); --replay re-runs the same
                            (config, seed) and verifies the audit logs
                            match bit-for-bit; --out writes per-class
                            CSV/JSON plus the deterministic audit log
                            (audit.log); see docs/scenarios.md for the
                            cookbook and docs/audit-log.md for the
                            record format
  federate [--instances N] [--nodes N] [--batch B] [--steal-threshold T]
           [--flush F] [--preset P] [--seed S] [--compare]
           [--sweep-rate R1,R2,...] [--jobs J] [--task-time T]
           [--knee K] [--out DIR]
                            run a contention mix through a federated
                            fleet: N independent schedulers (default
                            4), each owning nodes/N of the machine,
                            behind a batching submission gateway
                            (--batch jobs per flush, every --flush
                            seconds) with cross-scheduler work stealing
                            once a partition's pending depth passes
                            --steal-threshold; --compare instead sweeps
                            an open-loop stream of --jobs whole-node
                            jobs of --task-time seconds over a single
                            scheduler vs the fleet at each --sweep-rate
                            jobs/s and reports where each saturates
                            (p95 launch latency past --knee seconds)
                            plus the sustained-rate gain; --out writes
                            the v5 per-class CSV/JSON (or the sweep
                            JSON under --compare)
  trace [--preset P] [--nodes N] [--seed S] [--instances I]
        [--trace-cap N] [--trace-filter SUB] [--trace-out DIR]
        [--format F] [--profile] [--no-pool]
                            run one scenario with the scheduler flight
                            recorder on and export the decision trace:
                            P is any contention or churn preset
                            (default burst); --instances > 1 runs the
                            scenario through the federated gateway
                            fleet; the ring keeps the latest
                            --trace-cap records (default 65536);
                            --trace-filter keeps one subsystem
                            (scheduler|backfill|pool|fault|federation);
                            --format perfetto|log|both (default both)
                            writes trace.json (Chrome/Perfetto trace
                            viewer format) and trace.log (plain-text
                            decision log) under --trace-out (default
                            results); --profile additionally times
                            pick_next on the host and reports it
                            against the cost model's simulated charge;
                            --no-pool traces the batch-only path; see
                            docs/observability.md for the event
                            vocabulary
  explain [--preset P] [--nodes N] [--seed S] [--instances I]
          [--trace-cap N] [--job N] [--worst K] [--slo CLASS:P95]
          [--interval S] [--no-pool] [--out DIR]
                            run one scenario with the flight recorder +
                            wait attribution on and explain where job
                            latency came from: P is any contention or
                            churn preset (default burst); prints the
                            per-class wait-blame rollup over the causes
                            hol|fence|cold_start|requeue_backoff|
                            gateway_batch|steal, then the top --worst K
                            jobs by attributed wait (default 10), or
                            one job's full blame breakdown with
                            --job N; --slo CLASS:P95 (e.g.
                            interactive:2.0) checks the p95 attributed
                            wait of that class per --interval-second
                            window (default 1) and annotates every
                            breached window with its dominant blame
                            cause; --instances > 1 explains the
                            federated fleet, where gateway batching and
                            steal hops become blamable causes; --out
                            writes per-job blame.csv + blame.json, the
                            bucketed fleet timeline.csv +
                            timeline.json, and spans.json (Perfetto
                            wait/run span lanes); see
                            docs/observability.md for the attribution
                            vocabulary
  spot [--nodes N]          spot-job release-latency comparison
  artifacts                 verify AOT artifacts load and execute
";

fn opts_from(args: &Args) -> Result<ExperimentOpts> {
    let quick = args.flag("quick");
    Ok(ExperimentOpts {
        include_na: args.flag("include-na"),
        max_nodes: args.opt_parse("max-nodes", if quick { 128 } else { 512 })?,
        runs: args.opt_parse("runs", if quick { 1 } else { 3 })?,
        dt: 1.0,
    })
}

fn out_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.opt("out").unwrap_or("results"))
}

fn cmd_table3(args: &Args) -> Result<()> {
    args.expect_known(&["quick", "runs", "include-na", "out", "max-nodes"])?;
    let opts = opts_from(args)?;
    let t0 = std::time::Instant::now();
    let (points, _all) = run_matrix(&opts, |r| {
        eprintln!(
            "  {}  runtime {:>8}  overhead {:>8}  fill {:>8}{}",
            r.cell.label(),
            dur(r.runtime),
            dur(r.overhead),
            dur(r.dispatch_span),
            if r.unusable_in_production { "  [guard: unusable in production]" } else { "" },
        );
    })?;
    println!("\nTable III — summary of run times (simulated)\n");
    println!("{}", report::table3(&points));
    let dir = out_dir(args);
    std::fs::create_dir_all(&dir)?;
    std::fs::write(dir.join("table3.json"), report::results_json(&points).to_pretty())?;
    println!(
        "(matrix wall time {:.1}s; JSON in {:?})",
        t0.elapsed().as_secs_f64(),
        dir.join("table3.json")
    );
    Ok(())
}

fn cmd_fig1(args: &Args) -> Result<()> {
    args.expect_known(&["quick", "runs", "include-na", "out", "max-nodes"])?;
    let opts = opts_from(args)?;
    let (points, _) = run_matrix(&opts, |_| {})?;
    println!("Fig 1 — normalized overhead vs task time\n");
    println!("{}", report::fig1_plot(&points));
    let dir = out_dir(args);
    report::fig1_csv(&points).save(&dir.join("fig1.csv"))?;
    println!("(CSV in {:?})", dir.join("fig1.csv"));
    Ok(())
}

fn cmd_fig2(args: &Args) -> Result<()> {
    args.expect_known(&["quick", "runs", "include-na", "out", "max-nodes"])?;
    let opts = opts_from(args)?;
    let (_, all) = run_matrix(&opts, |_| {})?;
    let med = median_runs(&all);
    let series: Vec<(String, llsched::metrics::timeline::UtilizationSeries)> = med
        .iter()
        .map(|r| (fig2_label(&r.cell), r.utilization.clone()))
        .collect();
    println!("Fig 2 — system utilization over time (median runs)\n");
    // Plot a readable subset: largest scale, both modes, t=60.
    let subset: Vec<_> = series
        .iter()
        .filter(|(l, _)| l.ends_with("t60"))
        .cloned()
        .collect();
    println!("{}", report::fig2_plot(&subset));
    let dir = out_dir(args);
    report::fig2_csv(&series).save(&dir.join("fig2.csv"))?;
    println!("(full CSV in {:?})", dir.join("fig2.csv"));
    Ok(())
}

fn cmd_speedup(args: &Args) -> Result<()> {
    args.expect_known(&["runs"])?;
    // Only the cells the headline needs: 512 nodes, t=60, both modes.
    let opts = ExperimentOpts {
        include_na: false,
        max_nodes: 512,
        runs: args.opt_parse("runs", 3)?,
        dt: 1.0,
    };
    let (points, _) = run_matrix(&opts, |_| {})?;
    let m = points
        .iter()
        .find(|p| p.nodes == 512 && p.task_time == 60.0 && p.mode == Mode::MultiLevel)
        .expect("M* 512 t=60 present");
    println!("512-node scale (M* only measurable at t=60, as in the paper):");
    println!(
        "  M* t=60 runtimes: {:?}",
        m.runtimes.iter().map(|r| r.round()).collect::<Vec<_>>()
    );
    let mut med_ratios = Vec::new();
    let mut best_ratios = Vec::new();
    for n in points
        .iter()
        .filter(|p| p.nodes == 512 && p.mode == Mode::NodeBased)
    {
        let med = speedup(m, n, false);
        let best = speedup(m, n, true);
        med_ratios.push(med);
        best_ratios.push(best);
        println!(
            "  vs N* t={:<3} runtimes {:?}: overhead ratio {:>5.0}x (median) {:>5.0}x (best)",
            n.task_time,
            n.runtimes.iter().map(|r| r.round()).collect::<Vec<_>>(),
            med,
            best
        );
    }
    let max_med = med_ratios.iter().cloned().fold(0.0, f64::max);
    let max_best = best_ratios.iter().cloned().fold(0.0, f64::max);
    println!(
        "  headline: up to {max_med:.0}x (median basis) / {max_best:.0}x (best basis); paper reports ~57x / ~100x"
    );
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    args.expect_known(&["seed", "placement"])?;
    let path = args
        .positional
        .first()
        .ok_or_else(|| llsched::Error::Config("run needs a CONFIG.toml".into()))?;
    let mut cfg = RunConfig::from_file(std::path::Path::new(path))?;
    cfg.seed = args.opt_parse("seed", cfg.seed)?;
    if let Some(p) = args.opt("placement") {
        cfg.placement = Some(Strategy::parse(p)?);
    }
    let task = llsched::config::presets::TaskConfig {
        name: "custom",
        task_time: cfg.task_time,
        job_time: cfg.job_time,
    };
    let mut cell = llsched::workload::paper::PaperCell::new(cfg.nodes, task, cfg.mode, 0);
    cell.config = cfg;
    let res = llsched::coordinator::experiment::run_cell(&cell)?;
    println!("run {}:", cell.label());
    println!("  placement      {}", res.placement);
    println!("  runtime        {}", dur(res.runtime));
    println!("  overhead       {}", dur(res.overhead));
    println!("  dispatch span  {}", dur(res.dispatch_span));
    println!("  release span   {}", dur(res.release_span));
    println!("  peak util      {:.1}%", res.utilization.peak() * 100.0);
    println!("  busy stretch   {}", dur(res.longest_busy_stretch));
    if let Some(o) = &res.obs {
        println!(
            "  trace          {} events recorded ({} retained, {} dropped)",
            o.total_events(),
            o.events.len(),
            o.dropped
        );
    }
    Ok(())
}

fn cmd_placement(args: &Args) -> Result<()> {
    args.expect_known(&["nodes", "mode", "task-time"])?;
    let nodes: u32 = args.opt_parse("nodes", 32)?;
    let mode = Mode::parse(args.opt("mode").unwrap_or("node-based"))?;
    let task_time: f64 = args.opt_parse("task-time", 60.0)?;
    let task = llsched::config::presets::TASK_CONFIGS
        .iter()
        .find(|t| t.task_time == task_time)
        .copied()
        .unwrap_or(llsched::config::presets::TaskConfig {
            name: "custom",
            task_time,
            job_time: 240.0,
        });
    println!(
        "placement-policy comparison: {nodes} nodes, {mode} aggregation, t={task_time}s\n"
    );
    let mut table = llsched::util::fmt::Table::new(vec![
        "policy",
        "runtime",
        "overhead",
        "fill time",
        "release span",
    ]);
    for (strategy, res) in run_placement_sweep(nodes, &task, mode)? {
        table.row(vec![
            strategy.to_string(),
            dur(res.runtime),
            dur(res.overhead),
            dur(res.dispatch_span),
            dur(res.release_span),
        ]);
    }
    println!("{}", table.render());
    Ok(())
}

/// Parse the shared pool flags into a config (disabled when
/// `--pool-size` is absent or 0), mirroring the config-file validation.
fn pool_config_from(args: &Args, default_size: usize) -> Result<PoolConfig> {
    let size: usize = args.opt_parse("pool-size", default_size)?;
    let cfg = PoolConfig {
        size,
        min: args.opt_parse("pool-min", 0)?,
        max: args.opt_parse("pool-max", 0)?,
        hysteresis: args.opt_parse("pool-hysteresis", 0.25)?,
        ..PoolConfig::disabled()
    };
    cfg.validate().map_err(llsched::Error::Config)?;
    Ok(cfg)
}

/// Parse `--pools shape:size[:min[:max[:hysteresis]]],...` into fleet
/// shards (named shapes: general, large, wide, short). Mutually
/// exclusive with the legacy `--pool-size` knob.
fn pools_from(args: &Args) -> Result<Vec<ShardConfig>> {
    let Some(spec) = args.opt("pools") else {
        return Ok(Vec::new());
    };
    for legacy in ["pool-size", "pool-min", "pool-max", "pool-hysteresis"] {
        if args.opt(legacy).is_some() {
            return Err(llsched::Error::Config(format!(
                "--pools and the legacy --{legacy} knob are mutually exclusive \
                 (set per-shard bounds inside the --pools spec)"
            )));
        }
    }
    let mut shards = Vec::new();
    for item in spec.split(',').filter(|s| !s.trim().is_empty()) {
        let parts: Vec<&str> = item.trim().split(':').collect();
        if parts.len() < 2 || parts.len() > 5 {
            return Err(llsched::Error::Config(format!(
                "--pools entry {item:?} must be shape:size[:min[:max[:hysteresis]]]"
            )));
        }
        let parse_n = |s: &str, what: &str| -> Result<usize> {
            s.parse::<usize>().map_err(|_| {
                llsched::Error::Config(format!("--pools {item:?}: bad {what} {s:?}"))
            })
        };
        let size = parse_n(parts[1], "size")?;
        let min = parts.get(2).map(|s| parse_n(s, "min")).transpose()?.unwrap_or(0);
        let max = parts.get(3).map(|s| parse_n(s, "max")).transpose()?.unwrap_or(0);
        let mut shard = ShardConfig::named(parts[0], size, min, max).ok_or_else(|| {
            llsched::Error::Config(format!(
                "--pools: unknown shape {:?} (known: general, large, wide, short)",
                parts[0]
            ))
        })?;
        if let Some(h) = parts.get(4) {
            shard.pool.hysteresis = h.parse::<f64>().map_err(|_| {
                llsched::Error::Config(format!("--pools {item:?}: bad hysteresis {h:?}"))
            })?;
        }
        shards.push(shard);
    }
    if shards.is_empty() {
        return Err(llsched::Error::Config("--pools needs at least one shard".into()));
    }
    llsched::pool::FleetConfig { shards: shards.clone() }
        .validate()
        .map_err(llsched::Error::Config)?;
    Ok(shards)
}

fn cmd_contention(args: &Args) -> Result<()> {
    args.expect_known(&[
        "preset",
        "nodes",
        "seed",
        "no-backfill",
        "compare",
        "sweep",
        "holds",
        "aging",
        "aging-cap",
        "walltime-error",
        "pool-size",
        "pool-min",
        "pool-max",
        "pool-hysteresis",
        "pools",
        "preempt-overdue",
        "out",
    ])?;
    let nodes: u32 = args.opt_parse("nodes", 32)?;
    let seed: u64 = args.opt_parse("seed", 7)?;
    let holds: usize = args.opt_parse("holds", 4)?;
    let aging_slope: f64 = args.opt_parse("aging", 0.0)?;
    let aging_cap: i32 = args.opt_parse("aging-cap", 1000)?;
    let sigma: f64 = args.opt_parse("walltime-error", 0.0)?;
    let pool = pool_config_from(args, 0)?;
    let pools = pools_from(args)?;
    let preempt_overdue = args.flag("preempt-overdue");
    // Mirror the config-file validation: reject values that would
    // otherwise be silently clamped into a different policy.
    if holds < 1 {
        return Err(llsched::Error::Config("--holds must be >= 1".into()));
    }
    if aging_slope < 0.0 || aging_cap < 0 {
        return Err(llsched::Error::Config(
            "--aging and --aging-cap must be >= 0".into(),
        ));
    }
    if sigma < 0.0 {
        return Err(llsched::Error::Config("--walltime-error must be >= 0".into()));
    }
    let aging = if aging_slope > 0.0 {
        Some(AgingPolicy::new(aging_slope, aging_cap))
    } else {
        None
    };
    let opts_for = |backfill: bool| ContentionOpts {
        backfill,
        holds,
        aging,
        walltime_error: WalltimeError::from_sigma(sigma),
        pool,
        pools: pools.clone(),
        preempt_overdue,
        hot_path: llsched::scheduler::HotPath::default(),
        fault: FaultConfig::disabled(),
        trace_cap: 0,
        trace_profile: false,
        blame: false,
        seed,
    };
    let mut results: Vec<ContentionResult> = Vec::new();
    if args.flag("sweep") {
        println!("contention sweep: {nodes} nodes, seed {seed}\n");
        let mut table = llsched::util::fmt::Table::new(vec![
            "scenario",
            "class",
            "jobs",
            "median lat",
            "p95 lat",
            "max lat",
            "util",
        ]);
        for cell in llsched::config::presets::contention_sweep(nodes) {
            let res = run_contention_with(&cell.mix, opts_for(cell.backfill))?;
            for r in &res.reports {
                table.row(vec![
                    cell.label(),
                    r.class.to_string(),
                    r.jobs.to_string(),
                    dur(r.median_launch_latency),
                    dur(r.p95_launch_latency),
                    dur(r.max_launch_latency),
                    format!("{:.1}%", r.utilization * 100.0),
                ]);
            }
            results.push(res);
        }
        println!("{}", table.render());
    } else {
        let preset = args.opt("preset").unwrap_or("default");
        let mix = ContentionMix::preset(preset, nodes)?;
        let modes: Vec<bool> = if args.flag("compare") {
            vec![false, true]
        } else {
            vec![!args.flag("no-backfill")]
        };
        for backfill in modes {
            let res = run_contention_with(&mix, opts_for(backfill))?;
            print_contention(&res);
            results.push(res);
        }
    }
    if let Some(out) = args.opt("out") {
        let dir = PathBuf::from(out);
        std::fs::create_dir_all(&dir)?;
        contention_csv(&results).save(&dir.join("contention.csv"))?;
        std::fs::write(
            dir.join("contention.json"),
            contention_json(&results).to_pretty(),
        )?;
        println!("(per-class CSV/JSON in {dir:?})");
    }
    Ok(())
}

fn cmd_pool(args: &Args) -> Result<()> {
    args.expect_known(&[
        "preset",
        "nodes",
        "seed",
        "pool-size",
        "pool-min",
        "pool-max",
        "pool-hysteresis",
        "pools",
        "preempt-overdue",
        "compare",
        "out",
    ])?;
    let nodes: u32 = args.opt_parse("nodes", 128)?;
    let seed: u64 = args.opt_parse("seed", 7)?;
    let preset = args.opt("preset").unwrap_or("burst");
    let mix = ContentionMix::preset(preset, nodes)?;
    let pools = pools_from(args)?;
    // Elastic defaults scaled to the cluster: start at a quarter, never
    // below an eighth, grow up to three quarters of the machine. An
    // explicitly passed --pool-max caps the *default* size too; only an
    // explicit size below an explicit max is a user error.
    let n = nodes as usize;
    let mut pool = PoolConfig {
        size: args.opt_parse("pool-size", (n / 4).max(1))?,
        min: args.opt_parse("pool-min", 0)?,
        max: args.opt_parse("pool-max", 0)?,
        hysteresis: args.opt_parse("pool-hysteresis", 0.25)?,
        ..PoolConfig::disabled()
    };
    if pool.min == 0 {
        pool.min = n / 8;
    }
    if pool.max == 0 {
        pool.max = (3 * n / 4).max(pool.size);
    }
    if args.opt("pool-size").is_none() {
        pool.size = pool.size.min(pool.max);
        pool.min = pool.min.min(pool.size);
    }
    pool.validate().map_err(llsched::Error::Config)?;
    let preempt_overdue = args.flag("preempt-overdue");
    let opts = |pool: PoolConfig, pools: Vec<ShardConfig>| ContentionOpts {
        pool,
        pools,
        preempt_overdue,
        ..ContentionOpts::classic(true, seed)
    };
    let mut results: Vec<ContentionResult> = Vec::new();
    if args.flag("compare") {
        let baseline = run_contention_with(&mix, opts(PoolConfig::disabled(), Vec::new()))?;
        print_contention(&baseline);
        let pooled = if pools.is_empty() {
            run_contention_with(&mix, opts(pool, Vec::new()))?
        } else {
            run_contention_with(&mix, opts(PoolConfig::disabled(), pools))?
        };
        print_contention(&pooled);
        let base_lat = baseline.reports[0].median_launch_latency;
        let pool_lat = pooled.reports[0].median_launch_latency;
        if base_lat.is_finite() && pool_lat.is_finite() && pool_lat > 0.0 {
            println!(
                "pooled vs backfill-only: short-job median launch latency {} -> {} ({:.1}x)",
                dur(base_lat),
                dur(pool_lat),
                base_lat / pool_lat
            );
        }
        results.push(baseline);
        results.push(pooled);
    } else {
        let res = if pools.is_empty() {
            run_contention_with(&mix, opts(pool, Vec::new()))?
        } else {
            run_contention_with(&mix, opts(PoolConfig::disabled(), pools))?
        };
        print_contention(&res);
        results.push(res);
    }
    if let Some(out) = args.opt("out") {
        let dir = PathBuf::from(out);
        std::fs::create_dir_all(&dir)?;
        contention_csv(&results).save(&dir.join("pool.csv"))?;
        std::fs::write(dir.join("pool.json"), contention_json(&results).to_pretty())?;
        println!("(per-class + pool CSV/JSON in {dir:?})");
    }
    Ok(())
}

fn cmd_churn(args: &Args) -> Result<()> {
    args.expect_known(&["preset", "nodes", "seed", "no-pool", "replay", "out"])?;
    let nodes: u32 = args.opt_parse("nodes", 32)?;
    let seed: u64 = args.opt_parse("seed", 7)?;
    let preset = args.opt("preset").unwrap_or("churn_full");
    let scenario = ChurnScenario::preset(preset, nodes)?;
    // The pool fleet is on by default — churn is where its eviction and
    // re-grow paths earn their keep — with the same cluster-scaled
    // elastic bounds `pool` uses: quarter / eighth / three quarters.
    let pool = if args.flag("no-pool") {
        PoolConfig::disabled()
    } else {
        let n = nodes.max(2) as usize;
        PoolConfig {
            size: (n / 4).max(1),
            min: (n / 8).min((n / 4).max(1)),
            max: (3 * n / 4).max((n / 4).max(1)),
            ..PoolConfig::disabled()
        }
    };
    pool.validate().map_err(llsched::Error::Config)?;
    let opts = ContentionOpts {
        pool,
        fault: scenario.fault.clone(),
        ..ContentionOpts::classic(true, seed)
    };
    let res = run_contention_with(&scenario.mix, opts.clone())?;
    print_contention(&res);
    let audit = |r: &ContentionResult| -> AuditLog {
        r.fault.as_ref().map(|f| f.audit.clone()).unwrap_or_default()
    };
    if args.flag("replay") {
        // Deterministic replay: the same (config, seed) must reproduce
        // the run — and its audit log — bit for bit.
        let replayed = run_contention_with(&scenario.mix, opts)?;
        match AuditLog::replay_diff(&audit(&res), &audit(&replayed)) {
            None => println!(
                "replay: OK — {} audit records reproduced bit-for-bit",
                audit(&res).len()
            ),
            Some(diff) => {
                return Err(llsched::Error::Config(format!(
                    "replay diverged (this is a determinism bug): {diff}"
                )))
            }
        }
    }
    if let Some(out) = args.opt("out") {
        let dir = PathBuf::from(out);
        std::fs::create_dir_all(&dir)?;
        let results = [res];
        contention_csv(&results).save(&dir.join("contention.csv"))?;
        std::fs::write(
            dir.join("contention.json"),
            contention_json(&results).to_pretty(),
        )?;
        std::fs::write(dir.join("audit.log"), audit(&results[0]).to_text())?;
        println!("(per-class CSV/JSON + audit log in {dir:?})");
    }
    Ok(())
}

fn cmd_trace(args: &Args) -> Result<()> {
    args.expect_known(&[
        "preset",
        "nodes",
        "seed",
        "instances",
        "trace-cap",
        "trace-filter",
        "trace-out",
        "format",
        "profile",
        "no-pool",
    ])?;
    let nodes: u32 = args.opt_parse("nodes", 32)?;
    let seed: u64 = args.opt_parse("seed", 7)?;
    let instances: usize = args.opt_parse("instances", 1)?;
    if instances == 0 {
        return Err(llsched::Error::Config("instances must be >= 1".into()));
    }
    let trace_cap: usize = args.opt_parse("trace-cap", 65_536)?;
    if trace_cap == 0 {
        return Err(llsched::Error::Config(
            "trace-cap must be >= 1 (the recorder is the point of `trace`)".into(),
        ));
    }
    let filter = match args.opt("trace-filter") {
        Some(s) => Some(
            Subsystem::parse_list(s)
                .map_err(|e| llsched::Error::Config(format!("--trace-filter: {e}")))?,
        ),
        None => None,
    };
    let format = args.opt("format").unwrap_or("both");
    if !["perfetto", "log", "both"].contains(&format) {
        return Err(llsched::Error::Config(format!(
            "unknown --format {format:?} (one of perfetto|log|both)"
        )));
    }
    let preset = args.opt("preset").unwrap_or("burst");
    let (mix, fault) = if preset.starts_with("churn_") {
        let scenario = ChurnScenario::preset(preset, nodes)?;
        (scenario.mix, scenario.fault)
    } else {
        (ContentionMix::preset(preset, nodes)?, FaultConfig::disabled())
    };
    // Pool fleet on by default — the pool subsystem is worth tracing —
    // with `pool`'s cluster-scaled elastic bounds over the partition
    // each scheduler actually owns (nodes/instances of the machine).
    let pool = if args.flag("no-pool") {
        PoolConfig::disabled()
    } else {
        let n = (nodes as usize / instances).max(2);
        PoolConfig {
            size: (n / 4).max(1),
            min: (n / 8).min((n / 4).max(1)),
            max: (3 * n / 4).max((n / 4).max(1)),
            ..PoolConfig::disabled()
        }
    };
    pool.validate().map_err(llsched::Error::Config)?;
    let opts = ContentionOpts {
        pool,
        fault,
        trace_cap,
        trace_profile: args.flag("profile"),
        ..ContentionOpts::classic(true, seed)
    };
    let res = if instances > 1 {
        run_contention_federated(
            &mix,
            opts,
            FederationConfig {
                instances,
                ..FederationConfig::default()
            },
        )?
    } else {
        run_contention_with(&mix, opts)?
    };
    print_contention(&res);
    let snap = res.obs.as_ref().expect("a trace run always carries a recorder");
    println!(
        "flight recorder: {} decision(s) recorded, {} retained in the ring, {} dropped",
        snap.total_events(),
        snap.events.len(),
        snap.dropped
    );
    for sub in Subsystem::ALL {
        let n = snap.subsystem_events(sub);
        if n > 0 {
            println!("  {:<12} {n}", sub.name());
        }
    }
    if let Some(p) = &snap.profile {
        for line in profile_lines(p) {
            println!("  {line}");
        }
    }
    let dir = PathBuf::from(args.opt("trace-out").unwrap_or("results"));
    std::fs::create_dir_all(&dir)?;
    if matches!(format, "perfetto" | "both") {
        let json = perfetto_json(snap, filter.as_deref());
        std::fs::write(dir.join("trace.json"), json.to_pretty())?;
    }
    if matches!(format, "log" | "both") {
        std::fs::write(dir.join("trace.log"), decision_log(snap, filter.as_deref()))?;
    }
    println!("(trace exports in {dir:?})");
    Ok(())
}

fn cmd_explain(args: &Args) -> Result<()> {
    args.expect_known(&[
        "preset",
        "nodes",
        "seed",
        "instances",
        "trace-cap",
        "job",
        "worst",
        "slo",
        "interval",
        "no-pool",
        "out",
    ])?;
    let nodes: u32 = args.opt_parse("nodes", 32)?;
    let seed: u64 = args.opt_parse("seed", 7)?;
    let instances: usize = args.opt_parse("instances", 1)?;
    if instances == 0 {
        return Err(llsched::Error::Config("instances must be >= 1".into()));
    }
    // Attribution reconstructs spans from the ring window, so default
    // to a cap that comfortably retains whole scenario runs.
    let trace_cap: usize = args.opt_parse("trace-cap", 1 << 20)?;
    if trace_cap == 0 {
        return Err(llsched::Error::Config(
            "trace-cap must be >= 1 (attribution reads the recorder)".into(),
        ));
    }
    let interval: f64 = args.opt_parse("interval", 1.0)?;
    if !interval.is_finite() || interval <= 0.0 {
        return Err(llsched::Error::Config("--interval must be > 0".into()));
    }
    let job = match args.opt("job") {
        Some(s) => match s.parse::<u64>() {
            Ok(id) => Some(id),
            Err(_) => {
                return Err(llsched::Error::Config(format!("--job: bad job id {s:?}")));
            }
        },
        None => None,
    };
    let worst: usize = args.opt_parse("worst", 10)?;
    let slo = match args.opt("slo") {
        Some(s) => Some(parse_slo(s)?),
        None => None,
    };
    let preset = args.opt("preset").unwrap_or("burst");
    let (mix, fault) = if preset.starts_with("churn_") {
        let scenario = ChurnScenario::preset(preset, nodes)?;
        (scenario.mix, scenario.fault)
    } else {
        (ContentionMix::preset(preset, nodes)?, FaultConfig::disabled())
    };
    // Same pool-fleet default as `trace`: cold starts are one of the
    // causes worth attributing, over the partition each scheduler owns.
    let pool = if args.flag("no-pool") {
        PoolConfig::disabled()
    } else {
        let n = (nodes as usize / instances).max(2);
        PoolConfig {
            size: (n / 4).max(1),
            min: (n / 8).min((n / 4).max(1)),
            max: (3 * n / 4).max((n / 4).max(1)),
            ..PoolConfig::disabled()
        }
    };
    pool.validate().map_err(llsched::Error::Config)?;
    let opts = ContentionOpts {
        pool,
        fault,
        trace_cap,
        blame: true,
        ..ContentionOpts::classic(true, seed)
    };
    let res = if instances > 1 {
        run_contention_federated(
            &mix,
            opts,
            FederationConfig {
                instances,
                ..FederationConfig::default()
            },
        )?
    } else {
        run_contention_with(&mix, opts)?
    };
    // Job ids are dense submission indices on both the single-scheduler
    // and the gateway path, so regenerating the mix recovers the job →
    // class table without re-running anything.
    let classes: Vec<JobClass> = mix.generate(seed).into_iter().map(|s| s.class).collect();
    let snap = res.obs.as_ref().expect("an explain run always carries a recorder");
    let spans = reconstruct_spans(snap);
    let tl = build_timeline(snap, interval);
    print_contention(&res);
    println!();
    if spans.partial {
        println!(
            "note: the ring dropped {} record(s) — spans are partial; raise --trace-cap",
            snap.dropped
        );
    }
    if let Some(blame) = &res.blame {
        println!("wait blame by class (seconds attributed across launched jobs):");
        let mut table = llsched::util::fmt::Table::new(vec![
            "class",
            "jobs",
            "mean wait",
            "hol",
            "fence",
            "cold start",
            "requeue",
            "gateway",
            "steal",
        ]);
        for cb in blame {
            let mut row = vec![cb.class.to_string(), cb.jobs.to_string(), secs(cb.mean_wait_s)];
            for i in 0..BLAME_CAUSES.len() {
                row.push(secs(cb.blame.get(i)));
            }
            table.row(row);
        }
        println!("{}", table.render());
    }
    match job {
        Some(id) => match spans.get(id) {
            Some(s) => print_span(s, &classes),
            None => println!(
                "job {id}: no span reconstructed (unknown id, or its records left the ring)"
            ),
        },
        None => {
            println!("top {worst} job(s) by attributed wait:");
            let mut table = llsched::util::fmt::Table::new(vec![
                "job",
                "class",
                "wait",
                "dominant",
                "hol",
                "fence",
                "cold",
                "requeue",
                "gateway",
                "steal",
                "hops",
            ]);
            for s in spans.worst(worst) {
                let (cause, _) = s.blame.dominant();
                let mut row = vec![
                    s.job.to_string(),
                    class_label(&classes, s.job).to_string(),
                    secs(s.wait_s),
                    BLAME_CAUSES[cause].to_string(),
                ];
                for i in 0..BLAME_CAUSES.len() {
                    row.push(secs(s.blame.get(i)));
                }
                row.push(s.steal_hops.to_string());
                table.row(row);
            }
            println!("{}", table.render());
        }
    }
    if let Some((class, threshold)) = slo {
        println!("SLO {class}: p95 attributed wait <= {threshold:.3}s per {interval:.1}s window");
        let launched: Vec<&JobSpan> = spans
            .spans
            .iter()
            .filter(|s| s.launched && classes.get(s.job as usize).copied() == Some(class))
            .collect();
        let mut breaches = 0usize;
        for b in tl.fleet() {
            let t1 = b.t0 + tl.interval_s;
            let waits: Vec<f64> = launched
                .iter()
                .filter(|s| s.launch_t >= b.t0 && s.launch_t < t1)
                .map(|s| s.wait_s)
                .collect();
            if waits.is_empty() {
                continue;
            }
            let p95 = percentile(&waits, 95.0);
            if p95 > threshold {
                breaches += 1;
                // The max wait is >= p95 > threshold, so the breaching
                // set is never empty; blame the window on them.
                let mut blame = WaitBlame::default();
                for s in &launched {
                    if s.launch_t >= b.t0 && s.launch_t < t1 && s.wait_s > threshold {
                        blame.merge(&s.blame);
                    }
                }
                let (cause, cause_s) = blame.dominant();
                println!(
                    "  breach [{:.1}s, {t1:.1}s): p95 wait {p95:.3}s over {} launch(es), \
                     dominant blame {} ({:.3}s)",
                    b.t0,
                    waits.len(),
                    BLAME_CAUSES[cause],
                    cause_s,
                );
            }
        }
        if breaches == 0 {
            println!("  no breached windows");
        } else {
            println!("  {breaches} breached window(s)");
        }
    }
    if let Some(out) = args.opt("out") {
        let dir = PathBuf::from(out);
        std::fs::create_dir_all(&dir)?;
        blame_csv(&spans, &classes).save(&dir.join("blame.csv"))?;
        std::fs::write(
            dir.join("blame.json"),
            blame_json(&res, &spans, &classes).to_pretty(),
        )?;
        timeline_csv(&tl).save(&dir.join("timeline.csv"))?;
        std::fs::write(dir.join("timeline.json"), timeline_json(&tl).to_pretty())?;
        std::fs::write(dir.join("spans.json"), perfetto_spans(&spans).to_pretty())?;
        println!("(explain exports in {dir:?})");
    }
    Ok(())
}

/// `--slo CLASS:P95_SECONDS`, e.g. `interactive:2.0`.
fn parse_slo(s: &str) -> Result<(JobClass, f64)> {
    let (class, thr) = s.split_once(':').ok_or_else(|| {
        llsched::Error::Config(format!("--slo: expected CLASS:P95_SECONDS, got {s:?}"))
    })?;
    let class = JOB_CLASSES
        .into_iter()
        .find(|c| c.label() == class)
        .ok_or_else(|| {
            llsched::Error::Config(format!(
                "--slo: unknown class {class:?} (one of interactive|batch)"
            ))
        })?;
    let thr: f64 = thr
        .parse()
        .map_err(|_| llsched::Error::Config(format!("--slo: bad threshold {thr:?}")))?;
    if !thr.is_finite() || thr <= 0.0 {
        return Err(llsched::Error::Config("--slo: threshold must be > 0".into()));
    }
    Ok((class, thr))
}

fn class_label(classes: &[JobClass], job: u64) -> &'static str {
    classes.get(job as usize).map(|c| c.label()).unwrap_or("?")
}

/// Seconds cell: `-` for NaN (no data), fixed millisecond precision
/// otherwise.
fn secs(x: f64) -> String {
    if x.is_nan() {
        "-".into()
    } else {
        format!("{x:.3}s")
    }
}

fn print_span(s: &JobSpan, classes: &[JobClass]) {
    println!(
        "job {} ({}): {} task(s), instance {}",
        s.job,
        class_label(classes, s.job),
        s.tasks,
        s.pid
    );
    println!(
        "  submitted {}  launched {}  finished {}",
        secs(s.submit_t),
        secs(s.launch_t),
        secs(s.finish_t)
    );
    if !s.launched {
        println!("  never launched — no wait window to attribute");
        return;
    }
    println!("  wait {} attributed:", secs(s.wait_s));
    for (i, name) in BLAME_CAUSES.iter().enumerate() {
        let v = s.blame.get(i);
        if v > 0.0 {
            println!("    {name:<16} {v:>10.3}s  ({:.1}%)", 100.0 * v / s.wait_s.max(1e-12));
        }
    }
    if s.steal_hops > 0 {
        println!("  steal hops: {}", s.steal_hops);
    }
    if s.partial {
        println!("  (partial: the ring dropped records during this run)");
    }
}

/// Per-job blame table as CSV (one row per reconstructed span).
fn blame_csv(spans: &SpanSet, classes: &[JobClass]) -> Csv {
    let mut header: Vec<String> = ["job", "class", "pid", "tasks"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    header.extend(["submit_s", "launch_s", "finish_s", "wait_s"].iter().map(|s| s.to_string()));
    header.extend(BLAME_CAUSES.iter().map(|c| format!("{c}_s")));
    header.extend(["steal_hops", "launched", "partial"].iter().map(|s| s.to_string()));
    let mut c = Csv::with_header(&header);
    for s in &spans.spans {
        let mut row = vec![
            s.job.to_string(),
            class_label(classes, s.job).to_string(),
            s.pid.to_string(),
            s.tasks.to_string(),
        ];
        for x in [s.submit_t, s.launch_t, s.finish_t, s.wait_s] {
            row.push(if x.is_nan() { String::new() } else { format!("{x:.6}") });
        }
        for i in 0..BLAME_CAUSES.len() {
            row.push(format!("{:.6}", s.blame.get(i)));
        }
        row.push(s.steal_hops.to_string());
        row.push(s.launched.to_string());
        row.push(s.partial.to_string());
        c.row(&row);
    }
    c
}

/// Per-job spans plus the per-class rollup as one JSON document.
fn blame_json(res: &ContentionResult, spans: &SpanSet, classes: &[JobClass]) -> Json {
    let jobs: Vec<Json> = spans
        .spans
        .iter()
        .map(|s| {
            let mut o = Json::obj()
                .set("job", s.job)
                .set("class", class_label(classes, s.job))
                .set("pid", s.pid)
                .set("tasks", s.tasks)
                .set("submit_s", s.submit_t)
                .set("launch_s", s.launch_t)
                .set("finish_s", s.finish_t)
                .set("wait_s", s.wait_s)
                .set("steal_hops", s.steal_hops)
                .set("launched", s.launched)
                .set("partial", s.partial);
            for (i, name) in BLAME_CAUSES.iter().enumerate() {
                o = o.set(format!("{name}_s"), s.blame.get(i));
            }
            o
        })
        .collect();
    let mut doc = Json::obj()
        .set("scenario", res.mix_name.clone())
        .set("nodes", res.nodes)
        .set("seed", res.opts.seed)
        .set("partial", spans.partial)
        .set("jobs", Json::Arr(jobs));
    if let Some(blame) = &res.blame {
        let rows: Vec<Json> = blame
            .iter()
            .map(|cb| {
                let mut o = Json::obj()
                    .set("class", cb.class.label())
                    .set("jobs", cb.jobs)
                    .set("mean_wait_s", cb.mean_wait_s);
                for (i, name) in BLAME_CAUSES.iter().enumerate() {
                    o = o.set(format!("{name}_s"), cb.blame.get(i));
                }
                o
            })
            .collect();
        doc = doc.set("classes", Json::Arr(rows));
    }
    doc
}

fn cmd_federate(args: &Args) -> Result<()> {
    args.expect_known(&[
        "instances",
        "nodes",
        "batch",
        "steal-threshold",
        "flush",
        "preset",
        "seed",
        "compare",
        "sweep-rate",
        "jobs",
        "task-time",
        "knee",
        "out",
    ])?;
    let instances: usize = args.opt_parse("instances", 4)?;
    let nodes: u32 = args.opt_parse("nodes", 128)?;
    let seed: u64 = args.opt_parse("seed", 7)?;
    let fed = FederationConfig {
        instances,
        batch: args.opt_parse("batch", 8)?,
        flush_interval: args.opt_parse("flush", 1.0)?,
        steal_threshold: args.opt_parse("steal-threshold", 64)?,
    };
    fed.validate().map_err(llsched::Error::Config)?;
    if nodes as usize % instances != 0 {
        return Err(llsched::Error::Config(format!(
            "--instances ({instances}) must divide --nodes ({nodes}) into equal partitions"
        )));
    }
    if args.flag("compare") {
        // Launch latency vs submission rate: one scheduler owning a
        // single partition vs the federated fleet of `instances`
        // partitions of the same size, swept until each saturates.
        let rates = match args.opt("sweep-rate") {
            Some(spec) => spec
                .split(',')
                .filter(|s| !s.trim().is_empty())
                .map(|s| {
                    s.trim().parse::<f64>().map_err(|_| {
                        llsched::Error::Config(format!("--sweep-rate: bad rate {s:?}"))
                    })
                })
                .collect::<Result<Vec<f64>>>()?,
            None => FederationSweepOpts::default().rates,
        };
        let opts = FederationSweepOpts {
            instances,
            nodes: nodes / instances as u32,
            rates,
            jobs: args.opt_parse("jobs", 2000)?,
            task_s: args.opt_parse("task-time", 2.0)?,
            knee_s: args.opt_parse("knee", 15.0)?,
            batch: fed.batch,
            steal_threshold: fed.steal_threshold,
            seed,
        };
        println!(
            "federation rate sweep: {instances} x {} nodes vs 1 x {} nodes, \
             {} jobs/point, task {}s, knee {}s\n",
            opts.nodes, opts.nodes, opts.jobs, opts.task_s, opts.knee_s
        );
        let sweep = run_federation(opts)?;
        let mut table = llsched::util::fmt::Table::new(vec![
            "rate (jobs/s)",
            "single p95",
            "federated p95",
        ]);
        for pt in &sweep.points {
            table.row(vec![
                format!("{}", pt.rate),
                dur(pt.single_p95),
                dur(pt.federated_p95),
            ]);
        }
        println!("{}", table.render());
        println!(
            "  single scheduler sustains {} jobs/s; federated fleet sustains {} jobs/s ({})",
            sweep.single_saturation,
            sweep.federated_saturation,
            if sweep.rate_gain.is_finite() {
                format!("{:.1}x", sweep.rate_gain)
            } else {
                "n/a".to_string()
            }
        );
        if let Some(out) = args.opt("out") {
            let dir = PathBuf::from(out);
            std::fs::create_dir_all(&dir)?;
            let points: Vec<llsched::util::json::Json> = sweep
                .points
                .iter()
                .map(|pt| {
                    llsched::util::json::Json::obj()
                        .set("rate_jobs_per_s", pt.rate)
                        .set("single_p95_s", pt.single_p95)
                        .set("federated_p95_s", pt.federated_p95)
                })
                .collect();
            let json = llsched::util::json::Json::obj()
                .set("instances", sweep.opts.instances)
                .set("nodes_per_instance", sweep.opts.nodes)
                .set("knee_s", sweep.opts.knee_s)
                .set("points", llsched::util::json::Json::Arr(points))
                .set("single_saturation_jobs_per_s", sweep.single_saturation)
                .set("federated_saturation_jobs_per_s", sweep.federated_saturation)
                .set("rate_gain", sweep.rate_gain);
            std::fs::write(dir.join("federate.json"), json.to_pretty())?;
            println!("(sweep JSON in {dir:?})");
        }
    } else {
        let preset = args.opt("preset").unwrap_or("default");
        let mix = ContentionMix::preset(preset, nodes)?;
        let res = run_contention_federated(&mix, ContentionOpts::classic(true, seed), fed)?;
        print_contention(&res);
        if let Some(f) = &res.federation {
            println!(
                "  federation: {} instances  batch {} / {}s flush  steal threshold {}  \
                 batches {}  steals {}  fleet p95 {}",
                f.config.instances,
                f.config.batch,
                f.config.flush_interval,
                f.config.steal_threshold,
                f.batches,
                f.steals,
                dur(f.p95_latency),
            );
        }
        if let Some(out) = args.opt("out") {
            let dir = PathBuf::from(out);
            std::fs::create_dir_all(&dir)?;
            let results = [res];
            contention_csv(&results).save(&dir.join("contention.csv"))?;
            std::fs::write(
                dir.join("contention.json"),
                contention_json(&results).to_pretty(),
            )?;
            println!("(per-class CSV/JSON in {dir:?})");
        }
    }
    Ok(())
}

fn print_contention(res: &ContentionResult) {
    println!(
        "contention {}: {} nodes, backfill {}, holds {}, aging {}, walltime error {}",
        res.mix_name,
        res.nodes,
        if res.backfill { "on" } else { "off" },
        res.opts.holds,
        match res.opts.aging {
            Some(a) => format!("{}/s (cap {})", a.slope, a.cap),
            None => "off".to_string(),
        },
        res.opts.walltime_error,
    );
    let mut table = llsched::util::fmt::Table::new(vec![
        "class",
        "jobs",
        "tasks",
        "median lat",
        "p95 lat",
        "max lat",
        "core-seconds",
        "util",
    ]);
    for r in &res.reports {
        table.row(vec![
            r.class.to_string(),
            r.jobs.to_string(),
            r.tasks.to_string(),
            dur(r.median_launch_latency),
            dur(r.p95_launch_latency),
            dur(r.max_launch_latency),
            format!("{:.0}", r.core_seconds),
            format!("{:.1}%", r.utilization * 100.0),
        ]);
    }
    println!("{}", table.render());
    println!(
        "  span {}  cluster util {:.1}%  backfills {}  peak holds {}  holds respected {}  unfinished {}",
        dur(res.span),
        res.utilization * 100.0,
        res.backfills,
        res.max_active_holds,
        res.holds_respected,
        res.unfinished,
    );
    if let Some(p) = &res.pool {
        println!(
            "  pool: {} launches  peak {} leased  +{} / -{} resize nodes  median lat {}  util {:.1}%",
            p.launches,
            p.peak_leased,
            p.grows,
            p.shrinks,
            dur(p.median_launch_latency),
            p.utilization * 100.0,
        );
        if p.shards.len() > 1 {
            println!("  fleet: {} shards, {} cross-shard borrows", p.shards.len(), p.borrows);
            for sh in &p.shards {
                println!(
                    "    shard {:<8} {} launches  peak {} leased  +{} / -{}  median lat {}  p95 {}",
                    sh.name,
                    sh.launches,
                    sh.peak_leased,
                    sh.grows,
                    sh.shrinks,
                    dur(sh.median_launch_latency),
                    dur(sh.p95_launch_latency),
                );
            }
        }
    }
    if res.opts.preempt_overdue {
        println!("  preemptive backfill: {} overdue tasks killed", res.overdue_preemptions);
    }
    if let Some(f) = &res.fault {
        let s = &f.stats;
        println!(
            "  churn: {} failures / {} recoveries  {} reclaim waves  {} drains  \
             killed {}  requeued {}  lost {}  work lost {:.0} core-s",
            s.node_failures,
            s.node_recoveries,
            s.reclaim_waves,
            s.drains,
            s.tasks_killed,
            s.tasks_requeued,
            s.tasks_lost,
            s.work_lost_core_s,
        );
        println!("  audit: {} records (replayable; see docs/audit-log.md)", f.audit.len());
    }
    println!();
}

fn cmd_spot(args: &Args) -> Result<()> {
    args.expect_known(&["nodes"])?;
    let nodes: u32 = args.opt_parse("nodes", 32)?;
    for mode in [Mode::MultiLevel, Mode::NodeBased] {
        let r = llsched::spot::measure_release(mode, nodes, 64, 120.0, 7)?;
        println!(
            "{:<12} {:>6} sched tasks   release latency {:>9}",
            mode.to_string(),
            r.sched_tasks,
            dur(r.release_latency)
        );
    }
    Ok(())
}

fn cmd_artifacts(args: &Args) -> Result<()> {
    args.expect_known(&[])?;
    let mut pool = llsched::runtime::ExecPool::discover()?;
    let files = pool.list()?;
    println!("artifacts directory: {} file(s)", files.len());
    for f in &files {
        let name = f
            .file_name()
            .and_then(|n| n.to_str())
            .and_then(|n| n.strip_suffix(".hlo.txt"))
            .unwrap_or_default()
            .to_string();
        let rt = pool.get(&name)?;
        let a = &rt.artifact;
        let state = vec![0.5f32; a.elements()];
        let (out, checksum) = rt.step(&state)?;
        println!(
            "  {name}: platform={} shape={}x{}x{} checksum={checksum:.6} out[0]={:.6}",
            rt.platform(),
            a.batch,
            a.h,
            a.w,
            out[0]
        );
    }
    Ok(())
}
