//! Trace record / replay.
//!
//! A trace is the per-task `(task_id, duration)` list of a workload plus
//! the measured `(start, end)` once run. Traces serialize to CSV so runs
//! can be archived in `results/` and replayed as Explicit workloads —
//! the substitution for the paper's production scheduler logs.

use crate::aggregation::plan::Workload;
use crate::error::{Error, Result};
use std::path::Path;

/// A recorded workload trace.
#[derive(Debug, Clone, PartialEq)]
pub struct Trace {
    /// Per-task durations (seconds).
    pub durations: Vec<f64>,
}

impl Trace {
    /// Capture a (materialized) workload as a trace.
    pub fn from_workload(w: &Workload) -> Trace {
        let durations = match w {
            Workload::Uniform { count, duration } => vec![*duration; *count as usize],
            Workload::Explicit(v) => v.clone(),
        };
        Trace { durations }
    }

    /// Replay as a workload.
    pub fn to_workload(&self) -> Workload {
        Workload::Explicit(self.durations.clone())
    }

    /// Serialize as CSV (`task_id,duration`).
    pub fn to_csv(&self) -> String {
        let mut s = String::from("task_id,duration\n");
        for (i, d) in self.durations.iter().enumerate() {
            s.push_str(&format!("{i},{d}\n"));
        }
        s
    }

    /// Parse from CSV produced by [`Self::to_csv`].
    pub fn from_csv(text: &str) -> Result<Trace> {
        let mut durations = Vec::new();
        for (i, line) in text.lines().enumerate() {
            if i == 0 {
                if line.trim() != "task_id,duration" {
                    return Err(Error::Config(format!("bad trace header {line:?}")));
                }
                continue;
            }
            if line.trim().is_empty() {
                continue;
            }
            let mut parts = line.split(',');
            let id: usize = parts
                .next()
                .and_then(|p| p.trim().parse().ok())
                .ok_or_else(|| Error::Config(format!("trace line {}: bad id", i + 1)))?;
            let d: f64 = parts
                .next()
                .and_then(|p| p.trim().parse().ok())
                .ok_or_else(|| Error::Config(format!("trace line {}: bad duration", i + 1)))?;
            if id != durations.len() {
                return Err(Error::Config(format!(
                    "trace line {}: id {} out of order",
                    i + 1,
                    id
                )));
            }
            if d <= 0.0 {
                return Err(Error::Config(format!(
                    "trace line {}: non-positive duration",
                    i + 1
                )));
            }
            durations.push(d);
        }
        Ok(Trace { durations })
    }

    /// Save to a file.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_csv())?;
        Ok(())
    }

    /// Load from a file.
    pub fn load(path: &Path) -> Result<Trace> {
        let text = std::fs::read_to_string(path)?;
        Trace::from_csv(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_csv() {
        let t = Trace { durations: vec![1.0, 2.5, 3.0] };
        let parsed = Trace::from_csv(&t.to_csv()).unwrap();
        assert_eq!(parsed, t);
    }

    #[test]
    fn workload_roundtrip() {
        let w = Workload::Uniform { count: 5, duration: 2.0 };
        let t = Trace::from_workload(&w);
        assert_eq!(t.durations, vec![2.0; 5]);
        assert_eq!(t.to_workload().count(), 5);
    }

    #[test]
    fn bad_csv_rejected() {
        assert!(Trace::from_csv("nope\n").is_err());
        assert!(Trace::from_csv("task_id,duration\n0,abc\n").is_err());
        assert!(Trace::from_csv("task_id,duration\n5,1.0\n").is_err(), "out of order");
        assert!(Trace::from_csv("task_id,duration\n0,-1.0\n").is_err());
    }

    #[test]
    fn file_roundtrip() {
        let t = Trace { durations: vec![0.5, 1.5] };
        let p = std::env::temp_dir().join("llsched_trace_test/t.csv");
        t.save(&p).unwrap();
        assert_eq!(Trace::load(&p).unwrap(), t);
        let _ = std::fs::remove_file(&p);
    }
}
