//! Trace record / replay.
//!
//! A trace is the per-task list of a workload plus the measured
//! schedule once run. Traces serialize to CSV so runs can be archived
//! in `results/` and replayed as Explicit workloads — the substitution
//! for the paper's production scheduler logs.
//!
//! The format is versioned by header:
//!
//! * **v1** — `task_id,duration`: the original shape. Parsed forever;
//!   arrival defaults to `0.0` and class to `batch`.
//! * **v2** — `task_id,duration,arrival_s,class`: adds the submit time
//!   and job class ([`JobClass`]), which churn replays need — a killed
//!   task's retry schedule only makes sense relative to when it
//!   arrived, and per-class latency splits need the class to survive
//!   the round trip.
//!
//! [`Trace::to_csv`] emits v1 when every row is at the v2 defaults
//! (so archived v1 traces round-trip byte-for-byte) and v2 otherwise.
//! Parsing is strict in both versions: unknown headers, out-of-order
//! ids, non-positive durations, negative arrivals, unknown classes,
//! and rows with missing *or extra* fields are all hard errors — a
//! malformed archive must fail loudly, not replay a different workload.

use crate::aggregation::plan::Workload;
use crate::error::{Error, Result};
use crate::workload::contention::JobClass;
use std::path::Path;

const HEADER_V1: &str = "task_id,duration";
const HEADER_V2: &str = "task_id,duration,arrival_s,class";

/// A recorded workload trace. The three vectors are parallel, one
/// entry per task.
#[derive(Debug, Clone, PartialEq)]
pub struct Trace {
    /// Per-task durations (seconds).
    pub durations: Vec<f64>,
    /// Per-task submit times (seconds; all `0.0` for v1 traces).
    pub arrivals: Vec<f64>,
    /// Per-task job class (all [`JobClass::Batch`] for v1 traces).
    pub classes: Vec<JobClass>,
}

impl Trace {
    /// A v1-shaped trace: durations only, arrivals zero, class batch.
    pub fn new(durations: Vec<f64>) -> Trace {
        let n = durations.len();
        Trace {
            durations,
            arrivals: vec![0.0; n],
            classes: vec![JobClass::Batch; n],
        }
    }

    /// Capture a (materialized) workload as a trace.
    pub fn from_workload(w: &Workload) -> Trace {
        let durations = match w {
            Workload::Uniform { count, duration } => vec![*duration; *count as usize],
            Workload::Explicit(v) => v.clone(),
        };
        Trace::new(durations)
    }

    /// Replay as a workload.
    pub fn to_workload(&self) -> Workload {
        Workload::Explicit(self.durations.clone())
    }

    /// Whether any row carries v2-only data (a non-zero arrival or a
    /// non-batch class) — the serialization version switch.
    pub fn needs_v2(&self) -> bool {
        self.arrivals.iter().any(|&a| a != 0.0)
            || self.classes.iter().any(|&c| c != JobClass::Batch)
    }

    /// Serialize as CSV: v1 (`task_id,duration`) when every row is at
    /// the v2 defaults, else v2 (`task_id,duration,arrival_s,class`).
    pub fn to_csv(&self) -> String {
        if self.needs_v2() {
            let mut s = String::from(HEADER_V2);
            s.push('\n');
            for (i, d) in self.durations.iter().enumerate() {
                s.push_str(&format!(
                    "{i},{d},{},{}\n",
                    self.arrivals[i],
                    class_label(self.classes[i])
                ));
            }
            s
        } else {
            let mut s = String::from(HEADER_V1);
            s.push('\n');
            for (i, d) in self.durations.iter().enumerate() {
                s.push_str(&format!("{i},{d}\n"));
            }
            s
        }
    }

    /// Parse from CSV produced by [`Self::to_csv`], either version.
    pub fn from_csv(text: &str) -> Result<Trace> {
        let mut lines = text.lines().enumerate();
        let v2 = match lines.next() {
            Some((_, h)) if h.trim() == HEADER_V1 => false,
            Some((_, h)) if h.trim() == HEADER_V2 => true,
            Some((_, h)) => {
                return Err(Error::Config(format!("bad trace header {h:?}")))
            }
            None => return Err(Error::Config("empty trace".into())),
        };
        let want = if v2 { 4 } else { 2 };
        let mut t = Trace::new(Vec::new());
        for (i, line) in lines {
            if line.trim().is_empty() {
                continue;
            }
            let parts: Vec<&str> = line.split(',').collect();
            if parts.len() != want {
                return Err(Error::Config(format!(
                    "trace line {}: expected {} fields, got {}",
                    i + 1,
                    want,
                    parts.len()
                )));
            }
            let id: usize = parts[0]
                .trim()
                .parse()
                .map_err(|_| Error::Config(format!("trace line {}: bad id", i + 1)))?;
            let d: f64 = parts[1]
                .trim()
                .parse()
                .map_err(|_| Error::Config(format!("trace line {}: bad duration", i + 1)))?;
            if id != t.durations.len() {
                return Err(Error::Config(format!(
                    "trace line {}: id {} out of order",
                    i + 1,
                    id
                )));
            }
            if !(d > 0.0) || !d.is_finite() {
                return Err(Error::Config(format!(
                    "trace line {}: non-positive duration",
                    i + 1
                )));
            }
            let (arrival, class) = if v2 {
                let a: f64 = parts[2].trim().parse().map_err(|_| {
                    Error::Config(format!("trace line {}: bad arrival", i + 1))
                })?;
                if !(a >= 0.0) || !a.is_finite() {
                    return Err(Error::Config(format!(
                        "trace line {}: negative arrival",
                        i + 1
                    )));
                }
                (a, parse_class(parts[2 + 1].trim(), i + 1)?)
            } else {
                (0.0, JobClass::Batch)
            };
            t.durations.push(d);
            t.arrivals.push(arrival);
            t.classes.push(class);
        }
        Ok(t)
    }

    /// Save to a file.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_csv())?;
        Ok(())
    }

    /// Load from a file.
    pub fn load(path: &Path) -> Result<Trace> {
        let text = std::fs::read_to_string(path)?;
        Trace::from_csv(&text)
    }
}

fn class_label(c: JobClass) -> &'static str {
    match c {
        JobClass::Interactive => "interactive",
        JobClass::Batch => "batch",
    }
}

fn parse_class(s: &str, line: usize) -> Result<JobClass> {
    match s {
        "interactive" => Ok(JobClass::Interactive),
        "batch" => Ok(JobClass::Batch),
        other => Err(Error::Config(format!(
            "trace line {line}: unknown class {other:?} (known: interactive, batch)"
        ))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_csv_v1() {
        let t = Trace::new(vec![1.0, 2.5, 3.0]);
        let csv = t.to_csv();
        assert!(csv.starts_with("task_id,duration\n"), "defaults stay v1: {csv}");
        let parsed = Trace::from_csv(&csv).unwrap();
        assert_eq!(parsed, t);
    }

    #[test]
    fn roundtrip_csv_v2() {
        let t = Trace {
            durations: vec![1.0, 2.5],
            arrivals: vec![0.0, 10.5],
            classes: vec![JobClass::Interactive, JobClass::Batch],
        };
        let csv = t.to_csv();
        assert!(
            csv.starts_with("task_id,duration,arrival_s,class\n"),
            "non-default rows switch to v2: {csv}"
        );
        assert!(csv.contains("0,1,0,interactive\n"), "{csv}");
        assert!(csv.contains("1,2.5,10.5,batch\n"), "{csv}");
        let parsed = Trace::from_csv(&csv).unwrap();
        assert_eq!(parsed, t);
    }

    #[test]
    fn v1_parses_with_v2_defaults() {
        let t = Trace::from_csv("task_id,duration\n0,4.0\n1,2.0\n").unwrap();
        assert_eq!(t.durations, vec![4.0, 2.0]);
        assert_eq!(t.arrivals, vec![0.0, 0.0]);
        assert_eq!(t.classes, vec![JobClass::Batch, JobClass::Batch]);
    }

    #[test]
    fn workload_roundtrip() {
        let w = Workload::Uniform { count: 5, duration: 2.0 };
        let t = Trace::from_workload(&w);
        assert_eq!(t.durations, vec![2.0; 5]);
        assert_eq!(t.to_workload().count(), 5);
    }

    #[test]
    fn bad_csv_rejected() {
        assert!(Trace::from_csv("nope\n").is_err());
        assert!(Trace::from_csv("").is_err(), "empty input rejected");
        assert!(Trace::from_csv("task_id,duration\n0,abc\n").is_err());
        assert!(Trace::from_csv("task_id,duration\n5,1.0\n").is_err(), "out of order");
        assert!(Trace::from_csv("task_id,duration\n0,-1.0\n").is_err());
        assert!(Trace::from_csv("task_id,duration\n0,NaN\n").is_err(), "NaN rejected");
    }

    #[test]
    fn malformed_rows_rejected_not_truncated() {
        // The v1 parser used to silently ignore extra fields; both
        // versions now pin the exact field count.
        let extra = "task_id,duration\n0,1.0,99.0\n";
        let err = Trace::from_csv(extra).unwrap_err().to_string();
        assert!(err.contains("expected 2 fields"), "got: {err}");
        let missing = "task_id,duration,arrival_s,class\n0,1.0,5.0\n";
        let err = Trace::from_csv(missing).unwrap_err().to_string();
        assert!(err.contains("expected 4 fields"), "got: {err}");
        // v2 field-level errors.
        assert!(
            Trace::from_csv("task_id,duration,arrival_s,class\n0,1.0,x,batch\n").is_err(),
            "bad arrival"
        );
        assert!(
            Trace::from_csv("task_id,duration,arrival_s,class\n0,1.0,-2.0,batch\n").is_err(),
            "negative arrival"
        );
        let err = Trace::from_csv("task_id,duration,arrival_s,class\n0,1.0,2.0,urgent\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("unknown class"), "got: {err}");
    }

    #[test]
    fn file_roundtrip() {
        let t = Trace::new(vec![0.5, 1.5]);
        let p = std::env::temp_dir().join("llsched_trace_test/t.csv");
        t.save(&p).unwrap();
        assert_eq!(Trace::load(&p).unwrap(), t);
        let _ = std::fs::remove_file(&p);
    }
}
