//! Interactive-vs-batch contention mixes.
//!
//! The paper's node-based scheduler exists so large fleets of short
//! interactive jobs and long-running batch jobs can share one cluster
//! ("Best of Both Worlds", arXiv:2008.02223, frames the same tension).
//! This module generates multi-job scenarios for that regime: each
//! job class has a configurable arrival process ([`Arrival`]), job-size
//! and duration distributions, and a priority; [`ContentionMix`]
//! expands a set of classes into a time-sorted submission stream the
//! contention runner ([`crate::coordinator::experiment::run_contention`])
//! feeds to the scheduler. Per-class launch latency and utilization are
//! computed by [`crate::metrics::contention`], so the paper's "fast
//! interactive launch while batch keeps the machine busy" claim is
//! directly measurable — with and without backfill.

use crate::error::{Error, Result};
use crate::scheduler::job::{ComputeBatch, JobSpec, ResourceRequest, SchedTaskSpec};
use crate::sim::Time;
use crate::util::rng::Rng;
use crate::workload::taskgen::TaskGen;

/// Multiplicative walltime-estimate error models.
///
/// Real schedulers plan backfill from *user-declared* walltimes, which
/// are notoriously inaccurate — the reservation literature ("Best of
/// Both Worlds", arXiv:2008.02223; "Scalable System Scheduling for HPC
/// and Big Data", arXiv:1705.03102) stresses that backfill quality
/// lives or dies on them. A model turns the DES's oracle runtime into
/// the estimate the reservation ledger plans with
/// ([`crate::scheduler::core::SchedulerSim::with_walltime_error`]); the
/// simulation still runs every task for its true duration, so holds go
/// overdue (under-estimates) or fire early (over-estimates) and the
/// scheduler re-plans instead of stalling.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum WalltimeError {
    /// Estimates are exact — the idealized-oracle seed behaviour.
    /// Draws nothing, so existing seeds reproduce bit-for-bit.
    None,
    /// `estimate = true × exp(σ·N(0,1))` — heavy-tailed and
    /// median-unbiased, the shape walltime studies usually report.
    LogNormal { sigma: f64 },
    /// `estimate = true × U[1−frac, 1+frac]` — bounded symmetric error.
    Uniform { frac: f64 },
}

impl WalltimeError {
    /// The CLI/config mapping for `--walltime-error σ`: non-positive σ
    /// is the exact-oracle model.
    pub fn from_sigma(sigma: f64) -> WalltimeError {
        if sigma <= 0.0 {
            WalltimeError::None
        } else {
            WalltimeError::LogNormal { sigma }
        }
    }

    /// Whether this is the exact-oracle model.
    pub fn is_none(&self) -> bool {
        *self == WalltimeError::None
    }

    /// Sample a multiplicative estimate factor. [`WalltimeError::None`]
    /// returns exactly `1.0` without consuming randomness; noisy draws
    /// are floored at 0.05 so a pathological sample cannot produce a
    /// zero or negative estimate.
    pub fn factor(&self, rng: &mut Rng) -> f64 {
        match *self {
            WalltimeError::None => 1.0,
            WalltimeError::LogNormal { sigma } => (sigma * rng.normal()).exp().max(0.05),
            WalltimeError::Uniform { frac } => rng.range_f64(1.0 - frac, 1.0 + frac).max(0.05),
        }
    }
}

impl std::fmt::Display for WalltimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WalltimeError::None => write!(f, "exact"),
            WalltimeError::LogNormal { sigma } => write!(f, "lognormal({sigma})"),
            WalltimeError::Uniform { frac } => write!(f, "uniform({frac})"),
        }
    }
}

/// Which contention class a job belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum JobClass {
    /// Small, short, latency-sensitive core-level jobs.
    Interactive,
    /// Large, long-running whole-node array jobs.
    Batch,
}

/// Both classes, in report order.
pub const JOB_CLASSES: [JobClass; 2] = [JobClass::Interactive, JobClass::Batch];

impl JobClass {
    /// Short label used in job names and report rows.
    pub fn label(&self) -> &'static str {
        match self {
            JobClass::Interactive => "interactive",
            JobClass::Batch => "batch",
        }
    }
}

impl std::fmt::Display for JobClass {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.label())
    }
}

/// A job arrival process over a finite horizon.
#[derive(Debug, Clone, Copy)]
pub enum Arrival {
    /// Poisson arrivals at `rate` jobs per second.
    Poisson { rate: f64 },
    /// Fixed inter-arrival `gap`, first job at `start`.
    Periodic { gap: Time, start: Time },
    /// `count` jobs all submitted at `at`.
    Burst { at: Time, count: u64 },
}

impl Arrival {
    /// Materialize arrival times within `[0, horizon)`.
    pub fn times(&self, horizon: Time, rng: &mut Rng) -> Vec<Time> {
        match *self {
            Arrival::Poisson { rate } => {
                let mut out = Vec::new();
                if rate <= 0.0 {
                    return out;
                }
                let mut t = rng.exponential(rate);
                while t < horizon {
                    out.push(t);
                    t += rng.exponential(rate);
                }
                out
            }
            Arrival::Periodic { gap, start } => {
                let mut out = Vec::new();
                if gap <= 0.0 {
                    return out;
                }
                let mut t = start;
                while t < horizon {
                    out.push(t);
                    t += gap;
                }
                out
            }
            Arrival::Burst { at, count } => {
                if at < horizon {
                    vec![at; count as usize]
                } else {
                    Vec::new()
                }
            }
        }
    }
}

/// One job class of a contention mix.
#[derive(Debug, Clone)]
pub struct ClassSpec {
    pub class: JobClass,
    pub arrival: Arrival,
    /// Scheduling tasks per job (array size).
    pub tasks_per_job: u64,
    /// Per-task resource shape (core-level or whole-node).
    pub request: ResourceRequest,
    /// Per-task duration distribution.
    pub duration: TaskGen,
    /// Dispatch priority (higher first; interactive outranks batch).
    pub priority: i32,
    /// Parallel compute lanes per scheduling task.
    pub lanes: u32,
}

/// A named interactive-vs-batch scenario.
#[derive(Debug, Clone)]
pub struct ContentionMix {
    pub name: String,
    /// Cluster size the mix is scaled for.
    pub nodes: u32,
    /// Arrival horizon, seconds (the run itself drains past it).
    pub horizon: Time,
    pub classes: Vec<ClassSpec>,
}

/// One job submission: when, what, and which class it belongs to.
#[derive(Debug, Clone)]
pub struct Submission {
    pub at: Time,
    pub class: JobClass,
    pub spec: JobSpec,
}

impl ContentionMix {
    /// Expand the mix into a time-sorted submission stream. Arrival and
    /// duration streams are forked per class, so adding a class never
    /// perturbs another class's draws.
    pub fn generate(&self, seed: u64) -> Vec<Submission> {
        let mut root = Rng::new(seed);
        let mut subs = Vec::new();
        for (ci, cs) in self.classes.iter().enumerate() {
            let mut arr_rng = root.fork();
            let mut dur_rng = root.fork();
            let times = cs.arrival.times(self.horizon, &mut arr_rng);
            for (ji, at) in times.into_iter().enumerate() {
                let mut tasks = Vec::with_capacity(cs.tasks_per_job as usize);
                for _ in 0..cs.tasks_per_job {
                    // Floor keeps pathological samples out of the DES
                    // (durations must be strictly positive).
                    let d = cs.duration.sample(&mut dur_rng).max(0.01);
                    tasks.push(SchedTaskSpec {
                        request: cs.request,
                        duration: d,
                        batch: ComputeBatch { count: 1, each: d },
                        lanes: cs.lanes,
                    });
                }
                subs.push(Submission {
                    at,
                    class: cs.class,
                    spec: JobSpec {
                        name: format!("{}-{ci}-{ji}", cs.class.label()),
                        tasks,
                        reservation: None,
                        priority: cs.priority,
                        preemptable: false,
                    },
                });
            }
        }
        subs.sort_by(|a, b| a.at.partial_cmp(&b.at).expect("no NaN arrival"));
        subs
    }

    /// Total scheduling tasks across all submissions.
    pub fn total_tasks(&self, seed: u64) -> u64 {
        self.generate(seed)
            .iter()
            .map(|s| s.spec.array_size())
            .sum()
    }

    /// A named preset scaled to `nodes` (64-core nodes assumed):
    ///
    /// * `tiny` — seconds-long smoke mix for CI and tests;
    /// * `default` — a balanced mix: periodic half-machine batch
    ///   arrays under a Poisson stream of small interactive jobs;
    /// * `heavy` — full-machine batch arrays under sustained
    ///   interactive pressure (the starvation regime);
    /// * `burst` — periodic 1000-task volleys of *short whole-node*
    ///   jobs over a sustained batch stream: the paper's rapid-launch
    ///   regime, the scenario the node pool ([`crate::pool`]) exists
    ///   for. Volley tasks route to the pool when one is enabled and
    ///   dispatch as ordinary whole-node tasks otherwise, so pooled
    ///   vs backfill-only launch latency is directly comparable;
    /// * `burst_mixed` — interleaved volleys of two *shapes* of
    ///   rapid-launch work over the batch stream: big waves of 0.5 s
    ///   "general" tasks and waves of 45 s "large-capacity" tasks, with
    ///   the submission order alternating per round (large-first at one
    ///   round, general-first at the next). In one merged FIFO each
    ///   shape periodically queues behind the other — exactly the
    ///   mutual head-of-line blocking the shape-sharded fleet
    ///   ([`crate::pool::fleet`]) removes, which is what the per-class
    ///   p95 regression in `rust/tests/fleet_properties.rs` measures.
    pub fn preset(name: &str, nodes: u32) -> Result<ContentionMix> {
        let nodes = nodes.max(2);
        match name {
            "tiny" => Ok(ContentionMix {
                name: "tiny".into(),
                nodes,
                horizon: 150.0,
                classes: vec![
                    ClassSpec {
                        class: JobClass::Interactive,
                        arrival: Arrival::Poisson { rate: 0.2 },
                        tasks_per_job: 2,
                        request: ResourceRequest::Cores { cores: 2, mem_mib: 128 },
                        duration: TaskGen::LogNormal { median: 3.0, sigma: 0.5 },
                        priority: 10,
                        lanes: 2,
                    },
                    ClassSpec {
                        class: JobClass::Batch,
                        arrival: Arrival::Periodic { gap: 60.0, start: 5.0 },
                        tasks_per_job: (nodes / 2).max(1) as u64,
                        request: ResourceRequest::WholeNode,
                        duration: TaskGen::Constant { seconds: 60.0 },
                        priority: -5,
                        lanes: 64,
                    },
                ],
            }),
            "default" => Ok(ContentionMix {
                name: "default".into(),
                nodes,
                horizon: 600.0,
                classes: vec![
                    ClassSpec {
                        class: JobClass::Interactive,
                        arrival: Arrival::Poisson { rate: 0.25 },
                        tasks_per_job: 4,
                        request: ResourceRequest::Cores { cores: 2, mem_mib: 256 },
                        duration: TaskGen::Bimodal { short: 2.0, long: 20.0, p_long: 0.2 },
                        priority: 10,
                        lanes: 2,
                    },
                    ClassSpec {
                        class: JobClass::Batch,
                        arrival: Arrival::Periodic { gap: 150.0, start: 10.0 },
                        tasks_per_job: (nodes / 2).max(1) as u64,
                        request: ResourceRequest::WholeNode,
                        duration: TaskGen::Constant { seconds: 180.0 },
                        priority: -5,
                        lanes: 64,
                    },
                ],
            }),
            "heavy" => Ok(ContentionMix {
                name: "heavy".into(),
                nodes,
                horizon: 900.0,
                classes: vec![
                    ClassSpec {
                        class: JobClass::Interactive,
                        arrival: Arrival::Poisson { rate: 0.5 },
                        tasks_per_job: 4,
                        request: ResourceRequest::Cores { cores: 4, mem_mib: 256 },
                        duration: TaskGen::Bimodal { short: 2.0, long: 30.0, p_long: 0.25 },
                        priority: 10,
                        lanes: 4,
                    },
                    ClassSpec {
                        class: JobClass::Batch,
                        arrival: Arrival::Periodic { gap: 240.0, start: 10.0 },
                        tasks_per_job: nodes as u64,
                        request: ResourceRequest::WholeNode,
                        duration: TaskGen::Constant { seconds: 240.0 },
                        priority: -5,
                        lanes: 64,
                    },
                ],
            }),
            "burst" => Ok(ContentionMix {
                name: "burst".into(),
                nodes,
                horizon: 400.0,
                classes: vec![
                    // Rapid-launch volleys: 1000 short whole-node tasks
                    // per wave. Short (0.5 s) so the *scheduler*, not
                    // node capacity, is the bottleneck on the batch
                    // path — exactly the regime the paper's node-based
                    // dispatch is built for.
                    ClassSpec {
                        class: JobClass::Interactive,
                        arrival: Arrival::Periodic { gap: 120.0, start: 5.0 },
                        tasks_per_job: 1000,
                        request: ResourceRequest::WholeNode,
                        duration: TaskGen::Constant { seconds: 0.5 },
                        priority: 10,
                        lanes: 64,
                    },
                    // Sustained quarter-machine batch stream underneath
                    // (long tasks keep the leases contended, so the
                    // elastic resize actually has pressure to work
                    // against).
                    ClassSpec {
                        class: JobClass::Batch,
                        arrival: Arrival::Periodic { gap: 150.0, start: 0.5 },
                        tasks_per_job: (nodes / 4).max(1) as u64,
                        request: ResourceRequest::WholeNode,
                        duration: TaskGen::Constant { seconds: 150.0 },
                        priority: -5,
                        lanes: 64,
                    },
                ],
            }),
            "burst_mixed" => {
                // The two rapid-launch families. Durations sit on either
                // side of the "general" shape's 2 s boundary, so a
                // `general` + `large` fleet routes them to distinct
                // shards while one merged pool serves both FIFO.
                let general = |at: Time| ClassSpec {
                    class: JobClass::Interactive,
                    arrival: Arrival::Burst { at, count: 1 },
                    tasks_per_job: 6 * nodes as u64,
                    request: ResourceRequest::WholeNode,
                    duration: TaskGen::Constant { seconds: 0.5 },
                    priority: 10,
                    lanes: 64,
                };
                let large = |at: Time| ClassSpec {
                    class: JobClass::Interactive,
                    arrival: Arrival::Burst { at, count: 1 },
                    tasks_per_job: (nodes / 4).max(1) as u64,
                    request: ResourceRequest::WholeNode,
                    duration: TaskGen::Constant { seconds: 45.0 },
                    priority: 8,
                    lanes: 64,
                };
                Ok(ContentionMix {
                    name: "burst_mixed".into(),
                    nodes,
                    horizon: 400.0,
                    // Same-instant volleys whose submission order
                    // alternates per round (class listing order breaks
                    // arrival-time ties): large-first at t = 5 and 245,
                    // general-first at t = 125 and 365. A merged FIFO
                    // head-of-line-blocks whichever family comes second;
                    // per-shard queues never do.
                    classes: vec![
                        large(5.0),
                        general(5.0),
                        general(125.0),
                        large(125.0),
                        large(245.0),
                        general(245.0),
                        general(365.0),
                        large(365.0),
                        // The long batch stream underneath keeps the
                        // leases contended, like `burst`.
                        ClassSpec {
                            class: JobClass::Batch,
                            arrival: Arrival::Periodic { gap: 150.0, start: 0.5 },
                            tasks_per_job: (nodes / 4).max(1) as u64,
                            request: ResourceRequest::WholeNode,
                            duration: TaskGen::Constant { seconds: 150.0 },
                            priority: -5,
                            lanes: 64,
                        },
                    ],
                })
            }
            other => Err(Error::Config(format!(
                "unknown contention preset {other:?} \
                 (known: tiny, default, heavy, burst, burst_mixed)"
            ))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_roughly_respected() {
        let mut rng = Rng::new(1);
        let times = Arrival::Poisson { rate: 0.5 }.times(10_000.0, &mut rng);
        let n = times.len() as f64;
        assert!((n - 5000.0).abs() < 300.0, "count {n}");
        assert!(times.windows(2).all(|w| w[0] <= w[1]), "sorted");
        assert!(times.iter().all(|&t| (0.0..10_000.0).contains(&t)));
    }

    #[test]
    fn periodic_and_burst_arrivals() {
        let mut rng = Rng::new(2);
        let p = Arrival::Periodic { gap: 50.0, start: 10.0 }.times(200.0, &mut rng);
        assert_eq!(p, vec![10.0, 60.0, 110.0, 160.0]);
        let b = Arrival::Burst { at: 30.0, count: 3 }.times(200.0, &mut rng);
        assert_eq!(b, vec![30.0, 30.0, 30.0]);
        let late = Arrival::Burst { at: 250.0, count: 3 }.times(200.0, &mut rng);
        assert!(late.is_empty(), "out-of-horizon bursts are dropped");
        let none = Arrival::Poisson { rate: 0.0 }.times(100.0, &mut rng);
        assert!(none.is_empty());
    }

    #[test]
    fn generation_is_deterministic_and_sorted() {
        let mix = ContentionMix::preset("tiny", 8).unwrap();
        let a = mix.generate(42);
        let b = mix.generate(42);
        assert_eq!(a.len(), b.len());
        assert!(!a.is_empty());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.at, y.at);
            assert_eq!(x.class, y.class);
            assert_eq!(x.spec.array_size(), y.spec.array_size());
        }
        assert!(a.windows(2).all(|w| w[0].at <= w[1].at), "time-sorted");
        // Both classes are present.
        assert!(a.iter().any(|s| s.class == JobClass::Interactive));
        assert!(a.iter().any(|s| s.class == JobClass::Batch));
    }

    #[test]
    fn presets_resolve_and_validate() {
        for name in ["tiny", "default", "heavy", "burst", "burst_mixed"] {
            let mix = ContentionMix::preset(name, 16).unwrap();
            assert_eq!(mix.name, name);
            for sub in mix.generate(7) {
                sub.spec.validate(64).expect("generated job is valid");
            }
        }
        assert!(ContentionMix::preset("bogus", 16).is_err());
    }

    #[test]
    fn burst_mixed_interleaves_families_with_alternating_order() {
        let mix = ContentionMix::preset("burst_mixed", 32).unwrap();
        let subs = mix.generate(3);
        // Rounds at 5/125/245/365, one general + one large volley each.
        let volleys: Vec<_> = subs
            .iter()
            .filter(|s| s.class == JobClass::Interactive)
            .collect();
        assert_eq!(volleys.len(), 8);
        fn dur(s: &Submission) -> f64 {
            s.spec.tasks[0].duration
        }
        for v in &volleys {
            assert!(v.spec.tasks.iter().all(|t| t.request == ResourceRequest::WholeNode));
            let d = dur(v);
            assert!(
                (d - 0.5).abs() < 1e-9 || (d - 45.0).abs() < 1e-9,
                "volley durations are exactly the two families, got {d}"
            );
        }
        // The general family is the big wave; the large one is heavier
        // per task but smaller.
        let big: Vec<_> = volleys.iter().filter(|v| dur(v) < 1.0).collect();
        let heavy: Vec<_> = volleys.iter().filter(|v| dur(v) > 1.0).collect();
        assert_eq!(big.len(), 4);
        assert_eq!(heavy.len(), 4);
        assert!(big.iter().all(|v| v.spec.array_size() == 6 * 32));
        assert!(heavy.iter().all(|v| v.spec.array_size() == 8));
        // Alternating same-instant order: large first at 5 and 245,
        // general first at 125 and 365 (generation sort is stable).
        let order_at = |t: f64| -> Vec<f64> {
            subs.iter()
                .filter(|s| s.class == JobClass::Interactive && (s.at - t).abs() < 1e-9)
                .map(dur)
                .collect()
        };
        assert_eq!(order_at(5.0), vec![45.0, 0.5]);
        assert_eq!(order_at(125.0), vec![0.5, 45.0]);
        assert_eq!(order_at(245.0), vec![45.0, 0.5]);
        assert_eq!(order_at(365.0), vec![0.5, 45.0]);
        // The batch stream stays long and whole-node (never
        // pool-eligible under the general/large shapes).
        for b in subs.iter().filter(|s| s.class == JobClass::Batch) {
            assert!(b.spec.tasks.iter().all(|t| t.duration > 60.0));
        }
    }

    #[test]
    fn burst_preset_shape() {
        let mix = ContentionMix::preset("burst", 32).unwrap();
        let subs = mix.generate(3);
        let volleys: Vec<_> = subs
            .iter()
            .filter(|s| s.class == JobClass::Interactive)
            .collect();
        // Horizon 400, gap 120, start 5 → volleys at 5/125/245/365.
        assert_eq!(volleys.len(), 4);
        for v in &volleys {
            assert_eq!(v.spec.array_size(), 1000, "1000-task volleys");
            assert!(v
                .spec
                .tasks
                .iter()
                .all(|t| t.request == ResourceRequest::WholeNode && t.duration < 30.0));
        }
        // The batch stream is whole-node and long (never pool-eligible).
        let batch: Vec<_> = subs.iter().filter(|s| s.class == JobClass::Batch).collect();
        assert!(!batch.is_empty());
        for b in &batch {
            assert!(b
                .spec
                .tasks
                .iter()
                .all(|t| t.request == ResourceRequest::WholeNode && t.duration > 30.0));
        }
    }

    #[test]
    fn walltime_error_from_sigma_mapping() {
        assert_eq!(WalltimeError::from_sigma(0.0), WalltimeError::None);
        assert_eq!(WalltimeError::from_sigma(-1.0), WalltimeError::None);
        assert_eq!(
            WalltimeError::from_sigma(0.3),
            WalltimeError::LogNormal { sigma: 0.3 }
        );
        assert!(WalltimeError::None.is_none());
        assert!(!WalltimeError::from_sigma(0.3).is_none());
    }

    #[test]
    fn walltime_none_factor_is_exact_and_draws_nothing() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..10 {
            assert_eq!(WalltimeError::None.factor(&mut a), 1.0);
        }
        // The stream was not consumed: both generators still agree.
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn walltime_factors_are_positive_and_centered() {
        let mut rng = Rng::new(11);
        for model in [
            WalltimeError::LogNormal { sigma: 0.5 },
            WalltimeError::Uniform { frac: 0.4 },
        ] {
            let n = 4000;
            let mut sum = 0.0;
            for _ in 0..n {
                let f = model.factor(&mut rng);
                assert!(f >= 0.05, "{model}: factor {f} below floor");
                sum += f;
            }
            let mean = sum / n as f64;
            assert!((0.7..1.5).contains(&mean), "{model}: mean factor {mean}");
        }
        // Zero-width uniform error is exactly 1 (the noise-free noisy
        // path the equivalence property leans on).
        let mut rng = Rng::new(3);
        for _ in 0..16 {
            assert_eq!(WalltimeError::Uniform { frac: 0.0 }.factor(&mut rng), 1.0);
        }
    }

    #[test]
    fn walltime_display_labels() {
        assert_eq!(WalltimeError::None.to_string(), "exact");
        assert_eq!(
            WalltimeError::LogNormal { sigma: 0.3 }.to_string(),
            "lognormal(0.3)"
        );
        assert_eq!(WalltimeError::Uniform { frac: 0.2 }.to_string(), "uniform(0.2)");
    }

    #[test]
    fn batch_jobs_are_whole_node_and_lower_priority() {
        let mix = ContentionMix::preset("default", 32).unwrap();
        let subs = mix.generate(1);
        for s in &subs {
            match s.class {
                JobClass::Batch => {
                    assert!(s
                        .spec
                        .tasks
                        .iter()
                        .all(|t| t.request == ResourceRequest::WholeNode));
                    assert!(s.spec.priority < 0);
                }
                JobClass::Interactive => {
                    assert!(s
                        .spec
                        .tasks
                        .iter()
                        .all(|t| matches!(t.request, ResourceRequest::Cores { .. })));
                    assert!(s.spec.priority > 0);
                }
            }
        }
    }
}
