//! The paper's benchmark workloads (Tables I & II).

use crate::aggregation::plan::{ClusterShape, Workload};
use crate::config::presets::{TaskConfig, CORES_PER_NODE};
use crate::config::{Mode, RunConfig};

/// One cell of the Table III matrix, fully resolved.
#[derive(Debug, Clone)]
pub struct PaperCell {
    pub nodes: u32,
    pub task: TaskConfig,
    pub mode: Mode,
    pub run_idx: usize,
    pub config: RunConfig,
}

impl PaperCell {
    pub fn new(nodes: u32, task: TaskConfig, mode: Mode, run_idx: usize) -> PaperCell {
        PaperCell {
            nodes,
            task,
            mode,
            run_idx,
            config: crate::config::presets::cell(nodes, &task, mode, run_idx),
        }
    }

    /// The machine slice this cell fills.
    pub fn shape(&self) -> ClusterShape {
        ClusterShape {
            nodes: self.nodes,
            cores_per_node: CORES_PER_NODE,
            task_mem_mib: self.config.task_mem_mib,
        }
    }

    /// The compute workload: every processor runs T_job seconds of
    /// `task_time`-second tasks.
    pub fn workload(&self) -> Workload {
        paper_workload(&self.config)
    }

    /// Human label like `512n/1s/N*`.
    pub fn label(&self) -> String {
        format!(
            "{}n/{}s/{}",
            self.nodes,
            self.task.task_time as u64,
            self.mode.short()
        )
    }
}

/// Build the constant-time-task workload for a run configuration.
pub fn paper_workload(c: &RunConfig) -> Workload {
    Workload::paper(c.processors(), c.task_time, c.job_time)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::presets::{NODE_SCALES, TASK_CONFIGS};

    #[test]
    fn cell_workload_sizes() {
        let cell = PaperCell::new(512, TASK_CONFIGS[0], Mode::NodeBased, 0);
        assert_eq!(cell.workload().count(), 7_864_320);
        assert_eq!(cell.shape().processors(), 32_768);
        assert_eq!(cell.label(), "512n/1s/N*");
    }

    #[test]
    fn total_work_matches_table2() {
        // Table II: total processor time in hours.
        for (&nodes, hours) in NODE_SCALES.iter().zip([136.5, 273.1, 546.1, 1092.3, 2184.5]) {
            let cell = PaperCell::new(nodes, TASK_CONFIGS[3], Mode::MultiLevel, 0);
            let h = cell.workload().total_work() / 3600.0;
            assert!((h - hours).abs() < 0.06, "{nodes}: {h} vs {hours}");
        }
    }

    #[test]
    fn workload_independent_of_mode() {
        let a = PaperCell::new(64, TASK_CONFIGS[1], Mode::MultiLevel, 0);
        let b = PaperCell::new(64, TASK_CONFIGS[1], Mode::NodeBased, 0);
        assert_eq!(a.workload().count(), b.workload().count());
        assert_eq!(a.workload().total_work(), b.workload().total_work());
    }
}
