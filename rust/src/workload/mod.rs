//! Workload construction: the paper's benchmark matrix, synthetic task
//! distributions for extension studies, interactive-vs-batch contention
//! mixes, and trace record/replay.

pub mod contention;
pub mod paper;
pub mod taskgen;
pub mod trace;

pub use contention::{Arrival, ClassSpec, ContentionMix, JobClass, Submission, WalltimeError};
pub use paper::{paper_workload, PaperCell};
pub use taskgen::TaskGen;
pub use trace::Trace;
