//! Workload construction: the paper's benchmark matrix, synthetic task
//! distributions for extension studies, and trace record/replay.

pub mod paper;
pub mod taskgen;
pub mod trace;

pub use paper::{paper_workload, PaperCell};
pub use taskgen::TaskGen;
pub use trace::Trace;
