//! Synthetic task-duration generators.
//!
//! The paper's benchmark uses constant-time tasks; the extension studies
//! (ablation benches) also exercise realistic skew: log-normal service
//! times, bimodal mixes (short interactive + long batch), and heavy-tail
//! stragglers — the situations where per-node aggregation's max-lane
//! duration diverges from the mean.

use crate::aggregation::plan::Workload;
use crate::util::rng::Rng;

/// A task-duration distribution.
#[derive(Debug, Clone, Copy)]
pub enum TaskGen {
    /// All tasks take exactly `seconds`.
    Constant { seconds: f64 },
    /// Log-normal with given median and sigma (log-space).
    LogNormal { median: f64, sigma: f64 },
    /// Mixture: fraction `p_long` take `long` seconds, rest take `short`.
    Bimodal { short: f64, long: f64, p_long: f64 },
    /// Exponential with the given mean.
    Exponential { mean: f64 },
}

impl TaskGen {
    /// Generate a workload of `count` tasks.
    pub fn generate(&self, count: u64, seed: u64) -> Workload {
        match self {
            TaskGen::Constant { seconds } => Workload::Uniform {
                count,
                duration: *seconds,
            },
            _ => {
                let mut rng = Rng::new(seed);
                let v: Vec<f64> = (0..count).map(|_| self.sample(&mut rng)).collect();
                Workload::Explicit(v)
            }
        }
    }

    /// Sample one duration.
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        match self {
            TaskGen::Constant { seconds } => *seconds,
            TaskGen::LogNormal { median, sigma } => rng.lognormal(median.ln(), *sigma),
            TaskGen::Bimodal { short, long, p_long } => {
                if rng.chance(*p_long) {
                    *long
                } else {
                    *short
                }
            }
            TaskGen::Exponential { mean } => rng.exponential(1.0 / mean),
        }
    }

    /// Theoretical mean duration (used for capacity planning in tests).
    pub fn mean(&self) -> f64 {
        match self {
            TaskGen::Constant { seconds } => *seconds,
            TaskGen::LogNormal { median, sigma } => median * (sigma * sigma / 2.0).exp(),
            TaskGen::Bimodal { short, long, p_long } => {
                short * (1.0 - p_long) + long * p_long
            }
            TaskGen::Exponential { mean } => *mean,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_stays_symbolic() {
        let w = TaskGen::Constant { seconds: 5.0 }.generate(1_000_000, 1);
        assert!(matches!(w, Workload::Uniform { .. }), "no materialization");
        assert_eq!(w.count(), 1_000_000);
    }

    #[test]
    fn lognormal_median_near_target() {
        let w = TaskGen::LogNormal { median: 10.0, sigma: 0.5 }.generate(20_000, 2);
        if let Workload::Explicit(v) = w {
            let mut s = v.clone();
            s.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let med = s[s.len() / 2];
            assert!((med - 10.0).abs() < 0.5, "median {med}");
            assert!(v.iter().all(|&d| d > 0.0));
        } else {
            panic!("expected explicit");
        }
    }

    #[test]
    fn bimodal_fraction() {
        let g = TaskGen::Bimodal { short: 1.0, long: 100.0, p_long: 0.1 };
        let w = g.generate(50_000, 3);
        if let Workload::Explicit(v) = w {
            let longs = v.iter().filter(|&&d| d == 100.0).count() as f64;
            let frac = longs / v.len() as f64;
            assert!((frac - 0.1).abs() < 0.01, "frac {frac}");
        } else {
            panic!("expected explicit");
        }
    }

    #[test]
    fn empirical_means_match_theory() {
        let mut rng = Rng::new(9);
        for g in [
            TaskGen::Constant { seconds: 3.0 },
            TaskGen::LogNormal { median: 5.0, sigma: 0.4 },
            TaskGen::Bimodal { short: 1.0, long: 50.0, p_long: 0.2 },
            TaskGen::Exponential { mean: 7.0 },
        ] {
            let n = 100_000;
            let m = (0..n).map(|_| g.sample(&mut rng)).sum::<f64>() / n as f64;
            let want = g.mean();
            assert!(
                (m - want).abs() / want < 0.03,
                "{g:?}: empirical {m} vs {want}"
            );
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let a = TaskGen::Exponential { mean: 2.0 }.generate(100, 42);
        let b = TaskGen::Exponential { mean: 2.0 }.generate(100, 42);
        if let (Workload::Explicit(x), Workload::Explicit(y)) = (a, b) {
            assert_eq!(x, y);
        } else {
            panic!();
        }
    }
}
