//! Hand-rolled CLI argument parsing (offline build: no clap).

use crate::error::{Error, Result};
use std::collections::HashMap;

/// Parsed command line: a subcommand, positional args, and `--key[=value]`
/// flags.
#[derive(Debug, Default)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding `argv[0]`).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        if let Some(cmd) = it.next() {
            if cmd.starts_with('-') {
                return Err(Error::Config(format!(
                    "expected a subcommand before flags, got {cmd:?}"
                )));
            }
            out.command = cmd;
        }
        while let Some(a) = it.next() {
            if let Some(flag) = a.strip_prefix("--") {
                if flag.is_empty() {
                    return Err(Error::Config("bare `--` not supported".into()));
                }
                if let Some((k, v)) = flag.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    // `--key value` form, unless the next token is a flag.
                    let v = it.next().expect("peeked");
                    out.flags.insert(flag.to_string(), v);
                } else {
                    out.flags.insert(flag.to_string(), String::from("true"));
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    /// Boolean flag (present without value, or `=true/false`).
    pub fn flag(&self, name: &str) -> bool {
        matches!(self.flags.get(name).map(String::as_str), Some("true") | Some("1") | Some("yes"))
    }

    /// String option.
    pub fn opt(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    /// Typed option with default.
    pub fn opt_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T> {
        match self.flags.get(name) {
            None => Ok(default),
            Some(v) => v.parse::<T>().map_err(|_| {
                Error::Config(format!("flag --{name}: cannot parse {v:?}"))
            }),
        }
    }

    /// Error if unknown flags were passed (catches typos).
    pub fn expect_known(&self, known: &[&str]) -> Result<()> {
        for k in self.flags.keys() {
            if !known.contains(&k.as_str()) {
                return Err(Error::Config(format!(
                    "unknown flag --{k} (known: {})",
                    known.join(", ")
                )));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse(&["table3", "--quick", "--runs=2", "--out", "results"]);
        assert_eq!(a.command, "table3");
        assert!(a.flag("quick"));
        assert_eq!(a.opt_parse::<usize>("runs", 3).unwrap(), 2);
        assert_eq!(a.opt("out"), Some("results"));
        assert!(!a.flag("missing"));
    }

    #[test]
    fn positional_args() {
        let a = parse(&["run", "config.toml", "--seed", "7"]);
        assert_eq!(a.positional, vec!["config.toml"]);
        assert_eq!(a.opt_parse::<u64>("seed", 0).unwrap(), 7);
    }

    #[test]
    fn value_then_flag_disambiguation() {
        let a = parse(&["x", "--a", "--b", "v"]);
        assert!(a.flag("a"), "--a has no value because --b follows");
        assert_eq!(a.opt("b"), Some("v"));
    }

    #[test]
    fn unknown_flag_detection() {
        let a = parse(&["t", "--good", "--typo=1"]);
        assert!(a.expect_known(&["good", "typo"]).is_ok());
        assert!(a.expect_known(&["good"]).is_err());
    }

    #[test]
    fn parse_errors() {
        assert!(Args::parse(vec!["--flag-first".to_string()]).is_err());
        let bad = parse(&["c", "--n=abc"]);
        assert!(bad.opt_parse::<u32>("n", 1).is_err());
    }

    #[test]
    fn empty_argv() {
        let a = Args::parse(Vec::<String>::new()).unwrap();
        assert_eq!(a.command, "");
    }
}
