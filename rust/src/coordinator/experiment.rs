//! Experiment orchestration: run Table III cells end-to-end over the DES
//! scheduler and collect the paper's measurements.

use crate::aggregation;
use crate::cluster::Cluster;
use crate::config::presets::{self, NODE_SCALES, RUNS_PER_CELL, TASK_CONFIGS};
use crate::config::Mode;
use crate::error::{Error, Result};
use crate::fault::metrics::FaultOutcome;
use crate::fault::FaultConfig;
use crate::federation::{FederationConfig, FederationOutcome, Gateway};
use crate::metrics::contention::{per_class, pool_report, ClassReport, PoolReport};
use crate::metrics::overhead::OverheadPoint;
use crate::metrics::timeline::UtilizationSeries;
use crate::obs::{reconstruct_spans, Obs, ObsSnapshot, Subsystem, WaitBlame, BLAME_CAUSES};
use crate::placement::Strategy;
use crate::pool::{FleetConfig, PoolConfig, ShardConfig};
use crate::scheduler::core::{HotPath, SchedulerSim, SimOutcome, TaskModel};
use crate::scheduler::costmodel::CostModel;
use crate::scheduler::noise::NoiseModel;
use crate::scheduler::queue::AgingPolicy;
use crate::scheduler::{ComputeBatch, JobSpec, ResourceRequest, SchedTaskSpec};
use crate::sim::EventQueue;
use crate::util::csv::Csv;
use crate::util::json::Json;
use crate::util::stats;
use crate::workload::contention::{ContentionMix, JobClass, Submission, WalltimeError, JOB_CLASSES};
use crate::workload::paper::PaperCell;

/// Result of one benchmark run (one cell, one repetition).
#[derive(Debug)]
pub struct CellResult {
    pub cell: PaperCell,
    /// The paper's "job run time": first task start → last task end.
    pub runtime: f64,
    /// Runtime minus T_job.
    pub overhead: f64,
    /// Machine-fill span (first → last dispatch).
    pub dispatch_span: f64,
    /// First end → last cleanup (release span).
    pub release_span: f64,
    /// Utilization series for Fig 2.
    pub utilization: UtilizationSeries,
    /// Scheduler responsiveness indicator.
    pub longest_busy_stretch: f64,
    /// Whether the responsiveness guard would bar this from production.
    pub unusable_in_production: bool,
    /// Placement strategy the run dispatched through.
    pub placement: Strategy,
    /// DES events processed (engine throughput accounting).
    pub events: u64,
    /// Flight-recorder snapshot (`None` unless the config set
    /// `trace_cap > 0`).
    pub obs: Option<ObsSnapshot>,
}

/// Options for matrix runs.
#[derive(Debug, Clone, Copy)]
pub struct ExperimentOpts {
    /// Include the paper's N/A cells (multi-level 512 nodes, short tasks).
    pub include_na: bool,
    /// Only run scales up to this node count (quick mode).
    pub max_nodes: u32,
    /// Repetitions per cell (paper: 3).
    pub runs: usize,
    /// Fig 2 sampling step, seconds.
    pub dt: f64,
}

impl Default for ExperimentOpts {
    fn default() -> Self {
        ExperimentOpts {
            include_na: false,
            max_nodes: 512,
            runs: RUNS_PER_CELL,
            dt: 1.0,
        }
    }
}

/// Run one cell (one repetition) end-to-end. The placement strategy is
/// the config's explicit `placement` if set, else the aggregation
/// mode's default (node-based fast path for N*, first-fit otherwise).
pub fn run_cell(cell: &PaperCell) -> Result<CellResult> {
    let cfg = &cell.config;
    cfg.validate()?;
    let cluster = Cluster::homogeneous(cfg.nodes, cfg.cores_per_node, 192 * 1024);
    let noise = if cfg.dedicated {
        NoiseModel::dedicated()
    } else {
        NoiseModel::production()
    };
    let placement = cfg.placement_strategy();
    let mut sim = SchedulerSim::new(cluster, CostModel::slurm_like_tx_green(), noise, cfg.seed)
        .with_placement(placement)
        .with_backfill(cfg.backfill)
        .with_holds(cfg.holds)
        .with_aging(cfg.aging_policy())
        .with_walltime_error(WalltimeError::from_sigma(cfg.walltime_error))
        .with_fleet(cfg.fleet_config())
        .with_preempt_overdue(cfg.preempt_overdue)
        .with_faults(cfg.fault_config());
    if cfg.trace_cap > 0 {
        sim = sim.with_recorder(Box::new(Obs::new(cfg.trace_cap)));
    }
    let agg = aggregation::for_mode(cfg.mode);
    let job = agg.plan(&cell.label(), &cell.workload(), &cell.shape())?;
    let (outcome, job_id) = sim.run_single(job);
    summarize(cell.clone(), &outcome, job_id, placement, 1.0)
}

fn summarize(
    cell: PaperCell,
    outcome: &SimOutcome,
    job_id: u64,
    placement: Strategy,
    dt: f64,
) -> Result<CellResult> {
    let stats = outcome
        .job_stats(job_id, cell.config.job_time)
        .ok_or_else(|| Error::Infeasible(format!("{}: job did not finish", cell.label())))?;
    let utilization = UtilizationSeries::from_steps(
        &outcome.timeline,
        cell.config.processors(),
        dt,
    );
    Ok(CellResult {
        runtime: stats.runtime,
        overhead: stats.overhead,
        dispatch_span: stats.dispatch_span,
        release_span: stats.release_span,
        utilization,
        longest_busy_stretch: outcome.longest_busy_stretch,
        unusable_in_production: outcome.unusable_in_production(),
        placement,
        events: outcome.events_processed,
        obs: outcome.obs.clone(),
        cell,
    })
}

/// Run one cell under every placement strategy (same seed, same
/// workload) — the policy-comparison scenario the placement subsystem
/// opens up. Returns `(strategy, result)` pairs.
pub fn run_placement_sweep(
    nodes: u32,
    task: &presets::TaskConfig,
    mode: Mode,
) -> Result<Vec<(Strategy, CellResult)>> {
    presets::placement_sweep(nodes, task, mode)
        .into_iter()
        .map(|cfg| {
            let strategy = cfg.placement_strategy();
            let mut cell = PaperCell::new(cfg.nodes, *task, cfg.mode, 0);
            cell.config = cfg;
            Ok((strategy, run_cell(&cell)?))
        })
        .collect()
}

/// Knobs for one contention run: backfill plus the fairness / noise
/// layer — top-K holds, queue aging, walltime-estimate error — and the
/// rapid-launch pool fleet.
#[derive(Debug, Clone)]
pub struct ContentionOpts {
    pub backfill: bool,
    /// Max simultaneous earliest-start holds (K; `1` = the original
    /// EASY single-hold discipline).
    pub holds: usize,
    /// Queue aging (`None` = static priorities).
    pub aging: Option<AgingPolicy>,
    /// Walltime-estimate error model the ledger plans from.
    pub walltime_error: WalltimeError,
    /// Legacy single rapid-launch pool (disabled = the classic
    /// batch-only path, bit-for-bit). Ignored when `pools` is
    /// non-empty.
    pub pool: PoolConfig,
    /// Shape-sharded pool fleet: one shard per entry. Empty defers to
    /// the legacy `pool` knob (mapped to a one-shard fleet).
    pub pools: Vec<ShardConfig>,
    /// Preemptive backfill: kill overdue backfilled tasks when their
    /// node's hold comes due.
    pub preempt_overdue: bool,
    /// Dispatch-loop discipline: wake-driven (default) or the
    /// historical polled loop — same schedule either way (pinned by
    /// `rust/tests/event_equivalence.rs`), different per-pick cost.
    pub hot_path: HotPath,
    /// Fault & churn injection (disabled = the historical fault-free
    /// path, bit-for-bit — pinned by `rust/tests/fault_properties.rs`).
    pub fault: FaultConfig,
    /// Flight-recorder ring capacity, in events. `0` (the default)
    /// leaves the recorder out entirely — the dispatch hot path keeps
    /// its historical shape (pinned by `rust/tests/obs_properties.rs`).
    pub trace_cap: usize,
    /// Self-profile the dispatch loop (host-side `pick_next` timing).
    /// Only meaningful with `trace_cap > 0`; wall-clock, so excluded
    /// from the byte-determinism guarantees.
    pub trace_profile: bool,
    /// Reconstruct per-job wait-blame spans from the recorder and
    /// attach a per-class rollup to the result — the v7 export
    /// switch. Needs `trace_cap > 0` to have any effect; off by
    /// default so v6-and-earlier export bytes are untouched.
    pub blame: bool,
    pub seed: u64,
}

impl ContentionOpts {
    /// The classic (pre-fairness-layer) options: single hold, no aging,
    /// exact estimates, no pool — schedules are bit-for-bit the
    /// historical ones.
    pub fn classic(backfill: bool, seed: u64) -> ContentionOpts {
        ContentionOpts {
            backfill,
            holds: 1,
            aging: None,
            walltime_error: WalltimeError::None,
            pool: PoolConfig::disabled(),
            pools: Vec::new(),
            preempt_overdue: false,
            hot_path: HotPath::default(),
            fault: FaultConfig::disabled(),
            trace_cap: 0,
            trace_profile: false,
            blame: false,
            seed,
        }
    }

    /// Build the flight recorder this run asks for (`None` when
    /// `trace_cap` is 0). `pid` labels the recorder's process lane in
    /// merged/federated exports.
    fn recorder(&self, pid: u32) -> Option<Box<Obs>> {
        if self.trace_cap == 0 {
            return None;
        }
        let mut obs = Obs::new(self.trace_cap).with_pid(pid);
        if self.trace_profile {
            obs = obs.with_profiling();
        }
        Some(Box::new(obs))
    }

    /// The fleet this run installs: the explicit shard list when
    /// present, else the legacy pool knob as a one-shard fleet.
    pub fn fleet_config(&self) -> FleetConfig {
        FleetConfig::from_parts(&self.pools, self.pool)
    }

    /// Whether any rapid-launch pool participates (allocation-free —
    /// the export any-passes call this per result).
    pub fn fleet_enabled(&self) -> bool {
        !self.pools.is_empty() || self.pool.enabled()
    }

    /// Whether this run shards the fleet (> 1 shard) — the v3 export
    /// switch.
    pub fn fleet_sharded(&self) -> bool {
        self.pools.len() > 1
    }

    /// Whether fault injection participates — the v4 export switch.
    pub fn fault_enabled(&self) -> bool {
        self.fault.enabled()
    }
}

/// Result of one interactive-vs-batch contention run.
#[derive(Debug)]
pub struct ContentionResult {
    pub mix_name: String,
    pub nodes: u32,
    pub backfill: bool,
    /// The full knob set the run used.
    pub opts: ContentionOpts,
    /// Per-class launch latency / utilization ([`JobClass`] order:
    /// interactive, batch).
    pub reports: Vec<ClassReport>,
    /// First submit → last cleanup, seconds.
    pub span: f64,
    /// Whole-cluster utilization over the span, in `[0, 1]`.
    pub utilization: f64,
    /// Backfill dispatches performed.
    pub backfills: usize,
    /// Peak simultaneous holds observed (≤ the configured K).
    pub max_active_holds: usize,
    /// Every backfill placed on a held node vacated it by the hold's
    /// planned start (the no-delay invariant, checked from records).
    /// Trivially true under a walltime-error model: delays then are the
    /// modelled estimate error, not a scheduler bug — and under
    /// preemptive backfill, where overdue tasks are killed by design.
    pub holds_respected: bool,
    /// Rapid-launch pool metrics (`None` when the pool was disabled).
    pub pool: Option<PoolReport>,
    /// Overdue backfilled tasks killed for a due hold.
    pub overdue_preemptions: u64,
    /// Fault & churn outcome: counters plus the deterministic audit
    /// log (`None` when fault injection was disabled).
    pub fault: Option<FaultOutcome>,
    /// Tasks that never finished (should be 0 — arrivals are finite,
    /// though a churn run that permanently loses capacity may strand
    /// tail tasks).
    pub unfinished: usize,
    /// Federation rollup (`None` for classic single-scheduler runs —
    /// the v5 export switch).
    pub federation: Option<FederationRunSummary>,
    /// Flight-recorder snapshot (`None` when `opts.trace_cap == 0` —
    /// the v6 export switch).
    pub obs: Option<ObsSnapshot>,
    /// Per-class wait-blame rollup (`None` unless `opts.blame` and
    /// the recorder were both on — the v7 export switch).
    pub blame: Option<Vec<ClassBlame>>,
}

/// The federated slice of one contention run: the gateway knobs plus
/// the fleet-level counters the v5 export columns carry. The full
/// per-instance detail lives in [`crate::federation::FederationOutcome`].
#[derive(Debug, Clone)]
pub struct FederationRunSummary {
    pub config: FederationConfig,
    /// Jobs migrated between instances by the steal pass.
    pub steals: u64,
    /// Batch flushes across all instances.
    pub batches: u64,
    /// Aggregate p95 launch latency over all gateway jobs, seconds.
    pub p95_latency: f64,
}

/// Per-class wait-blame rollup reconstructed from the flight
/// recorder — the v7 export payload.
#[derive(Debug, Clone)]
pub struct ClassBlame {
    pub class: JobClass,
    /// Launched jobs of this class with a reconstructed span.
    pub jobs: usize,
    /// Mean attributed wait over those jobs, seconds.
    pub mean_wait_s: f64,
    /// Per-cause totals, seconds, in [`BLAME_CAUSES`] order.
    pub blame: WaitBlame,
}

/// Run one contention mix with the classic single-hold options — the
/// historical entry point; see [`run_contention_with`] for the fairness
/// and noise knobs.
pub fn run_contention(
    mix: &ContentionMix,
    backfill: bool,
    seed: u64,
) -> Result<ContentionResult> {
    run_contention_with(mix, ContentionOpts::classic(backfill, seed))
}

/// Run one contention mix end-to-end: submit the generated interactive
/// and batch streams, drain the scheduler, and split launch latency and
/// utilization by class. `opts.backfill` flips the reservation +
/// backfill machinery, `opts.holds`/`opts.aging`/`opts.walltime_error`
/// the fairness layer; placement uses the node-based fast path (the mix
/// contains whole-node jobs by construction).
pub fn run_contention_with(
    mix: &ContentionMix,
    opts: ContentionOpts,
) -> Result<ContentionResult> {
    let seed = opts.seed;
    let fleet = opts.fleet_config();
    fleet.validate().map_err(Error::Config)?;
    let cluster = Cluster::tx_green(mix.nodes);
    let total_cores = cluster.total_cores();
    let mut sim = SchedulerSim::new(
        cluster,
        CostModel::slurm_like_tx_green(),
        NoiseModel::dedicated(),
        seed,
    )
    .with_placement(Strategy::NodeBased)
    .with_backfill(opts.backfill)
    .with_holds(opts.holds)
    .with_aging(opts.aging)
    .with_walltime_error(opts.walltime_error)
    .with_fleet(fleet)
    .with_preempt_overdue(opts.preempt_overdue)
    .with_hot_path(opts.hot_path)
    .with_faults(opts.fault.clone());
    if let Some(obs) = opts.recorder(0) {
        sim = sim.with_recorder(obs);
    }
    let mut q = EventQueue::new();
    let subs = mix.generate(seed);
    if subs.is_empty() {
        return Err(Error::Infeasible(format!(
            "contention mix {:?} generated no submissions",
            mix.name
        )));
    }
    let mut classes: Vec<JobClass> = Vec::with_capacity(subs.len());
    for sub in subs {
        classes.push(sub.class);
        let id = sim.submit_at(&mut q, sub.at, sub.spec);
        debug_assert_eq!(id as usize, classes.len() - 1, "job ids are dense");
    }
    let outcome = sim.run(&mut q);
    let (reports, span) = per_class(&outcome.records, &classes, total_cores);
    let utilization: f64 = reports.iter().map(|r| r.utilization).sum();
    // Backfill admission uses the *declared* duration (a walltime
    // estimate); the task model adds half-normal jitter (σ = 0.4 s) on
    // top, modelling estimate error. Tolerate its tail here — the
    // strict zero-jitter invariant is pinned by the property tests in
    // `rust/tests/backfill_properties.rs`. Under an explicit
    // walltime-error model, hold delays are the *modelled* estimate
    // error — expected, not a bug — so the check is skipped.
    let jitter_slack = 5.0;
    let holds_respected = opts.walltime_error != WalltimeError::None
        || opts.preempt_overdue
        || outcome.backfills.iter().all(|b| {
            let Some(h) = b.hold else {
                return true;
            };
            if b.node != h.node {
                return true;
            }
            outcome.records[b.task as usize]
                .end_t
                .map(|end| end <= h.start + jitter_slack)
                .unwrap_or(false)
        });
    let unfinished = outcome
        .records
        .iter()
        .filter(|r| r.cleanup_t.is_none())
        .count();
    let pool = outcome
        .pool
        .as_ref()
        .map(|po| pool_report(&outcome.records, po, total_cores, span));
    let blame = match (&outcome.obs, opts.blame) {
        (Some(snap), true) => Some(class_blame(snap, &classes)),
        _ => None,
    };
    Ok(ContentionResult {
        mix_name: mix.name.clone(),
        nodes: mix.nodes,
        backfill: opts.backfill,
        opts,
        reports,
        span,
        utilization,
        backfills: outcome.backfills.len(),
        max_active_holds: outcome.max_active_holds,
        holds_respected,
        pool,
        overdue_preemptions: outcome.overdue_preemptions,
        fault: outcome.fault,
        unfinished,
        federation: None,
        obs: outcome.obs,
        blame,
    })
}

/// Run one contention mix through a federated fleet: `fed.instances`
/// independent schedulers, each owning `mix.nodes / instances` of the
/// machine, behind the submission gateway ([`crate::federation`]). The
/// per-class reports are computed from the *gateway's* job table —
/// launch latency is gateway submit → first task start on the final
/// owner, so batching delay and steal hops are charged to the fleet,
/// exactly what a client observes. With `instances = 1` and `batch = 1`
/// the result matches [`run_contention_with`] bit-for-bit (pinned by
/// `rust/tests/federation_properties.rs`).
pub fn run_contention_federated(
    mix: &ContentionMix,
    opts: ContentionOpts,
    fed: FederationConfig,
) -> Result<ContentionResult> {
    fed.validate().map_err(Error::Config)?;
    if mix.nodes as usize % fed.instances != 0 {
        return Err(Error::Config(format!(
            "federation.instances ({}) must divide the mix's nodes ({})",
            fed.instances, mix.nodes
        )));
    }
    let per_nodes = mix.nodes / fed.instances as u32;
    let fleet = opts.fleet_config();
    fleet.validate().map_err(Error::Config)?;
    let total_cores = Cluster::tx_green(mix.nodes).total_cores();
    let sims: Vec<SchedulerSim> = (0..fed.instances)
        .map(|i| {
            let mut sim = SchedulerSim::new(
                Cluster::tx_green(per_nodes),
                CostModel::slurm_like_tx_green(),
                NoiseModel::dedicated(),
                opts.seed.wrapping_add(i as u64),
            )
            .with_placement(Strategy::NodeBased)
            .with_backfill(opts.backfill)
            .with_holds(opts.holds)
            .with_aging(opts.aging)
            .with_walltime_error(opts.walltime_error)
            .with_fleet(opts.fleet_config())
            .with_preempt_overdue(opts.preempt_overdue)
            .with_hot_path(opts.hot_path)
            .with_faults(opts.fault.clone());
            if let Some(obs) = opts.recorder(i as u32) {
                sim = sim.with_recorder(obs);
            }
            sim
        })
        .collect();
    let subs = mix.generate(opts.seed);
    if subs.is_empty() {
        return Err(Error::Infeasible(format!(
            "contention mix {:?} generated no submissions",
            mix.name
        )));
    }
    // The gateway's own recorder takes the process lane after the last
    // instance, so merged exports keep one lane per actor.
    let gw_pid = fed.instances as u32;
    let mut gw = Gateway::new(fed, sims);
    if let Some(obs) = opts.recorder(gw_pid) {
        gw = gw.with_recorder(obs);
    }
    let out = gw.run(subs);
    let reports = federation_class_reports(&out, total_cores);
    let utilization: f64 = reports.iter().map(|r| r.utilization).sum();
    let blame = match (&out.obs, opts.blame) {
        (Some(snap), true) => {
            // Gateway job indices are dense submission indices, so the
            // gateway job table doubles as the class table.
            let classes: Vec<JobClass> = out.jobs.iter().map(|j| j.class).collect();
            Some(class_blame(snap, &classes))
        }
        _ => None,
    };
    Ok(ContentionResult {
        mix_name: mix.name.clone(),
        nodes: mix.nodes,
        backfill: opts.backfill,
        span: out.span,
        utilization,
        backfills: out.outcomes.iter().map(|o| o.backfills.len()).sum(),
        max_active_holds: out
            .outcomes
            .iter()
            .map(|o| o.max_active_holds)
            .max()
            .unwrap_or(0),
        // The no-delay invariant is a per-instance property pinned by
        // the backfill suites; the fleet rollup does not re-derive it.
        holds_respected: true,
        // Per-instance pool detail lives in the raw outcomes; the fleet
        // rollup does not merge pool reports across partitions.
        pool: None,
        overdue_preemptions: out.outcomes.iter().map(|o| o.overdue_preemptions).sum(),
        fault: None,
        unfinished: out.unfinished,
        federation: Some(FederationRunSummary {
            config: out.config,
            steals: out.steals,
            batches: out.batches,
            p95_latency: out.latency.p95,
        }),
        obs: out.obs,
        opts,
        blame,
    })
}

/// Per-class reports from the gateway's job table (class latency is the
/// end-to-end gateway latency, not any single instance's view).
fn federation_class_reports(out: &FederationOutcome, total_cores: u64) -> Vec<ClassReport> {
    let capacity = total_cores as f64 * out.span;
    JOB_CLASSES
        .iter()
        .map(|&class| {
            let mut latencies = Vec::new();
            let mut jobs = 0usize;
            let mut tasks = 0usize;
            let mut completed = 0usize;
            let mut core_seconds = 0.0;
            let mut starvation_age: f64 = 0.0;
            for j in out.jobs.iter().filter(|j| j.class == class) {
                jobs += 1;
                tasks += j.tasks;
                completed += j.completed;
                core_seconds += j.core_seconds;
                if j.latency.is_finite() {
                    latencies.push(j.latency);
                } else {
                    starvation_age = starvation_age.max((out.final_time - j.submit_t).max(0.0));
                }
            }
            let max_launch_latency = if latencies.is_empty() {
                f64::NAN
            } else {
                latencies.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
            };
            ClassReport {
                class,
                jobs,
                tasks,
                completed,
                median_launch_latency: stats::median(&latencies),
                p95_launch_latency: stats::percentile(&latencies, 95.0),
                max_launch_latency,
                starvation_age,
                core_seconds,
                utilization: if capacity > 0.0 {
                    core_seconds / capacity
                } else {
                    0.0
                },
            }
        })
        .collect()
}

/// Per-class wait-blame rollup from a flight-recorder snapshot:
/// spans reconstructed by [`crate::obs::reconstruct_spans`], bucketed
/// by the submission-order class table (job span keys are dense
/// submission indices in both standalone and federated runs).
fn class_blame(snap: &ObsSnapshot, classes: &[JobClass]) -> Vec<ClassBlame> {
    let spans = reconstruct_spans(snap);
    JOB_CLASSES
        .iter()
        .map(|&class| {
            let mut jobs = 0usize;
            let mut wait = 0.0f64;
            let mut blame = WaitBlame::default();
            for s in spans.spans.iter().filter(|s| s.launched) {
                if classes.get(s.job as usize).copied() == Some(class) {
                    jobs += 1;
                    wait += s.wait_s;
                    blame.merge(&s.blame);
                }
            }
            let mean_wait_s = if jobs > 0 { wait / jobs as f64 } else { f64::NAN };
            ClassBlame { class, jobs, mean_wait_s, blame }
        })
        .collect()
}

/// Options for the federation rate sweep ([`run_federation`]).
#[derive(Debug, Clone)]
pub struct FederationSweepOpts {
    /// Partitions in the federated fleet.
    pub instances: usize,
    /// Nodes *per partition*; the single-scheduler baseline is one
    /// instance of exactly this size, so the sweep isolates what the
    /// gateway + extra partitions buy over one scheduler of the same
    /// per-partition scale.
    pub nodes: u32,
    /// Submission rates to sweep, jobs/second, ascending.
    pub rates: Vec<f64>,
    /// Jobs injected per swept point.
    pub jobs: usize,
    /// Duration of each (single, whole-node) task, seconds.
    pub task_s: f64,
    /// The saturation knee: a configuration "sustains" a rate while its
    /// p95 launch latency stays at or below this, seconds.
    pub knee_s: f64,
    pub batch: usize,
    pub steal_threshold: usize,
    pub seed: u64,
}

impl Default for FederationSweepOpts {
    /// The default grid brackets both knees: one 32-node scheduler of
    /// 2 s whole-node jobs caps out at 16 jobs/s (node-bound), the
    /// 4-partition fleet at 64 jobs/s — so the sweep resolves a 4×
    /// sustained-rate gain with overloaded points on both sides.
    fn default() -> Self {
        FederationSweepOpts {
            instances: 4,
            nodes: 32,
            rates: vec![2.0, 4.0, 8.0, 12.0, 16.0, 24.0, 32.0, 48.0, 64.0],
            jobs: 2000,
            task_s: 2.0,
            knee_s: 15.0,
            batch: 8,
            steal_threshold: 64,
            seed: 42,
        }
    }
}

/// One swept submission rate: p95 launch latency under a single
/// scheduler vs the federated fleet.
#[derive(Debug, Clone, Copy)]
pub struct RatePoint {
    pub rate: f64,
    pub single_p95: f64,
    pub federated_p95: f64,
}

/// Result of [`run_federation`]: the latency-vs-rate curves and the
/// saturation points they imply.
#[derive(Debug)]
pub struct FederationSweep {
    pub opts: FederationSweepOpts,
    pub points: Vec<RatePoint>,
    /// Highest swept rate the single scheduler sustains (p95 ≤ knee);
    /// 0.0 if it never does.
    pub single_saturation: f64,
    /// Highest swept rate the federated fleet sustains.
    pub federated_saturation: f64,
    /// `federated_saturation / single_saturation` (NaN if the single
    /// scheduler saturates before the first swept point).
    pub rate_gain: f64,
}

/// A deterministic open-loop stream: `jobs` single-task whole-node
/// interactive jobs at a fixed `rate` (job k arrives at `k / rate`).
/// Whole-node tasks make capacity exact — one instance of N nodes runs
/// at most N tasks at once — so the saturation knee is a property of
/// scheduling, not of workload noise.
fn uniform_stream(rate: f64, jobs: usize, task_s: f64) -> Vec<Submission> {
    (0..jobs)
        .map(|k| Submission {
            at: k as f64 / rate,
            class: JobClass::Interactive,
            spec: JobSpec {
                name: format!("rate-{k}"),
                tasks: vec![SchedTaskSpec {
                    request: ResourceRequest::WholeNode,
                    duration: task_s,
                    batch: ComputeBatch {
                        count: 1,
                        each: task_s,
                    },
                    lanes: 1,
                }],
                reservation: None,
                priority: 10,
                preemptable: false,
            },
        })
        .collect()
}

/// Build one sweep participant: a fleet of `instances` schedulers of
/// `nodes` each behind a gateway (pass `instances = 1` for the single-
/// scheduler baseline — same measurement path, so the two curves are
/// directly comparable). The node-noise knobs are zeroed so each
/// partition's capacity is exactly `nodes / task_s` jobs per second and
/// the knee measures scheduling, not startup jitter.
fn sweep_fleet(opts: &FederationSweepOpts, instances: usize) -> (FederationConfig, Vec<SchedulerSim>) {
    let fed = FederationConfig {
        instances,
        batch: opts.batch,
        flush_interval: FederationConfig::default().flush_interval,
        steal_threshold: opts.steal_threshold,
    };
    let sims = (0..instances)
        .map(|i| {
            SchedulerSim::new(
                Cluster::tx_green(opts.nodes),
                CostModel::slurm_like_tx_green(),
                NoiseModel::dedicated(),
                opts.seed.wrapping_add(i as u64),
            )
            .with_placement(Strategy::NodeBased)
            .with_backfill(true)
            .with_task_model(TaskModel {
                startup: 0.0,
                jitter_sigma: 0.0,
                p_node_late: 0.0,
                late_range: (0.0, 0.0),
            })
            .with_server_speed(1.0)
        })
        .collect();
    (fed, sims)
}

/// The launch-latency-vs-submission-rate experiment behind
/// `llsched federate --compare`: sweep an open-loop job stream over a
/// single scheduler and over a federated fleet of `instances`
/// partitions of the same per-partition size, record the p95 launch
/// latency at each rate, and report where each configuration's knee
/// sits. The acceptance claim of `benches/bench_federation.rs` — the
/// fleet sustains ≥ 3× the single scheduler's rate — is this sweep's
/// `rate_gain`.
pub fn run_federation(opts: FederationSweepOpts) -> Result<FederationSweep> {
    if opts.rates.is_empty() {
        return Err(Error::Config("federation sweep needs at least one rate".into()));
    }
    if opts.jobs == 0 || opts.task_s <= 0.0 {
        return Err(Error::Config(
            "federation sweep needs jobs > 0 and task_s > 0".into(),
        ));
    }
    let mut points = Vec::with_capacity(opts.rates.len());
    for &rate in &opts.rates {
        if !(rate > 0.0) {
            return Err(Error::Config(format!("swept rate must be > 0, got {rate}")));
        }
        let subs = uniform_stream(rate, opts.jobs, opts.task_s);
        let (fed1, sims1) = sweep_fleet(&opts, 1);
        let single = Gateway::new(fed1, sims1).run(subs.clone());
        let (fedn, simsn) = sweep_fleet(&opts, opts.instances);
        let federated = Gateway::new(fedn, simsn).run(subs);
        points.push(RatePoint {
            rate,
            single_p95: single.latency.p95,
            federated_p95: federated.latency.p95,
        });
    }
    let sustained = |p95: fn(&RatePoint) -> f64| -> f64 {
        points
            .iter()
            .filter(|pt| p95(pt).is_finite() && p95(pt) <= opts.knee_s)
            .map(|pt| pt.rate)
            .fold(0.0, f64::max)
    };
    let single_saturation = sustained(|pt| pt.single_p95);
    let federated_saturation = sustained(|pt| pt.federated_p95);
    let rate_gain = if single_saturation > 0.0 {
        federated_saturation / single_saturation
    } else {
        f64::NAN
    };
    Ok(FederationSweep {
        opts,
        points,
        single_saturation,
        federated_saturation,
        rate_gain,
    })
}

/// Human label for the aging knob in exports: `off` or `slope/cap`.
fn aging_label(aging: Option<AgingPolicy>) -> String {
    match aging {
        None => "off".into(),
        Some(a) => format!("{}/{}", a.slope, a.cap),
    }
}

/// Fixed-precision CSV cell; NaN (e.g. no-task latency) renders empty,
/// matching [`Csv::row_f64`]'s convention.
fn f6(x: f64) -> String {
    if x.is_nan() {
        String::new()
    } else {
        format!("{x:.6}")
    }
}

/// The v1 (PR 3) per-class export schema — emitted, byte-for-byte, for
/// classic runs (no pool, no preemptive backfill), so downstream
/// consumers of the historical format never see a silent change.
const CONTENTION_SCHEMA_V1: [&str; 19] = [
    "scenario",
    "nodes",
    "backfill",
    "holds",
    "aging",
    "walltime_error",
    "class",
    "jobs",
    "tasks",
    "completed",
    "median_latency_s",
    "p95_latency_s",
    "max_latency_s",
    "starvation_age_s",
    "core_seconds",
    "utilization",
    "span_s",
    "backfills",
    "max_active_holds",
];

/// The v2 column extension: pool and preemption metrics. Only emitted
/// when some result in the export actually used those features — the
/// schema is versioned by feature use, not silently widened.
const CONTENTION_SCHEMA_V2_EXTRA: [&str; 9] = [
    "pool_size",
    "pool_launches",
    "pool_peak_leased",
    "pool_grows",
    "pool_shrinks",
    "pool_median_latency_s",
    "pool_utilization",
    "preempt_overdue",
    "overdue_preemptions",
];

/// The v3 column extension: fleet sharding. Emitted only when some
/// result actually ran a multi-shard fleet. Class rows carry the fleet
/// aggregates in the v2 pool columns with an empty `shard` cell; each
/// scenario additionally emits one `shard:<name>` row per shard whose
/// v2 pool columns hold that shard's own launches/peak/grows/shrinks/
/// latency/utilization.
const CONTENTION_SCHEMA_V3_EXTRA: [&str; 3] = ["pool_shards", "pool_borrows", "shard"];

/// The v4 column extension: fault & churn counters. Emitted only when
/// some result actually ran with fault injection enabled; fault-free
/// rows in a mixed v4 document zero-fill the counters and leave the
/// means empty (the NaN convention of [`f6`]).
const CONTENTION_SCHEMA_V4_EXTRA: [&str; 8] = [
    "node_failures",
    "node_recoveries",
    "tasks_killed",
    "tasks_requeued",
    "tasks_lost",
    "work_lost_core_s",
    "mean_requeue_delay_s",
    "mean_recovery_s",
];

/// The v5 column extension: scheduler federation. Emitted only when
/// some result actually ran through the gateway; single-scheduler rows
/// in a mixed v5 document zero-fill the counters and leave the latency
/// empty (the NaN convention of [`f6`]).
const CONTENTION_SCHEMA_V5_EXTRA: [&str; 6] = [
    "fed_instances",
    "fed_batch",
    "fed_steal_threshold",
    "fed_batches",
    "fed_steals",
    "fed_p95_latency_s",
];

/// The v6 column extension: flight-recorder counters. Emitted only when
/// some result actually ran with the recorder on (`trace_cap > 0`);
/// recorder-off rows in a mixed v6 document zero-fill every cell.
const CONTENTION_SCHEMA_V6_EXTRA: [&str; 7] = [
    "obs_events",
    "obs_dropped",
    "obs_sched_events",
    "obs_backfill_events",
    "obs_pool_events",
    "obs_fault_events",
    "obs_fed_events",
];

/// The v7 column extension: per-class wait-blame rollups reconstructed
/// from the flight recorder. Emitted only when some result opted into
/// attribution (`blame: true`, which itself needs `trace_cap > 0`);
/// blame-off rows in a mixed v7 document write a zero job count and
/// leave the seconds cells empty, and shard rows always zero-fill.
const CONTENTION_SCHEMA_V7_EXTRA: [&str; 8] = [
    "obs_blame_jobs",
    "obs_blame_mean_wait_s",
    "obs_blame_hol_s",
    "obs_blame_fence_s",
    "obs_blame_cold_start_s",
    "obs_blame_requeue_backoff_s",
    "obs_blame_gateway_batch_s",
    "obs_blame_steal_s",
];

/// Per-class contention series as CSV (one row per scenario × class),
/// mirroring `fig1 --out`: the `contention --out DIR` data dump.
/// Classic runs export the v1 schema exactly; any pool or preemptive-
/// backfill use switches the whole document to v2 (v1 columns + the
/// pool/preemption extension); any multi-shard fleet switches it to v3
/// (v2 columns + the shard extension and per-shard rows); any fault-
/// injected run switches it to v4 (+ the churn counter extension); any
/// federated run switches it to v5 (+ the gateway extension); any
/// recorder-on run switches it to v6 (+ the flight-recorder counters);
/// any blame-on run switches it to v7 (+ the wait-attribution rollups).
pub fn contention_csv(results: &[ContentionResult]) -> Csv {
    let extended = results
        .iter()
        .any(|r| r.opts.fleet_enabled() || r.opts.preempt_overdue);
    let sharded = results.iter().any(|r| r.opts.fleet_sharded());
    let faulted = results.iter().any(|r| r.opts.fault_enabled());
    let federated = results.iter().any(|r| r.federation.is_some());
    let traced = results.iter().any(|r| r.obs.is_some());
    let blamed = results.iter().any(|r| r.blame.is_some());
    let mut header: Vec<&str> = CONTENTION_SCHEMA_V1.to_vec();
    if extended {
        header.extend(CONTENTION_SCHEMA_V2_EXTRA);
    }
    if sharded {
        header.extend(CONTENTION_SCHEMA_V3_EXTRA);
    }
    if faulted {
        header.extend(CONTENTION_SCHEMA_V4_EXTRA);
    }
    if federated {
        header.extend(CONTENTION_SCHEMA_V5_EXTRA);
    }
    if traced {
        header.extend(CONTENTION_SCHEMA_V6_EXTRA);
    }
    if blamed {
        header.extend(CONTENTION_SCHEMA_V7_EXTRA);
    }
    let mut c = Csv::with_header(&header);
    for r in results {
        let fleet = r.opts.fleet_config();
        // The v1 prefix shared by class rows and shard rows; `stats` is
        // the ten class-dependent cells (class .. utilization).
        let prefix = |stats: [String; 10]| -> Vec<String> {
            let mut row = vec![
                r.mix_name.clone(),
                r.nodes.to_string(),
                r.backfill.to_string(),
                r.opts.holds.to_string(),
                aging_label(r.opts.aging),
                r.opts.walltime_error.to_string(),
            ];
            row.extend(stats);
            row.push(format!("{:.3}", r.span));
            row.push(r.backfills.to_string());
            row.push(r.max_active_holds.to_string());
            row
        };
        // The v2 pool extension; `cells` is (launches, peak, grows,
        // shrinks, median latency, utilization) — fleet aggregates on
        // class rows, the shard's own numbers on shard rows.
        let pool_cols = |row: &mut Vec<String>, cells: (u64, usize, u64, u64, f64, f64)| {
            row.push(fleet.total_size().to_string());
            row.push(cells.0.to_string());
            row.push(cells.1.to_string());
            row.push(cells.2.to_string());
            row.push(cells.3.to_string());
            row.push(f6(cells.4));
            row.push(f6(cells.5));
            row.push(r.opts.preempt_overdue.to_string());
            row.push(r.overdue_preemptions.to_string());
        };
        let shard_cols = |row: &mut Vec<String>, shard: &str| {
            row.push(fleet.shards.len().to_string());
            row.push(r.pool.as_ref().map(|p| p.borrows).unwrap_or(0).to_string());
            row.push(shard.to_string());
        };
        // The v4 churn extension: run-level counters, identical on
        // every row of the scenario (zero-filled / empty on fault-free
        // rows in a mixed document).
        let fault_cols = |row: &mut Vec<String>| match &r.fault {
            Some(f) => {
                row.push(f.stats.node_failures.to_string());
                row.push(f.stats.node_recoveries.to_string());
                row.push(f.stats.tasks_killed.to_string());
                row.push(f.stats.tasks_requeued.to_string());
                row.push(f.stats.tasks_lost.to_string());
                row.push(format!("{:.3}", f.stats.work_lost_core_s));
                row.push(f6(f.stats.mean_requeue_delay()));
                row.push(f6(f.stats.mean_recovery()));
            }
            None => {
                for _ in 0..5 {
                    row.push("0".into());
                }
                row.push("0.000".into());
                row.push(String::new());
                row.push(String::new());
            }
        };
        // The v5 gateway extension: run-level knobs and counters,
        // identical on every row of the scenario (zero-filled / empty
        // on single-scheduler rows in a mixed document).
        let fed_cols = |row: &mut Vec<String>| match &r.federation {
            Some(fed) => {
                row.push(fed.config.instances.to_string());
                row.push(fed.config.batch.to_string());
                row.push(fed.config.steal_threshold.to_string());
                row.push(fed.batches.to_string());
                row.push(fed.steals.to_string());
                row.push(f6(fed.p95_latency));
            }
            None => {
                for _ in 0..5 {
                    row.push("0".into());
                }
                row.push(String::new());
            }
        };
        // The v6 flight-recorder extension: run-level counters (total
        // recorded, ring drops, per-subsystem rollup), identical on
        // every row of the scenario (zero-filled on recorder-off rows
        // in a mixed document).
        let obs_cols = |row: &mut Vec<String>| match &r.obs {
            Some(o) => {
                row.push(o.total_events().to_string());
                row.push(o.dropped.to_string());
                for sub in Subsystem::ALL {
                    row.push(o.registry.subsystem_total(sub).to_string());
                }
            }
            None => {
                for _ in 0..7 {
                    row.push("0".into());
                }
            }
        };
        // The v7 wait-blame extension: per-class rollups reconstructed
        // from the flight recorder (zero-filled on shard rows and on
        // blame-off rows in a mixed document).
        let blame_cols = |row: &mut Vec<String>, cb: Option<&ClassBlame>| match cb {
            Some(cb) => {
                row.push(cb.jobs.to_string());
                row.push(f6(cb.mean_wait_s));
                for i in 0..BLAME_CAUSES.len() {
                    row.push(f6(cb.blame.get(i)));
                }
            }
            None => {
                row.push("0".into());
                for _ in 0..=BLAME_CAUSES.len() {
                    row.push(String::new());
                }
            }
        };
        for rep in &r.reports {
            let mut row = prefix([
                rep.class.to_string(),
                rep.jobs.to_string(),
                rep.tasks.to_string(),
                rep.completed.to_string(),
                f6(rep.median_launch_latency),
                f6(rep.p95_launch_latency),
                f6(rep.max_launch_latency),
                f6(rep.starvation_age),
                format!("{:.3}", rep.core_seconds),
                f6(rep.utilization),
            ]);
            if extended {
                match &r.pool {
                    Some(p) => pool_cols(
                        &mut row,
                        (
                            p.launches,
                            p.peak_leased,
                            p.grows,
                            p.shrinks,
                            p.median_launch_latency,
                            p.utilization,
                        ),
                    ),
                    None => pool_cols(&mut row, (0, 0, 0, 0, f64::NAN, f64::NAN)),
                }
            }
            if sharded {
                shard_cols(&mut row, "");
            }
            if faulted {
                fault_cols(&mut row);
            }
            if federated {
                fed_cols(&mut row);
            }
            if traced {
                obs_cols(&mut row);
            }
            if blamed {
                let cb = r
                    .blame
                    .as_ref()
                    .and_then(|b| b.iter().find(|cb| cb.class == rep.class));
                blame_cols(&mut row, cb);
            }
            c.row(&row);
        }
        // Shard rows only for results that actually sharded, so the
        // CSV and JSON views of one result always agree (a one-shard
        // legacy result in a mixed v3 document gets the columns but no
        // shard rows, matching its JSON which omits `pool.shards`).
        if sharded && r.opts.fleet_sharded() {
            if let Some(p) = &r.pool {
                for sh in &p.shards {
                    let mut row = prefix([
                        format!("shard:{}", sh.name),
                        "0".into(),
                        sh.launches.to_string(),
                        sh.completed.to_string(),
                        f6(sh.median_launch_latency),
                        f6(sh.p95_launch_latency),
                        f6(f64::NAN),
                        f6(f64::NAN),
                        format!("{:.3}", sh.core_seconds),
                        f6(sh.utilization),
                    ]);
                    pool_cols(
                        &mut row,
                        (
                            sh.launches,
                            sh.peak_leased,
                            sh.grows,
                            sh.shrinks,
                            sh.median_launch_latency,
                            sh.utilization,
                        ),
                    );
                    shard_cols(&mut row, &sh.name);
                    if faulted {
                        fault_cols(&mut row);
                    }
                    if federated {
                        fed_cols(&mut row);
                    }
                    if traced {
                        obs_cols(&mut row);
                    }
                    if blamed {
                        blame_cols(&mut row, None);
                    }
                    c.row(&row);
                }
            }
        }
    }
    c
}

/// The same per-class series as a JSON document (one object per
/// scenario, with a `classes` array), for plotting pipelines.
pub fn contention_json(results: &[ContentionResult]) -> Json {
    let runs: Vec<Json> = results
        .iter()
        .map(|r| {
            let classes: Vec<Json> = r
                .reports
                .iter()
                .map(|rep| {
                    Json::obj()
                        .set("class", rep.class.label())
                        .set("jobs", rep.jobs)
                        .set("tasks", rep.tasks)
                        .set("completed", rep.completed)
                        .set("median_latency_s", rep.median_launch_latency)
                        .set("p95_latency_s", rep.p95_launch_latency)
                        .set("max_latency_s", rep.max_launch_latency)
                        .set("starvation_age_s", rep.starvation_age)
                        .set("core_seconds", rep.core_seconds)
                        .set("utilization", rep.utilization)
                })
                .collect();
            let mut run = Json::obj()
                .set("scenario", r.mix_name.clone())
                .set("nodes", r.nodes)
                .set("backfill", r.backfill)
                .set("holds", r.opts.holds)
                .set("aging", aging_label(r.opts.aging))
                .set("walltime_error", r.opts.walltime_error.to_string())
                .set("seed", r.opts.seed)
                .set("span_s", r.span)
                .set("utilization", r.utilization)
                .set("backfills", r.backfills)
                .set("max_active_holds", r.max_active_holds)
                .set("holds_respected", r.holds_respected)
                .set("preempt_overdue", r.opts.preempt_overdue)
                .set("overdue_preemptions", r.overdue_preemptions)
                .set("unfinished", r.unfinished);
            if let Some(p) = &r.pool {
                let mut pool = Json::obj()
                    .set("size", r.opts.fleet_config().total_size())
                    .set("launches", p.launches)
                    .set("peak_leased", p.peak_leased)
                    .set("grows", p.grows)
                    .set("shrinks", p.shrinks)
                    .set("median_latency_s", p.median_launch_latency)
                    .set("p95_latency_s", p.p95_launch_latency)
                    .set("utilization", p.utilization);
                if p.shards.len() > 1 {
                    let shards: Vec<Json> = p
                        .shards
                        .iter()
                        .map(|sh| {
                            Json::obj()
                                .set("name", sh.name.clone())
                                .set("launches", sh.launches)
                                .set("completed", sh.completed)
                                .set("peak_leased", sh.peak_leased)
                                .set("grows", sh.grows)
                                .set("shrinks", sh.shrinks)
                                .set("median_latency_s", sh.median_launch_latency)
                                .set("p95_latency_s", sh.p95_launch_latency)
                                .set("utilization", sh.utilization)
                        })
                        .collect();
                    pool = pool.set("borrows", p.borrows).set("shards", Json::Arr(shards));
                }
                run = run.set("pool", pool);
            }
            if let Some(f) = &r.fault {
                let fault = Json::obj()
                    .set("node_failures", f.stats.node_failures)
                    .set("node_recoveries", f.stats.node_recoveries)
                    .set("reclaim_waves", f.stats.reclaim_waves)
                    .set("drains", f.stats.drains)
                    .set("tasks_killed", f.stats.tasks_killed)
                    .set("tasks_requeued", f.stats.tasks_requeued)
                    .set("tasks_lost", f.stats.tasks_lost)
                    .set("work_lost_core_s", f.stats.work_lost_core_s)
                    .set("mean_requeue_delay_s", f.stats.mean_requeue_delay())
                    .set("mean_recovery_s", f.stats.mean_recovery())
                    .set("audit_records", f.audit.len());
                run = run.set("fault", fault);
            }
            if let Some(fed) = &r.federation {
                let federation = Json::obj()
                    .set("instances", fed.config.instances)
                    .set("batch", fed.config.batch)
                    .set("steal_threshold", fed.config.steal_threshold)
                    .set("flush_interval_s", fed.config.flush_interval)
                    .set("batches", fed.batches)
                    .set("steals", fed.steals)
                    .set("p95_latency_s", fed.p95_latency);
                run = run.set("federation", federation);
            }
            if let Some(o) = &r.obs {
                let subsystems = Subsystem::ALL.iter().fold(Json::obj(), |acc, &sub| {
                    acc.set(sub.name(), o.registry.subsystem_total(sub))
                });
                run = run.set(
                    "obs",
                    Json::obj()
                        .set("trace_cap", r.opts.trace_cap)
                        .set("events", o.total_events())
                        .set("retained", o.events.len())
                        .set("dropped", o.dropped)
                        .set("subsystems", subsystems),
                );
            }
            if let Some(blame) = &r.blame {
                let rows: Vec<Json> = blame
                    .iter()
                    .map(|cb| {
                        let mut o = Json::obj()
                            .set("class", cb.class.label())
                            .set("jobs", cb.jobs)
                            .set("mean_wait_s", cb.mean_wait_s);
                        for (i, name) in BLAME_CAUSES.iter().enumerate() {
                            o = o.set(format!("{name}_s"), cb.blame.get(i));
                        }
                        o
                    })
                    .collect();
                run = run.set("blame", Json::Arr(rows));
            }
            run.set("classes", Json::Arr(classes))
        })
        .collect();
    Json::obj().set("contention", Json::Arr(runs))
}

/// Run the full (or truncated) Table III matrix. Returns the per-cell
/// overhead points (for Table III / Fig 1) and all individual results
/// (for Fig 2 and diagnostics). `progress` is called after each run.
pub fn run_matrix(
    opts: &ExperimentOpts,
    mut progress: impl FnMut(&CellResult),
) -> Result<(Vec<OverheadPoint>, Vec<CellResult>)> {
    let mut points = Vec::new();
    let mut all = Vec::new();
    for &nodes in NODE_SCALES.iter().filter(|&&n| n <= opts.max_nodes) {
        for task in &TASK_CONFIGS {
            for mode in [Mode::MultiLevel, Mode::NodeBased] {
                if !opts.include_na && presets::is_paper_na(nodes, task, mode) {
                    continue;
                }
                let mut runtimes = Vec::with_capacity(opts.runs);
                for run_idx in 0..opts.runs {
                    let cell = PaperCell::new(nodes, *task, mode, run_idx);
                    let res = run_cell(&cell)?;
                    runtimes.push(res.runtime);
                    progress(&res);
                    all.push(res);
                }
                points.push(OverheadPoint {
                    nodes,
                    task_time: task.task_time,
                    mode,
                    runtimes,
                    t_job: task.job_time,
                });
            }
        }
    }
    Ok((points, all))
}

/// Pick, per `(nodes, task, mode)`, the run whose runtime is the median of
/// its cell — the runs Fig 2 plots.
pub fn median_runs(all: &[CellResult]) -> Vec<&CellResult> {
    let mut out: Vec<&CellResult> = Vec::new();
    for &nodes in &NODE_SCALES {
        for task in &TASK_CONFIGS {
            for mode in [Mode::MultiLevel, Mode::NodeBased] {
                let mut cell_runs: Vec<&CellResult> = all
                    .iter()
                    .filter(|r| {
                        r.cell.nodes == nodes
                            && r.cell.task.task_time == task.task_time
                            && r.cell.mode == mode
                    })
                    .collect();
                if cell_runs.is_empty() {
                    continue;
                }
                cell_runs.sort_by(|a, b| a.runtime.partial_cmp(&b.runtime).expect("no NaN"));
                out.push(cell_runs[cell_runs.len() / 2]);
            }
        }
    }
    out
}

/// Label in the paper's Fig 2 convention: `M-S1-A` (mode, scale index,
/// run letter).
pub fn fig2_label(cell: &PaperCell) -> String {
    let scale_idx = NODE_SCALES
        .iter()
        .position(|&n| n == cell.nodes)
        .map(|i| i + 1)
        .unwrap_or(0);
    let mode = match cell.mode {
        Mode::MultiLevel => "M",
        Mode::NodeBased => "N",
        Mode::PerTask => "P",
    };
    let run = (b'A' + cell.run_idx as u8) as char;
    format!("{mode}-S{scale_idx}-{run}-t{}", cell.task.task_time as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cell(mode: Mode, run_idx: usize) -> PaperCell {
        PaperCell::new(32, TASK_CONFIGS[3], mode, run_idx) // 32 nodes, 60 s
    }

    #[test]
    fn single_cell_runs_and_lands_near_paper() {
        let res = run_cell(&small_cell(Mode::NodeBased, 0)).unwrap();
        // Paper: N* at 32 nodes ≈ 241–243 s.
        assert!(
            (240.5..250.0).contains(&res.runtime),
            "runtime {}",
            res.runtime
        );
        assert!(res.utilization.peak() > 0.99, "fills the machine");
    }

    #[test]
    fn multi_level_costs_more_at_32_nodes() {
        // Median of three runs, exactly like the paper's Table III.
        let med = |mode: Mode| {
            let mut rts: Vec<f64> = (0..3)
                .map(|i| run_cell(&small_cell(mode, i)).unwrap().runtime)
                .collect();
            rts.sort_by(|a, b| a.partial_cmp(b).unwrap());
            rts[1]
        };
        let m = med(Mode::MultiLevel);
        let n = med(Mode::NodeBased);
        // Paper: M* ≈ 277–305 vs N* ≈ 241–243.
        assert!(m > n + 10.0, "M {m} vs N {n}");
        assert!((260.0..340.0).contains(&m), "M median {m}");
        assert!((240.5..255.0).contains(&n), "N median {n}");
    }

    #[test]
    fn quick_matrix_has_expected_cells() {
        let opts = ExperimentOpts {
            max_nodes: 32,
            runs: 1,
            ..Default::default()
        };
        let (points, all) = run_matrix(&opts, |_| {}).unwrap();
        // 1 scale × 4 tasks × 2 modes.
        assert_eq!(points.len(), 8);
        assert_eq!(all.len(), 8);
        for p in &points {
            assert_eq!(p.runtimes.len(), 1);
            assert!(p.median_runtime() > 240.0);
        }
    }

    #[test]
    fn median_runs_picks_one_per_cell() {
        let opts = ExperimentOpts {
            max_nodes: 32,
            runs: 3,
            ..Default::default()
        };
        let (_, all) = run_matrix(&opts, |_| {}).unwrap();
        let med = median_runs(&all);
        assert_eq!(med.len(), 8);
        // The median run's runtime is the middle of its cell's three.
        for m in med {
            let mut cell_times: Vec<f64> = all
                .iter()
                .filter(|r| {
                    r.cell.nodes == m.cell.nodes
                        && r.cell.mode == m.cell.mode
                        && r.cell.task.task_time == m.cell.task.task_time
                })
                .map(|r| r.runtime)
                .collect();
            cell_times.sort_by(|a, b| a.partial_cmp(b).unwrap());
            assert_eq!(m.runtime, cell_times[1]);
        }
    }

    #[test]
    fn placement_sweep_runs_all_policies() {
        let sweep = run_placement_sweep(8, &TASK_CONFIGS[3], Mode::NodeBased).unwrap();
        assert_eq!(sweep.len(), 5);
        for (strategy, res) in &sweep {
            assert_eq!(res.placement, *strategy);
            // Every policy still completes the job in sane time (wide
            // bound: production noise can land a large burst mid-run).
            assert!(res.runtime > 240.0 && res.runtime < 700.0, "{strategy}: {}", res.runtime);
        }
    }

    #[test]
    fn contention_tiny_runs_end_to_end() {
        let mix = ContentionMix::preset("tiny", 8).unwrap();
        let res = run_contention(&mix, true, 11).unwrap();
        assert_eq!(res.unfinished, 0, "all tasks drain");
        assert!(res.holds_respected, "no backfill delays a reservation");
        assert_eq!(res.reports.len(), 2);
        let inter = &res.reports[0];
        let batch = &res.reports[1];
        assert_eq!(inter.class, JobClass::Interactive);
        assert_eq!(batch.class, JobClass::Batch);
        assert!(inter.tasks > 0 && batch.tasks > 0);
        assert_eq!(inter.completed, inter.tasks);
        assert_eq!(batch.completed, batch.tasks);
        assert!(res.span > 0.0);
        assert!(res.utilization > 0.0 && res.utilization <= 1.0);
        // Interactive launches must stay fast in the tiny mix.
        assert!(
            inter.median_launch_latency < 30.0,
            "interactive median {}",
            inter.median_launch_latency
        );
    }

    #[test]
    fn contention_backfill_flag_round_trips() {
        let mix = ContentionMix::preset("tiny", 4).unwrap();
        let off = run_contention(&mix, false, 3).unwrap();
        let on = run_contention(&mix, true, 3).unwrap();
        assert!(!off.backfill && on.backfill);
        assert_eq!(off.backfills, 0, "no backfill ops when disabled");
        assert_eq!(off.unfinished, 0);
        assert_eq!(on.unfinished, 0);
    }

    #[test]
    fn contention_with_fairness_knobs_runs_end_to_end() {
        let mix = ContentionMix::preset("tiny", 8).unwrap();
        let opts = ContentionOpts {
            holds: 4,
            aging: Some(AgingPolicy::new(0.5, 100)),
            walltime_error: WalltimeError::LogNormal { sigma: 0.3 },
            ..ContentionOpts::classic(true, 11)
        };
        let res = run_contention_with(&mix, opts).unwrap();
        assert_eq!(res.unfinished, 0, "noisy estimates must not wedge the run");
        assert!(res.max_active_holds <= 4);
        assert!(res.holds_respected, "trivially true under a noise model");
        assert_eq!(res.reports.len(), 2);
        assert!(res.reports.iter().all(|r| r.completed == r.tasks));
        assert_eq!(res.opts.holds, 4);
    }

    #[test]
    fn classic_wrapper_matches_explicit_classic_opts() {
        let mix = ContentionMix::preset("tiny", 4).unwrap();
        let a = run_contention(&mix, true, 5).unwrap();
        let b = run_contention_with(&mix, ContentionOpts::classic(true, 5)).unwrap();
        assert_eq!(a.backfills, b.backfills);
        assert_eq!(a.unfinished, b.unfinished);
        assert_eq!(a.span, b.span);
        for (x, y) in a.reports.iter().zip(&b.reports) {
            assert_eq!(x.median_launch_latency, y.median_launch_latency);
            assert_eq!(x.p95_launch_latency, y.p95_launch_latency);
            assert_eq!(x.core_seconds, y.core_seconds);
        }
        // The classic wrapper is the single-hold discipline.
        assert!(a.max_active_holds <= 1);
    }

    #[test]
    fn contention_export_schema_and_determinism() {
        // A golden-file-style test over the tiny preset at a fixed
        // seed: the schema is pinned exactly, and two identical runs
        // must serialize byte-for-byte identically (same seed → same
        // schedule → same export).
        let mix = ContentionMix::preset("tiny", 8).unwrap();
        let opts = ContentionOpts {
            holds: 2,
            aging: Some(AgingPolicy::new(0.5, 100)),
            walltime_error: WalltimeError::LogNormal { sigma: 0.3 },
            ..ContentionOpts::classic(true, 42)
        };
        let a = run_contention_with(&mix, opts.clone()).unwrap();
        let b = run_contention_with(&mix, opts).unwrap();
        let csv_a = contention_csv(std::slice::from_ref(&a));
        let csv_b = contention_csv(std::slice::from_ref(&b));
        assert_eq!(csv_a.as_str(), csv_b.as_str(), "export must be deterministic");
        let lines: Vec<&str> = csv_a.as_str().lines().collect();
        assert_eq!(
            lines[0],
            "scenario,nodes,backfill,holds,aging,walltime_error,class,jobs,tasks,\
             completed,median_latency_s,p95_latency_s,max_latency_s,starvation_age_s,\
             core_seconds,utilization,span_s,backfills,max_active_holds",
            "golden header"
        );
        assert_eq!(lines.len(), 3, "header + one row per class");
        assert!(lines[1].starts_with("tiny,8,true,2,0.5/100,lognormal(0.3),interactive,"));
        assert!(lines[2].starts_with("tiny,8,true,2,0.5/100,lognormal(0.3),batch,"));
        let json_a = contention_json(std::slice::from_ref(&a)).to_pretty();
        let json_b = contention_json(std::slice::from_ref(&b)).to_pretty();
        assert_eq!(json_a, json_b);
        for key in [
            "\"scenario\": \"tiny\"",
            "\"holds\": 2",
            "\"aging\": \"0.5/100\"",
            "\"walltime_error\": \"lognormal(0.3)\"",
            "\"classes\": [",
            "\"starvation_age_s\":",
            "\"max_latency_s\":",
        ] {
            assert!(json_a.contains(key), "json missing {key}: {json_a}");
        }
    }

    #[test]
    fn pooled_contention_runs_end_to_end() {
        let mix = ContentionMix::preset("burst", 16).unwrap();
        let opts = ContentionOpts {
            pool: PoolConfig { size: 4, min: 2, max: 8, ..PoolConfig::sized(4) },
            ..ContentionOpts::classic(true, 9)
        };
        let res = run_contention_with(&mix, opts).unwrap();
        assert_eq!(res.unfinished, 0, "pooled burst drains");
        let pool = res.pool.as_ref().expect("pool report present");
        let inter = &res.reports[0];
        assert_eq!(
            pool.launches, inter.tasks as u64,
            "every volley task went through the pool"
        );
        assert!(pool.peak_leased >= 4 && pool.peak_leased <= 8);
        assert!(pool.median_launch_latency.is_finite());
        // The classic path reports no pool.
        let classic = run_contention_with(&mix, ContentionOpts::classic(true, 9)).unwrap();
        assert!(classic.pool.is_none());
        assert_eq!(classic.unfinished, 0);
    }

    #[test]
    fn contention_export_v2_extends_v1_schema() {
        // A pooled run flips the export to the v2 schema: the v1
        // columns verbatim, then the pool/preemption extension. The v1
        // golden test above pins the classic path; this pins v2.
        let mix = ContentionMix::preset("burst", 16).unwrap();
        let opts = ContentionOpts {
            pool: PoolConfig { size: 4, min: 2, max: 8, ..PoolConfig::sized(4) },
            preempt_overdue: true,
            ..ContentionOpts::classic(true, 5)
        };
        let res = run_contention_with(&mix, opts).unwrap();
        let csv = contention_csv(std::slice::from_ref(&res));
        let lines: Vec<&str> = csv.as_str().lines().collect();
        assert_eq!(
            lines[0],
            "scenario,nodes,backfill,holds,aging,walltime_error,class,jobs,tasks,\
             completed,median_latency_s,p95_latency_s,max_latency_s,starvation_age_s,\
             core_seconds,utilization,span_s,backfills,max_active_holds,\
             pool_size,pool_launches,pool_peak_leased,pool_grows,pool_shrinks,\
             pool_median_latency_s,pool_utilization,preempt_overdue,overdue_preemptions",
            "v2 golden header"
        );
        let header_cols = lines[0].split(',').count();
        for line in &lines[1..] {
            assert_eq!(line.split(',').count(), header_cols, "row width matches header");
        }
        let json = contention_json(std::slice::from_ref(&res)).to_pretty();
        for key in ["\"pool\":", "\"launches\":", "\"preempt_overdue\": true"] {
            assert!(json.contains(key), "json missing {key}");
        }
        // A mixed export (one classic + one pooled result) is also v2,
        // with zero-filled pool columns on the classic rows.
        let classic = run_contention_with(
            &ContentionMix::preset("tiny", 8).unwrap(),
            ContentionOpts::classic(true, 5),
        )
        .unwrap();
        let both = contention_csv(&[classic, res]);
        let lines: Vec<&str> = both.as_str().lines().collect();
        assert!(lines[0].ends_with("overdue_preemptions"));
        assert!(lines[1].contains(",false,0"), "classic rows zero-fill the extension");
    }

    #[test]
    fn sharded_fleet_contention_exports_v3_schema() {
        // A two-shard fleet on the mixed-volley preset: the export
        // switches to v3 (v2 columns + the shard extension) and emits
        // one shard row per shard after the class rows.
        let mix = ContentionMix::preset("burst_mixed", 16).unwrap();
        let opts = ContentionOpts {
            pools: vec![
                ShardConfig::named("general", 4, 2, 10).unwrap(),
                ShardConfig::named("large", 2, 1, 6).unwrap(),
            ],
            ..ContentionOpts::classic(true, 7)
        };
        let res = run_contention_with(&mix, opts).unwrap();
        assert_eq!(res.unfinished, 0, "mixed burst drains");
        let pool = res.pool.as_ref().expect("pool report");
        assert_eq!(pool.shards.len(), 2);
        let inter = &res.reports[0];
        assert_eq!(
            pool.launches, inter.tasks as u64,
            "both volley families went through the fleet"
        );
        assert_eq!(
            pool.shards[0].launches + pool.shards[1].launches,
            pool.launches,
            "shard launches partition the fleet's"
        );
        assert!(pool.shards.iter().all(|s| s.launches > 0), "both shards served work");
        let csv = contention_csv(std::slice::from_ref(&res));
        let lines: Vec<&str> = csv.as_str().lines().collect();
        assert!(
            lines[0].ends_with("overdue_preemptions,pool_shards,pool_borrows,shard"),
            "v3 header extends v2: {}",
            lines[0]
        );
        let header_cols = lines[0].split(',').count();
        for line in &lines[1..] {
            assert_eq!(line.split(',').count(), header_cols, "row width matches header");
        }
        // Two class rows + two shard rows.
        assert_eq!(lines.len(), 5);
        assert!(lines[3].contains("shard:general"), "{}", lines[3]);
        assert!(lines[4].contains("shard:large"), "{}", lines[4]);
        assert!(lines[3].ends_with(",general"));
        assert!(lines[4].ends_with(",large"));
        let json = contention_json(std::slice::from_ref(&res)).to_pretty();
        for key in ["\"shards\": [", "\"name\": \"general\"", "\"borrows\":"] {
            assert!(json.contains(key), "json missing {key}");
        }
        // A single-shard run keeps the v2 schema untouched (no shard
        // columns), so PR 4 consumers never see a silent change.
        let single = run_contention_with(
            &ContentionMix::preset("burst", 16).unwrap(),
            ContentionOpts {
                pool: PoolConfig { size: 4, min: 2, max: 8, ..PoolConfig::sized(4) },
                ..ContentionOpts::classic(true, 7)
            },
        )
        .unwrap();
        let csv = contention_csv(std::slice::from_ref(&single));
        assert!(csv.as_str().lines().next().unwrap().ends_with("overdue_preemptions"));
    }

    #[test]
    fn faulted_contention_exports_v4_schema() {
        // A churn run flips the export to v4: the prior columns
        // verbatim, then the fault counter extension. A deterministic
        // maintenance drain keeps the scenario graceful (no kills), so
        // the test pins the schema without depending on kill timing.
        let mix = ContentionMix::preset("tiny", 8).unwrap();
        let fault = FaultConfig {
            drain_times: vec![50.0],
            drain_count: 1,
            drain_hold: 100.0,
            horizon: 100_000.0,
            ..FaultConfig::disabled()
        };
        let opts = ContentionOpts {
            fault: fault.clone(),
            ..ContentionOpts::classic(true, 13)
        };
        let res = run_contention_with(&mix, opts).unwrap();
        assert_eq!(res.unfinished, 0, "graceful drain strands nothing");
        let f = res.fault.as_ref().expect("fault outcome present");
        assert_eq!(f.stats.drains, 1);
        assert_eq!(f.stats.node_recoveries, 1, "drained node comes back");
        assert_eq!(f.stats.tasks_killed, 0, "drains are graceful");
        assert!(!f.audit.is_empty(), "audit log records the drain");
        let csv = contention_csv(std::slice::from_ref(&res));
        let lines: Vec<&str> = csv.as_str().lines().collect();
        assert_eq!(
            lines[0],
            "scenario,nodes,backfill,holds,aging,walltime_error,class,jobs,tasks,\
             completed,median_latency_s,p95_latency_s,max_latency_s,starvation_age_s,\
             core_seconds,utilization,span_s,backfills,max_active_holds,\
             node_failures,node_recoveries,tasks_killed,tasks_requeued,tasks_lost,\
             work_lost_core_s,mean_requeue_delay_s,mean_recovery_s",
            "v4 golden header (fault-only run: v1 + v4 extension)"
        );
        let header_cols = lines[0].split(',').count();
        for line in &lines[1..] {
            assert_eq!(line.split(',').count(), header_cols, "row width matches header");
        }
        // Two identical runs export byte-for-byte identically — the
        // audit-replay contract seen through the CSV lens.
        let again = run_contention_with(
            &mix,
            ContentionOpts {
                fault,
                ..ContentionOpts::classic(true, 13)
            },
        )
        .unwrap();
        let csv_b = contention_csv(std::slice::from_ref(&again));
        assert_eq!(csv.as_str(), csv_b.as_str(), "faulted export must be deterministic");
        assert_eq!(
            f.audit.to_text(),
            again.fault.as_ref().unwrap().audit.to_text(),
            "audit logs replay bit-for-bit"
        );
        let json = contention_json(std::slice::from_ref(&res)).to_pretty();
        for key in ["\"fault\":", "\"drains\": 1", "\"audit_records\":"] {
            assert!(json.contains(key), "json missing {key}");
        }
        // A mixed export (fault-free + faulted) zero-fills the fault
        // columns on the fault-free rows.
        let classic = run_contention_with(
            &ContentionMix::preset("tiny", 8).unwrap(),
            ContentionOpts::classic(true, 13),
        )
        .unwrap();
        assert!(classic.fault.is_none());
        let both = contention_csv(&[classic, res]);
        let lines: Vec<&str> = both.as_str().lines().collect();
        assert!(lines[0].ends_with("mean_recovery_s"));
        assert!(
            lines[1].ends_with(",0,0,0,0,0,0.000,,"),
            "fault-free rows zero-fill the v4 extension: {}",
            lines[1]
        );
    }

    #[test]
    fn federated_contention_runs_end_to_end() {
        // Two partitions of 4 nodes behind the gateway over the tiny
        // mix: every job drains on some instance and the per-class
        // rollup balances, with the fleet summary attached.
        let mix = ContentionMix::preset("tiny", 8).unwrap();
        let fed = FederationConfig {
            instances: 2,
            batch: 4,
            flush_interval: 1.0,
            steal_threshold: 4,
        };
        let res = run_contention_federated(&mix, ContentionOpts::classic(true, 11), fed).unwrap();
        assert_eq!(res.unfinished, 0, "federated tiny mix drains");
        assert_eq!(res.reports.len(), 2);
        let inter = &res.reports[0];
        let batch = &res.reports[1];
        assert_eq!(inter.class, JobClass::Interactive);
        assert_eq!(batch.class, JobClass::Batch);
        assert!(inter.tasks > 0 && batch.tasks > 0);
        assert_eq!(inter.completed, inter.tasks);
        assert_eq!(batch.completed, batch.tasks);
        assert!(res.span > 0.0);
        assert!(res.utilization > 0.0 && res.utilization <= 1.0);
        let summary = res.federation.as_ref().expect("federation summary present");
        assert_eq!(summary.config.instances, 2);
        assert!(summary.batches >= 2, "both instances saw flushes");
        assert!(summary.p95_latency.is_finite());
        // The partition count must divide the machine.
        let bad = run_contention_federated(
            &mix,
            ContentionOpts::classic(true, 11),
            FederationConfig {
                instances: 3,
                ..FederationConfig::default()
            },
        );
        assert!(bad.is_err(), "3 instances cannot split 8 nodes");
    }

    #[test]
    fn federated_contention_exports_v5_schema() {
        // A federated run flips the export to v5: the v1 columns
        // verbatim, then the gateway extension. Two identical runs
        // serialize byte-for-byte (the gateway is deterministic).
        let mix = ContentionMix::preset("tiny", 8).unwrap();
        let fed = FederationConfig {
            instances: 2,
            batch: 4,
            flush_interval: 1.0,
            steal_threshold: 4,
        };
        let a = run_contention_federated(&mix, ContentionOpts::classic(true, 42), fed).unwrap();
        let b = run_contention_federated(&mix, ContentionOpts::classic(true, 42), fed).unwrap();
        let csv_a = contention_csv(std::slice::from_ref(&a));
        let csv_b = contention_csv(std::slice::from_ref(&b));
        assert_eq!(csv_a.as_str(), csv_b.as_str(), "federated export must be deterministic");
        let lines: Vec<&str> = csv_a.as_str().lines().collect();
        assert_eq!(
            lines[0],
            "scenario,nodes,backfill,holds,aging,walltime_error,class,jobs,tasks,\
             completed,median_latency_s,p95_latency_s,max_latency_s,starvation_age_s,\
             core_seconds,utilization,span_s,backfills,max_active_holds,\
             fed_instances,fed_batch,fed_steal_threshold,fed_batches,fed_steals,\
             fed_p95_latency_s",
            "v5 golden header (federated-only run: v1 + v5 extension)"
        );
        let header_cols = lines[0].split(',').count();
        for line in &lines[1..] {
            assert_eq!(line.split(',').count(), header_cols, "row width matches header");
        }
        let json = contention_json(std::slice::from_ref(&a)).to_pretty();
        for key in [
            "\"federation\":",
            "\"instances\": 2",
            "\"steal_threshold\": 4",
            "\"p95_latency_s\":",
        ] {
            assert!(json.contains(key), "json missing {key}");
        }
        // A mixed export (single-scheduler + federated) zero-fills the
        // gateway columns on the single-scheduler rows.
        let classic = run_contention_with(&mix, ContentionOpts::classic(true, 42)).unwrap();
        assert!(classic.federation.is_none());
        let both = contention_csv(&[classic, a]);
        let lines: Vec<&str> = both.as_str().lines().collect();
        assert!(lines[0].ends_with("fed_p95_latency_s"));
        assert!(
            lines[1].ends_with(",0,0,0,0,0,"),
            "single-scheduler rows zero-fill the v5 extension: {}",
            lines[1]
        );
    }

    #[test]
    fn traced_contention_exports_v6_schema() {
        // A recorder-on run flips the export to v6: the v1 columns
        // verbatim, then the flight-recorder counters. Two identical
        // runs serialize byte-for-byte (the recorder is deterministic
        // and observes without steering).
        let mix = ContentionMix::preset("tiny", 8).unwrap();
        let opts = || ContentionOpts {
            trace_cap: 4096,
            ..ContentionOpts::classic(true, 42)
        };
        let a = run_contention_with(&mix, opts()).unwrap();
        let b = run_contention_with(&mix, opts()).unwrap();
        let obs = a.obs.as_ref().expect("recorder-on run carries a snapshot");
        assert!(obs.total_events() > 0, "a tiny mix still records decisions");
        assert_eq!(
            obs.total_events(),
            obs.events.len() as u64 + obs.dropped,
            "registry total = retained + dropped"
        );
        let csv_a = contention_csv(std::slice::from_ref(&a));
        let csv_b = contention_csv(std::slice::from_ref(&b));
        assert_eq!(csv_a.as_str(), csv_b.as_str(), "traced export must be deterministic");
        let lines: Vec<&str> = csv_a.as_str().lines().collect();
        assert_eq!(
            lines[0],
            "scenario,nodes,backfill,holds,aging,walltime_error,class,jobs,tasks,\
             completed,median_latency_s,p95_latency_s,max_latency_s,starvation_age_s,\
             core_seconds,utilization,span_s,backfills,max_active_holds,\
             obs_events,obs_dropped,obs_sched_events,obs_backfill_events,\
             obs_pool_events,obs_fault_events,obs_fed_events",
            "v6 golden header (traced-only run: v1 + v6 extension)"
        );
        let header_cols = lines[0].split(',').count();
        for line in &lines[1..] {
            assert_eq!(line.split(',').count(), header_cols, "row width matches header");
        }
        let json = contention_json(std::slice::from_ref(&a)).to_pretty();
        for key in [
            "\"obs\":",
            "\"trace_cap\": 4096",
            "\"subsystems\":",
            "\"scheduler\":",
        ] {
            assert!(json.contains(key), "json missing {key}");
        }
        // The recorder observes; it never steers. The recorder-off run
        // with the same seed must produce the identical schedule.
        let classic = run_contention_with(&mix, ContentionOpts::classic(true, 42)).unwrap();
        assert!(classic.obs.is_none());
        assert_eq!(a.span.to_bits(), classic.span.to_bits(), "recorder must not steer");
        // A mixed export (recorder-off + recorder-on) zero-fills the
        // recorder columns on the recorder-off rows.
        let both = contention_csv(&[classic, a]);
        let lines: Vec<&str> = both.as_str().lines().collect();
        assert!(lines[0].ends_with("obs_fed_events"));
        assert!(
            lines[1].ends_with(",0,0,0,0,0,0,0"),
            "recorder-off rows zero-fill the v6 extension: {}",
            lines[1]
        );
    }

    #[test]
    fn federation_sweep_structure_and_determinism() {
        // A miniature rate sweep: one point per requested rate, both
        // curves populated, saturation picked from the swept set, and
        // the whole sweep bit-for-bit reproducible. (Performance claims
        // — the ≥ 3× sustained-rate gain — live in
        // `benches/bench_federation.rs`, not here.)
        let opts = FederationSweepOpts {
            instances: 2,
            nodes: 4,
            rates: vec![1.0, 2.0],
            jobs: 20,
            task_s: 0.5,
            knee_s: 30.0,
            batch: 2,
            steal_threshold: 8,
            seed: 7,
        };
        let a = run_federation(opts.clone()).unwrap();
        assert_eq!(a.points.len(), 2);
        for (pt, &rate) in a.points.iter().zip(&opts.rates) {
            assert_eq!(pt.rate, rate);
            assert!(pt.single_p95.is_finite(), "single curve populated at {rate}");
            assert!(pt.federated_p95.is_finite(), "federated curve populated at {rate}");
        }
        for sat in [a.single_saturation, a.federated_saturation] {
            assert!(
                sat == 0.0 || opts.rates.contains(&sat),
                "saturation {sat} must come from the swept set"
            );
        }
        let b = run_federation(opts).unwrap();
        for (x, y) in a.points.iter().zip(&b.points) {
            assert_eq!(x.single_p95.to_bits(), y.single_p95.to_bits());
            assert_eq!(x.federated_p95.to_bits(), y.federated_p95.to_bits());
        }
        assert_eq!(a.single_saturation, b.single_saturation);
        assert_eq!(a.federated_saturation, b.federated_saturation);
        // Degenerate sweeps are rejected up front.
        assert!(run_federation(FederationSweepOpts {
            rates: vec![],
            ..FederationSweepOpts::default()
        })
        .is_err());
        assert!(run_federation(FederationSweepOpts {
            rates: vec![-1.0],
            ..FederationSweepOpts::default()
        })
        .is_err());
    }

    #[test]
    fn fig2_labels() {
        let c = PaperCell::new(512, TASK_CONFIGS[0], Mode::MultiLevel, 2);
        assert_eq!(fig2_label(&c), "M-S5-C-t1");
        let c2 = PaperCell::new(32, TASK_CONFIGS[3], Mode::NodeBased, 0);
        assert_eq!(fig2_label(&c2), "N-S1-A-t60");
    }
}
