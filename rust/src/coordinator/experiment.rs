//! Experiment orchestration: run Table III cells end-to-end over the DES
//! scheduler and collect the paper's measurements.

use crate::aggregation;
use crate::cluster::Cluster;
use crate::config::presets::{self, NODE_SCALES, RUNS_PER_CELL, TASK_CONFIGS};
use crate::config::Mode;
use crate::error::{Error, Result};
use crate::metrics::contention::{per_class, ClassReport};
use crate::metrics::overhead::OverheadPoint;
use crate::metrics::timeline::UtilizationSeries;
use crate::placement::Strategy;
use crate::scheduler::core::{SchedulerSim, SimOutcome};
use crate::scheduler::costmodel::CostModel;
use crate::scheduler::noise::NoiseModel;
use crate::sim::EventQueue;
use crate::workload::contention::{ContentionMix, JobClass};
use crate::workload::paper::PaperCell;

/// Result of one benchmark run (one cell, one repetition).
#[derive(Debug)]
pub struct CellResult {
    pub cell: PaperCell,
    /// The paper's "job run time": first task start → last task end.
    pub runtime: f64,
    /// Runtime minus T_job.
    pub overhead: f64,
    /// Machine-fill span (first → last dispatch).
    pub dispatch_span: f64,
    /// First end → last cleanup (release span).
    pub release_span: f64,
    /// Utilization series for Fig 2.
    pub utilization: UtilizationSeries,
    /// Scheduler responsiveness indicator.
    pub longest_busy_stretch: f64,
    /// Whether the responsiveness guard would bar this from production.
    pub unusable_in_production: bool,
    /// Placement strategy the run dispatched through.
    pub placement: Strategy,
    /// DES events processed (engine throughput accounting).
    pub events: u64,
}

/// Options for matrix runs.
#[derive(Debug, Clone, Copy)]
pub struct ExperimentOpts {
    /// Include the paper's N/A cells (multi-level 512 nodes, short tasks).
    pub include_na: bool,
    /// Only run scales up to this node count (quick mode).
    pub max_nodes: u32,
    /// Repetitions per cell (paper: 3).
    pub runs: usize,
    /// Fig 2 sampling step, seconds.
    pub dt: f64,
}

impl Default for ExperimentOpts {
    fn default() -> Self {
        ExperimentOpts {
            include_na: false,
            max_nodes: 512,
            runs: RUNS_PER_CELL,
            dt: 1.0,
        }
    }
}

/// Run one cell (one repetition) end-to-end. The placement strategy is
/// the config's explicit `placement` if set, else the aggregation
/// mode's default (node-based fast path for N*, first-fit otherwise).
pub fn run_cell(cell: &PaperCell) -> Result<CellResult> {
    let cfg = &cell.config;
    cfg.validate()?;
    let cluster = Cluster::homogeneous(cfg.nodes, cfg.cores_per_node, 192 * 1024);
    let noise = if cfg.dedicated {
        NoiseModel::dedicated()
    } else {
        NoiseModel::production()
    };
    let placement = cfg.placement_strategy();
    let sim = SchedulerSim::new(cluster, CostModel::slurm_like_tx_green(), noise, cfg.seed)
        .with_placement(placement)
        .with_backfill(cfg.backfill);
    let agg = aggregation::for_mode(cfg.mode);
    let job = agg.plan(&cell.label(), &cell.workload(), &cell.shape())?;
    let (outcome, job_id) = sim.run_single(job);
    summarize(cell.clone(), &outcome, job_id, placement, 1.0)
}

fn summarize(
    cell: PaperCell,
    outcome: &SimOutcome,
    job_id: u64,
    placement: Strategy,
    dt: f64,
) -> Result<CellResult> {
    let stats = outcome
        .job_stats(job_id, cell.config.job_time)
        .ok_or_else(|| Error::Infeasible(format!("{}: job did not finish", cell.label())))?;
    let utilization = UtilizationSeries::from_steps(
        &outcome.timeline,
        cell.config.processors(),
        dt,
    );
    Ok(CellResult {
        runtime: stats.runtime,
        overhead: stats.overhead,
        dispatch_span: stats.dispatch_span,
        release_span: stats.release_span,
        utilization,
        longest_busy_stretch: outcome.longest_busy_stretch,
        unusable_in_production: outcome.unusable_in_production(),
        placement,
        events: outcome.events_processed,
        cell,
    })
}

/// Run one cell under every placement strategy (same seed, same
/// workload) — the policy-comparison scenario the placement subsystem
/// opens up. Returns `(strategy, result)` pairs.
pub fn run_placement_sweep(
    nodes: u32,
    task: &presets::TaskConfig,
    mode: Mode,
) -> Result<Vec<(Strategy, CellResult)>> {
    presets::placement_sweep(nodes, task, mode)
        .into_iter()
        .map(|cfg| {
            let strategy = cfg.placement_strategy();
            let mut cell = PaperCell::new(cfg.nodes, *task, cfg.mode, 0);
            cell.config = cfg;
            Ok((strategy, run_cell(&cell)?))
        })
        .collect()
}

/// Result of one interactive-vs-batch contention run.
#[derive(Debug)]
pub struct ContentionResult {
    pub mix_name: String,
    pub nodes: u32,
    pub backfill: bool,
    /// Per-class launch latency / utilization ([`JobClass`] order:
    /// interactive, batch).
    pub reports: Vec<ClassReport>,
    /// First submit → last cleanup, seconds.
    pub span: f64,
    /// Whole-cluster utilization over the span, in `[0, 1]`.
    pub utilization: f64,
    /// Backfill dispatches performed.
    pub backfills: usize,
    /// Every backfill placed on a held node vacated it by the hold's
    /// planned start (the no-delay invariant, checked from records).
    pub holds_respected: bool,
    /// Tasks that never finished (should be 0 — arrivals are finite).
    pub unfinished: usize,
}

/// Run one contention mix end-to-end: submit the generated interactive
/// and batch streams, drain the scheduler, and split launch latency and
/// utilization by class. `backfill` flips the reservation + backfill
/// machinery; placement uses the node-based fast path (the mix contains
/// whole-node jobs by construction).
pub fn run_contention(
    mix: &ContentionMix,
    backfill: bool,
    seed: u64,
) -> Result<ContentionResult> {
    let cluster = Cluster::tx_green(mix.nodes);
    let total_cores = cluster.total_cores();
    let mut sim = SchedulerSim::new(
        cluster,
        CostModel::slurm_like_tx_green(),
        NoiseModel::dedicated(),
        seed,
    )
    .with_placement(Strategy::NodeBased)
    .with_backfill(backfill);
    let mut q = EventQueue::new();
    let subs = mix.generate(seed);
    if subs.is_empty() {
        return Err(Error::Infeasible(format!(
            "contention mix {:?} generated no submissions",
            mix.name
        )));
    }
    let mut classes: Vec<JobClass> = Vec::with_capacity(subs.len());
    for sub in subs {
        classes.push(sub.class);
        let id = sim.submit_at(&mut q, sub.at, sub.spec);
        debug_assert_eq!(id as usize, classes.len() - 1, "job ids are dense");
    }
    let outcome = sim.run(&mut q);
    let (reports, span) = per_class(&outcome.records, &classes, total_cores);
    let utilization: f64 = reports.iter().map(|r| r.utilization).sum();
    // Backfill admission uses the *declared* duration (a walltime
    // estimate); the task model adds half-normal jitter (σ = 0.4 s) on
    // top, modelling estimate error. Tolerate its tail here — the
    // strict zero-jitter invariant is pinned by the property tests in
    // `rust/tests/backfill_properties.rs`.
    let jitter_slack = 5.0;
    let holds_respected = outcome.backfills.iter().all(|b| {
        let Some(h) = b.hold else {
            return true;
        };
        if b.node != h.node {
            return true;
        }
        outcome.records[b.task as usize]
            .end_t
            .map(|end| end <= h.start + jitter_slack)
            .unwrap_or(false)
    });
    let unfinished = outcome
        .records
        .iter()
        .filter(|r| r.cleanup_t.is_none())
        .count();
    Ok(ContentionResult {
        mix_name: mix.name.clone(),
        nodes: mix.nodes,
        backfill,
        reports,
        span,
        utilization,
        backfills: outcome.backfills.len(),
        holds_respected,
        unfinished,
    })
}

/// Run the full (or truncated) Table III matrix. Returns the per-cell
/// overhead points (for Table III / Fig 1) and all individual results
/// (for Fig 2 and diagnostics). `progress` is called after each run.
pub fn run_matrix(
    opts: &ExperimentOpts,
    mut progress: impl FnMut(&CellResult),
) -> Result<(Vec<OverheadPoint>, Vec<CellResult>)> {
    let mut points = Vec::new();
    let mut all = Vec::new();
    for &nodes in NODE_SCALES.iter().filter(|&&n| n <= opts.max_nodes) {
        for task in &TASK_CONFIGS {
            for mode in [Mode::MultiLevel, Mode::NodeBased] {
                if !opts.include_na && presets::is_paper_na(nodes, task, mode) {
                    continue;
                }
                let mut runtimes = Vec::with_capacity(opts.runs);
                for run_idx in 0..opts.runs {
                    let cell = PaperCell::new(nodes, *task, mode, run_idx);
                    let res = run_cell(&cell)?;
                    runtimes.push(res.runtime);
                    progress(&res);
                    all.push(res);
                }
                points.push(OverheadPoint {
                    nodes,
                    task_time: task.task_time,
                    mode,
                    runtimes,
                    t_job: task.job_time,
                });
            }
        }
    }
    Ok((points, all))
}

/// Pick, per `(nodes, task, mode)`, the run whose runtime is the median of
/// its cell — the runs Fig 2 plots.
pub fn median_runs(all: &[CellResult]) -> Vec<&CellResult> {
    let mut out: Vec<&CellResult> = Vec::new();
    for &nodes in &NODE_SCALES {
        for task in &TASK_CONFIGS {
            for mode in [Mode::MultiLevel, Mode::NodeBased] {
                let mut cell_runs: Vec<&CellResult> = all
                    .iter()
                    .filter(|r| {
                        r.cell.nodes == nodes
                            && r.cell.task.task_time == task.task_time
                            && r.cell.mode == mode
                    })
                    .collect();
                if cell_runs.is_empty() {
                    continue;
                }
                cell_runs.sort_by(|a, b| a.runtime.partial_cmp(&b.runtime).expect("no NaN"));
                out.push(cell_runs[cell_runs.len() / 2]);
            }
        }
    }
    out
}

/// Label in the paper's Fig 2 convention: `M-S1-A` (mode, scale index,
/// run letter).
pub fn fig2_label(cell: &PaperCell) -> String {
    let scale_idx = NODE_SCALES
        .iter()
        .position(|&n| n == cell.nodes)
        .map(|i| i + 1)
        .unwrap_or(0);
    let mode = match cell.mode {
        Mode::MultiLevel => "M",
        Mode::NodeBased => "N",
        Mode::PerTask => "P",
    };
    let run = (b'A' + cell.run_idx as u8) as char;
    format!("{mode}-S{scale_idx}-{run}-t{}", cell.task.task_time as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cell(mode: Mode, run_idx: usize) -> PaperCell {
        PaperCell::new(32, TASK_CONFIGS[3], mode, run_idx) // 32 nodes, 60 s
    }

    #[test]
    fn single_cell_runs_and_lands_near_paper() {
        let res = run_cell(&small_cell(Mode::NodeBased, 0)).unwrap();
        // Paper: N* at 32 nodes ≈ 241–243 s.
        assert!(
            (240.5..250.0).contains(&res.runtime),
            "runtime {}",
            res.runtime
        );
        assert!(res.utilization.peak() > 0.99, "fills the machine");
    }

    #[test]
    fn multi_level_costs_more_at_32_nodes() {
        // Median of three runs, exactly like the paper's Table III.
        let med = |mode: Mode| {
            let mut rts: Vec<f64> = (0..3)
                .map(|i| run_cell(&small_cell(mode, i)).unwrap().runtime)
                .collect();
            rts.sort_by(|a, b| a.partial_cmp(b).unwrap());
            rts[1]
        };
        let m = med(Mode::MultiLevel);
        let n = med(Mode::NodeBased);
        // Paper: M* ≈ 277–305 vs N* ≈ 241–243.
        assert!(m > n + 10.0, "M {m} vs N {n}");
        assert!((260.0..340.0).contains(&m), "M median {m}");
        assert!((240.5..255.0).contains(&n), "N median {n}");
    }

    #[test]
    fn quick_matrix_has_expected_cells() {
        let opts = ExperimentOpts {
            max_nodes: 32,
            runs: 1,
            ..Default::default()
        };
        let (points, all) = run_matrix(&opts, |_| {}).unwrap();
        // 1 scale × 4 tasks × 2 modes.
        assert_eq!(points.len(), 8);
        assert_eq!(all.len(), 8);
        for p in &points {
            assert_eq!(p.runtimes.len(), 1);
            assert!(p.median_runtime() > 240.0);
        }
    }

    #[test]
    fn median_runs_picks_one_per_cell() {
        let opts = ExperimentOpts {
            max_nodes: 32,
            runs: 3,
            ..Default::default()
        };
        let (_, all) = run_matrix(&opts, |_| {}).unwrap();
        let med = median_runs(&all);
        assert_eq!(med.len(), 8);
        // The median run's runtime is the middle of its cell's three.
        for m in med {
            let mut cell_times: Vec<f64> = all
                .iter()
                .filter(|r| {
                    r.cell.nodes == m.cell.nodes
                        && r.cell.mode == m.cell.mode
                        && r.cell.task.task_time == m.cell.task.task_time
                })
                .map(|r| r.runtime)
                .collect();
            cell_times.sort_by(|a, b| a.partial_cmp(b).unwrap());
            assert_eq!(m.runtime, cell_times[1]);
        }
    }

    #[test]
    fn placement_sweep_runs_all_policies() {
        let sweep = run_placement_sweep(8, &TASK_CONFIGS[3], Mode::NodeBased).unwrap();
        assert_eq!(sweep.len(), 5);
        for (strategy, res) in &sweep {
            assert_eq!(res.placement, *strategy);
            // Every policy still completes the job in sane time (wide
            // bound: production noise can land a large burst mid-run).
            assert!(res.runtime > 240.0 && res.runtime < 700.0, "{strategy}: {}", res.runtime);
        }
    }

    #[test]
    fn contention_tiny_runs_end_to_end() {
        let mix = ContentionMix::preset("tiny", 8).unwrap();
        let res = run_contention(&mix, true, 11).unwrap();
        assert_eq!(res.unfinished, 0, "all tasks drain");
        assert!(res.holds_respected, "no backfill delays a reservation");
        assert_eq!(res.reports.len(), 2);
        let inter = &res.reports[0];
        let batch = &res.reports[1];
        assert_eq!(inter.class, JobClass::Interactive);
        assert_eq!(batch.class, JobClass::Batch);
        assert!(inter.tasks > 0 && batch.tasks > 0);
        assert_eq!(inter.completed, inter.tasks);
        assert_eq!(batch.completed, batch.tasks);
        assert!(res.span > 0.0);
        assert!(res.utilization > 0.0 && res.utilization <= 1.0);
        // Interactive launches must stay fast in the tiny mix.
        assert!(
            inter.median_launch_latency < 30.0,
            "interactive median {}",
            inter.median_launch_latency
        );
    }

    #[test]
    fn contention_backfill_flag_round_trips() {
        let mix = ContentionMix::preset("tiny", 4).unwrap();
        let off = run_contention(&mix, false, 3).unwrap();
        let on = run_contention(&mix, true, 3).unwrap();
        assert!(!off.backfill && on.backfill);
        assert_eq!(off.backfills, 0, "no backfill ops when disabled");
        assert_eq!(off.unfinished, 0);
        assert_eq!(on.unfinished, 0);
    }

    #[test]
    fn fig2_labels() {
        let c = PaperCell::new(512, TASK_CONFIGS[0], Mode::MultiLevel, 2);
        assert_eq!(fig2_label(&c), "M-S5-C-t1");
        let c2 = PaperCell::new(32, TASK_CONFIGS[3], Mode::NodeBased, 0);
        assert_eq!(fig2_label(&c2), "N-S1-A-t60");
    }
}
