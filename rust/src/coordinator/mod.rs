//! The top-level coordinator: experiment orchestration (the paper's
//! benchmark matrix), the CLI, and result persistence.

pub mod cli;
pub mod experiment;

pub use experiment::{run_cell, run_matrix, CellResult, ExperimentOpts};
