//! The deterministic fault audit log.
//!
//! Every scheduling-relevant churn event is recorded as an
//! [`AuditRecord`] — `(time, seq, event, reason)` — in the exact order
//! the scheduler applied it. Because the fault plan is materialized up
//! front from a seeded stream and the event queue breaks time ties by
//! FIFO seq, the log is a pure function of `(config, seed)`: re-run
//! the same scenario and [`AuditLog::to_text`] is byte-identical.
//! [`AuditLog::replay_diff`] is the verifier — it compares two logs
//! record by record and names the first divergence, which is how both
//! the `churn --replay` CLI path and the replay-determinism property
//! test check the contract.
//!
//! The text format is one record per line,
//! `seq<TAB>time<TAB>event<TAB>reason`, with time printed at fixed
//! 9-decimal precision so formatting can never mask (or invent) a
//! divergence. See `docs/audit-log.md` for the full contract.

use crate::cluster::NodeId;
use crate::scheduler::TaskId;
use crate::sim::Time;
use std::fmt;

/// What happened. Node ids and task ids are the scheduler's own.
#[derive(Debug, Clone, PartialEq)]
pub enum AuditEvent {
    /// Node went down hard.
    NodeFailed { node: NodeId },
    /// Node came back up.
    NodeRecovered { node: NodeId },
    /// Node entered a maintenance drain.
    NodeDrained { node: NodeId },
    /// A pooled lease on `node` was torn down because the node left
    /// service; `shard` is the owning shard.
    PoolEvicted { node: NodeId, shard: usize },
    /// A backfill reservation hold on `node` for `task` was cleared.
    HoldCleared { node: NodeId, task: TaskId },
    /// Running `task` on `node` was killed.
    TaskKilled { task: TaskId, node: NodeId },
    /// Killed task requeued for attempt `attempt`.
    TaskRequeued { task: TaskId, attempt: u32 },
    /// Killed task exhausted its retries after `attempts` tries.
    TaskLost { task: TaskId, attempts: u32 },
    /// Spot reclamation wave `wave` fired, taking `nodes` nodes.
    ReclaimWave { wave: u32, nodes: usize },
}

/// Why it happened.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultReason {
    /// Drawn from the per-node MTBF process.
    Mtbf,
    /// Taken by a spot/preemptible reclamation wave.
    SpotReclaim,
    /// Scheduled maintenance.
    Maintenance,
    /// The node's downtime or drain window ended.
    Recovery,
    /// Collateral of a node-level event (kills, evictions, hold
    /// clears triggered by a failure).
    Cascade,
    /// The retry policy ran out of attempts.
    RetryExhausted,
}

impl fmt::Display for FaultReason {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            FaultReason::Mtbf => "mtbf",
            FaultReason::SpotReclaim => "spot_reclaim",
            FaultReason::Maintenance => "maintenance",
            FaultReason::Recovery => "recovery",
            FaultReason::Cascade => "cascade",
            FaultReason::RetryExhausted => "retry_exhausted",
        };
        f.write_str(s)
    }
}

impl fmt::Display for AuditEvent {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AuditEvent::NodeFailed { node } => write!(f, "node_failed node={node}"),
            AuditEvent::NodeRecovered { node } => write!(f, "node_recovered node={node}"),
            AuditEvent::NodeDrained { node } => write!(f, "node_drained node={node}"),
            AuditEvent::PoolEvicted { node, shard } => {
                write!(f, "pool_evicted node={node} shard={shard}")
            }
            AuditEvent::HoldCleared { node, task } => {
                write!(f, "hold_cleared node={node} task={task}")
            }
            AuditEvent::TaskKilled { task, node } => {
                write!(f, "task_killed task={task} node={node}")
            }
            AuditEvent::TaskRequeued { task, attempt } => {
                write!(f, "task_requeued task={task} attempt={attempt}")
            }
            AuditEvent::TaskLost { task, attempts } => {
                write!(f, "task_lost task={task} attempts={attempts}")
            }
            AuditEvent::ReclaimWave { wave, nodes } => {
                write!(f, "reclaim_wave wave={wave} nodes={nodes}")
            }
        }
    }
}

/// One audit-log line: when, in what order, what, and why.
#[derive(Debug, Clone, PartialEq)]
pub struct AuditRecord {
    /// Simulation time the scheduler applied the event.
    pub time: Time,
    /// Application order; assigned by the log, strictly increasing.
    pub seq: u64,
    pub event: AuditEvent,
    pub reason: FaultReason,
}

impl fmt::Display for AuditRecord {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}\t{:.9}\t{}\t{}",
            self.seq, self.time, self.event, self.reason
        )
    }
}

/// Append-only record of everything the fault layer did this run.
#[derive(Debug, Clone, Default)]
pub struct AuditLog {
    records: Vec<AuditRecord>,
    next_seq: u64,
}

impl AuditLog {
    pub fn new() -> AuditLog {
        AuditLog::default()
    }

    /// Append an event; the log assigns the seq.
    pub fn push(&mut self, time: Time, event: AuditEvent, reason: FaultReason) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.records.push(AuditRecord {
            time,
            seq,
            event,
            reason,
        });
    }

    pub fn records(&self) -> &[AuditRecord] {
        &self.records
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Canonical text form: one record per line, trailing newline iff
    /// non-empty. This exact string is what the replay-determinism
    /// contract pins byte for byte.
    pub fn to_text(&self) -> String {
        let mut out = String::new();
        for r in &self.records {
            out.push_str(&r.to_string());
            out.push('\n');
        }
        out
    }

    /// The replay verifier: `None` when the logs agree record for
    /// record, else a human-readable description of the first
    /// divergence.
    pub fn replay_diff(a: &AuditLog, b: &AuditLog) -> Option<String> {
        for (i, (ra, rb)) in a.records.iter().zip(b.records.iter()).enumerate() {
            if ra != rb {
                return Some(format!(
                    "audit logs diverge at record {i}:\n  a: {ra}\n  b: {rb}"
                ));
            }
        }
        if a.records.len() != b.records.len() {
            return Some(format!(
                "audit logs diverge in length: a has {} records, b has {}",
                a.records.len(),
                b.records.len()
            ));
        }
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> AuditLog {
        let mut log = AuditLog::new();
        log.push(1.5, AuditEvent::NodeFailed { node: 3 }, FaultReason::Mtbf);
        log.push(
            1.5,
            AuditEvent::TaskKilled { task: 7, node: 3 },
            FaultReason::Cascade,
        );
        log.push(
            9.25,
            AuditEvent::NodeRecovered { node: 3 },
            FaultReason::Recovery,
        );
        log
    }

    #[test]
    fn seq_is_strictly_increasing_and_text_is_stable() {
        let log = sample();
        for (i, r) in log.records().iter().enumerate() {
            assert_eq!(r.seq, i as u64);
        }
        let text = log.to_text();
        assert_eq!(
            text,
            "0\t1.500000000\tnode_failed node=3\tmtbf\n\
             1\t1.500000000\ttask_killed task=7 node=3\tcascade\n\
             2\t9.250000000\tnode_recovered node=3\trecovery\n"
        );
        assert_eq!(text, sample().to_text());
    }

    #[test]
    fn replay_diff_catches_divergence_and_length() {
        let a = sample();
        let b = sample();
        assert_eq!(AuditLog::replay_diff(&a, &b), None);

        let mut c = sample();
        c.push(
            10.0,
            AuditEvent::TaskRequeued { task: 7, attempt: 1 },
            FaultReason::Cascade,
        );
        let d = AuditLog::replay_diff(&a, &c).expect("length divergence");
        assert!(d.contains("length"), "got: {d}");

        let mut e = AuditLog::new();
        e.push(1.5, AuditEvent::NodeFailed { node: 4 }, FaultReason::Mtbf);
        let d = AuditLog::replay_diff(&a, &e).expect("record divergence");
        assert!(d.contains("record 0"), "got: {d}");
    }

    #[test]
    fn empty_log_renders_empty() {
        let log = AuditLog::new();
        assert!(log.is_empty());
        assert_eq!(log.to_text(), "");
    }
}
