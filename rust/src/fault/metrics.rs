//! Counters and derived metrics for fault/churn runs.
//!
//! [`FaultStats`] is the raw tally the scheduler increments as it
//! applies churn events; [`FaultOutcome`] pairs it with the audit log
//! and rides out on the simulation outcome so the experiment layer can
//! export v4 contention columns (see `docs/scenarios.md`).

use super::audit::AuditLog;

/// Raw churn tallies, incremented inline by the scheduler.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct FaultStats {
    /// Nodes taken down hard (MTBF + reclamation, counted per node).
    pub node_failures: u64,
    /// Nodes returned to service.
    pub node_recoveries: u64,
    /// Spot reclamation waves fired.
    pub reclaim_waves: u64,
    /// Maintenance drains started.
    pub drains: u64,
    /// Running tasks killed by a node failure.
    pub tasks_killed: u64,
    /// Killed tasks put back on the queue.
    pub tasks_requeued: u64,
    /// Killed tasks that exhausted their retries.
    pub tasks_lost: u64,
    /// Core-seconds of completed-but-wasted work on killed tasks.
    pub work_lost_core_s: f64,
    /// Sum over restarted tasks of (restart time − kill time).
    pub requeue_delay_s: f64,
    /// Restarts counted into `requeue_delay_s`.
    pub requeue_n: u64,
    /// Sum over recoveries of (up time − down time).
    pub recovery_s: f64,
    /// Recoveries counted into `recovery_s`.
    pub recovery_n: u64,
}

impl FaultStats {
    /// Mean kill-to-restart latency, `NaN` when nothing restarted.
    pub fn mean_requeue_delay(&self) -> f64 {
        if self.requeue_n == 0 {
            f64::NAN
        } else {
            self.requeue_delay_s / self.requeue_n as f64
        }
    }

    /// Mean node downtime, `NaN` when nothing recovered.
    pub fn mean_recovery(&self) -> f64 {
        if self.recovery_n == 0 {
            f64::NAN
        } else {
            self.recovery_s / self.recovery_n as f64
        }
    }
}

/// What a faulty run hands back: the tallies plus the replayable log.
#[derive(Debug, Clone, Default)]
pub struct FaultOutcome {
    pub stats: FaultStats,
    pub audit: AuditLog,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn means_handle_empty_and_nonempty() {
        let mut s = FaultStats::default();
        assert!(s.mean_requeue_delay().is_nan());
        assert!(s.mean_recovery().is_nan());
        s.requeue_delay_s = 6.0;
        s.requeue_n = 3;
        s.recovery_s = 20.0;
        s.recovery_n = 4;
        assert_eq!(s.mean_requeue_delay(), 2.0);
        assert_eq!(s.mean_recovery(), 5.0);
    }
}
