//! Named churn scenarios: a workload mix paired with a fault plan.
//!
//! The `churn` CLI subcommand runs these end to end; the full cookbook
//! (exact invocations, what each preset exercises, how to read the
//! output) lives in `docs/scenarios.md`. Each preset pairs a
//! [`ContentionMix`] with a [`FaultConfig`] whose horizon matches the
//! mix, so churn keeps hitting the cluster for as long as work arrives.

use super::{FaultConfig, RetryPolicy};
use crate::error::{Error, Result};
use crate::scheduler::job::ResourceRequest;
use crate::spot::SPOT_PRIORITY;
use crate::workload::contention::{Arrival, ClassSpec, ContentionMix, JobClass};
use crate::workload::taskgen::TaskGen;

/// A named churn scenario: what runs and what breaks.
#[derive(Debug, Clone)]
pub struct ChurnScenario {
    pub name: String,
    pub mix: ContentionMix,
    pub fault: FaultConfig,
}

/// Preset names, in registry order (kept in sync with
/// `docs/scenarios.md` by the CI docs-drift lane).
pub const CHURN_PRESETS: [&str; 4] = ["churn_mtbf", "churn_reclaim", "churn_drain", "churn_full"];

impl ChurnScenario {
    /// Resolve a churn preset scaled to `nodes`:
    ///
    /// * `churn_mtbf` — the `tiny` contention mix under a per-node
    ///   MTBF failure process (a handful of hard failures per run,
    ///   ~30 s repairs). The baseline recover-and-requeue scenario.
    /// * `churn_reclaim` — the `burst` rapid-launch mix with a
    ///   low-priority spot filler class (at [`SPOT_PRIORITY`], reviving
    ///   `spot/mod.rs`'s release-latency regime) and two reclamation
    ///   waves that each yank an eighth of the machine mid-volley; the
    ///   pool fleet must evict dead leases and re-grow past them.
    /// * `churn_drain` — the `default` mix under rolling maintenance
    ///   drains (graceful: running work finishes, drained nodes take
    ///   no new work until their window ends).
    /// * `churn_full` — everything at once on the `burst` mix: MTBF
    ///   failures, one reclamation wave, one drain window, and 5%
    ///   stragglers running 4× their declared walltime (which drives
    ///   the `preempt_overdue` path when it is enabled).
    pub fn preset(name: &str, nodes: u32) -> Result<ChurnScenario> {
        let nodes = nodes.max(2);
        match name {
            "churn_mtbf" => {
                let mix = ContentionMix::preset("tiny", nodes)?;
                let fault = FaultConfig {
                    // Scaled so the whole cluster sees a few failures
                    // per 150 s horizon regardless of node count.
                    mtbf: 30.0 * nodes as f64,
                    mttr: 30.0,
                    horizon: mix.horizon,
                    retry: RetryPolicy {
                        max_retries: 3,
                        backoff: 1.0,
                    },
                    ..FaultConfig::disabled()
                };
                Ok(ChurnScenario::checked("churn_mtbf", mix, fault))
            }
            "churn_reclaim" => {
                let mut mix = ContentionMix::preset("burst", nodes)?;
                mix.name = "churn_reclaim".into();
                mix.classes.push(spot_filler(nodes));
                let fault = FaultConfig {
                    reclaim_times: vec![60.0, 200.0],
                    reclaim_count: (nodes / 8).max(1) as usize,
                    reclaim_hold: 90.0,
                    horizon: mix.horizon,
                    retry: RetryPolicy {
                        max_retries: 4,
                        backoff: 0.5,
                    },
                    ..FaultConfig::disabled()
                };
                Ok(ChurnScenario::checked("churn_reclaim", mix, fault))
            }
            "churn_drain" => {
                let mix = ContentionMix::preset("default", nodes)?;
                let fault = FaultConfig {
                    drain_times: vec![100.0, 300.0],
                    drain_count: (nodes / 8).max(1) as usize,
                    drain_hold: 120.0,
                    horizon: mix.horizon,
                    ..FaultConfig::disabled()
                };
                Ok(ChurnScenario::checked("churn_drain", mix, fault))
            }
            "churn_full" => {
                let mut mix = ContentionMix::preset("burst", nodes)?;
                mix.name = "churn_full".into();
                mix.classes.push(spot_filler(nodes));
                let fault = FaultConfig {
                    mtbf: 60.0 * nodes as f64,
                    mttr: 45.0,
                    reclaim_times: vec![150.0],
                    reclaim_count: (nodes / 8).max(1) as usize,
                    reclaim_hold: 100.0,
                    drain_times: vec![250.0],
                    drain_count: (nodes / 16).max(1) as usize,
                    drain_hold: 80.0,
                    straggler_prob: 0.05,
                    straggler_factor: 4.0,
                    horizon: mix.horizon,
                    retry: RetryPolicy {
                        max_retries: 3,
                        backoff: 1.0,
                    },
                };
                Ok(ChurnScenario::checked("churn_full", mix, fault))
            }
            other => Err(Error::Config(format!(
                "unknown churn preset {other:?} (known: churn_mtbf, churn_reclaim, \
                 churn_drain, churn_full)"
            ))),
        }
    }

    fn checked(name: &str, mix: ContentionMix, fault: FaultConfig) -> ChurnScenario {
        debug_assert!(fault.validate().is_ok(), "preset {name} fails validation");
        ChurnScenario {
            name: name.into(),
            mix,
            fault,
        }
    }
}

/// The spot-class revival: a steady stream of preemptible whole-node
/// filler at [`SPOT_PRIORITY`], the `spot/mod.rs` regime — it soaks
/// idle capacity between volleys and is first in line to die when a
/// reclamation wave takes its node.
fn spot_filler(nodes: u32) -> ClassSpec {
    ClassSpec {
        class: JobClass::Batch,
        arrival: Arrival::Periodic {
            gap: 40.0,
            start: 2.0,
        },
        tasks_per_job: (nodes / 8).max(1) as u64,
        request: ResourceRequest::WholeNode,
        duration: TaskGen::Constant { seconds: 90.0 },
        priority: SPOT_PRIORITY,
        lanes: 64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_presets_resolve_and_validate() {
        for name in CHURN_PRESETS {
            let s = ChurnScenario::preset(name, 32).expect(name);
            assert_eq!(s.name, name);
            assert!(s.fault.enabled(), "{name} must enable some churn");
            assert!(s.fault.validate().is_ok(), "{name} must validate");
            assert_eq!(s.fault.horizon, s.mix.horizon, "{name} horizon mismatch");
        }
    }

    #[test]
    fn unknown_preset_lists_known_names() {
        let err = ChurnScenario::preset("nope", 8).unwrap_err().to_string();
        for name in CHURN_PRESETS {
            assert!(err.contains(name), "error must list {name}: {err}");
        }
    }

    #[test]
    fn reclaim_presets_carry_the_spot_class() {
        let s = ChurnScenario::preset("churn_reclaim", 16).unwrap();
        assert!(
            s.mix.classes.iter().any(|c| c.priority == SPOT_PRIORITY),
            "churn_reclaim must include the spot filler class"
        );
        assert!(s.fault.reclaim_count >= 1);
    }
}
