//! Fault injection: node failures, spot reclamation, drains, stragglers.
//!
//! Production clusters lose nodes mid-run. This module plans *when* and
//! *how* nodes churn — per-node MTBF failure processes, spot/preemptible
//! reclamation waves, scheduled maintenance drains, and per-task
//! straggler slowdowns — entirely up front, from a dedicated seeded RNG
//! stream. The scheduler consumes the resulting [`FaultPlan`] as
//! ordinary pre-scheduled events, so a faulty run is exactly as
//! deterministic as a healthy one: same `(config, seed)` in, same
//! schedule and same audit log out (see [`crate::fault::audit`]).
//!
//! The plan's RNG stream is salted ([`FAULT_STREAM_SALT`]) and forked
//! per node, so enabling faults never perturbs the placement, walltime,
//! or workload streams — and a disabled [`FaultConfig`] draws nothing
//! at all, which is what makes the fault-off bit-for-bit equivalence
//! pin in `rust/tests/fault_properties.rs` possible.

pub mod audit;
pub mod metrics;
pub mod scenario;

use crate::cluster::NodeId;
use crate::sim::Time;
use crate::util::rng::{Rng, SplitMix64};

/// Salt XORed into the run seed to derive the fault stream, so fault
/// draws never overlap the scheduler/placement/walltime streams.
pub const FAULT_STREAM_SALT: u64 = 0xA076_1D64_78BD_642F;

/// Shortest node downtime the planner will emit; keeps Fail/Recover
/// pairs strictly ordered even for tiny MTTR draws.
const MIN_DOWNTIME: f64 = 1e-3;

/// How killed tasks come back: up to `max_retries` requeues with
/// exponential backoff.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetryPolicy {
    /// Requeue a killed task at most this many times before declaring
    /// it lost.
    pub max_retries: u32,
    /// Base requeue delay in seconds; attempt `k` waits
    /// `backoff * 2^k`.
    pub backoff: f64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_retries: 3,
            backoff: 1.0,
        }
    }
}

impl RetryPolicy {
    /// Delay before the next requeue after `retries` prior attempts.
    /// The exponent is clamped so pathological retry counts cannot
    /// overflow into infinity.
    pub fn delay(&self, retries: u32) -> f64 {
        self.backoff * f64::powi(2.0, retries.min(20) as i32)
    }
}

/// Everything the fault planner needs: which churn processes are on
/// and how hard they hit. A default config is fully disabled.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultConfig {
    /// Mean time between failures per node, seconds; `0.0` disables
    /// MTBF failures.
    pub mtbf: f64,
    /// Mean time to recovery once a node has failed, seconds.
    pub mttr: f64,
    /// Times at which spot-reclamation waves fire.
    pub reclaim_times: Vec<Time>,
    /// Nodes reclaimed per wave.
    pub reclaim_count: usize,
    /// Seconds after the wave before reclaimed nodes return;
    /// `<= 0.0` means they never come back.
    pub reclaim_hold: f64,
    /// Times at which maintenance drains start.
    pub drain_times: Vec<Time>,
    /// Nodes drained per maintenance window.
    pub drain_count: usize,
    /// Seconds a drained node stays out before recovering;
    /// `<= 0.0` means it never comes back.
    pub drain_hold: f64,
    /// Probability a task is a straggler.
    pub straggler_prob: f64,
    /// Actual-runtime multiplier applied to stragglers (their walltime
    /// *estimate* keeps the declared duration, so stragglers overrun).
    pub straggler_factor: f64,
    /// Planning horizon: no fault event is generated at or beyond it.
    pub horizon: Time,
    /// Requeue policy for tasks killed by a fault.
    pub retry: RetryPolicy,
}

impl Default for FaultConfig {
    fn default() -> Self {
        FaultConfig::disabled()
    }
}

impl FaultConfig {
    /// The no-faults config: plans nothing, draws nothing.
    pub fn disabled() -> FaultConfig {
        FaultConfig {
            mtbf: 0.0,
            mttr: 30.0,
            reclaim_times: Vec::new(),
            reclaim_count: 0,
            reclaim_hold: 0.0,
            drain_times: Vec::new(),
            drain_count: 0,
            drain_hold: 0.0,
            straggler_prob: 0.0,
            straggler_factor: 1.0,
            horizon: 0.0,
            retry: RetryPolicy::default(),
        }
    }

    /// True when any churn process would generate work.
    pub fn enabled(&self) -> bool {
        self.mtbf > 0.0
            || (!self.reclaim_times.is_empty() && self.reclaim_count > 0)
            || (!self.drain_times.is_empty() && self.drain_count > 0)
            || self.straggler_prob > 0.0
    }

    /// Reject configs the planner cannot honor.
    pub fn validate(&self) -> Result<(), String> {
        if self.mtbf < 0.0 || !self.mtbf.is_finite() {
            return Err(format!("fault mtbf must be finite and >= 0, got {}", self.mtbf));
        }
        if self.mtbf > 0.0 && (self.mttr <= 0.0 || !self.mttr.is_finite()) {
            return Err(format!("fault mttr must be finite and > 0, got {}", self.mttr));
        }
        if !(0.0..=1.0).contains(&self.straggler_prob) {
            return Err(format!(
                "straggler_prob must be in [0, 1], got {}",
                self.straggler_prob
            ));
        }
        if self.straggler_prob > 0.0 && self.straggler_factor < 1.0 {
            return Err(format!(
                "straggler_factor must be >= 1, got {}",
                self.straggler_factor
            ));
        }
        if self.enabled() && self.horizon <= 0.0 {
            return Err("fault horizon must be > 0 when faults are enabled".into());
        }
        Ok(())
    }
}

/// One planned churn event, resolved to a concrete node or wave.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PlannedFault {
    /// Node goes down hard; running work on it is killed.
    Fail(NodeId),
    /// Node comes back up.
    Recover(NodeId),
    /// Spot reclamation wave `w` fires (members live in
    /// [`FaultPlan::wave`]).
    ReclaimWave(u32),
    /// Node enters a maintenance drain (finishes its work, takes no
    /// more).
    Drain(NodeId),
}

/// The fully materialized churn timetable for one run.
///
/// Generated once before the simulation starts; the scheduler turns
/// each `(time, PlannedFault)` row into a pre-scheduled event. Events
/// are sorted by time with generation order as the tie-break, which
/// the event queue's FIFO seq ordering then preserves — the source of
/// the replay-determinism contract.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    /// The config this plan was drawn from.
    pub cfg: FaultConfig,
    /// Time-sorted churn timetable.
    pub events: Vec<(Time, PlannedFault)>,
    /// Members of each reclamation wave, indexed by wave id.
    waves: Vec<Vec<NodeId>>,
    /// Seed of the fault stream; also keys the straggler hash.
    fault_seed: u64,
}

impl FaultPlan {
    /// Draw the full churn timetable for `n_nodes` nodes from the
    /// fault stream of `seed`.
    pub fn generate(cfg: &FaultConfig, n_nodes: u32, seed: u64) -> FaultPlan {
        let fault_seed = seed ^ FAULT_STREAM_SALT;
        let mut rng = Rng::new(fault_seed);
        let mut events: Vec<(Time, PlannedFault)> = Vec::new();
        let mut waves: Vec<Vec<NodeId>> = Vec::new();

        // Per-node MTBF process: alternating up-gap / down-time draws
        // from a per-node forked stream, so adding nodes never
        // perturbs earlier nodes' draws.
        if cfg.mtbf > 0.0 && cfg.horizon > 0.0 {
            for node in 0..n_nodes {
                let mut nrng = rng.fork();
                let mut t = nrng.exponential(1.0 / cfg.mtbf);
                while t < cfg.horizon {
                    events.push((t, PlannedFault::Fail(node)));
                    let down = nrng.exponential(1.0 / cfg.mttr).max(MIN_DOWNTIME);
                    let up_at = t + down;
                    if up_at >= cfg.horizon {
                        break;
                    }
                    events.push((up_at, PlannedFault::Recover(node)));
                    t = up_at + nrng.exponential(1.0 / cfg.mtbf);
                }
            }
        }

        // Reclamation waves: each picks `reclaim_count` distinct nodes
        // by partial shuffle; members recover together after the hold.
        if cfg.reclaim_count > 0 {
            for &at in &cfg.reclaim_times {
                if at <= 0.0 || at >= cfg.horizon {
                    continue;
                }
                let members = pick_nodes(&mut rng, n_nodes, cfg.reclaim_count);
                let wave = waves.len() as u32;
                events.push((at, PlannedFault::ReclaimWave(wave)));
                if cfg.reclaim_hold > 0.0 {
                    let back = at + cfg.reclaim_hold;
                    if back < cfg.horizon {
                        for &m in &members {
                            events.push((back, PlannedFault::Recover(m)));
                        }
                    }
                }
                waves.push(members);
            }
        }

        // Maintenance drains: graceful — running work finishes, the
        // node just stops taking new work until it recovers.
        if cfg.drain_count > 0 {
            for &at in &cfg.drain_times {
                if at <= 0.0 || at >= cfg.horizon {
                    continue;
                }
                let members = pick_nodes(&mut rng, n_nodes, cfg.drain_count);
                for &m in &members {
                    events.push((at, PlannedFault::Drain(m)));
                }
                if cfg.drain_hold > 0.0 {
                    let back = at + cfg.drain_hold;
                    if back < cfg.horizon {
                        for &m in &members {
                            events.push((back, PlannedFault::Recover(m)));
                        }
                    }
                }
            }
        }

        // Stable sort: equal times keep generation order, which the
        // event queue's FIFO tie-break then preserves at run time.
        events.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        FaultPlan {
            cfg: cfg.clone(),
            events,
            waves,
            fault_seed,
        }
    }

    /// Members of reclamation wave `w`.
    pub fn wave(&self, w: u32) -> &[NodeId] {
        &self.waves[w as usize]
    }

    /// Number of reclamation waves planned.
    pub fn n_waves(&self) -> usize {
        self.waves.len()
    }

    /// Straggler slowdown for one task: `straggler_factor` with
    /// probability `straggler_prob`, else `1.0`. A pure hash of
    /// `(fault_seed, task)` — no stream state — so the factor of task
    /// `t` never depends on how many other tasks were submitted.
    pub fn straggler_factor(&self, task: u64) -> f64 {
        if self.cfg.straggler_prob <= 0.0 || self.cfg.straggler_factor <= 1.0 {
            return 1.0;
        }
        let key = self.fault_seed ^ task.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let h = SplitMix64::new(key).next_u64();
        // Map the top 53 bits onto [0, 1) exactly like `Rng::f64`.
        let u = (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        if u < self.cfg.straggler_prob {
            self.cfg.straggler_factor
        } else {
            1.0
        }
    }
}

/// Pick `count` distinct node ids by partial Fisher-Yates over a
/// scratch identity vector.
fn pick_nodes(rng: &mut Rng, n_nodes: u32, count: usize) -> Vec<NodeId> {
    let mut ids: Vec<NodeId> = (0..n_nodes).collect();
    let take = count.min(ids.len());
    let mut out = Vec::with_capacity(take);
    for i in 0..take {
        let j = i + rng.below((ids.len() - i) as u64) as usize;
        ids.swap(i, j);
        out.push(ids[i]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mtbf_cfg() -> FaultConfig {
        FaultConfig {
            mtbf: 100.0,
            mttr: 10.0,
            horizon: 1000.0,
            ..FaultConfig::disabled()
        }
    }

    #[test]
    fn disabled_plan_is_empty() {
        let plan = FaultPlan::generate(&FaultConfig::disabled(), 64, 42);
        assert!(plan.events.is_empty());
        assert_eq!(plan.n_waves(), 0);
        assert_eq!(plan.straggler_factor(7), 1.0);
    }

    #[test]
    fn plan_is_deterministic() {
        let cfg = mtbf_cfg();
        let a = FaultPlan::generate(&cfg, 32, 1234);
        let b = FaultPlan::generate(&cfg, 32, 1234);
        assert_eq!(a.events, b.events);
        let c = FaultPlan::generate(&cfg, 32, 1235);
        assert_ne!(a.events, c.events);
    }

    #[test]
    fn events_sorted_and_within_horizon() {
        let mut cfg = mtbf_cfg();
        cfg.reclaim_times = vec![50.0, 500.0, 2000.0];
        cfg.reclaim_count = 4;
        cfg.reclaim_hold = 60.0;
        cfg.drain_times = vec![300.0];
        cfg.drain_count = 2;
        cfg.drain_hold = 100.0;
        let plan = FaultPlan::generate(&cfg, 32, 7);
        assert!(!plan.events.is_empty());
        for w in plan.events.windows(2) {
            assert!(w[0].0 <= w[1].0);
        }
        for &(t, _) in &plan.events {
            assert!(t > 0.0 && t < cfg.horizon);
        }
        // The 2000.0 wave is beyond the horizon and must be dropped.
        assert_eq!(plan.n_waves(), 2);
        for w in 0..plan.n_waves() {
            let members = plan.wave(w as u32);
            assert_eq!(members.len(), 4);
            let mut uniq = members.to_vec();
            uniq.sort_unstable();
            uniq.dedup();
            assert_eq!(uniq.len(), members.len(), "wave members must be distinct");
        }
    }

    #[test]
    fn fail_recover_pairs_alternate_per_node() {
        let plan = FaultPlan::generate(&mtbf_cfg(), 8, 99);
        for node in 0..8u32 {
            let mut down = false;
            for &(_, ev) in &plan.events {
                match ev {
                    PlannedFault::Fail(n) if n == node => {
                        assert!(!down, "double fail without recover on node {node}");
                        down = true;
                    }
                    PlannedFault::Recover(n) if n == node => {
                        assert!(down, "recover while up on node {node}");
                        down = false;
                    }
                    _ => {}
                }
            }
        }
    }

    #[test]
    fn adding_nodes_preserves_earlier_node_schedules() {
        let cfg = mtbf_cfg();
        let small = FaultPlan::generate(&cfg, 4, 5);
        let large = FaultPlan::generate(&cfg, 8, 5);
        let only_small = |plan: &FaultPlan| -> Vec<(Time, PlannedFault)> {
            plan.events
                .iter()
                .filter(|(_, ev)| match ev {
                    PlannedFault::Fail(n) | PlannedFault::Recover(n) | PlannedFault::Drain(n) => {
                        *n < 4
                    }
                    PlannedFault::ReclaimWave(_) => false,
                })
                .cloned()
                .collect()
        };
        assert_eq!(only_small(&small), only_small(&large));
    }

    #[test]
    fn straggler_hash_is_stable_and_hits_rate() {
        let mut cfg = FaultConfig::disabled();
        cfg.straggler_prob = 0.2;
        cfg.straggler_factor = 4.0;
        cfg.horizon = 100.0;
        let plan = FaultPlan::generate(&cfg, 4, 11);
        let mut hits = 0;
        for t in 0..10_000u64 {
            let f = plan.straggler_factor(t);
            assert_eq!(f, plan.straggler_factor(t), "hash must be pure");
            assert!(f == 1.0 || f == 4.0);
            if f == 4.0 {
                hits += 1;
            }
        }
        let rate = hits as f64 / 10_000.0;
        assert!((rate - 0.2).abs() < 0.03, "straggler rate {rate} far from 0.2");
    }

    #[test]
    fn retry_backoff_doubles_and_clamps() {
        let r = RetryPolicy {
            max_retries: 3,
            backoff: 2.0,
        };
        assert_eq!(r.delay(0), 2.0);
        assert_eq!(r.delay(1), 4.0);
        assert_eq!(r.delay(2), 8.0);
        assert!(r.delay(1000).is_finite());
    }

    #[test]
    fn validate_rejects_bad_configs() {
        let mut cfg = FaultConfig::disabled();
        assert!(cfg.validate().is_ok());
        cfg.mtbf = -1.0;
        assert!(cfg.validate().is_err());
        cfg.mtbf = 10.0;
        cfg.mttr = 0.0;
        assert!(cfg.validate().is_err());
        cfg.mttr = 5.0;
        cfg.horizon = 0.0;
        assert!(cfg.validate().is_err());
        cfg.horizon = 100.0;
        assert!(cfg.validate().is_ok());
        cfg.straggler_prob = 1.5;
        assert!(cfg.validate().is_err());
    }
}
