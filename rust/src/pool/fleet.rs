//! The shape-sharded pool fleet: several rapid-launch pools, each keyed
//! by a [`JobShape`], sharing one cluster under a single conservation
//! invariant.
//!
//! PR 4's single pool serves one undifferentiated short-job stream; on
//! a real mixed partition (CPU-core launches next to GPU/exclusive
//! launches — "Best of Both Worlds", arXiv:2008.02223) that lets one
//! shape starve the other behind its FIFO. [`PoolFleet`] holds one
//! [`Shard`] per shape — each with its own [`NodePool`],
//! [`NodeDispatcher`], [`PoolManager`] and pending queue — and adds the
//! fleet-level mechanics the shards cannot provide alone:
//!
//! * **routing** — [`PoolFleet::route`] sends a task to the unique
//!   shard whose shape matches (shapes are validated pairwise-disjoint
//!   by [`FleetConfig::validate`], so first-match is the only match);
//! * **rebalancing** — a growing shard first *borrows* free nodes from
//!   sibling shards ([`PoolFleet::borrow_into`]) before it leases idle
//!   batch nodes or drains busy ones, so a volley in one shape class
//!   reuses capacity another class just finished with instead of
//!   raiding batch;
//! * **drain forecasting** — each shard tracks when its busy leases are
//!   expected to free ([`PoolFleet::earliest_release_estimate`]), which
//!   backfill hold planning borrows when every batch candidate node is
//!   pool-fenced;
//! * **conservation** — [`PoolFleet::check_conservation`]: every node
//!   is in exactly one shard or batch-owned, never two shards at once.

use crate::cluster::NodeId;
use crate::pool::node_pool::NodePool;
use crate::pool::shape::JobShape;
use crate::pool::{NodeDispatcher, PoolConfig, PoolManager, Resize};
use crate::scheduler::job::TaskId;
use crate::sim::Time;
use std::collections::VecDeque;

/// Dense shard index inside one fleet (carried by `Op::Pool*` server
/// operations as a `u32`).
pub type ShardId = usize;

/// Capacity of the fleet's recent-launch debug ring. Launch *counts*
/// are plain counters; the ring only keeps the most recent task ids for
/// post-mortem inspection, so a 10M-task trace no longer accumulates
/// 10M-entry launch logs.
pub const LAUNCH_RING_CAP: usize = 1024;

/// Configuration of one shard: a name (for exports and errors), the
/// shape it serves, and the elastic pool knobs it runs under.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardConfig {
    pub name: String,
    pub shape: JobShape,
    pub pool: PoolConfig,
}

impl ShardConfig {
    /// A shard from a named shape with explicit size bounds.
    pub fn named(name: &str, size: usize, min: usize, max: usize) -> Option<ShardConfig> {
        let shape = JobShape::named(name)?;
        Some(ShardConfig {
            name: name.to_string(),
            shape,
            pool: PoolConfig {
                size,
                min,
                max,
                short_threshold: shape.max_walltime,
                ..PoolConfig::disabled()
            },
        })
    }
}

/// The fleet configuration: an ordered list of shard configs. Empty
/// means the subsystem is disabled entirely.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FleetConfig {
    pub shards: Vec<ShardConfig>,
}

impl FleetConfig {
    /// The disabled fleet.
    pub fn disabled() -> FleetConfig {
        FleetConfig { shards: Vec::new() }
    }

    /// The backward-compatible mapping from the legacy `pool_size` keys:
    /// one shard named `pool` whose shape is the old short-threshold
    /// classifier. A disabled [`PoolConfig`] maps to the disabled fleet.
    pub fn single(cfg: PoolConfig) -> FleetConfig {
        if !cfg.enabled() {
            return FleetConfig::disabled();
        }
        FleetConfig {
            shards: vec![ShardConfig {
                name: "pool".into(),
                shape: JobShape::up_to(cfg.short_threshold),
                pool: cfg,
            }],
        }
    }

    /// The shared explicit-list-else-legacy precedence rule (one source
    /// of truth for config files and the CLI): a non-empty shard list
    /// wins; otherwise the legacy single-pool knob maps via
    /// [`Self::single`].
    pub fn from_parts(pools: &[ShardConfig], legacy: PoolConfig) -> FleetConfig {
        if !pools.is_empty() {
            FleetConfig { shards: pools.to_vec() }
        } else {
            FleetConfig::single(legacy)
        }
    }

    /// Whether any shard participates.
    pub fn enabled(&self) -> bool {
        !self.shards.is_empty()
    }

    /// Sum of initial shard sizes (the `pool_size` export column).
    pub fn total_size(&self) -> usize {
        self.shards.iter().map(|s| s.pool.size).sum()
    }

    /// Validate every shard and — the bug guard — reject overlapping
    /// shard shapes: two shards claiming the same job would make
    /// routing depend on declaration order, which is exactly the kind
    /// of silent misconfiguration that strands one workload class.
    pub fn validate(&self) -> std::result::Result<(), String> {
        for s in &self.shards {
            if s.pool.size == 0 {
                return Err(format!("shard {:?} has size 0 (drop it instead)", s.name));
            }
            s.shape
                .validate()
                .map_err(|e| format!("shard {:?}: {e}", s.name))?;
            s.pool
                .validate()
                .map_err(|e| format!("shard {:?}: {e}", s.name))?;
        }
        for (i, a) in self.shards.iter().enumerate() {
            for b in &self.shards[i + 1..] {
                if a.name == b.name {
                    return Err(format!("duplicate shard name {:?}", a.name));
                }
                if a.shape.overlaps(&b.shape) {
                    return Err(format!(
                        "shards {:?} ({}) and {:?} ({}) claim overlapping job shapes; \
                         shard shapes must be disjoint so routing is unambiguous",
                        a.name, a.shape, b.name, b.shape
                    ));
                }
            }
        }
        Ok(())
    }
}

/// One live shard: its own membership table, dispatcher, controller and
/// FIFO of tasks waiting for a free leased node.
#[derive(Debug)]
pub struct Shard {
    pub name: String,
    pub shape: JobShape,
    pub cfg: PoolConfig,
    pub nodes: NodePool,
    pub dispatcher: NodeDispatcher,
    pub manager: PoolManager,
    /// FIFO of pool-routed tasks waiting for a free leased node.
    pub pending: VecDeque<TaskId>,
    /// Tasks launched through this shard (counter, not a log — the old
    /// append-only `Vec<TaskId>` leaked without bound).
    pub launches: u64,
    /// The last grow attempt found nothing to take (no sibling-free
    /// node, no batch node); cleared when a release could have produced
    /// a candidate. Gates the starving-shard cooldown bypass.
    pub grow_blocked: bool,
    /// Per-node drain forecast, indexed by `NodeId`: `Some(t)` while a
    /// launch occupies the node and is expected to free it at `t`.
    /// Node-indexed so a release is O(1) — the old `Vec<(NodeId, Time)>`
    /// paid an O(n) `retain` per release, quadratic across a busy
    /// shard's drain.
    busy_until: Vec<Option<Time>>,
}

impl Shard {
    /// Nodes this shard owns (leased + draining).
    pub fn owned(&self) -> usize {
        self.nodes.n_leased() + self.nodes.n_draining()
    }

    /// The manager's resize decision against the shard's own pressure.
    pub fn decision(&self) -> Resize {
        self.manager.decide(
            self.pending.len(),
            self.nodes.n_free(),
            self.nodes.n_leased(),
            self.nodes.n_draining(),
        )
    }

    /// Materialized drain forecast as `(node, est_end)` pairs, node
    /// ascending — a test/diagnostics hook; the hot path only ever
    /// indexes or scans the per-node slots directly.
    pub fn busy_forecast(&self) -> Vec<(NodeId, Time)> {
        self.busy_until
            .iter()
            .enumerate()
            .filter_map(|(n, t)| t.map(|t| (n as NodeId, t)))
            .collect()
    }
}

/// The shard registry plus fleet-level accounting.
#[derive(Debug)]
pub struct PoolFleet {
    pub shards: Vec<Shard>,
    /// Node → core capacity (from the placement index), for the
    /// capacity-class side of shape matching.
    capacity: Vec<u32>,
    /// Tasks launched through any shard (counter, not a log).
    launches: u64,
    /// The last [`LAUNCH_RING_CAP`] launched task ids, oldest first —
    /// the bounded debugging window that replaces the unbounded log.
    recent_launches: VecDeque<TaskId>,
    /// Cross-shard transfers performed by the rebalancer.
    borrows: u64,
    /// True fleet-wide high-water mark of simultaneous leases
    /// (refreshed by [`Self::note_peak`] after every lease-changing
    /// step; NOT the sum of per-shard peaks, which can overstate when
    /// shards peak at different times).
    peak_leased: usize,
    /// Sticky invariant flag (set by the scheduler glue on any refused
    /// transition or fence breach).
    pub violated: bool,
}

impl PoolFleet {
    /// Build the fleet over a cluster of `capacity.len()` nodes. Shard
    /// bounds are clamped to the cluster size, like the single pool's
    /// were.
    pub fn new(capacity: Vec<u32>, cfg: &FleetConfig) -> PoolFleet {
        let n = capacity.len();
        let shards = cfg
            .shards
            .iter()
            .map(|sc| {
                let max = sc.pool.effective_max().min(n);
                let min = sc.pool.effective_min().min(max);
                Shard {
                    name: sc.name.clone(),
                    shape: sc.shape,
                    cfg: sc.pool,
                    nodes: NodePool::new(n),
                    dispatcher: NodeDispatcher::new(),
                    manager: PoolManager::new(min, max, sc.pool.hysteresis),
                    pending: VecDeque::new(),
                    launches: 0,
                    grow_blocked: false,
                    busy_until: vec![None; n],
                }
            })
            .collect();
        PoolFleet {
            shards,
            capacity,
            launches: 0,
            recent_launches: VecDeque::new(),
            borrows: 0,
            peak_leased: 0,
            violated: false,
        }
    }

    /// Refresh the fleet-wide lease high-water mark. The scheduler glue
    /// calls this after every step that can add leases (bootstrap,
    /// resize, drain promotion); borrows are net-zero and need no call.
    pub fn note_peak(&mut self) {
        let cur: usize = self.shards.iter().map(|s| s.nodes.n_leased()).sum();
        if cur > self.peak_leased {
            self.peak_leased = cur;
        }
    }

    /// The fleet-wide simultaneous-lease peak.
    pub fn peak_leased(&self) -> usize {
        self.peak_leased
    }

    /// Number of nodes the fleet spans.
    pub fn n_nodes(&self) -> usize {
        self.capacity.len()
    }

    /// Core capacity of one node.
    pub fn capacity(&self, node: NodeId) -> u32 {
        self.capacity[node as usize]
    }

    /// The shard a task of this width and walltime estimate routes to.
    /// Shapes are disjoint by validation, so the first match is the
    /// only match.
    pub fn route(&self, lanes: u32, est_walltime: Time) -> Option<ShardId> {
        self.shards
            .iter()
            .position(|s| s.shape.matches(lanes, est_walltime))
    }

    /// Whether any shard owns `node` — the union fence every batch
    /// placement, backfill and hold query applies.
    pub fn in_pool(&self, node: NodeId) -> bool {
        self.shards.iter().any(|s| s.nodes.in_pool(node))
    }

    /// The shard owning `node`, if any.
    pub fn owner(&self, node: NodeId) -> Option<ShardId> {
        self.shards.iter().position(|s| s.nodes.in_pool(node))
    }

    /// Whether any node is pool-owned at all (cheap fence-active check).
    pub fn any_pooled(&self) -> bool {
        self.shards.iter().any(|s| s.nodes.any_pooled())
    }

    /// Cross-shard transfers performed so far.
    pub fn borrows(&self) -> u64 {
        self.borrows
    }

    /// Fleet-wide launch count.
    pub fn launches(&self) -> u64 {
        self.launches
    }

    /// The most recent launches (≤ [`LAUNCH_RING_CAP`]), oldest first.
    pub fn recent_launches(&self) -> &VecDeque<TaskId> {
        &self.recent_launches
    }

    /// Record a launch: bump the per-shard and fleet-wide counters,
    /// remember the task in the capped debug ring, and set the node's
    /// drain-forecast slot.
    pub fn note_launch(&mut self, sid: ShardId, node: NodeId, est_end: Time, task: TaskId) {
        let sh = &mut self.shards[sid];
        sh.launches += 1;
        sh.busy_until[node as usize] = Some(est_end);
        self.launches += 1;
        if self.recent_launches.len() == LAUNCH_RING_CAP {
            self.recent_launches.pop_front();
        }
        self.recent_launches.push_back(task);
    }

    /// Record a release: clear the node's drain-forecast slot. O(1) by
    /// node index.
    pub fn note_release(&mut self, sid: ShardId, node: NodeId) {
        self.shards[sid].busy_until[node as usize] = None;
    }

    /// The rebalancer's first grow source: transfer one free node from
    /// a sibling shard into `into`. A sibling donates only when it has
    /// no backlog of its own, a free node that fits the receiver's
    /// capacity class and passes `allow` (the scheduler fences out
    /// nodes carrying reservation holds — a planted forecast hold must
    /// not be whisked to another shard), and stays at or above its
    /// floor afterwards — otherwise the donation would just bounce back
    /// on the donor's next resize.
    pub fn borrow_into(
        &mut self,
        into: ShardId,
        allow: &dyn Fn(NodeId) -> bool,
    ) -> Option<NodeId> {
        let shape = self.shards[into].shape;
        let mut pick: Option<(ShardId, NodeId)> = None;
        for (did, donor) in self.shards.iter().enumerate() {
            if did == into || !donor.pending.is_empty() || donor.owned() <= donor.manager.min {
                continue;
            }
            if let Some(&n) = donor
                .nodes
                .free_nodes()
                .iter()
                .rev()
                .find(|&&n| shape.node_fits(self.capacity[n as usize]) && allow(n))
            {
                pick = Some((did, n));
                break;
            }
        }
        let (did, node) = pick?;
        if !self.shards[did].nodes.return_node(node) {
            self.violated = true;
            return None;
        }
        if !self.shards[into].nodes.lease(node) {
            self.violated = true;
            return None;
        }
        self.borrows += 1;
        Some(node)
    }

    /// The fleet's drain forecast: the pooled node expected to return
    /// to batch soonest, and when. Only shards that *can* actually give
    /// a node back are considered: no backlog of their own (a
    /// backlogged shard keeps its nodes) and above their `min` floor
    /// (a shard pinned at its floor never shrinks, so forecasting its
    /// nodes would plant a permanently-overdue hold). A qualifying
    /// shard with an idle lease could shrink it on its next resize pass
    /// (estimate: now), otherwise its earliest-ending busy lease bounds
    /// the release. `None` when no shard qualifies — the hold is
    /// skipped, exactly the pre-fleet behaviour.
    pub fn earliest_release_estimate(&self, now: Time) -> Option<(NodeId, Time)> {
        let mut best: Option<(NodeId, Time)> = None;
        for sh in &self.shards {
            if !sh.pending.is_empty() || sh.owned() <= sh.manager.min {
                continue;
            }
            let cand = if sh.nodes.n_free() > 0 {
                sh.nodes.free_nodes().last().map(|&n| (n, now))
            } else {
                sh.busy_until
                    .iter()
                    .enumerate()
                    .filter_map(|(n, t)| t.map(|t| (n as NodeId, t)))
                    .min_by(|a, b| a.1.partial_cmp(&b.1).expect("no NaN estimates"))
                    .map(|(n, t)| (n, t.max(now)))
            };
            if let Some((n, t)) = cand {
                let better = best.map(|(_, bt)| t < bt).unwrap_or(true);
                if better {
                    best = Some((n, t));
                }
            }
        }
        best
    }

    /// The fleet-wide conservation invariant: every shard's own
    /// bookkeeping is consistent, and no node is owned by two shards at
    /// once (so each node is in exactly one shard or batch).
    pub fn check_conservation(&self) -> std::result::Result<(), String> {
        let mut owner: Vec<Option<usize>> = vec![None; self.capacity.len()];
        for (sid, sh) in self.shards.iter().enumerate() {
            sh.nodes
                .check_conservation()
                .map_err(|e| format!("shard {:?}: {e}", sh.name))?;
            for n in 0..self.capacity.len() as NodeId {
                if sh.nodes.in_pool(n) {
                    if let Some(prev) = owner[n as usize] {
                        return Err(format!(
                            "node {n} owned by shards {:?} and {:?} at once",
                            self.shards[prev].name, sh.name
                        ));
                    }
                    owner[n as usize] = Some(sid);
                }
            }
            for (n, t) in sh.busy_until.iter().enumerate() {
                if t.is_some() && !sh.nodes.is_leased(n as NodeId) {
                    return Err(format!(
                        "shard {:?} forecasts busy node {n} it does not lease",
                        sh.name
                    ));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_shard_cfg() -> FleetConfig {
        FleetConfig {
            shards: vec![
                ShardConfig::named("general", 2, 1, 4).unwrap(),
                ShardConfig::named("large", 2, 1, 4).unwrap(),
            ],
        }
    }

    fn fleet(n: usize, cfg: &FleetConfig) -> PoolFleet {
        PoolFleet::new(vec![64; n], cfg)
    }

    #[test]
    fn single_mapping_reproduces_the_legacy_classifier() {
        let legacy = PoolConfig { size: 4, min: 2, max: 8, ..PoolConfig::disabled() };
        let f = FleetConfig::single(legacy);
        assert_eq!(f.shards.len(), 1);
        assert_eq!(f.shards[0].pool, legacy);
        assert_eq!(f.shards[0].shape, JobShape::up_to(legacy.short_threshold));
        assert_eq!(f.total_size(), 4);
        assert!(f.validate().is_ok());
        assert!(!FleetConfig::single(PoolConfig::disabled()).enabled());
    }

    #[test]
    fn overlapping_shard_shapes_are_rejected() {
        // The satellite bug guard: nothing used to stop two shards from
        // claiming the same job.
        let cfg = FleetConfig {
            shards: vec![
                ShardConfig::named("general", 2, 1, 4).unwrap(),
                ShardConfig {
                    name: "also-general".into(),
                    shape: JobShape::named("general").unwrap(),
                    pool: PoolConfig { size: 2, ..PoolConfig::sized(2) },
                },
            ],
        };
        let err = cfg.validate().unwrap_err();
        assert!(err.contains("overlap"), "{err}");
        // Disjoint shapes pass; duplicate names and zero sizes fail.
        assert!(two_shard_cfg().validate().is_ok());
        let mut dup = two_shard_cfg();
        dup.shards[1].name = "general".into();
        assert!(dup.validate().unwrap_err().contains("duplicate"));
        let mut zero = two_shard_cfg();
        zero.shards[0].pool.size = 0;
        assert!(zero.validate().unwrap_err().contains("size 0"));
    }

    #[test]
    fn routing_is_shape_keyed_and_unambiguous() {
        let f = fleet(8, &two_shard_cfg());
        assert_eq!(f.route(64, 0.5), Some(0), "rapid narrow job → general");
        assert_eq!(f.route(64, 45.0), Some(1), "heavy short job → large");
        assert_eq!(f.route(64, 120.0), None, "too long for any shard");
        assert_eq!(f.route(64, 2.0), Some(0), "boundary belongs to general");
    }

    #[test]
    fn borrowing_prefers_idle_siblings_and_respects_floors() {
        let mut f = fleet(8, &two_shard_cfg());
        // Shard 1 owns three free nodes (floor 1); shard 0 owns none.
        for n in [0, 1, 2] {
            assert!(f.shards[1].nodes.lease(n));
        }
        assert_eq!(f.borrow_into(0, &|_| true), Some(2), "LIFO top transfers first");
        assert_eq!(f.borrows(), 1);
        assert!(f.shards[0].nodes.is_leased(2));
        assert!(!f.shards[1].nodes.in_pool(2));
        f.check_conservation().unwrap();
        // Donor at its floor refuses; backlogged donor refuses.
        assert_eq!(f.borrow_into(0, &|_| true), Some(1));
        assert_eq!(f.borrow_into(0, &|_| true), None, "donor at min keeps its last node");
        f.shards[1].nodes.lease(3);
        f.shards[1].pending.push_back(7);
        assert_eq!(f.borrow_into(0, &|_| true), None, "backlogged donor keeps its nodes");
        f.check_conservation().unwrap();
    }

    #[test]
    fn borrowing_skips_disallowed_nodes() {
        // The scheduler passes a hold fence: a node carrying a planted
        // (forecast) reservation hold must not be whisked to a sibling.
        let mut f = fleet(8, &two_shard_cfg());
        for n in [0, 1, 2] {
            assert!(f.shards[1].nodes.lease(n));
        }
        assert_eq!(f.borrow_into(0, &|n| n != 2), Some(1), "held LIFO top skipped");
        assert_eq!(f.borrow_into(0, &|n| n != 2), Some(0));
        assert_eq!(f.borrow_into(0, &|n| n != 2), None, "only the held node is left");
        assert_eq!(f.borrows(), 2);
        f.check_conservation().unwrap();
    }

    #[test]
    fn borrowing_respects_capacity_class() {
        // Node 0 is narrow (64 cores), node 1 wide (128). A wide shard
        // only borrows nodes that fit its jobs.
        let cfg = FleetConfig {
            shards: vec![
                ShardConfig::named("general", 1, 0, 4).unwrap(),
                ShardConfig::named("wide", 1, 0, 4).unwrap(),
            ],
        };
        let mut f = PoolFleet::new(vec![64, 128], &cfg);
        assert!(f.shards[0].nodes.lease(0));
        assert!(f.shards[0].nodes.lease(1));
        assert_eq!(f.borrow_into(1, &|_| true), Some(1), "only the 128-core node fits");
        assert_eq!(f.borrow_into(1, &|_| true), None, "the 64-core node never transfers");
        f.check_conservation().unwrap();
    }

    #[test]
    fn fleet_peak_tracks_simultaneous_leases_not_shard_sums() {
        let mut f = fleet(8, &two_shard_cfg());
        // Shard 0 peaks at 3 leases, shrinks to 0, then shard 1 peaks
        // at 2: the true fleet peak is 3, not 5.
        for n in [0, 1, 2] {
            f.shards[0].nodes.lease(n);
        }
        f.note_peak();
        while f.shards[0].nodes.return_free().is_some() {}
        f.note_peak();
        f.shards[1].nodes.lease(3);
        f.shards[1].nodes.lease(4);
        f.note_peak();
        assert_eq!(f.peak_leased(), 3);
        let shard_sum: usize = f.shards.iter().map(|s| s.nodes.peak_leased()).sum();
        assert_eq!(shard_sum, 5, "per-shard peaks would overstate");
        f.check_conservation().unwrap();
    }

    #[test]
    fn release_estimate_tracks_the_soonest_freeing_shard() {
        // Floors at 0 so both shards are above min and may give nodes
        // back; the floor rule itself is pinned below.
        let cfg = FleetConfig {
            shards: vec![
                ShardConfig::named("general", 1, 0, 4).unwrap(),
                ShardConfig::named("large", 1, 0, 4).unwrap(),
            ],
        };
        let mut f = fleet(4, &cfg);
        assert_eq!(f.earliest_release_estimate(5.0), None, "empty fleet");
        // Shard 0: node 0 busy until 40; shard 1: node 1 busy until 12.
        f.shards[0].nodes.lease(0);
        f.shards[0].nodes.acquire();
        f.note_launch(0, 0, 40.0, 1);
        f.shards[1].nodes.lease(1);
        f.shards[1].nodes.acquire();
        f.note_launch(1, 1, 12.0, 2);
        assert_eq!(f.earliest_release_estimate(5.0), Some((1, 12.0)));
        // A backlogged shard is excluded even if it frees soonest.
        f.shards[1].pending.push_back(9);
        assert_eq!(f.earliest_release_estimate(5.0), Some((0, 40.0)));
        f.shards[1].pending.clear();
        // A free (idle) lease beats every busy forecast.
        f.note_release(1, 1);
        f.shards[1].nodes.release_task(1);
        assert_eq!(f.earliest_release_estimate(5.0), Some((1, 5.0)));
        // Past estimates clamp to now (re-launching on node 0 overwrites
        // its forecast slot in place).
        f.note_launch(0, 0, 1.0, 1);
        f.shards[1].nodes.acquire();
        f.note_launch(1, 1, 100.0, 3);
        assert_eq!(f.earliest_release_estimate(5.0), Some((0, 5.0)));
        f.check_conservation().unwrap();
    }

    #[test]
    fn release_estimate_skips_shards_pinned_at_their_floor() {
        // A shard at owned == min never shrinks: forecasting its nodes
        // would plant a hold that can never become ready.
        let cfg = FleetConfig {
            shards: vec![
                ShardConfig::named("general", 1, 1, 4).unwrap(),
                ShardConfig::named("large", 2, 0, 4).unwrap(),
            ],
        };
        let mut f = fleet(4, &cfg);
        f.shards[0].nodes.lease(0); // at its floor, idle
        assert_eq!(
            f.earliest_release_estimate(5.0),
            None,
            "pinned shard's free lease is not a release candidate"
        );
        f.shards[1].nodes.lease(1);
        f.shards[1].nodes.acquire();
        f.note_launch(1, 1, 30.0, 4);
        assert_eq!(
            f.earliest_release_estimate(5.0),
            Some((1, 30.0)),
            "only the above-floor shard forecasts"
        );
        f.check_conservation().unwrap();
    }

    #[test]
    fn launch_accounting_is_counters_plus_capped_ring() {
        // Launch-count-equivalence regression: the launch log used to be
        // two append-only Vecs — pure leak at 10M launches. Counters
        // must keep the exact totals while the debug ring stays bounded
        // and holds only the most recent launches.
        let mut f = fleet(4, &two_shard_cfg());
        f.shards[0].nodes.lease(0);
        let total = LAUNCH_RING_CAP as u64 + 7;
        for t in 0..total {
            f.note_launch(0, 0, 1.0, t);
            f.note_release(0, 0);
        }
        assert_eq!(f.launches(), total, "fleet counter counts every launch");
        assert_eq!(f.shards[0].launches, total, "shard counter counts every launch");
        assert_eq!(f.shards[1].launches, 0);
        assert_eq!(f.recent_launches().len(), LAUNCH_RING_CAP, "ring stays capped");
        assert_eq!(*f.recent_launches().front().unwrap(), 7, "oldest entries evicted");
        assert_eq!(*f.recent_launches().back().unwrap(), total - 1);
        f.check_conservation().unwrap();
    }

    #[test]
    fn release_clears_only_its_own_forecast_slot() {
        // The node-indexed forecast must behave exactly like the old
        // list under launch/release churn: a release drops one node's
        // entry, a re-launch overwrites in place.
        let mut f = fleet(4, &two_shard_cfg());
        for n in [0, 1, 2] {
            f.shards[0].nodes.lease(n);
            f.shards[0].nodes.acquire();
        }
        f.note_launch(0, 0, 10.0, 100);
        f.note_launch(0, 1, 20.0, 101);
        f.note_launch(0, 2, 30.0, 102);
        assert_eq!(f.shards[0].busy_forecast(), vec![(0, 10.0), (1, 20.0), (2, 30.0)]);
        f.note_release(0, 1);
        assert_eq!(f.shards[0].busy_forecast(), vec![(0, 10.0), (2, 30.0)]);
        f.note_launch(0, 0, 15.0, 103);
        assert_eq!(f.shards[0].busy_forecast(), vec![(0, 15.0), (2, 30.0)]);
        f.note_release(0, 0);
        f.note_release(0, 2);
        assert!(f.shards[0].busy_forecast().is_empty());
    }

    #[test]
    fn conservation_catches_double_ownership() {
        let mut f = fleet(4, &two_shard_cfg());
        f.shards[0].nodes.lease(2);
        f.check_conservation().unwrap();
        f.shards[1].nodes.lease(2);
        assert!(f.check_conservation().is_err(), "node 2 owned twice");
    }
}
