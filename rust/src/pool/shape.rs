//! Job-shape classification for the sharded pool fleet.
//!
//! Real rapid-launch partitions serve *heterogeneous* short workloads
//! side by side — CPU-core launches next to GPU/exclusive launches
//! ("Best of Both Worlds", arXiv:2008.02223) — and a single
//! undifferentiated pool lets one shape starve the other. The fleet
//! ([`crate::pool::fleet`]) therefore keys its shards by [`JobShape`]:
//! a rectangular classifier over **capacity class** (the task's
//! requested parallel width, `lanes`) and **walltime** (the declared
//! estimate). A whole-node task routes to the shard whose shape matches
//! it; shard shapes are validated pairwise-disjoint at config time so
//! routing is unambiguous ("Scalable System Scheduling for HPC and Big
//! Data", arXiv:1705.03102, partitions workloads the same way).

use crate::sim::Time;

/// A rectangular job classifier: lanes in `[min_lanes, max_lanes]` and
/// walltime estimate in `(min_walltime, max_walltime]`. The half-open
/// walltime band makes adjacent shards (e.g. `(0, 2]` and `(2, 60]`)
/// exactly disjoint at the boundary.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct JobShape {
    /// Smallest requested parallel width this shard serves (inclusive).
    /// Doubles as the shard's node capacity class: grow/bootstrap only
    /// lease nodes with at least this many cores.
    pub min_lanes: u32,
    /// Largest requested parallel width (inclusive); `u32::MAX` is
    /// unbounded.
    pub max_lanes: u32,
    /// Exclusive lower walltime bound, seconds.
    pub min_walltime: Time,
    /// Inclusive upper walltime bound, seconds.
    pub max_walltime: Time,
}

impl JobShape {
    /// The legacy single-pool classifier: any width, walltime in
    /// `(0, threshold]` — exactly PR 4's `est_duration <= threshold`
    /// test (estimates are strictly positive by construction).
    pub fn up_to(threshold: Time) -> JobShape {
        JobShape {
            min_lanes: 0,
            max_lanes: u32::MAX,
            min_walltime: 0.0,
            max_walltime: threshold,
        }
    }

    /// Named shapes for config files and the CLI (`shape = "general"`):
    ///
    /// * `general` — narrow rapid launches: lanes ≤ 64, walltime ≤ 2 s;
    /// * `large` — heavier short jobs (the "GPU-ish" batch-of-one
    ///   style): any width, walltime in (2, 60] s;
    /// * `wide` — wide-node capacity class: lanes ≥ 65, walltime ≤ 2 s
    ///   (pairs with `general`, not with `large`).
    pub fn named(name: &str) -> Option<JobShape> {
        match name {
            "general" => Some(JobShape {
                min_lanes: 0,
                max_lanes: 64,
                min_walltime: 0.0,
                max_walltime: 2.0,
            }),
            "large" => Some(JobShape {
                min_lanes: 0,
                max_lanes: u32::MAX,
                min_walltime: 2.0,
                max_walltime: 60.0,
            }),
            "wide" => Some(JobShape {
                min_lanes: 65,
                max_lanes: u32::MAX,
                min_walltime: 0.0,
                max_walltime: 2.0,
            }),
            "short" => Some(JobShape::up_to(crate::pool::DEFAULT_SHORT_THRESHOLD)),
            _ => None,
        }
    }

    /// Whether a task of the given width and walltime estimate belongs
    /// to this shard.
    pub fn matches(&self, lanes: u32, est_walltime: Time) -> bool {
        lanes >= self.min_lanes
            && lanes <= self.max_lanes
            && est_walltime > self.min_walltime
            && est_walltime <= self.max_walltime
    }

    /// Whether a node of `capacity` cores can serve this shard's jobs
    /// (the capacity-class side of the classifier: a shard for wide
    /// jobs must not lease narrow nodes).
    pub fn node_fits(&self, capacity: u32) -> bool {
        capacity >= self.min_lanes
    }

    /// Whether two shapes claim any common job — the bug guard: two
    /// shards with overlapping shapes would make routing order-dependent,
    /// so fleet validation rejects them outright.
    pub fn overlaps(&self, other: &JobShape) -> bool {
        let lanes = self.min_lanes.max(other.min_lanes) <= self.max_lanes.min(other.max_lanes);
        let wall =
            self.min_walltime.max(other.min_walltime) < self.max_walltime.min(other.max_walltime);
        lanes && wall
    }

    /// Structural sanity: non-empty bands.
    pub fn validate(&self) -> std::result::Result<(), String> {
        if self.min_lanes > self.max_lanes {
            return Err(format!(
                "shape lanes band [{}, {}] is empty",
                self.min_lanes, self.max_lanes
            ));
        }
        if !(self.max_walltime > self.min_walltime) || self.min_walltime < 0.0 {
            return Err(format!(
                "shape walltime band ({}, {}] is empty or negative",
                self.min_walltime, self.max_walltime
            ));
        }
        Ok(())
    }
}

impl std::fmt::Display for JobShape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.max_lanes == u32::MAX {
            write!(f, "lanes {}+", self.min_lanes)?;
        } else {
            write!(f, "lanes {}..={}", self.min_lanes, self.max_lanes)?;
        }
        write!(f, " x walltime ({}, {}]s", self.min_walltime, self.max_walltime)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn legacy_shape_matches_threshold_rule() {
        let s = JobShape::up_to(30.0);
        assert!(s.matches(64, 0.5));
        assert!(s.matches(1, 30.0), "boundary is inclusive");
        assert!(!s.matches(64, 30.1));
        assert!(s.node_fits(1) && s.node_fits(64));
        assert!(s.validate().is_ok());
    }

    #[test]
    fn named_shapes_resolve_and_partition() {
        let g = JobShape::named("general").unwrap();
        let l = JobShape::named("large").unwrap();
        let w = JobShape::named("wide").unwrap();
        assert!(JobShape::named("bogus").is_none());
        // The burst_mixed families route unambiguously.
        assert!(g.matches(64, 0.5) && !l.matches(64, 0.5));
        assert!(l.matches(64, 45.0) && !g.matches(64, 45.0));
        assert!(g.matches(64, 2.0) && !l.matches(64, 2.0), "2 s is general's boundary");
        // The capacity-class shape takes wide jobs general refuses.
        assert!(w.matches(128, 0.5) && !g.matches(128, 0.5));
        assert!(!w.node_fits(64) && w.node_fits(128), "wide shard leases wide nodes only");
        // Disjoint pairs do not overlap; large/wide genuinely do.
        assert!(!g.overlaps(&l) && !l.overlaps(&g));
        assert!(!g.overlaps(&w) && !w.overlaps(&g));
        assert!(l.overlaps(&w));
    }

    #[test]
    fn overlap_is_two_dimensional() {
        let a = JobShape { min_lanes: 0, max_lanes: 64, min_walltime: 0.0, max_walltime: 10.0 };
        // Same walltime band, disjoint lanes: no overlap.
        let b = JobShape { min_lanes: 65, max_lanes: 128, ..a };
        assert!(!a.overlaps(&b));
        // Same lanes, adjacent walltime bands: the shared boundary point
        // belongs to the lower band only, so no overlap.
        let c = JobShape { min_walltime: 10.0, max_walltime: 20.0, ..a };
        assert!(!a.overlaps(&c) && !c.overlaps(&a));
        // Genuine intersection in both dimensions.
        let d = JobShape { min_lanes: 32, max_lanes: 128, min_walltime: 5.0, max_walltime: 15.0 };
        assert!(a.overlaps(&d) && d.overlaps(&a));
    }

    #[test]
    fn degenerate_shapes_rejected() {
        let mut s = JobShape::up_to(30.0);
        s.min_lanes = 10;
        s.max_lanes = 5;
        assert!(s.validate().is_err(), "empty lanes band");
        let mut s = JobShape::up_to(30.0);
        s.min_walltime = 30.0;
        assert!(s.validate().is_err(), "empty walltime band");
        let mut s = JobShape::up_to(30.0);
        s.min_walltime = -1.0;
        assert!(s.validate().is_err(), "negative walltime bound");
    }
}
