//! The hysteresis controller that elastically sizes the pool.
//!
//! Pressure signals: the pool-queue backlog (demand) against the pool's
//! free-plus-incoming capacity (supply); batch pressure is represented
//! implicitly — the pool only ever grows by taking nodes the batch side
//! is not running work on (idle leases) or has been asked to vacate
//! (drains), and shrinking hands drained nodes straight back to batch.
//! A dead band proportional to the current pool size plus a cooldown
//! between resize operations keep the partition from thrashing when
//! demand hovers around capacity ("Best of Both Worlds",
//! arXiv:2008.02223, resizes its rapid-launch partition the same way).

use crate::sim::Time;

/// One resize decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Resize {
    /// Take this many more nodes (lease idle batch nodes, else drain
    /// busy ones).
    Grow(usize),
    /// Return this many drained (idle) pool nodes to batch.
    Shrink(usize),
    /// Inside the dead band: do nothing.
    Hold,
}

/// The pool-size controller.
#[derive(Debug, Clone)]
pub struct PoolManager {
    /// Never shrink below this many pool-owned nodes.
    pub min: usize,
    /// Never grow beyond this many pool-owned nodes.
    pub max: usize,
    /// Dead-band fraction in `[0, 1)` (see [`Self::dead_band`]).
    pub hysteresis: f64,
    /// Minimum virtual time between resize operations.
    pub cooldown: Time,
    last_resize: Time,
    grows: u64,
    shrinks: u64,
}

impl PoolManager {
    /// Controller with a 1-second resize cooldown.
    pub fn new(min: usize, max: usize, hysteresis: f64) -> PoolManager {
        PoolManager {
            min,
            max,
            hysteresis,
            cooldown: 1.0,
            last_resize: f64::NEG_INFINITY,
            grows: 0,
            shrinks: 0,
        }
    }

    /// Whether enough time has passed since the last resize operation.
    pub fn due(&self, now: Time) -> bool {
        now - self.last_resize >= self.cooldown
    }

    /// Note that a resize operation ran (arms the cooldown even when it
    /// changed nothing, so a blocked grow does not busy-spin the server).
    pub fn note_resize(&mut self, now: Time) {
        self.last_resize = now;
    }

    /// The dead band at a given pool size: demand or surplus must
    /// exceed it before the controller acts.
    pub fn dead_band(&self, owned: usize) -> usize {
        (self.hysteresis * owned as f64).ceil() as usize
    }

    /// Decide a resize from the current pressure readings.
    ///
    /// * `queued` — pool-queue backlog (tasks waiting for a node);
    /// * `free` — idle leased nodes;
    /// * `leased` / `draining` — current membership counts.
    ///
    /// Draining nodes count as capacity already in flight, so repeated
    /// decisions under a sustained backlog do not over-drain batch.
    pub fn decide(&self, queued: usize, free: usize, leased: usize, draining: usize) -> Resize {
        let owned = leased + draining;
        // Below the floor: always grow back (bootstrap / post-churn).
        if owned < self.min {
            return Resize::Grow((self.min - owned).min(self.max.saturating_sub(owned)));
        }
        let band = self.dead_band(owned);
        let in_flight = free + draining;
        if queued > in_flight + band && owned < self.max {
            let want = (queued - in_flight).min(self.max - owned);
            if want > 0 {
                return Resize::Grow(want);
            }
        }
        if queued == 0 && owned > self.min && free > band {
            let give = (free - band).min(owned - self.min);
            if give > 0 {
                return Resize::Shrink(give);
            }
        }
        Resize::Hold
    }

    /// Account `n` nodes grown (leased or drained) by one resize op.
    pub fn record_grow(&mut self, n: usize) {
        self.grows += n as u64;
    }

    /// Account `n` nodes returned to batch by one resize op.
    pub fn record_shrink(&mut self, n: usize) {
        self.shrinks += n as u64;
    }

    /// Total nodes grown over the run.
    pub fn grows(&self) -> u64 {
        self.grows
    }

    /// Total nodes shrunk over the run.
    pub fn shrinks(&self) -> u64 {
        self.shrinks
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mgr(min: usize, max: usize, h: f64) -> PoolManager {
        PoolManager::new(min, max, h)
    }

    #[test]
    fn cooldown_gates_resizes() {
        let mut m = mgr(0, 8, 0.25);
        assert!(m.due(0.0), "first resize is always due");
        m.note_resize(10.0);
        assert!(!m.due(10.5));
        assert!(m.due(11.0));
    }

    #[test]
    fn grows_under_queue_pressure() {
        let m = mgr(0, 16, 0.25);
        // 8 leased, 2 free, band = 2: backlog of 10 exceeds 2 + 2.
        assert_eq!(m.decide(10, 2, 8, 0), Resize::Grow(8));
        // Backlog inside the dead band: hold.
        assert_eq!(m.decide(4, 2, 8, 0), Resize::Hold);
        // Draining nodes damp growth (capacity already in flight):
        // backlog 20 vs 2 free + 6 incoming, band 4 → only 2 more fit
        // under the 16-node cap.
        assert_eq!(m.decide(20, 2, 8, 6), Resize::Grow(2));
        assert_eq!(m.decide(20, 2, 8, 8), Resize::Hold, "at max");
    }

    #[test]
    fn grow_is_capped_at_max() {
        let m = mgr(0, 10, 0.0);
        assert_eq!(m.decide(100, 0, 8, 0), Resize::Grow(2));
        assert_eq!(m.decide(100, 0, 10, 0), Resize::Hold);
    }

    #[test]
    fn empty_pool_with_backlog_grows() {
        // Regression bait: an empty pool must bootstrap itself out of a
        // backlog (band is 0 at owned = 0), or queued tasks starve.
        let m = mgr(0, 8, 0.5);
        assert_eq!(m.decide(1, 0, 0, 0), Resize::Grow(1));
    }

    #[test]
    fn shrinks_when_idle_beyond_the_band() {
        let m = mgr(2, 16, 0.25);
        // 8 leased, all free, queue empty, band 2: give back 6 — but the
        // floor keeps 2, so give 6 and land at min.
        assert_eq!(m.decide(0, 8, 8, 0), Resize::Shrink(6));
        // Free inside the band: hold.
        assert_eq!(m.decide(0, 2, 8, 0), Resize::Hold);
        // Any backlog blocks shrinking.
        assert_eq!(m.decide(1, 8, 8, 0), Resize::Hold);
        // Never below the floor.
        assert_eq!(m.decide(0, 2, 2, 0), Resize::Hold);
    }

    #[test]
    fn below_min_always_grows_back() {
        let m = mgr(4, 8, 0.25);
        assert_eq!(m.decide(0, 0, 1, 0), Resize::Grow(3));
        assert_eq!(m.decide(0, 0, 1, 2), Resize::Grow(1), "drains count");
    }

    #[test]
    fn resize_accounting() {
        let mut m = mgr(0, 8, 0.25);
        m.record_grow(3);
        m.record_grow(2);
        m.record_shrink(4);
        assert_eq!(m.grows(), 5);
        assert_eq!(m.shrinks(), 4);
    }

    #[test]
    fn dead_band_scales_with_pool_size() {
        let m = mgr(0, 64, 0.25);
        assert_eq!(m.dead_band(0), 0);
        assert_eq!(m.dead_band(4), 1);
        assert_eq!(m.dead_band(16), 4);
        let greedy = mgr(0, 64, 0.0);
        assert_eq!(greedy.dead_band(16), 0, "zero hysteresis = no band");
    }
}
