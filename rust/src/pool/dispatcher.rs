//! The node-based dispatch hot path: launch = pop a node off the pool's
//! free list, release = push it back. O(1) per job, no placement engine,
//! no per-core bookkeeping — this is the mechanism behind the paper's
//! "up to 100× faster scheduler performance" for short-job fleets, and
//! `benches/bench_pool.rs` measures exactly this path against full
//! placement.

use crate::cluster::NodeId;
use crate::pool::node_pool::NodePool;

/// Launch/release counters over a [`NodePool`].
#[derive(Debug, Clone, Default)]
pub struct NodeDispatcher {
    launches: u64,
    releases: u64,
}

impl NodeDispatcher {
    pub fn new() -> NodeDispatcher {
        NodeDispatcher::default()
    }

    /// Acquire a whole node for one short job. `None` when every leased
    /// node is busy (the job waits in the pool queue).
    pub fn launch(&mut self, pool: &mut NodePool) -> Option<NodeId> {
        let node = pool.acquire()?;
        self.launches += 1;
        Some(node)
    }

    /// Return a finished job's node to the free list.
    pub fn release(&mut self, pool: &mut NodePool, node: NodeId) -> bool {
        if pool.release_task(node) {
            self.releases += 1;
            true
        } else {
            false
        }
    }

    /// Jobs launched so far.
    pub fn launches(&self) -> u64 {
        self.launches
    }

    /// Jobs released so far.
    pub fn releases(&self) -> u64 {
        self.releases
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn launch_release_counts() {
        let mut pool = NodePool::new(3);
        pool.lease(0);
        pool.lease(1);
        let mut d = NodeDispatcher::new();
        let a = d.launch(&mut pool).unwrap();
        let b = d.launch(&mut pool).unwrap();
        assert_ne!(a, b);
        assert!(d.launch(&mut pool).is_none(), "pool exhausted");
        assert_eq!(d.launches(), 2);
        assert!(d.release(&mut pool, a));
        assert!(!d.release(&mut pool, 2), "batch node refused");
        assert_eq!(d.releases(), 1);
        assert_eq!(d.launch(&mut pool), Some(a), "freed node relaunches");
        assert!(d.release(&mut pool, a));
        assert!(d.release(&mut pool, b));
        pool.check_conservation().unwrap();
    }
}
