//! Pool membership bookkeeping: batch / leased / draining, plus the
//! O(1) free list the node-based dispatch path pops.
//!
//! The conservation invariant the property suite pins down
//! (`rust/tests/pool_properties.rs`): at every step, every node is in
//! exactly one of the three membership states, the counters agree with
//! the membership table, and the free list holds exactly the idle
//! leased nodes. All mutators are total — an illegal transition returns
//! `false` and changes nothing, so a confused caller can never corrupt
//! the accounting (the scheduler surfaces refusals as an invariant
//! flag in [`crate::scheduler::core::SimOutcome`]).

use crate::cluster::NodeId;

/// Which side of the partition a node is on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Membership {
    /// Owned by the general (batch) scheduler.
    Batch,
    /// Earmarked for the pool, still finishing batch work; fenced from
    /// new batch placements, promoted to [`Membership::Leased`] when it
    /// goes wholly idle.
    Draining,
    /// In the pool, serving (or ready to serve) rapid-launch jobs.
    Leased,
}

/// The node pool: membership table + idle free list.
#[derive(Debug, Clone)]
pub struct NodePool {
    membership: Vec<Membership>,
    /// Idle leased nodes, LIFO (pop to launch, push on release).
    free: Vec<NodeId>,
    /// `in_free[n]` mirrors free-list membership for O(1) checks.
    in_free: Vec<bool>,
    leased: usize,
    draining: usize,
    peak_leased: usize,
}

impl NodePool {
    /// A pool over `n_nodes` nodes, all initially batch-owned.
    pub fn new(n_nodes: usize) -> NodePool {
        NodePool {
            membership: vec![Membership::Batch; n_nodes],
            free: Vec::new(),
            in_free: vec![false; n_nodes],
            leased: 0,
            draining: 0,
            peak_leased: 0,
        }
    }

    /// Number of nodes the pool tracks (the whole cluster).
    pub fn n_nodes(&self) -> usize {
        self.membership.len()
    }

    /// Membership of one node.
    pub fn membership(&self, node: NodeId) -> Membership {
        self.membership[node as usize]
    }

    /// Whether `node` belongs to the pool side of the partition (leased
    /// or draining) — the fence predicate every batch placement query
    /// applies.
    pub fn in_pool(&self, node: NodeId) -> bool {
        self.membership[node as usize] != Membership::Batch
    }

    /// Whether `node` is currently leased.
    pub fn is_leased(&self, node: NodeId) -> bool {
        self.membership[node as usize] == Membership::Leased
    }

    /// Whether `node` is draining toward the pool.
    pub fn is_draining(&self, node: NodeId) -> bool {
        self.membership[node as usize] == Membership::Draining
    }

    /// Whether any node is pool-owned at all (cheap "is the fence
    /// active" check for the dispatch hot path).
    pub fn any_pooled(&self) -> bool {
        self.leased + self.draining > 0
    }

    /// Leased nodes.
    pub fn n_leased(&self) -> usize {
        self.leased
    }

    /// Draining nodes.
    pub fn n_draining(&self) -> usize {
        self.draining
    }

    /// Idle leased nodes (free-list length).
    pub fn n_free(&self) -> usize {
        self.free.len()
    }

    /// Leased nodes currently running pool work.
    pub fn n_busy(&self) -> usize {
        self.leased - self.free.len()
    }

    /// Batch-owned nodes.
    pub fn n_batch(&self) -> usize {
        self.n_nodes() - self.leased - self.draining
    }

    /// Peak lease count over the pool's lifetime.
    pub fn peak_leased(&self) -> usize {
        self.peak_leased
    }

    /// Lease an *idle* batch node into the pool (batch → leased; joins
    /// the free list). The caller is responsible for only leasing nodes
    /// with no batch work on them.
    pub fn lease(&mut self, node: NodeId) -> bool {
        if self.membership[node as usize] != Membership::Batch {
            return false;
        }
        self.membership[node as usize] = Membership::Leased;
        self.leased += 1;
        self.free.push(node);
        self.in_free[node as usize] = true;
        if self.leased > self.peak_leased {
            self.peak_leased = self.leased;
        }
        true
    }

    /// Earmark a *busy* batch node for the pool (batch → draining): no
    /// new batch work lands on it, and [`Self::promote`] moves it into
    /// the pool once its running batch tasks have released.
    pub fn begin_drain(&mut self, node: NodeId) -> bool {
        if self.membership[node as usize] != Membership::Batch {
            return false;
        }
        self.membership[node as usize] = Membership::Draining;
        self.draining += 1;
        true
    }

    /// A draining node went wholly idle: it joins the pool
    /// (draining → leased, onto the free list).
    pub fn promote(&mut self, node: NodeId) -> bool {
        if self.membership[node as usize] != Membership::Draining {
            return false;
        }
        self.membership[node as usize] = Membership::Leased;
        self.draining -= 1;
        self.leased += 1;
        self.free.push(node);
        self.in_free[node as usize] = true;
        if self.leased > self.peak_leased {
            self.peak_leased = self.leased;
        }
        true
    }

    /// Abort a pending drain (draining → batch) — a shrink decision
    /// arrived before the node ever went idle.
    pub fn cancel_drain(&mut self, node: NodeId) -> bool {
        if self.membership[node as usize] != Membership::Draining {
            return false;
        }
        self.membership[node as usize] = Membership::Batch;
        self.draining -= 1;
        true
    }

    /// Pop an idle leased node to run a pool job on (O(1); the node
    /// stays leased, just off the free list).
    pub fn acquire(&mut self) -> Option<NodeId> {
        let node = self.free.pop()?;
        self.in_free[node as usize] = false;
        Some(node)
    }

    /// A pool job on `node` released it: back onto the free list (O(1)).
    pub fn release_task(&mut self, node: NodeId) -> bool {
        if self.membership[node as usize] != Membership::Leased || self.in_free[node as usize] {
            return false;
        }
        self.free.push(node);
        self.in_free[node as usize] = true;
        true
    }

    /// Return one drained (idle) pool node to the batch scheduler
    /// (leased → batch) — the shrink path.
    pub fn return_free(&mut self) -> Option<NodeId> {
        let node = self.free.pop()?;
        self.in_free[node as usize] = false;
        self.membership[node as usize] = Membership::Batch;
        self.leased -= 1;
        Some(node)
    }

    /// The idle leased nodes, in free-list (LIFO push) order — read by
    /// the fleet rebalancer to pick a capacity-fitting donation.
    pub fn free_nodes(&self) -> &[NodeId] {
        &self.free
    }

    /// Return one *specific* idle leased node to batch (leased → batch)
    /// — the cross-shard transfer path: the fleet hands the node
    /// straight to a sibling shard's `lease`. Refused unless the node
    /// is leased and idle.
    pub fn return_node(&mut self, node: NodeId) -> bool {
        if self.membership[node as usize] != Membership::Leased || !self.in_free[node as usize] {
            return false;
        }
        let i = self
            .free
            .iter()
            .position(|&n| n == node)
            .expect("in_free mirrors the free list");
        self.free.swap_remove(i);
        self.in_free[node as usize] = false;
        self.membership[node as usize] = Membership::Batch;
        self.leased -= 1;
        true
    }

    /// Force `node` out of the pool whatever its state — the fault
    /// path (a leased node died or was reclaimed; there is no graceful
    /// drain to wait for). An idle lease leaves the free list, a busy
    /// lease just drops its membership (the running task is the
    /// caller's problem), a draining node loses its earmark. Returns
    /// `false` for batch nodes (nothing to evict).
    pub fn evict(&mut self, node: NodeId) -> bool {
        match self.membership[node as usize] {
            Membership::Batch => false,
            Membership::Leased => {
                if self.in_free[node as usize] {
                    let i = self
                        .free
                        .iter()
                        .position(|&n| n == node)
                        .expect("in_free mirrors the free list");
                    self.free.swap_remove(i);
                    self.in_free[node as usize] = false;
                }
                self.membership[node as usize] = Membership::Batch;
                self.leased -= 1;
                true
            }
            Membership::Draining => {
                self.membership[node as usize] = Membership::Batch;
                self.draining -= 1;
                true
            }
        }
    }

    /// Any draining node, for shrink-time drain cancellation.
    pub fn any_draining(&self) -> Option<NodeId> {
        if self.draining == 0 {
            return None;
        }
        self.membership
            .iter()
            .position(|&m| m == Membership::Draining)
            .map(|i| i as NodeId)
    }

    /// Verify the conservation invariant: membership counts match the
    /// counters (batch + leased + draining == cluster), and the free
    /// list holds distinct leased nodes mirrored by `in_free`.
    pub fn check_conservation(&self) -> std::result::Result<(), String> {
        let mut leased = 0usize;
        let mut draining = 0usize;
        for &m in &self.membership {
            match m {
                Membership::Leased => leased += 1,
                Membership::Draining => draining += 1,
                Membership::Batch => {}
            }
        }
        if leased != self.leased || draining != self.draining {
            return Err(format!(
                "counters ({}, {}) disagree with membership ({leased}, {draining})",
                self.leased, self.draining
            ));
        }
        if self.free.len() > self.leased {
            return Err(format!(
                "{} free entries exceed {} leases",
                self.free.len(),
                self.leased
            ));
        }
        let mut seen = vec![false; self.membership.len()];
        for &n in &self.free {
            let i = n as usize;
            if self.membership[i] != Membership::Leased {
                return Err(format!("free-list node {n} is not leased"));
            }
            if seen[i] {
                return Err(format!("free-list node {n} appears twice"));
            }
            seen[i] = true;
            if !self.in_free[i] {
                return Err(format!("free-list node {n} not mirrored in in_free"));
            }
        }
        for (i, &f) in self.in_free.iter().enumerate() {
            if f && !seen[i] {
                return Err(format!("in_free[{i}] set but node absent from free list"));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn checked(p: &NodePool) {
        p.check_conservation().unwrap();
    }

    #[test]
    fn fresh_pool_is_all_batch() {
        let p = NodePool::new(4);
        assert_eq!(p.n_batch(), 4);
        assert_eq!(p.n_leased(), 0);
        assert_eq!(p.n_draining(), 0);
        assert!(!p.any_pooled());
        assert!(!p.in_pool(0));
        checked(&p);
    }

    #[test]
    fn lease_acquire_release_roundtrip() {
        let mut p = NodePool::new(4);
        assert!(p.lease(1));
        assert!(p.lease(2));
        assert!(!p.lease(1), "double lease refused");
        assert_eq!(p.n_leased(), 2);
        assert_eq!(p.n_free(), 2);
        assert!(p.in_pool(1) && p.is_leased(1));
        checked(&p);
        // LIFO: the most recently freed node launches first.
        assert_eq!(p.acquire(), Some(2));
        assert_eq!(p.n_busy(), 1);
        assert!(!p.release_task(3), "release of a batch node refused");
        assert!(!p.release_task(1), "release of an already-free node refused");
        assert!(p.release_task(2));
        assert_eq!(p.n_free(), 2);
        assert_eq!(p.peak_leased(), 2);
        checked(&p);
    }

    #[test]
    fn acquire_exhausts_then_none() {
        let mut p = NodePool::new(2);
        p.lease(0);
        assert!(p.acquire().is_some());
        assert!(p.acquire().is_none(), "no idle leased node left");
        checked(&p);
    }

    #[test]
    fn drain_promote_lifecycle() {
        let mut p = NodePool::new(3);
        assert!(p.begin_drain(0));
        assert!(!p.begin_drain(0), "double drain refused");
        assert!(p.in_pool(0) && p.is_draining(0) && !p.is_leased(0));
        assert_eq!(p.n_draining(), 1);
        assert_eq!(p.n_free(), 0, "draining nodes are not dispatchable");
        assert_eq!(p.any_draining(), Some(0));
        checked(&p);
        assert!(p.promote(0));
        assert!(!p.promote(0), "already leased");
        assert_eq!(p.n_leased(), 1);
        assert_eq!(p.n_free(), 1);
        assert_eq!(p.any_draining(), None);
        checked(&p);
    }

    #[test]
    fn cancel_drain_returns_to_batch() {
        let mut p = NodePool::new(2);
        p.begin_drain(1);
        assert!(p.cancel_drain(1));
        assert!(!p.cancel_drain(1));
        assert!(!p.in_pool(1));
        assert_eq!(p.n_batch(), 2);
        checked(&p);
    }

    #[test]
    fn evict_idle_lease_leaves_free_list() {
        let mut p = NodePool::new(4);
        p.lease(1);
        p.lease(2);
        assert!(p.evict(1), "idle lease evicted");
        assert!(!p.in_pool(1));
        assert_eq!(p.n_leased(), 1);
        assert_eq!(p.n_free(), 1, "evicted node left the free list");
        checked(&p);
        // The evicted node is batch again and can be re-leased — the
        // fleet's re-grow path after the node recovers.
        assert!(p.lease(1));
        assert_eq!(p.n_leased(), 2);
        checked(&p);
    }

    #[test]
    fn evict_busy_lease_drops_membership_only() {
        let mut p = NodePool::new(3);
        p.lease(0);
        assert_eq!(p.acquire(), Some(0), "node 0 goes busy");
        assert!(p.evict(0), "busy lease evicted");
        assert_eq!(p.n_leased(), 0);
        assert_eq!(p.n_free(), 0);
        assert!(!p.in_pool(0));
        checked(&p);
        // The kill already tore the task down; a stray release of the
        // now-batch node must be refused, not corrupt the accounting.
        assert!(!p.release_task(0), "release after evict refused");
        checked(&p);
    }

    #[test]
    fn evict_draining_node_loses_earmark() {
        let mut p = NodePool::new(2);
        p.begin_drain(1);
        assert!(p.evict(1), "draining node evicted");
        assert_eq!(p.n_draining(), 0);
        assert!(!p.in_pool(1));
        assert!(!p.promote(1), "promote after evict refused");
        checked(&p);
    }

    #[test]
    fn evict_batch_node_is_a_no_op() {
        let mut p = NodePool::new(2);
        assert!(!p.evict(0), "nothing to evict");
        assert_eq!(p.n_batch(), 2);
        checked(&p);
    }

    #[test]
    fn shrink_returns_free_nodes_only() {
        let mut p = NodePool::new(3);
        p.lease(0);
        p.lease(1);
        let busy = p.acquire().unwrap();
        assert_eq!(busy, 1);
        // Only node 0 idles; shrink returns it, not the busy one.
        assert_eq!(p.return_free(), Some(0));
        assert!(!p.in_pool(0));
        assert_eq!(p.n_leased(), 1);
        assert_eq!(p.return_free(), None, "busy lease cannot be returned");
        checked(&p);
        // The busy node releases and can then be returned.
        assert!(p.release_task(1));
        assert_eq!(p.return_free(), Some(1));
        assert!(!p.any_pooled());
        checked(&p);
    }

    #[test]
    fn return_node_transfers_specific_free_leases() {
        let mut p = NodePool::new(4);
        p.lease(0);
        p.lease(1);
        p.lease(2);
        assert_eq!(p.free_nodes(), &[0, 1, 2]);
        // A busy lease and a batch node both refuse.
        let busy = p.acquire().unwrap();
        assert_eq!(busy, 2);
        assert!(!p.return_node(2), "busy lease refused");
        assert!(!p.return_node(3), "batch node refused");
        // A specific idle lease (not the LIFO top) returns cleanly.
        assert!(p.return_node(0));
        assert!(!p.in_pool(0));
        assert_eq!(p.n_leased(), 2);
        assert_eq!(p.n_free(), 1);
        assert!(!p.return_node(0), "already batch");
        checked(&p);
    }

    #[test]
    fn peak_tracks_high_water_mark() {
        let mut p = NodePool::new(4);
        for n in 0..4 {
            p.lease(n);
        }
        for _ in 0..3 {
            p.return_free();
        }
        assert_eq!(p.n_leased(), 1);
        assert_eq!(p.peak_leased(), 4);
        checked(&p);
    }
}
