//! The elastic rapid-launch node pool.
//!
//! The paper's headline mechanism is a dedicated pool of whole nodes
//! managed with *node-based* scheduling: large fleets of short jobs
//! launch and release in O(nodes) work instead of going through full
//! per-core placement, which is what delivers the "up to 100× faster
//! scheduler performance" claim. "Best of Both Worlds" (arXiv:2008.02223)
//! shows the same cluster must serve batch and rapid-launch traffic
//! simultaneously through a *dynamically sized* partition, and "Scalable
//! System Scheduling for HPC and Big Data" (arXiv:1705.03102) motivates
//! bypassing the general scheduler on the hot path.
//!
//! This module is that subsystem:
//!
//! * [`NodePool`] — membership bookkeeping over the cluster: every node
//!   is exactly one of **batch** (owned by the general scheduler),
//!   **leased** (in the pool) or **draining** (earmarked for the pool,
//!   still finishing batch work). Idle leased nodes sit on a LIFO free
//!   list, so acquiring and returning a node for a short job is O(1) —
//!   no `PlacementEngine`, no per-core bookkeeping ([`node_pool`]);
//! * [`NodeDispatcher`] — the node-based dispatch hot path: pop a node
//!   off the free list to launch, push it back on release ([`dispatcher`]);
//! * [`PoolManager`] — the hysteresis controller that elastically
//!   resizes the pool: grow by draining batch nodes as they go idle
//!   when pool-queue pressure exceeds free pool capacity, shrink by
//!   returning drained pool nodes when the queue is empty, with a
//!   dead band and a cooldown so the partition does not thrash
//!   ([`manager`]);
//! * [`JobShape`] / [`PoolFleet`] — the shape-sharded fleet layer:
//!   several pools keyed by capacity class + walltime, each shard with
//!   its own membership table, dispatcher and controller, plus a
//!   fleet-level rebalancer (sibling-free → lease-idle → drain-busy),
//!   a drain forecast for pool-aware hold planning, and one fleet-wide
//!   conservation invariant ([`shape`], [`fleet`]).
//!
//! The scheduler integration lives in [`crate::scheduler`]: jobs
//! classified short-whole-node route to the pool queue at registration,
//! `Op::Pool*` server operations service it ahead of the batch
//! machinery, and leased/draining nodes are fenced out of every batch
//! placement and backfill-hold query through the existing `_where`
//! filters of the [`crate::placement`] engine.

pub mod dispatcher;
pub mod fleet;
pub mod manager;
pub mod node_pool;
pub mod shape;

pub use dispatcher::NodeDispatcher;
pub use fleet::{FleetConfig, PoolFleet, Shard, ShardConfig, ShardId};
pub use manager::{PoolManager, Resize};
pub use node_pool::{Membership, NodePool};
pub use shape::JobShape;

use crate::sim::Time;

/// Whole-node tasks with an estimated duration at or below this route to
/// the pool by default (seconds). The paper's "short running jobs" are
/// seconds-to-a-minute; long whole-node work stays on the batch path.
pub const DEFAULT_SHORT_THRESHOLD: Time = 30.0;

/// Rapid-launch pool configuration, as threaded through config files
/// (`pool_size = 8`), presets and CLI flags.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PoolConfig {
    /// Initial lease count; `0` disables the pool entirely (the batch
    /// scheduler then behaves bit-for-bit as if this subsystem did not
    /// exist).
    pub size: usize,
    /// The manager never shrinks below this many pool-owned nodes.
    pub min: usize,
    /// The manager never grows beyond this many pool-owned nodes;
    /// `0` means "same as `size`" (a fixed, non-elastic pool).
    pub max: usize,
    /// Hysteresis dead-band fraction in `[0, 1)`: grow only when the
    /// pool-queue backlog exceeds free-plus-incoming capacity by more
    /// than `ceil(hysteresis × owned)` nodes, shrink only when at least
    /// that many leased nodes idle with an empty queue.
    pub hysteresis: f64,
    /// Whole-node tasks with an estimated duration at or below this are
    /// classified short and routed to the pool.
    pub short_threshold: Time,
}

impl PoolConfig {
    /// The disabled pool (the default everywhere).
    pub fn disabled() -> PoolConfig {
        PoolConfig {
            size: 0,
            min: 0,
            max: 0,
            hysteresis: 0.25,
            short_threshold: DEFAULT_SHORT_THRESHOLD,
        }
    }

    /// An elastic pool starting at `size` leases with default bounds
    /// (`min = size / 2`, `max = 2 × size`).
    pub fn sized(size: usize) -> PoolConfig {
        PoolConfig {
            size,
            min: size / 2,
            max: size * 2,
            ..PoolConfig::disabled()
        }
    }

    /// Whether the pool participates at all.
    pub fn enabled(&self) -> bool {
        self.size > 0
    }

    /// The resolved upper bound (`max`, or `size` when `max` is 0).
    pub fn effective_max(&self) -> usize {
        self.max.max(self.size)
    }

    /// The resolved lower bound (never above the upper bound).
    pub fn effective_min(&self) -> usize {
        self.min.min(self.effective_max())
    }

    /// Range checks shared by the config file and CLI paths.
    pub fn validate(&self) -> std::result::Result<(), String> {
        if !(0.0..1.0).contains(&self.hysteresis) {
            return Err(format!(
                "pool hysteresis must be in [0, 1), got {}",
                self.hysteresis
            ));
        }
        if self.short_threshold <= 0.0 {
            return Err("pool short-job threshold must be > 0".into());
        }
        if self.enabled() && self.max != 0 && self.max < self.size {
            return Err(format!(
                "pool_max {} below pool_size {} (use pool_max = 0 for a fixed pool)",
                self.max, self.size
            ));
        }
        if self.enabled() && self.min > self.effective_max() {
            return Err(format!(
                "pool_min {} exceeds pool_max {}",
                self.min,
                self.effective_max()
            ));
        }
        Ok(())
    }
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig::disabled()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_config_is_inert_and_valid() {
        let c = PoolConfig::disabled();
        assert!(!c.enabled());
        assert_eq!(c.effective_max(), 0);
        assert_eq!(c.effective_min(), 0);
        assert!(c.validate().is_ok());
        assert_eq!(PoolConfig::default(), c);
    }

    #[test]
    fn sized_config_bounds() {
        let c = PoolConfig::sized(8);
        assert!(c.enabled());
        assert_eq!(c.min, 4);
        assert_eq!(c.effective_max(), 16);
        assert!(c.validate().is_ok());
        // max = 0 resolves to size (fixed pool).
        let fixed = PoolConfig { size: 4, min: 0, max: 0, ..PoolConfig::disabled() };
        assert_eq!(fixed.effective_max(), 4);
        assert_eq!(fixed.effective_min(), 0);
    }

    #[test]
    fn validation_rejects_bad_knobs() {
        let mut c = PoolConfig::sized(4);
        c.hysteresis = 1.0;
        assert!(c.validate().is_err(), "hysteresis must stay below 1");
        let mut c = PoolConfig::sized(4);
        c.hysteresis = -0.1;
        assert!(c.validate().is_err());
        let mut c = PoolConfig::sized(4);
        c.short_threshold = 0.0;
        assert!(c.validate().is_err());
        let mut c = PoolConfig::sized(4);
        c.min = 10;
        c.max = 8;
        assert!(c.validate().is_err(), "min above max rejected");
        let mut c = PoolConfig::sized(8);
        c.max = 4;
        assert!(
            c.validate().is_err(),
            "an explicit max below size is an error, not a silent override"
        );
        // min above max is tolerated while the pool is disabled.
        let c = PoolConfig { size: 0, min: 10, max: 0, ..PoolConfig::disabled() };
        assert!(c.validate().is_ok());
    }
}
