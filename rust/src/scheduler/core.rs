//! The scheduler simulation façade.
//!
//! [`SchedulerSim`] is a single-threaded scheduler server serializing
//! registration, dispatch, cleanup, preemption signalling and background
//! (production) work over the cluster model, driven by the DES engine.
//! This file holds the public types and the construction/run API; the
//! behaviour is split across focused submodules:
//!
//! * [`crate::scheduler::server`] — the op loop and work-conserving
//!   service discipline (what the server does next, and what it costs);
//! * [`crate::scheduler::lifecycle`] — task state transitions:
//!   placement (through the [`crate::placement`] engine), completion
//!   cleanup, and preemption.
//!
//! This is the substrate the paper's two aggregation modes are measured
//! against. The collapse mechanism at 512-node scale is *emergent*, not
//! scripted: dispatching 32768 core-level scheduling tasks takes longer
//! than T_job = 240 s, so completions start flooding the server while it
//! is still dispatching; cleanup transactions (which cost more than
//! dispatches and grow with array size) then starve dispatch, which
//! delays the remaining placements past the 2500 s mark — exactly the
//! behaviour reported in the paper's §III.B.

use crate::cluster::{Cluster, NodeId, NodeState};
use crate::fault::audit::AuditLog;
use crate::fault::metrics::{FaultOutcome, FaultStats};
use crate::fault::{FaultConfig, FaultPlan, PlannedFault};
use crate::obs::{Obs, ObsSnapshot, TraceKind};
use crate::placement::{Hold, PlacementEngine, ReservationLedger, Strategy};
use crate::pool::{FleetConfig, PoolConfig, PoolFleet};
use crate::scheduler::accounting::{JobStats, TaskRecord};
use crate::scheduler::costmodel::CostModel;
use crate::scheduler::job::{JobId, JobSpec, Placement, SchedTaskSpec, TaskId};
use crate::scheduler::noise::NoiseModel;
use crate::scheduler::queue::{AgingPolicy, PendingQueue};
use crate::sim::{self, EventQueue, Time};
use crate::util::rng::Rng;
use crate::workload::contention::WalltimeError;
use std::collections::VecDeque;

/// Events of the scheduler simulation.
#[derive(Debug)]
pub enum SchedEvent {
    /// A job submission arrives at the scheduler.
    Submit(JobId),
    /// The server finished its current operation.
    ServerDone(Op),
    /// A running scheduling task's occupancy ended.
    TaskEnded(TaskId),
    /// Background (production) small-burst arrival.
    NoiseSmall,
    /// Background large-burst arrival (another user's big launch).
    NoiseLarge,
    /// Preemption of a (spot) job is requested.
    Preempt(JobId),
    /// A fleet shard's resize cooldown expired (wake-driven hot path).
    /// Scheduled by every resize apply; the handler only marks the
    /// shard for attention — the decision itself still happens inside
    /// `pick_next` at the next natural server op boundary, so the
    /// schedule is bit-for-bit the polled one.
    ShardWake(u32),
    /// A planned churn event (node failure / recovery / reclamation
    /// wave / maintenance drain) reaches the scheduler. Pre-scheduled
    /// at [`SchedulerSim::run`] from the materialized
    /// [`crate::fault::FaultPlan`]; the payload is always one of the
    /// fault [`Op`] variants.
    Fault(Op),
    /// A fault-killed task's retry backoff expired: put it back on the
    /// queue.
    Requeue(TaskId),
}

/// Operations the server can be busy with.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Op {
    /// Register a submitted job (materialize array tasks).
    Register(JobId),
    /// Scheduling-cycle scan before a batch of dispatches.
    Cycle,
    /// Dispatch one scheduling task.
    Dispatch(TaskId),
    /// Backfill-dispatch one core-level task around a blocked
    /// whole-node head (admitted against the reservation ledger).
    Backfill(TaskId),
    /// Cleanup transaction for one finished task.
    Cleanup(TaskId),
    /// Background work burst of the given demand.
    Noise(f64),
    /// Preemption signal to one running task.
    PreemptSignal(TaskId),
    /// Rapid-launch pool dispatch of one short whole-node task through
    /// the given fleet shard (O(1) free-list pop; no placement engine,
    /// no per-core bookkeeping).
    PoolDispatch(u32, TaskId),
    /// Rapid-launch pool release of one finished task back to its shard
    /// (O(1) free-list push; constant cost, unlike the
    /// array-size-dependent cleanup).
    PoolRelease(u32, TaskId),
    /// One hysteresis-driven resize pass of the given fleet shard
    /// (borrow / lease / drain / return).
    PoolResize(u32),
    /// A node goes down hard: running tasks die, holds clear, pooled
    /// leases are evicted, the node leaves the placement index.
    NodeFail(NodeId),
    /// A down/draining node returns to service.
    NodeRecover(NodeId),
    /// A spot reclamation wave fires: every node in the plan's wave
    /// fails at once (the `spot/` release regime at node granularity).
    ReclaimWave(u32),
    /// A maintenance drain starts: the node stops taking new work but
    /// running tasks finish.
    DrainNode(NodeId),
}

/// Per-task live state (record + dispatch bookkeeping).
#[derive(Debug)]
pub(crate) struct TaskSlot {
    pub(crate) spec: SchedTaskSpec,
    pub(crate) record: TaskRecord,
    pub(crate) placement: Option<Placement>,
    pub(crate) priority: i32,
    /// The walltime *estimate* backfill admission and hold planning use
    /// (`spec.duration × WalltimeError::factor`; equal to the true
    /// duration when the error model is [`WalltimeError::None`]).
    pub(crate) est_duration: Time,
    /// When the task joined the pending queue — preserved across
    /// head-of-line reinsertions so aging credit is never reset.
    pub(crate) enqueued_at: Time,
    /// The fleet shard and leased node a pool-routed task is running on
    /// (`None` for every batch-path task; pool tasks never carry a
    /// `placement`).
    pub(crate) pool_node: Option<(u32, NodeId)>,
    /// Whether this task was admitted by the backfill scan — the only
    /// tasks the preempt-overdue policy may kill.
    pub(crate) backfilled: bool,
    /// A preempt signal is already queued for this task (guards the
    /// overdue scan against double-signalling).
    pub(crate) kill_signalled: bool,
    /// How many times this task has been requeued after a fault kill.
    pub(crate) retries: u32,
    /// The node whose failure killed this task (`Some` from the moment
    /// the failure marks it until the kill is fully accounted — or
    /// cleared if the task's natural completion raced the kill signal).
    pub(crate) fault_node: Option<NodeId>,
    /// When the fault kill landed; consumed by the next launch to
    /// measure kill-to-restart latency (`NAN` = no pending restart).
    pub(crate) killed_at: Time,
}

/// Per-job metadata.
#[derive(Debug, Clone)]
pub struct JobMeta {
    pub id: JobId,
    pub name: String,
    pub array_size: u64,
    pub reservation: Option<String>,
    pub priority: i32,
    pub preemptable: bool,
    pub submit_t: Time,
    /// First task id of this job's contiguous task-slot range (tasks
    /// are materialized in one block at registration, so `Register`
    /// iterates `first_task..first_task + task_count` instead of
    /// scanning the whole task arena).
    pub first_task: TaskId,
    /// Number of task slots in the range.
    pub task_count: u32,
}

impl JobMeta {
    /// Inert filler for never-registered job ids (arena slots must stay
    /// dense; a placeholder is cheaper than an `Option` on every read).
    pub(crate) fn placeholder() -> JobMeta {
        JobMeta {
            id: 0,
            name: String::new(),
            array_size: 0,
            reservation: None,
            priority: 0,
            preemptable: false,
            submit_t: 0.0,
            first_task: 0,
            task_count: 0,
        }
    }
}

/// Which dispatch-loop discipline `pick_next` runs.
///
/// * [`HotPath::Polled`] — the historical discipline: every pick scans
///   all fleet shards for due resizes and re-runs the hold/backfill
///   scans unconditionally.
/// * [`HotPath::WakeDriven`] — the event-calendar discipline: shard
///   cooldown expiries arrive as [`SchedEvent::ShardWake`] events and
///   state transitions mark dirty flags, so a pick skips shards and
///   backfill scans that provably cannot act. The *schedule* is
///   bit-for-bit identical (pinned by `rust/tests/event_equivalence.rs`);
///   only the per-pick work shrinks.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum HotPath {
    /// Scan everything on every pick (pre-event-calendar behaviour).
    Polled,
    /// Skip work unless a wake event or dirty flag says it can matter.
    #[default]
    WakeDriven,
}

/// How much server time went to each class of work.
#[derive(Debug, Clone, Copy, Default)]
pub struct BusyBreakdown {
    pub register: Time,
    pub cycle: Time,
    pub dispatch: Time,
    pub cleanup: Time,
    pub noise: Time,
    pub preempt: Time,
    /// Rapid-launch pool work (dispatch + release + resize).
    pub pool: Time,
    /// Fault-event handling (failures, recoveries, reclaims, drains).
    pub fault: Time,
}

impl BusyBreakdown {
    /// Total server-busy time.
    pub fn total(&self) -> Time {
        self.register
            + self.cycle
            + self.dispatch
            + self.cleanup
            + self.noise
            + self.preempt
            + self.pool
            + self.fault
    }
}

/// Tunables of the task execution model (outside the scheduler proper).
#[derive(Debug, Clone)]
pub struct TaskModel {
    /// Fixed startup overhead when a scheduling task launches on its
    /// resources (script spin-up, binary load).
    pub startup: Time,
    /// Additive half-normal jitter sigma on occupancy duration.
    pub jitter_sigma: f64,
    /// Probability that a *whole-node* allocation joins late in
    /// production mode, at full (512-node) machine scale; the effective
    /// probability is `p_node_late × (cluster_nodes / 512)²` — grabbing
    /// nearly the whole machine inevitably includes draining nodes,
    /// while partial allocations pick from spare capacity. Core-level
    /// requests fit into gaps and do not suffer drain contention.
    pub p_node_late: f64,
    /// Late-join delay range, seconds.
    pub late_range: (Time, Time),
}

impl Default for TaskModel {
    fn default() -> Self {
        TaskModel {
            startup: 0.8,
            jitter_sigma: 0.4,
            p_node_late: 0.0008,
            late_range: (20.0, 250.0),
        }
    }
}

/// One backfill dispatch, as recorded for diagnostics and the backfill
/// invariant tests (no backfilled task may delay a reservation).
#[derive(Debug, Clone, Copy)]
pub struct BackfillEvent {
    /// The backfilled (core-level) task.
    pub task: TaskId,
    /// Node it was placed on.
    pub node: NodeId,
    /// Placement time.
    pub time: Time,
    /// The earliest-start reservation fencing the *placed node* at
    /// placement time, if any (a backfill can also jump a blocked
    /// core-level head, which plans no hold, or land on an unheld
    /// node while other nodes carry holds).
    pub hold: Option<Hold>,
}

/// Everything measured from one simulation run.
#[derive(Debug)]
pub struct SimOutcome {
    pub records: Vec<TaskRecord>,
    pub jobs: Vec<JobMeta>,
    /// `(time, running_cores)` after each change (Fig 2 raw series).
    pub timeline: Vec<(Time, u64)>,
    pub busy: BusyBreakdown,
    pub final_time: Time,
    pub events_processed: u64,
    /// Peak completion backlog (responsiveness indicator).
    pub max_completion_backlog: usize,
    /// Longest continuous stretch of server-busy time (the paper's
    /// "scheduler becomes unresponsive" indicator).
    pub longest_busy_stretch: Time,
    /// Backfill dispatches performed (empty when backfill is off).
    pub backfills: Vec<BackfillEvent>,
    /// Peak number of simultaneously active holds (≤ the configured K).
    pub max_active_holds: usize,
    /// Whether the ledger ever violated the hold invariants (> K holds,
    /// overlapping nodes, duplicate tasks). Must stay `false`; checked
    /// by the fairness property suite after every planning pass.
    pub hold_invariant_violated: bool,
    /// Rapid-launch pool accounting (`None` when the pool is disabled).
    pub pool: Option<PoolOutcome>,
    /// Overdue backfilled tasks killed so a due hold could start
    /// (0 unless `preempt_overdue` is on).
    pub overdue_preemptions: u64,
    /// Churn tallies + the deterministic audit log (`None` when fault
    /// injection is disabled — fault-off runs carry no trace of the
    /// subsystem, pinned by `rust/tests/fault_properties.rs`).
    pub fault: Option<FaultOutcome>,
    /// Flight-recorder snapshot (`None` unless a recorder was installed
    /// with [`SchedulerSim::with_recorder`] — recorder-off runs carry no
    /// trace of the subsystem, pinned by `rust/tests/obs_properties.rs`).
    pub obs: Option<ObsSnapshot>,
}

/// What the rapid-launch pool fleet did over one run. The scalar fields
/// aggregate over the shards (one-shard fleets report exactly the PR 4
/// single-pool numbers); [`Self::shards`] carries the per-shard split.
#[derive(Debug, Clone)]
pub struct PoolOutcome {
    /// Short whole-node tasks launched through any shard.
    pub launches: u64,
    /// The most recent launched tasks, oldest first, capped at
    /// [`crate::pool::fleet::LAUNCH_RING_CAP`] — a debugging window, not
    /// a log (the per-task attribution the metrics join lives on each
    /// record's `pool_shard` tag).
    pub recent_launches: Vec<TaskId>,
    /// Nodes taken from batch (leases + drains) across all resizes.
    pub grows: u64,
    /// Nodes returned to batch across all resizes.
    pub shrinks: u64,
    /// True fleet-wide peak of simultaneous leases (shards peaking at
    /// different times do not add up; per-shard peaks are in
    /// [`Self::shards`]).
    pub peak_leased: usize,
    /// Lease count when the run ended.
    pub final_leased: usize,
    /// Free nodes transferred between sibling shards by the fleet
    /// rebalancer (0 for a one-shard fleet).
    pub borrows: u64,
    /// Per-shard accounting, in shard-config order.
    pub shards: Vec<ShardOutcome>,
    /// Whether the fleet ever broke its conservation invariant (every
    /// node in exactly one shard or batch) or a batch placement landed
    /// on a pool-owned node. Must stay `false`; pinned by
    /// `rust/tests/pool_properties.rs` and `rust/tests/fleet_properties.rs`.
    pub invariant_violated: bool,
}

/// One shard's slice of a [`PoolOutcome`].
#[derive(Debug, Clone)]
pub struct ShardOutcome {
    /// Shard name from the fleet config.
    pub name: String,
    /// Tasks launched through this shard.
    pub launches: u64,
    /// Nodes this shard took from batch across all resizes.
    pub grows: u64,
    /// Nodes this shard returned to batch across all resizes.
    pub shrinks: u64,
    /// Peak simultaneous lease count of this shard.
    pub peak_leased: usize,
    /// Lease count when the run ended.
    pub final_leased: usize,
}

/// Live state of the rapid-launch pool fleet inside the scheduler.
#[derive(Debug)]
pub(crate) struct PoolState {
    pub(crate) fleet: PoolFleet,
    /// Finished pool tasks awaiting their (cheap) release op, tagged
    /// with the shard that launched them.
    pub(crate) completions: VecDeque<(u32, TaskId)>,
    /// Wake-driven dirty flags, one per shard: set at every state
    /// transition that could change the shard's resize decision or let
    /// a dispatch proceed; cleared by `pick_next` once a pick finds
    /// nothing to do for the shard. `Polled` mode ignores them.
    pub(crate) attention: Vec<bool>,
    /// Outstanding [`SchedEvent::ShardWake`] events per shard. A shard
    /// whose wake is still in flight may become due *at the same
    /// instant* as another event that pops first; the counter keeps the
    /// due check live over exactly that window so wake-driven picks see
    /// what polled picks see.
    pub(crate) wakes_pending: Vec<u32>,
}

impl PoolState {
    #[inline]
    pub(crate) fn mark(&mut self, sid: usize) {
        if let Some(a) = self.attention.get_mut(sid) {
            *a = true;
        }
    }

    #[inline]
    pub(crate) fn mark_all(&mut self) {
        for a in self.attention.iter_mut() {
            *a = true;
        }
    }
}

impl SimOutcome {
    /// Job statistics (Table III row ingredients) for one job.
    pub fn job_stats(&self, job: JobId, t_job: Time) -> Option<JobStats> {
        JobStats::compute(job, &self.records, t_job)
    }

    /// The paper's responsiveness guard: a production scheduler is
    /// "unusable" when it stays saturated for minutes at a time.
    pub fn unusable_in_production(&self) -> bool {
        self.longest_busy_stretch > 60.0
    }
}

/// The scheduler simulation actor. Create, submit jobs, then [`Self::run`].
pub struct SchedulerSim {
    pub(crate) cluster: Cluster,
    pub(crate) engine: PlacementEngine,
    /// Backfill reservation ledger (expected node free times + the
    /// active hold). Maintained on every placement/release; consulted
    /// only when `backfill` is on.
    pub(crate) ledger: ReservationLedger,
    /// Enable EASY-style backfill around blocked whole-node heads.
    pub(crate) backfill: bool,
    /// How many pending entries a backfill scan may inspect.
    pub(crate) backfill_lookahead: usize,
    pub(crate) backfill_log: Vec<BackfillEvent>,
    /// Queue-aging policy (mirrored into the pending queue); `None`
    /// keeps the static priority-then-FIFO discipline.
    pub(crate) aging: Option<AgingPolicy>,
    /// Walltime-estimate error model: what the ledger plans from.
    pub(crate) walltime: WalltimeError,
    /// Estimate-noise stream, independent of the sim stream so turning
    /// noise on or off never perturbs jitter/arrival draws.
    pub(crate) walltime_rng: Rng,
    /// Peak simultaneous holds + invariant flag (see [`SimOutcome`]).
    pub(crate) max_holds_seen: usize,
    pub(crate) hold_invariant_violated: bool,
    /// Rapid-launch pool (`None` = disabled; the batch machinery then
    /// behaves bit-for-bit as if the subsystem did not exist).
    pub(crate) pool: Option<PoolState>,
    /// Kill overdue backfilled tasks when their node's hold comes due,
    /// instead of waiting for them to vacate (off by default).
    pub(crate) preempt_overdue: bool,
    pub(crate) overdue_preemptions: u64,
    /// Backfilled tasks currently running, by node — the overdue scan's
    /// working set (bounded by live backfills, unlike the append-only
    /// `backfill_log`). Maintained only while `preempt_overdue` is on.
    pub(crate) live_backfills: Vec<(TaskId, NodeId)>,
    pub(crate) cost: CostModel,
    pub(crate) noise: NoiseModel,
    pub(crate) task_model: TaskModel,
    pub(crate) rng: Rng,
    pub(crate) production: bool,
    /// The construction seed, kept so [`Self::with_faults`] can derive
    /// the fault plan's salted stream no matter when it is called.
    pub(crate) seed: u64,

    /// Fault-injection config ([`Self::with_faults`]; disabled by
    /// default, in which case none of the fault state below is touched).
    pub(crate) fault_cfg: FaultConfig,
    /// The materialized churn schedule (`None` = fault injection off).
    pub(crate) fault_plan: Option<FaultPlan>,
    /// Fault ops awaiting the server (served before all other work).
    pub(crate) fault_q: VecDeque<Op>,
    pub(crate) fault_stats: FaultStats,
    /// The deterministic audit log (see [`crate::fault::audit`]).
    pub(crate) audit: AuditLog,
    /// Per-node time it went out of service (`NAN` = in service);
    /// recovery turns the difference into downtime metrics.
    pub(crate) down_since: Vec<Time>,

    /// Dispatch-loop discipline (see [`HotPath`]).
    pub(crate) hot_path: HotPath,
    /// Wake-driven gate on the hold-ready and backfill-admission scans:
    /// set at every transition that can create a ready hold or an
    /// admissible backfill; cleared once both scans come up empty.
    pub(crate) backfill_dirty: bool,
    /// Scratch buffer for hold iteration in `pick_next` /
    /// `signal_overdue_backfills` — reused across picks so the hot loop
    /// never allocates (the two sites run sequentially, never nested).
    pub(crate) hold_scratch: Vec<Hold>,
    /// Tasks not yet DONE (counting PENDING, RUNNING and COMPLETING) —
    /// keeps `has_outstanding_work` O(1) instead of scanning the arena.
    pub(crate) not_done: usize,
    /// Bench-only compatibility switch: reproduce the pre-arena
    /// `Register` that scanned every task slot per job instead of
    /// walking the job's contiguous range. Never enabled outside
    /// `benches/` and the equivalence suite.
    pub(crate) legacy_register: bool,

    pub(crate) specs: Vec<Option<JobSpec>>, // consumed at Submit
    pub(crate) jobs: Vec<JobMeta>,
    pub(crate) tasks: Vec<TaskSlot>,
    pub(crate) pending: PendingQueue,
    pub(crate) completions: VecDeque<TaskId>,
    pub(crate) preempt_q: VecDeque<TaskId>,
    pub(crate) noise_q: VecDeque<f64>,

    /// Per-run multiplicative factor on all server op costs (hardware /
    /// kernel / filesystem variability between runs; sampled log-normal,
    /// σ = 5 %). Gives dedicated-system runs the paper's natural spread.
    pub(crate) op_scale: f64,
    pub(crate) server_busy: bool,
    pub(crate) busy_since: Time,
    pub(crate) longest_busy_stretch: Time,
    pub(crate) hol_blocked: bool,
    pub(crate) cycle_budget: u32,
    pub(crate) cleanups_since_dispatch: u32,

    pub(crate) busy: BusyBreakdown,
    pub(crate) running_cores: u64,
    /// Raw `(time, ±cores)` deltas; late-joining nodes stamp their start
    /// in the future relative to the dispatch event, so deltas are sorted
    /// and prefix-summed into the absolute series when the run finishes.
    pub(crate) timeline: Vec<(Time, i64)>,
    pub(crate) record_timeline: bool,
    pub(crate) max_completion_backlog: usize,
    /// Flight recorder (`None` = off; every observation site is then a
    /// single branch on this option, so the hot path is unchanged).
    pub(crate) obs: Option<Box<Obs>>,
}

impl SchedulerSim {
    /// New simulation over `cluster`. `production = !dedicated` enables
    /// the background-noise process and node-churn late joins. Placement
    /// defaults to [`Strategy::FirstFit`] (the historical scan order);
    /// override with [`Self::with_placement`].
    pub fn new(cluster: Cluster, cost: CostModel, noise: NoiseModel, seed: u64) -> SchedulerSim {
        let production = noise.mean_load() > 0.0;
        let mut rng = Rng::new(seed);
        let op_scale = rng.lognormal(0.0, 0.05);
        // The placement rng stream is derived from, but independent of,
        // the sim stream: policy choice must not perturb jitter/noise.
        let engine = PlacementEngine::new(
            &cluster,
            Strategy::FirstFit,
            seed ^ 0x9E37_79B9_7F4A_7C15,
        );
        let ledger = ReservationLedger::new(cluster.n_nodes() as usize);
        let n_nodes = cluster.n_nodes() as usize;
        SchedulerSim {
            cluster,
            engine,
            ledger,
            backfill: false,
            backfill_lookahead: 64,
            backfill_log: Vec::new(),
            aging: None,
            walltime: WalltimeError::None,
            walltime_rng: Rng::new(seed ^ 0x5DEE_CE66_D5A6_1C5D),
            max_holds_seen: 0,
            hold_invariant_violated: false,
            pool: None,
            preempt_overdue: false,
            overdue_preemptions: 0,
            live_backfills: Vec::new(),
            cost,
            noise,
            task_model: TaskModel::default(),
            rng,
            production,
            seed,
            fault_cfg: FaultConfig::disabled(),
            fault_plan: None,
            fault_q: VecDeque::new(),
            fault_stats: FaultStats::default(),
            audit: AuditLog::new(),
            down_since: vec![f64::NAN; n_nodes],
            hot_path: HotPath::default(),
            backfill_dirty: true,
            hold_scratch: Vec::new(),
            not_done: 0,
            legacy_register: false,
            op_scale,
            specs: Vec::new(),
            jobs: Vec::new(),
            tasks: Vec::new(),
            pending: PendingQueue::new(),
            completions: VecDeque::new(),
            preempt_q: VecDeque::new(),
            noise_q: VecDeque::new(),
            server_busy: false,
            busy_since: 0.0,
            longest_busy_stretch: 0.0,
            hol_blocked: false,
            cycle_budget: 0,
            cleanups_since_dispatch: 0,
            busy: BusyBreakdown::default(),
            running_cores: 0,
            timeline: Vec::new(),
            record_timeline: true,
            max_completion_backlog: 0,
            obs: None,
        }
    }

    /// Override the task execution model.
    pub fn with_task_model(mut self, tm: TaskModel) -> Self {
        self.task_model = tm;
        self
    }

    /// Select the placement strategy (see [`crate::placement`]).
    pub fn with_placement(mut self, strategy: Strategy) -> Self {
        self.engine.set_strategy(strategy);
        self
    }

    /// The active placement strategy.
    pub fn placement(&self) -> Strategy {
        self.engine.strategy()
    }

    /// Enable/disable backfill scheduling: blocked whole-node heads get
    /// an earliest-start reservation and small core-level tasks may
    /// jump the queue into gaps they vacate before it starts (see
    /// [`crate::placement::backfill`]). Off by default — it changes
    /// dispatch order, so the paper-reproduction runs keep the plain
    /// head-of-line discipline unless a config opts in.
    pub fn with_backfill(mut self, on: bool) -> Self {
        self.backfill = on;
        self
    }

    /// Whether backfill scheduling is enabled.
    pub fn backfill_enabled(&self) -> bool {
        self.backfill
    }

    /// Bound on how many pending entries one backfill scan inspects.
    pub fn with_backfill_lookahead(mut self, entries: usize) -> Self {
        self.backfill_lookahead = entries;
        self
    }

    /// Reserve for up to `k` blocked whole-node tasks at once (top-K
    /// multi-hold backfill; clamped to ≥ 1). The default `1` is the
    /// original EASY single-hold discipline — `with_holds(1)` schedules
    /// are bit-for-bit identical to it, which the equivalence property
    /// in `rust/tests/fairness_properties.rs` pins down.
    pub fn with_holds(mut self, k: usize) -> Self {
        self.ledger.set_max_holds(k);
        self
    }

    /// The configured hold capacity K.
    pub fn holds(&self) -> usize {
        self.ledger.max_holds()
    }

    /// Install a queue-aging policy (`None` = static priorities): a
    /// pending task's effective priority rises with its wait, so a
    /// low-priority whole-node job behind a sustained high-priority
    /// stream eventually reaches the head — and, with backfill on, an
    /// earliest-start hold.
    pub fn with_aging(mut self, policy: Option<AgingPolicy>) -> Self {
        self.aging = policy;
        self.pending.set_aging(policy);
        self
    }

    /// The active aging policy.
    pub fn aging(&self) -> Option<AgingPolicy> {
        self.aging
    }

    /// Install a walltime-estimate error model: tasks carry an
    /// *estimated* runtime distinct from their true runtime, the
    /// reservation ledger plans from the estimates, and overdue holds
    /// are re-planned rather than stalling dispatch. The default
    /// [`WalltimeError::None`] keeps the DES's exact-oracle estimates
    /// (and draws nothing, so seeds reproduce bit-for-bit).
    pub fn with_walltime_error(mut self, model: WalltimeError) -> Self {
        self.walltime = model;
        self
    }

    /// The active walltime-estimate error model.
    pub fn walltime_error(&self) -> WalltimeError {
        self.walltime
    }

    /// Install the rapid-launch node pool ([`crate::pool`]) as a
    /// one-shard fleet — the backward-compatible entry point: short
    /// whole-node tasks (estimated duration ≤ the config's threshold)
    /// route to a dedicated queue served by O(1) node-based dispatch
    /// over leased nodes, and a hysteresis controller elastically
    /// resizes the lease set against batch pressure. A disabled config
    /// (`size = 0`) leaves the scheduler bit-for-bit unchanged — the
    /// equivalence property in `rust/tests/pool_properties.rs` pins
    /// this down.
    pub fn with_pool(self, cfg: PoolConfig) -> Self {
        self.with_fleet(FleetConfig::single(cfg))
    }

    /// Install a shape-sharded pool fleet ([`crate::pool::fleet`]):
    /// several rapid-launch shards keyed by job shape (capacity class +
    /// walltime), each with its own membership table, dispatcher and
    /// hysteresis controller, sharing one fleet-wide conservation
    /// invariant and a cross-shard rebalancer. An empty config disables
    /// the subsystem entirely. The config is expected to be validated
    /// ([`FleetConfig::validate`]) by the caller — config and CLI
    /// boundaries do; the debug assertion catches test mistakes.
    pub fn with_fleet(mut self, cfg: FleetConfig) -> Self {
        debug_assert!(cfg.validate().is_ok(), "invalid fleet config: {:?}", cfg.validate());
        if cfg.enabled() {
            let n = self.cluster.n_nodes() as usize;
            let capacity: Vec<u32> = (0..n as NodeId)
                .map(|i| self.engine.index().node_capacity(i))
                .collect();
            let fleet = PoolFleet::new(capacity, &cfg);
            let n_shards = fleet.shards.len();
            self.pool = Some(PoolState {
                fleet,
                completions: VecDeque::new(),
                // Every shard starts dirty: the bootstrap lease happens
                // before the first event, so the first pick must look.
                attention: vec![true; n_shards],
                wakes_pending: vec![0; n_shards],
            });
        } else {
            self.pool = None;
        }
        self
    }

    /// Select the dispatch-loop discipline (see [`HotPath`]). The
    /// default is [`HotPath::WakeDriven`]; `Polled` keeps the historical
    /// scan-everything loop for the equivalence suite and benchmarks.
    pub fn with_hot_path(mut self, hp: HotPath) -> Self {
        self.hot_path = hp;
        self
    }

    /// The active dispatch-loop discipline.
    pub fn hot_path(&self) -> HotPath {
        self.hot_path
    }

    /// Bench-only: reproduce the pre-arena O(tasks) per-job `Register`
    /// scan (the schedule is unchanged — only the modelled server walks
    /// a longer data structure). Used by `benches/bench_pool.rs` to
    /// measure the arena speedup and by the equivalence suite.
    pub fn with_legacy_register(mut self, on: bool) -> Self {
        self.legacy_register = on;
        self
    }

    /// Whether the rapid-launch pool is active.
    pub fn pool_enabled(&self) -> bool {
        self.pool.is_some()
    }

    /// Enable preemptive backfill: when a hold comes due and backfilled
    /// tasks on its node have overstayed their walltime estimate, kill
    /// them through the existing preempt path instead of waiting for
    /// them to vacate. Off by default — it changes schedules, so runs
    /// opt in via the `preempt_overdue` config key.
    pub fn with_preempt_overdue(mut self, on: bool) -> Self {
        self.preempt_overdue = on;
        self
    }

    /// Whether preemptive backfill is enabled.
    pub fn preempt_overdue_enabled(&self) -> bool {
        self.preempt_overdue
    }

    /// Install a fault-injection plan ([`crate::fault`]): per-node MTBF
    /// failures, spot reclamation waves, maintenance drains and
    /// straggler stretch, all materialized up front from this sim's
    /// seed on a dedicated salted stream. A disabled config draws
    /// nothing and schedules nothing, so fault-off runs are bit-for-bit
    /// the fault-free scheduler (pinned by
    /// `rust/tests/fault_properties.rs`). The config is expected to be
    /// validated by the caller; the debug assertion catches test
    /// mistakes.
    pub fn with_faults(mut self, cfg: FaultConfig) -> Self {
        debug_assert!(cfg.validate().is_ok(), "invalid fault config: {:?}", cfg.validate());
        self.fault_plan = if cfg.enabled() {
            Some(FaultPlan::generate(&cfg, self.cluster.n_nodes(), self.seed))
        } else {
            None
        };
        self.fault_cfg = cfg;
        self
    }

    /// Whether fault injection is active.
    pub fn faults_enabled(&self) -> bool {
        self.fault_plan.is_some()
    }

    /// Disable the (possibly large) utilization timeline recording and
    /// drop anything already buffered. Every delta push site — batch
    /// start, occupancy end, and the pool launch path — is gated on the
    /// flag, so a disabled run finishes with a provably empty timeline
    /// (regression-pinned by `rust/tests/obs_properties.rs`).
    pub fn without_timeline(mut self) -> Self {
        self.record_timeline = false;
        self.timeline = Vec::new();
        self
    }

    /// Install a flight recorder ([`crate::obs`]): a bounded trace ring
    /// of typed decision records plus the metrics registry, snapshotted
    /// into [`SimOutcome::obs`] when the run finishes. The recorder
    /// only observes — it draws no randomness and feeds nothing back —
    /// so recorder-on schedules are bit-for-bit the recorder-off ones,
    /// and without one every observation site is a single branch on an
    /// `Option` (both pinned by `rust/tests/obs_properties.rs`).
    pub fn with_recorder(mut self, obs: Box<Obs>) -> Self {
        self.obs = Some(obs);
        self
    }

    /// Whether a flight recorder is installed.
    pub fn recorder_enabled(&self) -> bool {
        self.obs.is_some()
    }

    /// Fix the per-run server-speed factor (tests use 1.0 for exact
    /// accounting; experiments keep the sampled value).
    pub fn with_server_speed(mut self, scale: f64) -> Self {
        assert!(scale > 0.0);
        self.op_scale = scale;
        self
    }

    /// Queue a job for submission at virtual time `t`. Returns its id.
    pub fn submit_at(&mut self, q: &mut EventQueue<SchedEvent>, t: Time, spec: JobSpec) -> JobId {
        let id = self.specs.len() as JobId;
        self.specs.push(Some(spec));
        q.at(t, SchedEvent::Submit(id));
        id
    }

    /// Request preemption of a job at virtual time `t`.
    pub fn preempt_at(&mut self, q: &mut EventQueue<SchedEvent>, t: Time, job: JobId) {
        q.at(t, SchedEvent::Preempt(job));
    }

    /// Drive the simulation to completion and return the outcome. The
    /// placement index built at construction is still current: the
    /// cluster moves into the sim at [`Self::new`] and nothing mutates
    /// it between then and here.
    pub fn run(mut self, q: &mut EventQueue<SchedEvent>) -> SimOutcome {
        self.prepare(q);
        let (final_time, events) = sim::run(&mut self, q);
        self.finish(final_time, events)
    }

    /// Stage the run: size the arenas, bootstrap the pool fleet, prime
    /// the noise process, and materialize the fault schedule into
    /// events. [`Self::run`] calls this itself; the federation gateway
    /// calls it once per instance before driving the instances in
    /// lock-step with [`sim::run_until_before`], submitting more work
    /// between windows. Call exactly once, after the up-front
    /// submissions and before the first event is popped.
    pub fn prepare(&mut self, q: &mut EventQueue<SchedEvent>) {
        // The up-front workload is known: size the job and task arenas
        // once so the op path never grows a Vec mid-run (a 10M task
        // trace would otherwise pay ~24 doubling copies). Late
        // gateway-routed submissions still append normally.
        let n_tasks: usize = self.specs.iter().flatten().map(|s| s.tasks.len()).sum();
        self.jobs.reserve(self.specs.len());
        self.tasks.reserve(n_tasks);
        self.bootstrap_pool();
        self.prime_noise(q);
        // The churn schedule is materialized: turn it into events now,
        // after all submissions, so fault events at a tied time always
        // pop after the submissions planned for that instant.
        if let Some(plan) = self.fault_plan.as_ref() {
            for &(t, pf) in &plan.events {
                let op = match pf {
                    PlannedFault::Fail(n) => Op::NodeFail(n),
                    PlannedFault::Recover(n) => Op::NodeRecover(n),
                    PlannedFault::ReclaimWave(w) => Op::ReclaimWave(w),
                    PlannedFault::Drain(n) => Op::DrainNode(n),
                };
                q.at(t, SchedEvent::Fault(op));
            }
        }
    }

    /// Assemble the [`SimOutcome`] once the event loop has drained (or
    /// the caller stopped driving it). `final_time` and `events` are
    /// what the engine loop returned — for a lock-step federation
    /// instance, the last window's clock and the summed per-window
    /// event counts.
    pub fn finish(mut self, final_time: Time, events: u64) -> SimOutcome {
        let pool = self.pool.take().map(|p| {
            let f = p.fleet;
            let invariant_violated = f.violated || f.check_conservation().is_err();
            let borrows = f.borrows();
            let peak_leased = f.peak_leased();
            let recent_launches: Vec<TaskId> = f.recent_launches().iter().copied().collect();
            let shards: Vec<ShardOutcome> = f
                .shards
                .into_iter()
                .map(|s| ShardOutcome {
                    name: s.name,
                    launches: s.dispatcher.launches(),
                    grows: s.manager.grows(),
                    shrinks: s.manager.shrinks(),
                    peak_leased: s.nodes.peak_leased(),
                    final_leased: s.nodes.n_leased(),
                })
                .collect();
            PoolOutcome {
                launches: shards.iter().map(|s| s.launches).sum(),
                recent_launches,
                grows: shards.iter().map(|s| s.grows).sum(),
                shrinks: shards.iter().map(|s| s.shrinks).sum(),
                peak_leased,
                final_leased: shards.iter().map(|s| s.final_leased).sum(),
                borrows,
                shards,
                invariant_violated,
            }
        });
        let mut deltas = self.timeline;
        deltas.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("no NaN times"));
        let mut running: i64 = 0;
        let timeline: Vec<(Time, u64)> = deltas
            .into_iter()
            .map(|(t, d)| {
                running += d;
                debug_assert!(running >= 0, "negative core count in timeline");
                (t, running as u64)
            })
            .collect();
        let fault = if self.fault_cfg.enabled() {
            Some(FaultOutcome {
                stats: self.fault_stats,
                audit: self.audit,
            })
        } else {
            None
        };
        let obs = self.obs.take().map(|o| o.snapshot());
        SimOutcome {
            records: self.tasks.into_iter().map(|t| t.record).collect(),
            jobs: self.jobs,
            timeline,
            busy: self.busy,
            final_time,
            events_processed: events,
            max_completion_backlog: self.max_completion_backlog,
            longest_busy_stretch: self.longest_busy_stretch,
            backfills: self.backfill_log,
            max_active_holds: self.max_holds_seen,
            hold_invariant_violated: self.hold_invariant_violated,
            pool,
            overdue_preemptions: self.overdue_preemptions,
            fault,
            obs,
        }
    }

    /// Record one flight-recorder event. A single branch on the
    /// recorder option when off — the observation sites in the op loop
    /// and lifecycle stay free for recorder-less runs.
    #[inline]
    pub(crate) fn trace(&mut self, kind: TraceKind, unit: u32, id: u64, t: Time, detail: i64) {
        if let Some(o) = self.obs.as_mut() {
            o.record(kind, unit, id, t, detail);
        }
    }

    /// Convenience: run a single job on a fresh queue; returns
    /// `(outcome, job_id)`.
    pub fn run_single(mut self, spec: JobSpec) -> (SimOutcome, JobId) {
        let mut q = EventQueue::new();
        let id = self.submit_at(&mut q, 0.0, spec);
        (self.run(&mut q), id)
    }

    fn prime_noise(&mut self, q: &mut EventQueue<SchedEvent>) {
        if let Some((gap, _)) = self.noise.next_small(&mut self.rng) {
            q.after(gap, SchedEvent::NoiseSmall);
        }
        if let Some((gap, _)) = self.noise.next_large(&mut self.rng) {
            q.after(gap, SchedEvent::NoiseLarge);
        }
    }

    /// Number of nodes currently fully idle (test/metric helper).
    pub fn idle_nodes(&self) -> usize {
        self.cluster
            .nodes()
            .filter(|n| n.state() == NodeState::Up && n.is_idle())
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::accounting::TaskRecord;
    use crate::scheduler::job::{ComputeBatch, ResourceRequest, TaskId, TaskState};

    fn uniform_job(
        n_tasks: usize,
        request: ResourceRequest,
        duration: f64,
        lanes: u32,
    ) -> JobSpec {
        JobSpec {
            name: "test".into(),
            tasks: vec![
                SchedTaskSpec {
                    request,
                    duration,
                    batch: ComputeBatch { count: 1, each: duration },
                    lanes,
                };
                n_tasks
            ],
            reservation: None,
            priority: 0,
            preemptable: false,
        }
    }

    fn quiet_sim(nodes: u32) -> SchedulerSim {
        SchedulerSim::new(
            Cluster::tx_green(nodes),
            CostModel::slurm_like_tx_green(),
            NoiseModel::dedicated(),
            42,
        )
        .with_task_model(TaskModel {
            startup: 0.0,
            jitter_sigma: 0.0,
            p_node_late: 0.0,
            late_range: (0.0, 0.0),
        })
        .with_server_speed(1.0)
    }

    #[test]
    fn single_node_task_full_lifecycle() {
        let sim = quiet_sim(1);
        let (out, job) = sim.run_single(uniform_job(1, ResourceRequest::WholeNode, 100.0, 64));
        let stats = out.job_stats(job, 100.0).unwrap();
        assert_eq!(stats.array_size, 1);
        assert!((stats.runtime - 100.0).abs() < 1e-6, "{}", stats.runtime);
        let r = &out.records[0];
        assert_eq!(r.state, TaskState::Done);
        assert_eq!(r.cores, 64);
        assert!(r.cleanup_t.unwrap() >= r.end_t.unwrap());
    }

    #[test]
    fn all_tasks_complete_and_resources_return() {
        let sim = quiet_sim(4);
        let (out, _) = sim.run_single(uniform_job(
            256,
            ResourceRequest::Cores { cores: 1, mem_mib: 16 },
            10.0,
            1,
        ));
        assert!(out.records.iter().all(|r| r.state == TaskState::Done));
        assert_eq!(out.records.len(), 256);
        // Timeline returns to zero.
        assert_eq!(out.timeline.last().unwrap().1, 0);
    }

    #[test]
    fn oversubscription_serializes_wave_by_wave() {
        // 2 nodes × 64 cores, 256 single-core 10 s tasks → ≥2 waves.
        let sim = quiet_sim(2);
        let (out, job) = sim.run_single(uniform_job(
            256,
            ResourceRequest::Cores { cores: 1, mem_mib: 0 },
            10.0,
            1,
        ));
        let stats = out.job_stats(job, 10.0).unwrap();
        // 256 tasks on 128 cores: runtime at least 2 waves of 10 s.
        assert!(stats.runtime >= 20.0 - 1e-9, "runtime {}", stats.runtime);
        assert!(out.records.iter().all(|r| r.state == TaskState::Done));
    }

    #[test]
    fn dispatch_cost_shows_in_fill_time() {
        let sim = quiet_sim(8);
        let (out, job) = sim.run_single(uniform_job(
            512,
            ResourceRequest::Cores { cores: 1, mem_mib: 0 },
            240.0,
            1,
        ));
        let stats = out.job_stats(job, 240.0).unwrap();
        let c = CostModel::slurm_like_tx_green();
        let expected_fill = 512.0 * c.dispatch_core;
        assert!(
            (stats.dispatch_span - expected_fill).abs() < 0.5 + expected_fill * 0.2,
            "span {} vs expected {}",
            stats.dispatch_span,
            expected_fill
        );
    }

    #[test]
    fn node_based_fill_is_much_faster_than_core_based() {
        let core = quiet_sim(8).run_single(uniform_job(
            512,
            ResourceRequest::Cores { cores: 1, mem_mib: 0 },
            240.0,
            1,
        ));
        let node = quiet_sim(8).run_single(uniform_job(8, ResourceRequest::WholeNode, 240.0, 64));
        let cs = core.0.job_stats(core.1, 240.0).unwrap();
        let ns = node.0.job_stats(node.1, 240.0).unwrap();
        assert!(
            ns.dispatch_span * 10.0 < cs.dispatch_span,
            "node {} vs core {}",
            ns.dispatch_span,
            cs.dispatch_span
        );
    }

    #[test]
    fn cleanup_serialization_holds_resources() {
        // One node, 64 single-core tasks, all end together: cleanup is
        // serialized so release_span > 0 and grows with array size.
        let sim = quiet_sim(1);
        let (out, job) = sim.run_single(uniform_job(
            64,
            ResourceRequest::Cores { cores: 1, mem_mib: 0 },
            50.0,
            1,
        ));
        let stats = out.job_stats(job, 50.0).unwrap();
        assert!(stats.release_span > 0.0);
        let c = CostModel::slurm_like_tx_green();
        // At least ~64 cleanups' worth of serialized work in the span.
        assert!(stats.release_span >= 32.0 * c.cleanup(64), "{}", stats.release_span);
    }

    #[test]
    fn preemption_releases_resources() {
        let mut sim = quiet_sim(2);
        let mut q = EventQueue::new();
        let spot = sim.submit_at(
            &mut q,
            0.0,
            JobSpec {
                priority: -10,
                preemptable: true,
                ..uniform_job(2, ResourceRequest::WholeNode, 10_000.0, 64)
            },
        );
        sim.preempt_at(&mut q, 50.0, spot);
        let out = sim.run(&mut q);
        assert!(out.records.iter().all(|r| r.state == TaskState::Done));
        // Ended + cleaned at preemption (~50 s), not at 10 000 s. (The
        // stale TaskEnded calendar entries still drain, so final_time is
        // the original horizon — only the records matter.)
        for r in &out.records {
            assert!(r.end_t.unwrap() < 100.0, "end {}", r.end_t.unwrap());
            assert!(r.cleanup_t.unwrap() < 100.0, "cleanup {}", r.cleanup_t.unwrap());
        }
    }

    #[test]
    fn preempting_pending_tasks_cancels_them() {
        // 1 node, 2 whole-node spot tasks: second stays pending; preempt
        // cancels it without it ever running.
        let mut sim = quiet_sim(1);
        let mut q = EventQueue::new();
        let spot = sim.submit_at(
            &mut q,
            0.0,
            JobSpec {
                priority: -10,
                preemptable: true,
                ..uniform_job(2, ResourceRequest::WholeNode, 10_000.0, 64)
            },
        );
        sim.preempt_at(&mut q, 20.0, spot);
        let out = sim.run(&mut q);
        assert!(out.records.iter().all(|r| r.state == TaskState::Done));
        let started: Vec<_> = out
            .records
            .iter()
            .filter(|r| r.cores > 0)
            .collect();
        assert_eq!(started.len(), 1, "only the first task ever ran");
    }

    #[test]
    fn higher_priority_wins_when_resources_free() {
        // One node; a low-priority 2-task job occupies it (task A runs,
        // task B queues). A high-priority job submitted later jumps the
        // queue: when the node frees, it runs before low-priority task B.
        let mut sim = quiet_sim(1);
        let mut q = EventQueue::new();
        let low = sim.submit_at(
            &mut q,
            0.0,
            JobSpec {
                priority: -10,
                ..uniform_job(2, ResourceRequest::WholeNode, 10.0, 64)
            },
        );
        let high = sim.submit_at(
            &mut q,
            1.0,
            JobSpec {
                priority: 10,
                ..uniform_job(1, ResourceRequest::WholeNode, 10.0, 64)
            },
        );
        let out = sim.run(&mut q);
        let hi = out.records.iter().find(|r| r.job == high).unwrap();
        let lo_b = out
            .records
            .iter()
            .filter(|r| r.job == low)
            .max_by(|a, b| a.start_t.partial_cmp(&b.start_t).unwrap())
            .unwrap();
        assert!(
            hi.start_t.unwrap() < lo_b.start_t.unwrap(),
            "high prio {} should start before low-prio task B {}",
            hi.start_t.unwrap(),
            lo_b.start_t.unwrap()
        );
    }

    #[test]
    fn ideal_cost_model_has_zero_overhead() {
        let sim = SchedulerSim::new(
            Cluster::tx_green(2),
            CostModel::ideal(),
            NoiseModel::dedicated(),
            1,
        )
        .with_task_model(TaskModel {
            startup: 0.0,
            jitter_sigma: 0.0,
            p_node_late: 0.0,
            late_range: (0.0, 0.0),
        });
        let (out, job) = sim.run_single(uniform_job(
            128,
            ResourceRequest::Cores { cores: 1, mem_mib: 0 },
            30.0,
            1,
        ));
        let stats = out.job_stats(job, 30.0).unwrap();
        assert!(stats.overhead.abs() < 1e-6, "overhead {}", stats.overhead);
    }

    #[test]
    fn busy_breakdown_accounts_for_work() {
        let sim = quiet_sim(2);
        let (out, _) = sim.run_single(uniform_job(
            128,
            ResourceRequest::Cores { cores: 1, mem_mib: 0 },
            10.0,
            1,
        ));
        let c = CostModel::slurm_like_tx_green();
        assert!((out.busy.dispatch - 128.0 * c.dispatch_core).abs() < 1e-6);
        assert!((out.busy.cleanup - 128.0 * c.cleanup(128)).abs() < 1e-6);
        assert!(out.busy.noise == 0.0);
        assert!(out.busy.total() > 0.0);
    }

    #[test]
    fn timeline_is_monotone_in_time_and_conserves_cores() {
        let sim = quiet_sim(2);
        let (out, _) = sim.run_single(uniform_job(
            100,
            ResourceRequest::Cores { cores: 1, mem_mib: 0 },
            5.0,
            1,
        ));
        let mut prev_t = 0.0;
        for &(t, cores) in &out.timeline {
            assert!(t >= prev_t);
            assert!(cores <= 128);
            prev_t = t;
        }
        assert_eq!(out.timeline.last().unwrap().1, 0);
    }

    #[test]
    fn pool_dispatches_short_whole_node_jobs() {
        let cfg = PoolConfig {
            size: 2,
            min: 1,
            max: 3,
            hysteresis: 0.25,
            short_threshold: 30.0,
        };
        let sim = quiet_sim(4).with_pool(cfg);
        assert!(sim.pool_enabled());
        let (out, _) = sim.run_single(uniform_job(8, ResourceRequest::WholeNode, 5.0, 64));
        assert!(out.records.iter().all(|r| r.state == TaskState::Done));
        let pool = out.pool.expect("pool outcome present");
        assert_eq!(pool.launches, 8, "every short task went through the pool");
        assert_eq!(pool.recent_launches.len(), 8, "small run fits the debug ring");
        assert_eq!(
            out.records.iter().filter(|r| r.pool_shard.is_some()).count(),
            8,
            "every record carries its pool-launch tag"
        );
        assert!(!pool.invariant_violated);
        assert!(pool.peak_leased >= 2 && pool.peak_leased <= 3);
        assert!(out.busy.pool > 0.0, "pool work is accounted");
        assert_eq!(out.busy.dispatch, 0.0, "nothing took the batch path");
        assert_eq!(out.busy.cleanup, 0.0, "pool releases bypass cleanup");
        assert_eq!(out.timeline.last().unwrap().1, 0, "cores conserved");
    }

    #[test]
    fn pool_disabled_is_bit_for_bit_identical() {
        let job = || uniform_job(32, ResourceRequest::WholeNode, 5.0, 64);
        let (plain, _) = quiet_sim(4).run_single(job());
        let (gated, _) = quiet_sim(4)
            .with_pool(PoolConfig::disabled())
            .run_single(job());
        assert!(gated.pool.is_none());
        assert_eq!(plain.events_processed, gated.events_processed);
        for (a, b) in plain.records.iter().zip(&gated.records) {
            assert_eq!(a.start_t, b.start_t);
            assert_eq!(a.end_t, b.end_t);
            assert_eq!(a.cleanup_t, b.cleanup_t);
            assert_eq!(a.cores, b.cores);
        }
    }

    #[test]
    fn pool_grows_by_draining_busy_batch_nodes() {
        // 2 nodes; the pool bootstraps with node 0, a long batch task
        // occupies node 1, then a volley of short jobs forces a grow:
        // with no idle batch node left, node 1 is earmarked (draining),
        // keeps its batch task, and joins the pool when it releases.
        let cfg = PoolConfig {
            size: 1,
            min: 1,
            max: 2,
            hysteresis: 0.25,
            short_threshold: 30.0,
        };
        let mut sim = quiet_sim(2).with_pool(cfg);
        let mut q = EventQueue::new();
        let batch = sim.submit_at(
            &mut q,
            0.0,
            uniform_job(1, ResourceRequest::WholeNode, 50.0, 64),
        );
        let volley = sim.submit_at(
            &mut q,
            1.0,
            uniform_job(6, ResourceRequest::WholeNode, 5.0, 64),
        );
        let out = sim.run(&mut q);
        assert!(out.records.iter().all(|r| r.state == TaskState::Done));
        let pool = out.pool.expect("pool outcome");
        assert_eq!(pool.launches, 6);
        assert!(!pool.invariant_violated);
        assert_eq!(pool.peak_leased, 2, "the drained node joined the pool");
        assert!(pool.grows >= 2, "bootstrap lease + drain both count");
        // The batch task ran to completion on the draining node.
        let b = out.records.iter().find(|r| r.job == batch).unwrap();
        assert!(b.end_t.unwrap() >= 50.0);
        // Volley tasks finished on both nodes eventually.
        let v_done = out.records.iter().filter(|r| r.job == volley).count();
        assert_eq!(v_done, 6);
    }

    #[test]
    fn long_whole_node_jobs_stay_on_the_batch_path() {
        let cfg = PoolConfig {
            size: 1,
            min: 1,
            max: 1,
            hysteresis: 0.25,
            short_threshold: 30.0,
        };
        // Duration above the threshold: batch dispatch, around the
        // leased node (fence), still drains.
        let sim = quiet_sim(4).with_pool(cfg);
        let (out, _) = sim.run_single(uniform_job(3, ResourceRequest::WholeNode, 100.0, 64));
        assert!(out.records.iter().all(|r| r.state == TaskState::Done));
        let pool = out.pool.expect("pool outcome present");
        assert_eq!(pool.launches, 0, "long jobs never route to the pool");
        assert!(!pool.invariant_violated, "batch placements avoided the lease");
        assert!(out.busy.dispatch > 0.0);
    }

    /// Hand-materialize a pending whole-node task slot (unit-level
    /// fixture for `pick_next` tests that bypass the submit path).
    fn pending_whole_node_slot(tid: TaskId) -> TaskSlot {
        TaskSlot {
            spec: SchedTaskSpec {
                request: ResourceRequest::WholeNode,
                duration: 50.0,
                batch: ComputeBatch { count: 1, each: 50.0 },
                lanes: 64,
            },
            est_duration: 50.0,
            enqueued_at: 0.0,
            pool_node: None,
            backfilled: false,
            kill_signalled: false,
            retries: 0,
            fault_node: None,
            killed_at: f64::NAN,
            record: TaskRecord {
                task: tid,
                job: 0,
                state: TaskState::Pending,
                submit_t: 0.0,
                start_t: None,
                end_t: None,
                cleanup_t: None,
                cores: 0,
                pool_shard: None,
            },
            placement: None,
            priority: 0,
        }
    }

    #[test]
    fn multi_hold_ready_scan_dispatches_and_unfences_without_cloning() {
        // Two active holds while the head is blocked: task 0's hold is
        // stale (the task was cancelled, so it is no longer pending) and
        // must be unfenced; task 1's node drained, so it dispatches out
        // of order. Exercises the scratch-buffer hold iteration that
        // replaced the per-pick `holds().to_vec()` clone.
        let mut sim = quiet_sim(2).with_backfill(true).with_holds(2);
        sim.jobs.push(JobMeta::placeholder());
        sim.tasks.push(pending_whole_node_slot(0));
        sim.tasks.push(pending_whole_node_slot(1));
        sim.pending.push(1, 0, 0.0);
        sim.hol_blocked = true;
        assert!(sim.ledger.set_hold(0, 0, 0.0));
        assert!(sim.ledger.set_hold(1, 1, 0.0));

        let picked = sim.pick_next(0.0);
        match picked {
            Some((Op::Dispatch(tid), _)) => assert_eq!(tid, 1, "ready hold's own task"),
            other => panic!("expected hold-ready dispatch, got {other:?}"),
        }
        assert!(sim.ledger.hold_for(0).is_none(), "stale hold unfenced");
        assert!(sim.ledger.hold_for(1).is_some(), "dispatch leaves the hold to start_running");
        assert!(sim.hold_scratch.capacity() >= 2, "scratch buffer retained for reuse");

        // Nothing left to pick: the second pass clears the now-stale
        // hold 1 (its task left the queue) and, in wake-driven mode,
        // drops the backfill dirty flag once both scans come up empty.
        assert!(sim.pick_next(0.0).is_none());
        assert!(!sim.ledger.has_holds());
        assert!(!sim.backfill_dirty, "empty scans clear the gate");
        // A third pick is gated off entirely and stays consistent.
        assert!(sim.pick_next(0.0).is_none());
    }

    #[test]
    fn placement_strategy_defaults_and_overrides() {
        let sim = quiet_sim(2);
        assert_eq!(sim.placement(), Strategy::FirstFit);
        let sim = quiet_sim(2).with_placement(Strategy::Spread);
        assert_eq!(sim.placement(), Strategy::Spread);
        // The run still drains under a non-default policy.
        let (out, _) = sim.run_single(uniform_job(
            64,
            ResourceRequest::Cores { cores: 1, mem_mib: 0 },
            5.0,
            1,
        ));
        assert!(out.records.iter().all(|r| r.state == TaskState::Done));
    }
}
