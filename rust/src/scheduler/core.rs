//! The scheduler simulation core: a single-threaded scheduler server
//! serializing registration, dispatch, cleanup, preemption signalling and
//! background (production) work over the cluster model, driven by the DES
//! engine.
//!
//! This is the substrate the paper's two aggregation modes are measured
//! against. The collapse mechanism at 512-node scale is *emergent*, not
//! scripted: dispatching 32768 core-level scheduling tasks takes longer
//! than T_job = 240 s, so completions start flooding the server while it
//! is still dispatching; cleanup transactions (which cost more than
//! dispatches and grow with array size) then starve dispatch, which
//! delays the remaining placements past the 2500 s mark — exactly the
//! behaviour reported in the paper's §III.B.

use crate::cluster::{Cluster, NodeState};
use crate::scheduler::costmodel::CostModel;
use crate::scheduler::job::{
    JobId, JobSpec, Placement, ResourceRequest, SchedTaskSpec, TaskId, TaskState,
};
use crate::scheduler::noise::NoiseModel;
use crate::scheduler::queue::PendingQueue;
use crate::scheduler::accounting::{JobStats, TaskRecord};
use crate::sim::{self, EventQueue, Time};
use crate::util::rng::Rng;
use std::collections::VecDeque;

/// Events of the scheduler simulation.
#[derive(Debug)]
pub enum SchedEvent {
    /// A job submission arrives at the scheduler.
    Submit(JobId),
    /// The server finished its current operation.
    ServerDone(Op),
    /// A running scheduling task's occupancy ended.
    TaskEnded(TaskId),
    /// Background (production) small-burst arrival.
    NoiseSmall,
    /// Background large-burst arrival (another user's big launch).
    NoiseLarge,
    /// Preemption of a (spot) job is requested.
    Preempt(JobId),
}

/// Operations the server can be busy with.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Op {
    /// Register a submitted job (materialize array tasks).
    Register(JobId),
    /// Scheduling-cycle scan before a batch of dispatches.
    Cycle,
    /// Dispatch one scheduling task.
    Dispatch(TaskId),
    /// Cleanup transaction for one finished task.
    Cleanup(TaskId),
    /// Background work burst of the given demand.
    Noise(f64),
    /// Preemption signal to one running task.
    PreemptSignal(TaskId),
}

/// Per-task live state (record + dispatch bookkeeping).
#[derive(Debug)]
struct TaskSlot {
    spec: SchedTaskSpec,
    record: TaskRecord,
    placement: Option<Placement>,
    priority: i32,
}

/// Per-job metadata.
#[derive(Debug, Clone)]
pub struct JobMeta {
    pub id: JobId,
    pub name: String,
    pub array_size: u64,
    pub reservation: Option<String>,
    pub priority: i32,
    pub preemptable: bool,
    pub submit_t: Time,
}

/// How much server time went to each class of work.
#[derive(Debug, Clone, Copy, Default)]
pub struct BusyBreakdown {
    pub register: Time,
    pub cycle: Time,
    pub dispatch: Time,
    pub cleanup: Time,
    pub noise: Time,
    pub preempt: Time,
}

impl BusyBreakdown {
    /// Total server-busy time.
    pub fn total(&self) -> Time {
        self.register + self.cycle + self.dispatch + self.cleanup + self.noise + self.preempt
    }
}

/// Tunables of the task execution model (outside the scheduler proper).
#[derive(Debug, Clone)]
pub struct TaskModel {
    /// Fixed startup overhead when a scheduling task launches on its
    /// resources (script spin-up, binary load).
    pub startup: Time,
    /// Additive half-normal jitter sigma on occupancy duration.
    pub jitter_sigma: f64,
    /// Probability that a *whole-node* allocation joins late in
    /// production mode, at full (512-node) machine scale; the effective
    /// probability is `p_node_late × (cluster_nodes / 512)²` — grabbing
    /// nearly the whole machine inevitably includes draining nodes,
    /// while partial allocations pick from spare capacity. Core-level
    /// requests fit into gaps and do not suffer drain contention.
    pub p_node_late: f64,
    /// Late-join delay range, seconds.
    pub late_range: (Time, Time),
}

impl Default for TaskModel {
    fn default() -> Self {
        TaskModel {
            startup: 0.8,
            jitter_sigma: 0.4,
            p_node_late: 0.0008,
            late_range: (20.0, 250.0),
        }
    }
}

/// Everything measured from one simulation run.
#[derive(Debug)]
pub struct SimOutcome {
    pub records: Vec<TaskRecord>,
    pub jobs: Vec<JobMeta>,
    /// `(time, running_cores)` after each change (Fig 2 raw series).
    pub timeline: Vec<(Time, u64)>,
    pub busy: BusyBreakdown,
    pub final_time: Time,
    pub events_processed: u64,
    /// Peak completion backlog (responsiveness indicator).
    pub max_completion_backlog: usize,
    /// Longest continuous stretch of server-busy time (the paper's
    /// "scheduler becomes unresponsive" indicator).
    pub longest_busy_stretch: Time,
}

impl SimOutcome {
    /// Job statistics (Table III row ingredients) for one job.
    pub fn job_stats(&self, job: JobId, t_job: Time) -> Option<JobStats> {
        JobStats::compute(job, &self.records, t_job)
    }

    /// The paper's responsiveness guard: a production scheduler is
    /// "unusable" when it stays saturated for minutes at a time.
    pub fn unusable_in_production(&self) -> bool {
        self.longest_busy_stretch > 60.0
    }
}

/// The scheduler simulation actor. Create, submit jobs, then [`Self::run`].
pub struct SchedulerSim {
    cluster: Cluster,
    cost: CostModel,
    noise: NoiseModel,
    task_model: TaskModel,
    rng: Rng,
    production: bool,

    specs: Vec<Option<JobSpec>>, // consumed at Submit
    jobs: Vec<JobMeta>,
    tasks: Vec<TaskSlot>,
    pending: PendingQueue,
    completions: VecDeque<TaskId>,
    preempt_q: VecDeque<TaskId>,
    noise_q: VecDeque<f64>,

    /// Per-run multiplicative factor on all server op costs (hardware /
    /// kernel / filesystem variability between runs; sampled log-normal,
    /// σ = 5 %). Gives dedicated-system runs the paper's natural spread.
    op_scale: f64,
    server_busy: bool,
    busy_since: Time,
    longest_busy_stretch: Time,
    hol_blocked: bool,
    cycle_budget: u32,
    cleanups_since_dispatch: u32,

    busy: BusyBreakdown,
    running_cores: u64,
    /// Raw `(time, ±cores)` deltas; late-joining nodes stamp their start
    /// in the future relative to the dispatch event, so deltas are sorted
    /// and prefix-summed into the absolute series when the run finishes.
    timeline: Vec<(Time, i64)>,
    record_timeline: bool,
    max_completion_backlog: usize,
}

impl SchedulerSim {
    /// New simulation over `cluster`. `production = !dedicated` enables
    /// the background-noise process and node-churn late joins.
    pub fn new(cluster: Cluster, cost: CostModel, noise: NoiseModel, seed: u64) -> SchedulerSim {
        let production = noise.mean_load() > 0.0;
        let mut rng = Rng::new(seed);
        let op_scale = rng.lognormal(0.0, 0.05);
        SchedulerSim {
            cluster,
            cost,
            noise,
            task_model: TaskModel::default(),
            rng,
            production,
            op_scale,
            specs: Vec::new(),
            jobs: Vec::new(),
            tasks: Vec::new(),
            pending: PendingQueue::new(),
            completions: VecDeque::new(),
            preempt_q: VecDeque::new(),
            noise_q: VecDeque::new(),
            server_busy: false,
            busy_since: 0.0,
            longest_busy_stretch: 0.0,
            hol_blocked: false,
            cycle_budget: 0,
            cleanups_since_dispatch: 0,
            busy: BusyBreakdown::default(),
            running_cores: 0,
            timeline: Vec::new(),
            record_timeline: true,
            max_completion_backlog: 0,
        }
    }

    /// Override the task execution model.
    pub fn with_task_model(mut self, tm: TaskModel) -> Self {
        self.task_model = tm;
        self
    }

    /// Disable the (possibly large) utilization timeline recording.
    pub fn without_timeline(mut self) -> Self {
        self.record_timeline = false;
        self
    }

    /// Fix the per-run server-speed factor (tests use 1.0 for exact
    /// accounting; experiments keep the sampled value).
    pub fn with_server_speed(mut self, scale: f64) -> Self {
        assert!(scale > 0.0);
        self.op_scale = scale;
        self
    }

    /// Queue a job for submission at virtual time `t`. Returns its id.
    pub fn submit_at(&mut self, q: &mut EventQueue<SchedEvent>, t: Time, spec: JobSpec) -> JobId {
        let id = self.specs.len() as JobId;
        self.specs.push(Some(spec));
        q.at(t, SchedEvent::Submit(id));
        id
    }

    /// Request preemption of a job at virtual time `t`.
    pub fn preempt_at(&mut self, q: &mut EventQueue<SchedEvent>, t: Time, job: JobId) {
        q.at(t, SchedEvent::Preempt(job));
    }

    /// Drive the simulation to completion and return the outcome.
    pub fn run(mut self, q: &mut EventQueue<SchedEvent>) -> SimOutcome {
        self.prime_noise(q);
        let (final_time, events) = sim::run(&mut self, q);
        let mut deltas = self.timeline;
        deltas.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("no NaN times"));
        let mut running: i64 = 0;
        let timeline: Vec<(Time, u64)> = deltas
            .into_iter()
            .map(|(t, d)| {
                running += d;
                debug_assert!(running >= 0, "negative core count in timeline");
                (t, running as u64)
            })
            .collect();
        SimOutcome {
            records: self.tasks.into_iter().map(|t| t.record).collect(),
            jobs: self.jobs,
            timeline,
            busy: self.busy,
            final_time,
            events_processed: events,
            max_completion_backlog: self.max_completion_backlog,
            longest_busy_stretch: self.longest_busy_stretch,
        }
    }

    /// Convenience: run a single job on a fresh queue; returns
    /// `(outcome, job_id)`.
    pub fn run_single(mut self, spec: JobSpec) -> (SimOutcome, JobId) {
        let mut q = EventQueue::new();
        let id = self.submit_at(&mut q, 0.0, spec);
        (self.run(&mut q), id)
    }

    fn prime_noise(&mut self, q: &mut EventQueue<SchedEvent>) {
        if let Some((gap, _)) = self.noise.next_small(&mut self.rng) {
            q.after(gap, SchedEvent::NoiseSmall);
        }
        if let Some((gap, _)) = self.noise.next_large(&mut self.rng) {
            q.after(gap, SchedEvent::NoiseLarge);
        }
    }

    // ---- server loop -----------------------------------------------------

    /// If the server is idle, pick the next operation and start it.
    fn kick(&mut self, now: Time, q: &mut EventQueue<SchedEvent>) {
        if self.server_busy {
            return;
        }
        if let Some((op, cost)) = self.pick_next() {
            self.server_busy = true;
            self.busy_since = now;
            q.after(cost, SchedEvent::ServerDone(op));
        }
    }

    /// Work-conserving service discipline (see module docs):
    /// noise → preempt signals → cleanups (with bounded dispatch
    /// interleave) → dispatches (cycle-batched).
    fn pick_next(&mut self) -> Option<(Op, Time)> {
        let s = self.op_scale;
        if let Some(demand) = self.noise_q.pop_front() {
            return Some((Op::Noise(demand), demand * s));
        }
        if let Some(t) = self.preempt_q.pop_front() {
            return Some((Op::PreemptSignal(t), self.cost.preempt_signal * s));
        }
        let can_dispatch = !self.pending.is_empty() && !self.hol_blocked;
        if !self.completions.is_empty() {
            let must_interleave =
                can_dispatch && self.cleanups_since_dispatch >= self.cost.cleanup_interleave;
            if !must_interleave {
                let tid = self.completions.pop_front().expect("checked non-empty");
                self.cleanups_since_dispatch += 1;
                let array = self.jobs[self.tasks[tid as usize].record.job as usize].array_size;
                return Some((Op::Cleanup(tid), self.cost.cleanup(array) * s));
            }
        }
        if can_dispatch {
            if self.cycle_budget == 0 {
                return Some((Op::Cycle, self.cost.cycle(self.pending.len()) * s));
            }
            let tid = self.pending.pop().expect("checked non-empty");
            self.cleanups_since_dispatch = 0;
            self.cycle_budget -= 1;
            let node_level =
                self.tasks[tid as usize].spec.request == ResourceRequest::WholeNode;
            return Some((Op::Dispatch(tid), self.cost.dispatch(node_level) * s));
        }
        None
    }

    fn apply_op(&mut self, now: Time, op: Op, q: &mut EventQueue<SchedEvent>) {
        match op {
            Op::Register(job) => {
                self.busy.register +=
                    self.cost.submit(self.jobs[job as usize].array_size) * self.op_scale;
                // Materialized at Submit; now they become schedulable.
                let prio = self.jobs[job as usize].priority;
                let ids: Vec<TaskId> = self
                    .tasks
                    .iter()
                    .filter(|t| t.record.job == job && t.record.state == TaskState::Pending)
                    .map(|t| t.record.task)
                    .collect();
                for tid in ids {
                    self.pending.push(tid, prio);
                }
            }
            Op::Cycle => {
                self.busy.cycle += self.cost.cycle(self.pending.len()) * self.op_scale;
                self.cycle_budget = self.cost.dispatch_cycle_batch;
            }
            Op::Dispatch(tid) => {
                let node_level =
                    self.tasks[tid as usize].spec.request == ResourceRequest::WholeNode;
                self.busy.dispatch += self.cost.dispatch(node_level) * self.op_scale;
                self.try_place(now, tid, q);
            }
            Op::Cleanup(tid) => {
                let array = self.jobs[self.tasks[tid as usize].record.job as usize].array_size;
                self.busy.cleanup += self.cost.cleanup(array) * self.op_scale;
                self.finish_cleanup(now, tid);
            }
            Op::Noise(d) => {
                self.busy.noise += d * self.op_scale;
            }
            Op::PreemptSignal(tid) => {
                self.busy.preempt += self.cost.preempt_signal * self.op_scale;
                self.apply_preempt_signal(now, tid);
            }
        }
    }

    /// Attempt placement of a dispatched task; on failure the task goes
    /// back to the head of the queue and dispatch blocks until a cleanup
    /// frees resources.
    fn try_place(&mut self, now: Time, tid: TaskId, q: &mut EventQueue<SchedEvent>) {
        let slot = &self.tasks[tid as usize];
        let job = &self.jobs[slot.record.job as usize];
        let reservation = job.reservation.clone();
        let request = slot.spec.request;
        let placement = match request {
            ResourceRequest::WholeNode => {
                let nodes = self.cluster.find_idle_nodes(1, reservation.as_deref());
                nodes.first().copied().map(|node| {
                    let mem = self.cluster.node(node).expect("valid node").free_mem_mib();
                    let mask = self
                        .cluster
                        .node_mut(node)
                        .expect("valid node")
                        .allocate_whole()
                        .expect("idle node allocates");
                    Placement { node, mask, mem_mib: mem }
                })
            }
            ResourceRequest::Cores { cores, mem_mib } => self
                .cluster
                .find_fit_node(cores, mem_mib, reservation.as_deref())
                .map(|node| {
                    let mask = self
                        .cluster
                        .allocate_on(node, cores, mem_mib)
                        .expect("fit search said it fits");
                    Placement { node, mask, mem_mib }
                }),
        };
        match placement {
            Some(p) => {
                // Production node-churn: whole-node allocations on a
                // near-machine-scale job occasionally get a node that is
                // still draining and joins late.
                let cores = p.mask.count();
                let whole_node = request == ResourceRequest::WholeNode;
                let late = if self.production && whole_node {
                    let frac = self.cluster.n_nodes() as f64 / 512.0;
                    let prob = self.task_model.p_node_late * frac * frac;
                    if self.rng.chance(prob.min(1.0)) {
                        self.rng
                            .range_f64(self.task_model.late_range.0, self.task_model.late_range.1)
                    } else {
                        0.0
                    }
                } else {
                    0.0
                };
                let start = now + late;
                let slot = &mut self.tasks[tid as usize];
                slot.record.state = TaskState::Running;
                slot.record.start_t = Some(start);
                slot.record.cores = cores;
                slot.placement = Some(p);
                let jitter = self.rng.normal().abs() * self.task_model.jitter_sigma;
                let occupancy = self.task_model.startup + slot.spec.duration + jitter;
                self.running_cores += cores as u64;
                if self.record_timeline {
                    self.timeline.push((start, cores as i64));
                }
                q.at(start + occupancy, SchedEvent::TaskEnded(tid));
            }
            None => {
                // Head-of-line blocked: wait for resources to free.
                let prio = self.tasks[tid as usize].priority;
                self.pending.push_front(tid, prio);
                self.cycle_budget = 0; // a fresh cycle rescans when unblocked
                self.hol_blocked = true;
            }
        }
    }

    fn finish_cleanup(&mut self, now: Time, tid: TaskId) {
        let slot = &mut self.tasks[tid as usize];
        debug_assert!(
            slot.record.state == TaskState::Completing
                || slot.record.state == TaskState::Preempted,
            "cleanup of task in state {:?}",
            slot.record.state
        );
        slot.record.state = TaskState::Done;
        slot.record.cleanup_t = Some(now);
        if let Some(p) = slot.placement.take() {
            self.cluster
                .release_on(p.node, &p.mask, p.mem_mib)
                .expect("release of held placement");
        }
        // Resources freed: head-of-line dispatch may proceed.
        self.hol_blocked = false;
    }

    fn apply_preempt_signal(&mut self, now: Time, tid: TaskId) {
        let slot = &mut self.tasks[tid as usize];
        if slot.record.state != TaskState::Running {
            return; // finished on its own before the signal landed
        }
        slot.record.state = TaskState::Preempted;
        slot.record.end_t = Some(now);
        let cores = slot.record.cores as u64;
        self.running_cores -= cores;
        if self.record_timeline {
            self.timeline.push((now, -(cores as i64)));
        }
        self.completions.push_back(tid);
        self.note_backlog();
    }

    fn note_backlog(&mut self) {
        if self.completions.len() > self.max_completion_backlog {
            self.max_completion_backlog = self.completions.len();
        }
    }
}

impl sim::Actor for SchedulerSim {
    type Event = SchedEvent;

    fn handle(&mut self, now: Time, ev: SchedEvent, q: &mut EventQueue<SchedEvent>) {
        match ev {
            SchedEvent::Submit(id) => {
                let spec = self.specs[id as usize].take().expect("double submit");
                spec.validate(64).expect("invalid job spec submitted");
                let meta = JobMeta {
                    id,
                    name: spec.name.clone(),
                    array_size: spec.array_size(),
                    reservation: spec.reservation.clone(),
                    priority: spec.priority,
                    preemptable: spec.preemptable,
                    submit_t: now,
                };
                // Materialize task slots (records in PENDING).
                for t in &spec.tasks {
                    let tid = self.tasks.len() as TaskId;
                    self.tasks.push(TaskSlot {
                        spec: t.clone(),
                        record: TaskRecord {
                            task: tid,
                            job: id,
                            state: TaskState::Pending,
                            submit_t: now,
                            start_t: None,
                            end_t: None,
                            cleanup_t: None,
                            cores: 0,
                        },
                        placement: None,
                        priority: spec.priority,
                    });
                }
                while self.jobs.len() <= id as usize {
                    // placeholder ordering safety (ids are dense by construction)
                    self.jobs.push(meta.clone());
                }
                self.jobs[id as usize] = meta;
                // Registration is server work.
                let cost = self.cost.submit(spec.array_size());
                if self.server_busy {
                    // Serialize behind current op by queueing as noise-less
                    // op: model keeps it simple — registration happens when
                    // the server frees up; we enqueue a zero-arrival noise
                    // slot carrying the register op via the preempt path.
                    // Simpler: treat registration as an immediate follow-up
                    // event retry.
                    q.after(sim::TICK, SchedEvent::Submit(id));
                    // restore spec for retry
                    self.specs[id as usize] = Some(spec);
                    // drop the duplicate task slots we just materialized
                    for _ in 0..self.jobs[id as usize].array_size {
                        self.tasks.pop();
                    }
                    return;
                }
                self.server_busy = true;
                self.busy_since = now;
                q.after(cost * self.op_scale, SchedEvent::ServerDone(Op::Register(id)));
            }
            SchedEvent::ServerDone(op) => {
                self.apply_op(now, op, q);
                self.server_busy = false;
                // Background bursts do not count as *scheduler* saturation:
                // the unusable-in-production guard measures the load this
                // job itself puts on the server, matching the paper's
                // observation about multi-level runs.
                let is_noise = matches!(op, Op::Noise(_));
                let stretch_started = if is_noise { now } else { self.busy_since };
                let stretch = now - stretch_started;
                if stretch > self.longest_busy_stretch {
                    self.longest_busy_stretch = stretch;
                }
                self.kick(now, q);
                if self.server_busy {
                    // The server went straight back to work: this is one
                    // continuous saturated stretch, so keep its start time.
                    self.busy_since = stretch_started;
                }
            }
            SchedEvent::TaskEnded(tid) => {
                let slot = &mut self.tasks[tid as usize];
                if slot.record.state != TaskState::Running {
                    return; // stale (e.g. preempted)
                }
                slot.record.state = TaskState::Completing;
                slot.record.end_t = Some(now);
                let cores = slot.record.cores as u64;
                self.running_cores -= cores;
                if self.record_timeline {
                    self.timeline.push((now, -(cores as i64)));
                }
                self.completions.push_back(tid);
                self.note_backlog();
                self.kick(now, q);
            }
            SchedEvent::NoiseSmall => {
                if let Some((gap, demand)) = self.noise.next_small(&mut self.rng) {
                    self.noise_q.push_back(demand);
                    // Only keep the process alive while user work exists;
                    // otherwise the sim would never terminate.
                    if self.has_outstanding_work() {
                        q.after(gap, SchedEvent::NoiseSmall);
                    }
                }
                self.kick(now, q);
            }
            SchedEvent::NoiseLarge => {
                if let Some((gap, demand)) = self.noise.next_large(&mut self.rng) {
                    self.noise_q.push_back(demand);
                    if self.has_outstanding_work() {
                        q.after(gap, SchedEvent::NoiseLarge);
                    }
                }
                self.kick(now, q);
            }
            SchedEvent::Preempt(job) => {
                // Pending tasks of the job are simply removed (cheap, no
                // server involvement beyond the dequeue).
                let ids: Vec<TaskId> = self
                    .tasks
                    .iter()
                    .filter(|t| t.record.job == job)
                    .map(|t| t.record.task)
                    .collect();
                for tid in ids {
                    match self.tasks[tid as usize].record.state {
                        TaskState::Pending => {
                            if self.pending.remove(tid) {
                                let slot = &mut self.tasks[tid as usize];
                                slot.record.state = TaskState::Done;
                                slot.record.start_t = Some(now);
                                slot.record.end_t = Some(now);
                                slot.record.cleanup_t = Some(now);
                            }
                        }
                        TaskState::Running => self.preempt_q.push_back(tid),
                        _ => {}
                    }
                }
                self.kick(now, q);
            }
        }
    }
}

impl SchedulerSim {
    fn has_outstanding_work(&self) -> bool {
        !self.pending.is_empty()
            || !self.completions.is_empty()
            || !self.preempt_q.is_empty()
            || self.running_cores > 0
            || self.tasks.iter().any(|t| {
                matches!(
                    t.record.state,
                    TaskState::Pending | TaskState::Running | TaskState::Completing
                )
            })
    }

    /// Number of nodes currently fully idle (test/metric helper).
    pub fn idle_nodes(&self) -> usize {
        self.cluster
            .nodes()
            .filter(|n| n.state() == NodeState::Up && n.is_idle())
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::job::ComputeBatch;

    fn uniform_job(
        n_tasks: usize,
        request: ResourceRequest,
        duration: f64,
        lanes: u32,
    ) -> JobSpec {
        JobSpec {
            name: "test".into(),
            tasks: vec![
                SchedTaskSpec {
                    request,
                    duration,
                    batch: ComputeBatch { count: 1, each: duration },
                    lanes,
                };
                n_tasks
            ],
            reservation: None,
            priority: 0,
            preemptable: false,
        }
    }

    fn quiet_sim(nodes: u32) -> SchedulerSim {
        SchedulerSim::new(
            Cluster::tx_green(nodes),
            CostModel::slurm_like_tx_green(),
            NoiseModel::dedicated(),
            42,
        )
        .with_task_model(TaskModel {
            startup: 0.0,
            jitter_sigma: 0.0,
            p_node_late: 0.0,
            late_range: (0.0, 0.0),
        })
        .with_server_speed(1.0)
    }

    #[test]
    fn single_node_task_full_lifecycle() {
        let sim = quiet_sim(1);
        let (out, job) = sim.run_single(uniform_job(1, ResourceRequest::WholeNode, 100.0, 64));
        let stats = out.job_stats(job, 100.0).unwrap();
        assert_eq!(stats.array_size, 1);
        assert!((stats.runtime - 100.0).abs() < 1e-6, "{}", stats.runtime);
        let r = &out.records[0];
        assert_eq!(r.state, TaskState::Done);
        assert_eq!(r.cores, 64);
        assert!(r.cleanup_t.unwrap() >= r.end_t.unwrap());
    }

    #[test]
    fn all_tasks_complete_and_resources_return() {
        let sim = quiet_sim(4);
        let (out, _) = sim.run_single(uniform_job(
            256,
            ResourceRequest::Cores { cores: 1, mem_mib: 16 },
            10.0,
            1,
        ));
        assert!(out.records.iter().all(|r| r.state == TaskState::Done));
        assert_eq!(out.records.len(), 256);
        // Timeline returns to zero.
        assert_eq!(out.timeline.last().unwrap().1, 0);
    }

    #[test]
    fn oversubscription_serializes_wave_by_wave() {
        // 2 nodes × 64 cores, 256 single-core 10 s tasks → ≥2 waves.
        let sim = quiet_sim(2);
        let (out, job) = sim.run_single(uniform_job(
            256,
            ResourceRequest::Cores { cores: 1, mem_mib: 0 },
            10.0,
            1,
        ));
        let stats = out.job_stats(job, 10.0).unwrap();
        // 256 tasks on 128 cores: runtime at least 2 waves of 10 s.
        assert!(stats.runtime >= 20.0 - 1e-9, "runtime {}", stats.runtime);
        assert!(out.records.iter().all(|r| r.state == TaskState::Done));
    }

    #[test]
    fn dispatch_cost_shows_in_fill_time() {
        let sim = quiet_sim(8);
        let (out, job) = sim.run_single(uniform_job(
            512,
            ResourceRequest::Cores { cores: 1, mem_mib: 0 },
            240.0,
            1,
        ));
        let stats = out.job_stats(job, 240.0).unwrap();
        let c = CostModel::slurm_like_tx_green();
        let expected_fill = 512.0 * c.dispatch_core;
        assert!(
            (stats.dispatch_span - expected_fill).abs() < 0.5 + expected_fill * 0.2,
            "span {} vs expected {}",
            stats.dispatch_span,
            expected_fill
        );
    }

    #[test]
    fn node_based_fill_is_much_faster_than_core_based() {
        let core = quiet_sim(8)
            .run_single(uniform_job(512, ResourceRequest::Cores { cores: 1, mem_mib: 0 }, 240.0, 1));
        let node = quiet_sim(8).run_single(uniform_job(8, ResourceRequest::WholeNode, 240.0, 64));
        let cs = core.0.job_stats(core.1, 240.0).unwrap();
        let ns = node.0.job_stats(node.1, 240.0).unwrap();
        assert!(
            ns.dispatch_span * 10.0 < cs.dispatch_span,
            "node {} vs core {}",
            ns.dispatch_span,
            cs.dispatch_span
        );
    }

    #[test]
    fn cleanup_serialization_holds_resources() {
        // One node, 64 single-core tasks, all end together: cleanup is
        // serialized so release_span > 0 and grows with array size.
        let sim = quiet_sim(1);
        let (out, job) = sim.run_single(uniform_job(
            64,
            ResourceRequest::Cores { cores: 1, mem_mib: 0 },
            50.0,
            1,
        ));
        let stats = out.job_stats(job, 50.0).unwrap();
        assert!(stats.release_span > 0.0);
        let c = CostModel::slurm_like_tx_green();
        // At least ~64 cleanups' worth of serialized work in the span.
        assert!(stats.release_span >= 32.0 * c.cleanup(64), "{}", stats.release_span);
    }

    #[test]
    fn preemption_releases_resources() {
        let mut sim = quiet_sim(2);
        let mut q = EventQueue::new();
        let spot = sim.submit_at(
            &mut q,
            0.0,
            JobSpec {
                priority: -10,
                preemptable: true,
                ..uniform_job(2, ResourceRequest::WholeNode, 10_000.0, 64)
            },
        );
        sim.preempt_at(&mut q, 50.0, spot);
        let out = sim.run(&mut q);
        assert!(out.records.iter().all(|r| r.state == TaskState::Done));
        // Ended + cleaned at preemption (~50 s), not at 10 000 s. (The
        // stale TaskEnded calendar entries still drain, so final_time is
        // the original horizon — only the records matter.)
        for r in &out.records {
            assert!(r.end_t.unwrap() < 100.0, "end {}", r.end_t.unwrap());
            assert!(r.cleanup_t.unwrap() < 100.0, "cleanup {}", r.cleanup_t.unwrap());
        }
    }

    #[test]
    fn preempting_pending_tasks_cancels_them() {
        // 1 node, 2 whole-node spot tasks: second stays pending; preempt
        // cancels it without it ever running.
        let mut sim = quiet_sim(1);
        let mut q = EventQueue::new();
        let spot = sim.submit_at(
            &mut q,
            0.0,
            JobSpec {
                priority: -10,
                preemptable: true,
                ..uniform_job(2, ResourceRequest::WholeNode, 10_000.0, 64)
            },
        );
        sim.preempt_at(&mut q, 20.0, spot);
        let out = sim.run(&mut q);
        assert!(out.records.iter().all(|r| r.state == TaskState::Done));
        let started: Vec<_> = out
            .records
            .iter()
            .filter(|r| r.cores > 0)
            .collect();
        assert_eq!(started.len(), 1, "only the first task ever ran");
    }

    #[test]
    fn higher_priority_wins_when_resources_free() {
        // One node; a low-priority 2-task job occupies it (task A runs,
        // task B queues). A high-priority job submitted later jumps the
        // queue: when the node frees, it runs before low-priority task B.
        let mut sim = quiet_sim(1);
        let mut q = EventQueue::new();
        let low = sim.submit_at(
            &mut q,
            0.0,
            JobSpec {
                priority: -10,
                ..uniform_job(2, ResourceRequest::WholeNode, 10.0, 64)
            },
        );
        let high = sim.submit_at(
            &mut q,
            1.0,
            JobSpec {
                priority: 10,
                ..uniform_job(1, ResourceRequest::WholeNode, 10.0, 64)
            },
        );
        let out = sim.run(&mut q);
        let hi = out.records.iter().find(|r| r.job == high).unwrap();
        let lo_b = out
            .records
            .iter()
            .filter(|r| r.job == low)
            .max_by(|a, b| a.start_t.partial_cmp(&b.start_t).unwrap())
            .unwrap();
        assert!(
            hi.start_t.unwrap() < lo_b.start_t.unwrap(),
            "high prio {} should start before low-prio task B {}",
            hi.start_t.unwrap(),
            lo_b.start_t.unwrap()
        );
    }

    #[test]
    fn ideal_cost_model_has_zero_overhead() {
        let sim = SchedulerSim::new(
            Cluster::tx_green(2),
            CostModel::ideal(),
            NoiseModel::dedicated(),
            1,
        )
        .with_task_model(TaskModel {
            startup: 0.0,
            jitter_sigma: 0.0,
            p_node_late: 0.0,
            late_range: (0.0, 0.0),
        });
        let (out, job) = sim.run_single(uniform_job(
            128,
            ResourceRequest::Cores { cores: 1, mem_mib: 0 },
            30.0,
            1,
        ));
        let stats = out.job_stats(job, 30.0).unwrap();
        assert!(stats.overhead.abs() < 1e-6, "overhead {}", stats.overhead);
    }

    #[test]
    fn busy_breakdown_accounts_for_work() {
        let sim = quiet_sim(2);
        let (out, _) = sim.run_single(uniform_job(
            128,
            ResourceRequest::Cores { cores: 1, mem_mib: 0 },
            10.0,
            1,
        ));
        let c = CostModel::slurm_like_tx_green();
        assert!((out.busy.dispatch - 128.0 * c.dispatch_core).abs() < 1e-6);
        assert!((out.busy.cleanup - 128.0 * c.cleanup(128)).abs() < 1e-6);
        assert!(out.busy.noise == 0.0);
        assert!(out.busy.total() > 0.0);
    }

    #[test]
    fn timeline_is_monotone_in_time_and_conserves_cores() {
        let sim = quiet_sim(2);
        let (out, _) = sim.run_single(uniform_job(
            100,
            ResourceRequest::Cores { cores: 1, mem_mib: 0 },
            5.0,
            1,
        ));
        let mut prev_t = 0.0;
        for &(t, cores) in &out.timeline {
            assert!(t >= prev_t);
            assert!(cores <= 128);
            prev_t = t;
        }
        assert_eq!(out.timeline.last().unwrap().1, 0);
    }
}
