//! Task lifecycle: placement, completion, cleanup and preemption.
//!
//! These are the effects of the server's operations on the task state
//! machine (`PENDING → RUNNING → COMPLETING → DONE`, with `PREEMPTED`
//! off the running state) and on the cluster's resources. All resource
//! allocation and release flows through the placement engine
//! ([`crate::placement::PlacementEngine`]), so the free-capacity index
//! is maintained incrementally and dispatch never scans the node table.

use crate::cluster::{NodeId, NodeState};
use crate::fault::audit::{AuditEvent, FaultReason};
use crate::obs::TraceKind;
use crate::pool::Resize;
use crate::scheduler::core::{BackfillEvent, SchedEvent, SchedulerSim};
use crate::scheduler::job::{JobId, Placement, ResourceRequest, TaskId, TaskState};
use crate::sim::{EventQueue, Time};

impl SchedulerSim {
    /// Attempt placement of a dispatched task; on failure the task goes
    /// back to the head of the queue and dispatch blocks until a cleanup
    /// frees resources. With backfill enabled, a block additionally
    /// plans earliest-start reservations — for the failing whole-node
    /// head and, with multi-hold (K > 1), the next blocked whole-node
    /// tasks in the lookahead window — and all placements made while
    /// holds are active are filtered so they cannot delay any of them.
    pub(crate) fn try_place(&mut self, now: Time, tid: TaskId, q: &mut EventQueue<SchedEvent>) {
        let (request, reservation) = {
            let slot = &self.tasks[tid as usize];
            let job = &self.jobs[slot.record.job as usize];
            (slot.spec.request, job.reservation.clone())
        };
        let hold_active = self.backfill && self.ledger.has_holds();
        // While the rapid-launch fleet owns nodes, every batch placement
        // goes through the filtered queries so leased/draining nodes
        // (of every shard) are fenced out; with the fleet off (or empty)
        // the unfiltered fast paths below are bit-for-bit the historical
        // behaviour.
        let pool_fence = self.pool.as_ref().map(|p| p.fleet.any_pooled()).unwrap_or(false);
        let placement = match request {
            ResourceRequest::WholeNode => {
                if hold_active || pool_fence {
                    // The held node is fenced off for the reservation's
                    // own task; everyone else picks around it — and
                    // nobody takes a pool-owned node.
                    let ledger = &self.ledger;
                    let pool = self.pool.as_ref().map(|p| &p.fleet);
                    self.engine.place_whole_where(
                        &mut self.cluster,
                        reservation.as_deref(),
                        &|n| {
                            (!hold_active || ledger.allows_whole_node(n, tid))
                                && pool.map(|pn| !pn.in_pool(n)).unwrap_or(true)
                        },
                    )
                } else {
                    self.engine
                        .place_whole(&mut self.cluster, reservation.as_deref())
                }
            }
            ResourceRequest::Cores { cores, mem_mib } => {
                if hold_active || pool_fence {
                    // Admission uses the walltime estimate, exactly as
                    // the backfill scan does (exact when the error
                    // model is off).
                    let est_end =
                        now + self.task_model.startup + self.tasks[tid as usize].est_duration;
                    let ledger = &self.ledger;
                    let pool = self.pool.as_ref().map(|p| &p.fleet);
                    self.engine.place_cores_where(
                        &mut self.cluster,
                        cores,
                        mem_mib,
                        reservation.as_deref(),
                        &|n| {
                            (!hold_active || ledger.allows_backfill(n, est_end))
                                && pool.map(|pn| !pn.in_pool(n)).unwrap_or(true)
                        },
                    )
                } else {
                    self.engine.place_cores(
                        &mut self.cluster,
                        cores,
                        mem_mib,
                        reservation.as_deref(),
                    )
                }
            }
        };
        match placement {
            Some(p) => {
                self.start_running(now, tid, p, request == ResourceRequest::WholeNode, q);
            }
            None => {
                // Wait-cause marker for the span layer: a fenced
                // failure (holds active or pool-owned nodes excluded)
                // is a fence-reject (code 2); an unconstrained failure
                // is plain head-of-line capacity blocking (code 0).
                let cause = if hold_active || pool_fence { 2 } else { 0 };
                self.trace(TraceKind::WaitCause, cause, tid, now, 0);
                if self.backfill {
                    self.plan_holds(now, tid, request);
                }
                // Head-of-line blocked: wait for resources to free. The
                // reinsertion carries the original enqueue timestamp so
                // retries never reset aging credit.
                let prio = self.tasks[tid as usize].priority;
                let enqueued_at = self.tasks[tid as usize].enqueued_at;
                self.pending.push_front(tid, prio, enqueued_at);
                self.cycle_budget = 0; // a fresh cycle rescans when unblocked
                self.hol_blocked = true;
                // Fresh block, fresh holds: the backfill scans must run.
                self.backfill_dirty = true;
            }
        }
    }

    /// Apply a successful placement: state transition, accounting,
    /// ledger bookkeeping, and the occupancy-end event. Shared by the
    /// normal dispatch path and the backfill path; the RNG call order
    /// (late-join draw, then jitter draw) matches the historical
    /// `try_place` body exactly, so existing seeds reproduce.
    pub(crate) fn start_running(
        &mut self,
        now: Time,
        tid: TaskId,
        p: Placement,
        whole_node: bool,
        q: &mut EventQueue<SchedEvent>,
    ) {
        // Production node-churn: whole-node allocations on a
        // near-machine-scale job occasionally get a node that is
        // still draining and joins late.
        let cores = p.mask.count();
        let node = p.node;
        // A batch placement on a pool-owned node means the fence broke
        // somewhere: record it for the pool property suite.
        if let Some(pl) = self.pool.as_mut() {
            if pl.fleet.in_pool(node) {
                pl.fleet.violated = true;
            }
        }
        let late = if self.production && whole_node {
            let frac = self.cluster.n_nodes() as f64 / 512.0;
            let prob = self.task_model.p_node_late * frac * frac;
            if self.rng.chance(prob.min(1.0)) {
                self.rng
                    .range_f64(self.task_model.late_range.0, self.task_model.late_range.1)
            } else {
                0.0
            }
        } else {
            0.0
        };
        let start = now + late;
        self.note_restart(now, tid);
        let slot = &mut self.tasks[tid as usize];
        slot.record.state = TaskState::Running;
        slot.record.start_t = Some(start);
        slot.record.cores = cores;
        slot.placement = Some(p);
        let jitter = self.rng.normal().abs() * self.task_model.jitter_sigma;
        let occupancy = self.task_model.startup + slot.spec.duration + jitter;
        // The ledger plans from walltime *estimates*: with an error
        // model installed the expected end is the declared one (startup
        // + estimate), not the DES oracle's exact occupancy — overdue
        // holds are re-planned when the mismatch surfaces. Without a
        // model the oracle value is kept, bit-for-bit the historical
        // behaviour.
        let expected_end = if self.walltime.is_none() {
            start + occupancy
        } else {
            start + self.task_model.startup + slot.est_duration
        };
        self.running_cores += cores as u64;
        self.ledger.note_start(node, expected_end);
        if self.obs.is_some() && self.ledger.hold_for(tid).is_some() {
            self.trace(TraceKind::HoldClear, node, tid, start, 0);
        }
        self.ledger.clear_hold(tid);
        // A cleared hold loosens the admission fences: rescan.
        self.backfill_dirty = true;
        if self.record_timeline {
            self.timeline.push((start, cores as i64));
        }
        q.at(start + occupancy, SchedEvent::TaskEnded(tid));
    }

    /// Place a backfill-admitted core-level task. Runs the same filtered
    /// query the admission scan used (state cannot change in between:
    /// the server serializes all mutating operations), then records the
    /// backfill against the active hold for the invariant tests.
    pub(crate) fn try_place_backfill(
        &mut self,
        now: Time,
        tid: TaskId,
        q: &mut EventQueue<SchedEvent>,
    ) {
        let request = self.tasks[tid as usize].spec.request;
        let (cores, mem_mib) = match request {
            ResourceRequest::Cores { cores, mem_mib } => (cores, mem_mib),
            ResourceRequest::WholeNode => {
                // Never admitted by the scan; requeue defensively.
                let prio = self.tasks[tid as usize].priority;
                let enqueued_at = self.tasks[tid as usize].enqueued_at;
                self.pending.push_front(tid, prio, enqueued_at);
                self.trace(TraceKind::BackfillReject, u32::MAX, tid, now, 1);
                return;
            }
        };
        let est_duration = self.tasks[tid as usize].est_duration;
        let reservation = self.jobs[self.tasks[tid as usize].record.job as usize]
            .reservation
            .clone();
        let est_end = now + self.task_model.startup + est_duration;
        let ledger = &self.ledger;
        let pool = self.pool.as_ref().map(|p| &p.fleet);
        let placement = self.engine.place_cores_where(
            &mut self.cluster,
            cores,
            mem_mib,
            reservation.as_deref(),
            &|n| {
                ledger.allows_backfill(n, est_end) && pool.map(|pn| !pn.in_pool(n)).unwrap_or(true)
            },
        );
        match placement {
            Some(p) => {
                let node = p.node;
                let hold = self.ledger.hold_on(node);
                self.tasks[tid as usize].backfilled = true;
                if self.preempt_overdue {
                    self.live_backfills.push((tid, node));
                }
                self.backfill_log.push(BackfillEvent { task: tid, node, time: now, hold });
                self.start_running(now, tid, p, false, q);
                let fencing = hold.map(|h| h.task as i64).unwrap_or(-1);
                self.trace(TraceKind::BackfillAdmit, node, tid, now, fencing);
            }
            None => {
                // Admission raced a hold change; requeue at the front of
                // its bucket so ordering churn stays minimal.
                let prio = self.tasks[tid as usize].priority;
                let enqueued_at = self.tasks[tid as usize].enqueued_at;
                self.pending.push_front(tid, prio, enqueued_at);
                self.trace(TraceKind::BackfillReject, u32::MAX, tid, now, 0);
            }
        }
    }

    /// Plan (or refresh) earliest-start reservations for the blocked
    /// head (when it is whole-node) plus — with multi-hold enabled
    /// (K > 1) — the next whole-node tasks in the lookahead window, up
    /// to K in total, each fencing a distinct node.
    ///
    /// Per task the EASY skip rules apply: a hold whose estimated start
    /// is still ahead of the clock is kept stable instead of re-running
    /// the O(nodes) planning scan on every head-of-line retry; only an
    /// *overdue* hold (node freed late, walltime under-estimate, node
    /// went down, …) is re-planned — this is what keeps dispatch moving
    /// instead of stalling when estimates are noisy.
    fn plan_holds(&mut self, now: Time, head: TaskId, head_request: ResourceRequest) {
        let k = self.ledger.max_holds();
        let mut candidates: Vec<TaskId> = Vec::new();
        // Position 0 is reserved for the blocked head itself: with
        // K = 1 only a blocked whole-node *head* ever plans a hold,
        // exactly the single-hold discipline.
        if head_request == ResourceRequest::WholeNode {
            candidates.push(head);
        }
        // Scanning the window is pointless when every hold slot is
        // taken and every hold's estimate is still ahead of the clock:
        // each candidate would hit a skip arm below. This keeps the
        // per-retry cost of a *stable* multi-hold state at O(1), like
        // the single-hold discipline's.
        let worth_scanning =
            !self.ledger.is_full() || self.ledger.holds().iter().any(|h| now >= h.start);
        if k > 1 && worth_scanning {
            for tid in self.pending.iter_ordered(now, self.backfill_lookahead) {
                if candidates.len() >= k {
                    break;
                }
                if tid == head || candidates.contains(&tid) {
                    continue;
                }
                if self.tasks[tid as usize].spec.request == ResourceRequest::WholeNode {
                    candidates.push(tid);
                }
            }
        }
        for tid in candidates {
            match self.ledger.hold_for(tid) {
                // Estimate still ahead of the clock: keep the fence.
                Some(h) if now < h.start => continue,
                // Overdue: fall through and re-plan.
                Some(_) => {}
                // No hold and no free slot: set_hold would refuse —
                // skip the planning scan entirely.
                None if self.ledger.is_full() => continue,
                None => {}
            }
            let reservation = self.jobs[self.tasks[tid as usize].record.job as usize]
                .reservation
                .clone();
            let Some(part) = self.engine.index().partition_for(reservation.as_deref()) else {
                continue;
            };
            // Pool-owned nodes look idle to the index but do not serve
            // batch reservations while leased: plan around them.
            let pool = self.pool.as_ref().map(|p| &p.fleet);
            let planned = self.ledger.plan_whole_node_where(
                self.engine.index(),
                &self.cluster,
                part,
                now,
                tid,
                &|n| pool.map(|fl| !fl.in_pool(n)).unwrap_or(true),
            );
            match planned {
                Some((node, start)) => {
                    let _ = self.ledger.set_hold(tid, node, start);
                    self.trace(TraceKind::HoldPlan, node, tid, now, 0);
                }
                None => {
                    // Planning found no admissible node. When the pool
                    // fence is what is binding — an unfenced re-plan
                    // *would* find a node, and that node is pool-owned
                    // — borrow the start estimate from the fleet's
                    // drain forecast instead of skipping the hold
                    // entirely (the PR 4 behaviour, which left the
                    // blocked job unprotected until a shrink happened
                    // by chance). Any other failure cause (every node
                    // down, or fenced by *other tasks'* holds) keeps
                    // the PR 4 no-hold outcome, so the next planning
                    // pass stays free to take a real batch candidate
                    // the moment one appears. A forecast hold stays
                    // fenced off from batch placement until the owning
                    // shard actually returns the node (the hold-ready
                    // check in `pick_next` skips still-pooled nodes).
                    let pool_bound = match self.pool.as_ref() {
                        Some(p) if p.fleet.any_pooled() => self
                            .ledger
                            .plan_whole_node_where(
                                self.engine.index(),
                                &self.cluster,
                                part,
                                now,
                                tid,
                                &|_| true,
                            )
                            .map(|(n, _)| p.fleet.in_pool(n))
                            .unwrap_or(false),
                        _ => false,
                    };
                    let forecast = if pool_bound {
                        self.pool
                            .as_ref()
                            .and_then(|p| p.fleet.earliest_release_estimate(now))
                    } else {
                        None
                    };
                    if let Some((node, at)) = forecast {
                        let _ = self.ledger.set_hold(tid, node, at.max(now));
                        self.trace(TraceKind::HoldPlan, node, tid, now, 1);
                    }
                }
            }
        }
        if self.ledger.holds().len() > self.max_holds_seen {
            self.max_holds_seen = self.ledger.holds().len();
        }
        if self.ledger.check_invariants().is_err() {
            self.hold_invariant_violated = true;
        }
        debug_assert!(!self.hold_invariant_violated, "hold invariants broken");
    }

    /// A running task's occupancy ended: it enters COMPLETING and waits
    /// for the server's cleanup transaction (resources still held).
    /// Pool tasks queue for the cheap pool release instead of the
    /// array-size-dependent batch cleanup.
    pub(crate) fn finish_task(&mut self, now: Time, tid: TaskId) {
        if self.tasks[tid as usize].record.state != TaskState::Running {
            return; // stale (e.g. preempted)
        }
        self.tasks[tid as usize].record.state = TaskState::Completing;
        self.end_occupancy(now, tid);
    }

    /// Shared end-of-occupancy accounting for completion and preemption:
    /// stamp the end time, return the cores to the running count and
    /// timeline, and queue the task for its release path (cheap pool
    /// release for pool tasks, the batch cleanup transaction otherwise).
    fn end_occupancy(&mut self, now: Time, tid: TaskId) {
        let slot = &mut self.tasks[tid as usize];
        slot.record.end_t = Some(now);
        let cores = slot.record.cores as u64;
        let pooled = slot.pool_node.map(|(sid, _)| sid);
        self.running_cores -= cores;
        if self.record_timeline {
            self.timeline.push((now, -(cores as i64)));
        }
        if let Some(sid) = pooled {
            self.pool
                .as_mut()
                .expect("pool task implies a pool")
                .completions
                .push_back((sid, tid));
        } else {
            self.completions.push_back(tid);
            self.note_backlog();
        }
    }

    /// The cleanup transaction completed: release resources, mark DONE.
    /// Fault-killed tasks leave here into the retry path: their record
    /// is stamped like any finished task's, then the requeue (if the
    /// retry policy grants one) resets it when the backoff expires.
    pub(crate) fn finish_cleanup(&mut self, now: Time, tid: TaskId, q: &mut EventQueue<SchedEvent>) {
        let slot = &mut self.tasks[tid as usize];
        debug_assert!(
            slot.record.state == TaskState::Completing
                || slot.record.state == TaskState::Preempted,
            "cleanup of task in state {:?}",
            slot.record.state
        );
        // PREEMPTED already left the outstanding set at the signal.
        let was_completing = slot.record.state == TaskState::Completing;
        slot.record.state = TaskState::Done;
        slot.record.cleanup_t = Some(now);
        let was_backfilled = slot.backfilled;
        if let Some(p) = slot.placement.take() {
            self.engine
                .release(&mut self.cluster, &p)
                .expect("release of held placement");
            // Backfill release hook: expected free times update so hold
            // planning sees the node drain.
            self.ledger.note_release(p.node);
            // Pool hooks: a draining node that just went wholly idle
            // finishes its batch → pool transition here, and any batch
            // release may unblock a previously-stalled grow on any
            // shard.
            if let Some(pl) = self.pool.as_mut() {
                for sh in pl.fleet.shards.iter_mut() {
                    sh.grow_blocked = false;
                }
                let owner = pl.fleet.owner(p.node);
                if let Some(sid) = owner {
                    let idle = self.cluster.node(p.node).map(|n| n.is_idle()).unwrap_or(false);
                    let sh = &mut pl.fleet.shards[sid];
                    if sh.nodes.is_draining(p.node) && idle && sh.nodes.promote(p.node) {
                        pl.fleet.note_peak();
                    }
                }
            }
        }
        if was_backfilled && self.preempt_overdue {
            self.live_backfills.retain(|&(t, _)| t != tid);
        }
        if was_completing {
            self.not_done -= 1;
        }
        // Resources freed: head-of-line dispatch may proceed — and a
        // freed node can ready a hold or open a backfill window, and
        // every shard's `grow_blocked` latch cleared above.
        self.hol_blocked = false;
        self.backfill_dirty = true;
        if let Some(p) = self.pool.as_mut() {
            p.mark_all();
        }
        if self.tasks[tid as usize].fault_node.is_some() {
            if was_completing {
                // The natural completion raced the kill signal: the
                // task finished its work before the failure's signal
                // landed, so there is nothing to retry.
                let slot = &mut self.tasks[tid as usize];
                slot.fault_node = None;
                slot.killed_at = f64::NAN;
            } else {
                self.schedule_retry(now, tid, q);
            }
        }
    }

    /// A preemption signal landed on a (possibly already finished) task.
    pub(crate) fn apply_preempt_signal(&mut self, now: Time, tid: TaskId) {
        let slot = &mut self.tasks[tid as usize];
        if slot.record.state != TaskState::Running {
            return; // finished on its own before the signal landed
        }
        slot.record.state = TaskState::Preempted;
        // An overdue-backfill kill is only counted when it actually
        // lands on a still-running task — a task that finished first
        // was never preempted, whatever the signal queue says.
        let overdue_kill = slot.kill_signalled;
        if overdue_kill {
            self.overdue_preemptions += 1;
        }
        // Same landed-only rule for fault kills: the killed/lost work
        // tallies and the audit record are written here, where the kill
        // demonstrably took a running task down.
        let killed_on = slot.fault_node;
        let started = slot.record.start_t;
        let cores = slot.record.cores;
        self.not_done -= 1; // RUNNING → PREEMPTED leaves the outstanding set
        if let Some(node) = killed_on {
            self.fault_stats.tasks_killed += 1;
            let ran = (now - started.unwrap_or(now)).max(0.0);
            self.fault_stats.work_lost_core_s += ran * cores as f64;
            self.audit
                .push(now, AuditEvent::TaskKilled { task: tid, node }, FaultReason::Cascade);
        }
        self.trace(
            TraceKind::Preempt,
            killed_on.unwrap_or(u32::MAX),
            tid,
            now,
            i64::from(overdue_kill),
        );
        self.end_occupancy(now, tid);
    }

    /// Preempt a whole job: pending tasks are cancelled outright (cheap,
    /// no server involvement beyond the dequeue); running tasks queue a
    /// preemption signal through the server.
    pub(crate) fn preempt_job(&mut self, now: Time, job: JobId) {
        // The job's slots are one contiguous arena range — no
        // whole-arena scan. A preempt can land before the job exists
        // (count 0 placeholder): nothing to do then.
        let (first, count) = match self.jobs.get(job as usize) {
            Some(m) if m.task_count > 0 => (m.first_task, m.task_count),
            _ => return,
        };
        for tid in first..first + count as TaskId {
            match self.tasks[tid as usize].record.state {
                TaskState::Pending => {
                    if self.pending.remove(tid) || self.pool_pending_remove(tid) {
                        let slot = &mut self.tasks[tid as usize];
                        slot.record.state = TaskState::Done;
                        slot.record.start_t = Some(now);
                        slot.record.end_t = Some(now);
                        slot.record.cleanup_t = Some(now);
                        self.not_done -= 1;
                        // A cancelled task must not keep a node fenced —
                        // and a vanished hold/queue entry re-opens the
                        // backfill scans.
                        self.ledger.clear_hold(tid);
                        self.backfill_dirty = true;
                    }
                }
                TaskState::Running => self.preempt_q.push_back(tid),
                _ => {}
            }
        }
    }

    /// Withdraw a job for cross-scheduler migration: succeed only when
    /// every task is still parked in a queue (nothing has touched a
    /// node, no dispatch op is in flight), and then cancel the whole
    /// job through the same path [`Self::preempt_job`] uses for pending
    /// tasks. Returns `false` — and changes nothing — if the job has
    /// not materialized yet, any task already started, or any task is
    /// mid-dispatch (`Pending`-state but popped from its queue: the
    /// membership check below is what makes the withdrawal atomic — all
    /// tasks leave, or none do). The federation gateway calls this
    /// between lock-step windows and resubmits the withdrawn spec to
    /// another instance; the donor's records keep the withdrawn tasks
    /// as zero-length completions at `now`.
    pub fn withdraw_job(&mut self, now: Time, job: JobId) -> bool {
        let (first, count) = match self.jobs.get(job as usize) {
            Some(m) if m.task_count > 0 => (m.first_task, m.task_count),
            _ => return false,
        };
        let all_queued = (first..first + count as TaskId).all(|tid| {
            self.tasks[tid as usize].record.state == TaskState::Pending
                && (self.pending.contains(tid)
                    || self
                        .pool
                        .as_ref()
                        .is_some_and(|p| {
                            p.fleet.shards.iter().any(|sh| sh.pending.contains(&tid))
                        }))
        });
        if !all_queued {
            return false;
        }
        for tid in first..first + count as TaskId {
            let removed = self.pending.remove(tid) || self.pool_pending_remove(tid);
            debug_assert!(removed, "pending task {tid} missing from every queue");
            let slot = &mut self.tasks[tid as usize];
            slot.record.state = TaskState::Done;
            slot.record.start_t = Some(now);
            slot.record.end_t = Some(now);
            slot.record.cleanup_t = Some(now);
            self.not_done -= 1;
            self.ledger.clear_hold(tid);
            self.backfill_dirty = true;
        }
        true
    }

    /// Total tasks queued but not yet launched: the batch pending queue
    /// plus every pool shard's FIFO. The federation gateway reads this
    /// as each instance's backlog for least-loaded routing and the
    /// steal trigger.
    pub fn pending_depth(&self) -> usize {
        let pool: usize = self
            .pool
            .as_ref()
            .map(|p| p.fleet.shards.iter().map(|s| s.pending.len()).sum())
            .unwrap_or(0);
        self.pending.len() + pool
    }

    pub(crate) fn note_backlog(&mut self) {
        if self.completions.len() > self.max_completion_backlog {
            self.max_completion_backlog = self.completions.len();
        }
    }

    pub(crate) fn has_outstanding_work(&self) -> bool {
        !self.pending.is_empty()
            || !self.completions.is_empty()
            || !self.preempt_q.is_empty()
            || !self.fault_q.is_empty()
            || self.running_cores > 0
            || self
                .pool
                .as_ref()
                .map(|p| {
                    !p.completions.is_empty()
                        || p.fleet.shards.iter().any(|s| !s.pending.is_empty())
                })
                .unwrap_or(false)
            // Live counter over {PENDING, RUNNING, COMPLETING} — the
            // historical whole-arena scan made every noise arrival
            // O(tasks).
            || self.not_done > 0
    }

    // ---- rapid-launch fleet glue ---------------------------------------
    //
    // The pool subsystem proper lives in `crate::pool` (the sharded
    // fleet in `crate::pool::fleet`); these methods are the
    // scheduler-side integration: shape routing, the O(1) launch and
    // release effects, the per-shard hysteresis resize op with the
    // fleet rebalancer, and the preemptive-backfill scan. Every one of
    // them is a no-op (and unreachable) while the fleet is disabled,
    // which keeps pool-off runs bit-for-bit identical to the pre-pool
    // scheduler.

    /// Lease each shard's configured initial node set (all nodes are
    /// idle before the first event, so the bootstrap never needs to
    /// drain). Shards with the narrowest capacity demand lease *last*
    /// and every shard prefers the narrowest nodes that fit it, so a
    /// catch-all shard cannot absorb the scarce wide nodes a
    /// higher-`min_lanes` shard needs.
    pub(crate) fn bootstrap_pool(&mut self) {
        let Some(p) = self.pool.as_mut() else { return };
        let mut plans: Vec<(usize, usize, crate::pool::JobShape)> = p
            .fleet
            .shards
            .iter()
            .enumerate()
            .map(|(sid, sh)| (sid, sh.cfg.size.max(sh.manager.min).min(sh.manager.max), sh.shape))
            .collect();
        plans.sort_by(|a, b| b.2.min_lanes.cmp(&a.2.min_lanes));
        for (sid, want, shape) in plans {
            if want == 0 {
                continue;
            }
            let mut ids: Vec<NodeId> = {
                let fl = &p.fleet;
                self.engine
                    .index()
                    .partition_nodes_iter(0)
                    .filter(|&n| {
                        !fl.in_pool(n)
                            && shape.node_fits(fl.capacity(n))
                            && self
                                .cluster
                                .node(n)
                                .map(|x| x.state() == NodeState::Up && x.is_idle())
                                .unwrap_or(false)
                    })
                    .collect()
            };
            // Narrowest fitting nodes first (stable: id order on ties,
            // so homogeneous clusters behave exactly as before).
            ids.sort_by_key(|&n| p.fleet.capacity(n));
            ids.truncate(want);
            let sh = &mut p.fleet.shards[sid];
            for n in ids {
                if sh.nodes.lease(n) {
                    sh.manager.record_grow(1);
                }
            }
        }
        p.fleet.note_peak();
    }

    /// The shard this task belongs on, if any: whole-node, unreserved
    /// (the fleet leases out of the open partition, so reservation-
    /// tagged jobs stay on the batch path where their fenced nodes
    /// live), and matching exactly one shard's shape over (lanes,
    /// declared walltime estimate — a real scheduler only knows the
    /// declared value).
    pub(crate) fn route_to_pool(&self, tid: TaskId) -> Option<usize> {
        let p = self.pool.as_ref()?;
        let slot = &self.tasks[tid as usize];
        if slot.spec.request != ResourceRequest::WholeNode
            || self.jobs[slot.record.job as usize].reservation.is_some()
        {
            return None;
        }
        p.fleet.route(slot.spec.lanes, slot.est_duration)
    }

    /// Remove a task from any shard's pending queue (job cancellation
    /// path).
    pub(crate) fn pool_pending_remove(&mut self, tid: TaskId) -> bool {
        let Some(p) = self.pool.as_mut() else {
            return false;
        };
        let mut found: Option<usize> = None;
        for (sid, sh) in p.fleet.shards.iter_mut().enumerate() {
            if let Some(i) = sh.pending.iter().position(|&t| t == tid) {
                sh.pending.remove(i);
                found = Some(sid);
                break;
            }
        }
        match found {
            Some(sid) => {
                // A shorter queue can flip the shard's resize decision.
                p.mark(sid);
                true
            }
            None => false,
        }
    }

    /// Apply a pool dispatch on one shard: pop a leased node off the
    /// shard's free list and start the task on it — no placement
    /// engine, no per-core bookkeeping, no cluster mutation (the lease
    /// fence keeps batch off the node).
    pub(crate) fn pool_launch(
        &mut self,
        now: Time,
        sid: u32,
        tid: TaskId,
        q: &mut EventQueue<SchedEvent>,
    ) {
        let node = {
            let Some(p) = self.pool.as_mut() else { return };
            let Some(sh) = p.fleet.shards.get_mut(sid as usize) else {
                p.fleet.violated = true;
                return;
            };
            match sh.dispatcher.launch(&mut sh.nodes) {
                Some(n) => n,
                None => {
                    // A shrink raced the dispatch decision: requeue at
                    // the head so FIFO order is preserved.
                    sh.pending.push_front(tid);
                    p.mark(sid as usize);
                    return;
                }
            }
        };
        let cores = self.engine.index().node_capacity(node);
        self.note_restart(now, tid);
        let slot = &mut self.tasks[tid as usize];
        slot.record.state = TaskState::Running;
        slot.record.start_t = Some(now);
        slot.record.cores = cores;
        slot.record.pool_shard = Some(sid);
        slot.pool_node = Some((sid, node));
        let duration = slot.spec.duration;
        let est_end = now + self.task_model.startup + slot.est_duration;
        let jitter = self.rng.normal().abs() * self.task_model.jitter_sigma;
        let occupancy = self.task_model.startup + duration + jitter;
        self.running_cores += cores as u64;
        if self.record_timeline {
            self.timeline.push((now, cores as i64));
        }
        let p = self.pool.as_mut().expect("checked above");
        p.fleet.note_launch(sid as usize, node, est_end, tid);
        // The free list shrank: the shard's next decision may differ.
        p.mark(sid as usize);
        self.trace(TraceKind::PoolDispatch, sid, tid, now, i64::from(node));
        q.at(now + occupancy, SchedEvent::TaskEnded(tid));
    }

    /// Apply a pool release: mark the task DONE and push its node back
    /// on its shard's free list (or complete a pending drain-return).
    /// Constant cost — the batch cleanup's array-size term never
    /// applies. A sibling shard's stalled grow may now have a borrow
    /// candidate, so its `grow_blocked` latch clears.
    pub(crate) fn finish_pool_release(&mut self, now: Time, sid: u32, tid: TaskId) {
        let slot = &mut self.tasks[tid as usize];
        debug_assert!(
            slot.record.state == TaskState::Completing
                || slot.record.state == TaskState::Preempted,
            "pool release of task in state {:?}",
            slot.record.state
        );
        let was_completing = slot.record.state == TaskState::Completing;
        slot.record.state = TaskState::Done;
        slot.record.cleanup_t = Some(now);
        if was_completing {
            self.not_done -= 1;
        }
        let home = slot.pool_node.take();
        if let Some(p) = self.pool.as_mut() {
            match home {
                Some((s, n)) if s == sid && (sid as usize) < p.fleet.shards.len() => {
                    let sh = &mut p.fleet.shards[sid as usize];
                    if !sh.dispatcher.release(&mut sh.nodes, n) {
                        p.fleet.violated = true;
                    }
                    p.fleet.note_release(sid as usize, n);
                    for (i, sh) in p.fleet.shards.iter_mut().enumerate() {
                        if i != sid as usize {
                            sh.grow_blocked = false;
                        }
                    }
                    // A freed lease can serve this shard's next
                    // dispatch and un-stalls every sibling's grow.
                    p.mark_all();
                }
                _ => p.fleet.violated = true,
            }
        }
        let freed = home.map(|(_, n)| i64::from(n)).unwrap_or(-1);
        self.trace(TraceKind::PoolRelease, sid, tid, now, freed);
    }

    /// Apply one hysteresis resize pass on one shard. Grow sources, in
    /// rebalancer order: **sibling-free** (borrow an idle lease from a
    /// shard with no backlog), **lease-idle** (an idle batch node of
    /// the shard's capacity class), **drain-busy** (earmark the busy
    /// batch node the ledger's expected-completion table says frees
    /// soonest — not the lowest id — so the shard starts serving as
    /// early as possible). Shrink returns drained shard nodes to batch.
    /// The decision is re-evaluated at apply time — state may have
    /// moved since the op was scheduled.
    ///
    /// Every apply (including a no-op `Hold`) restarts the cooldown and
    /// schedules a [`SchedEvent::ShardWake`] for its expiry, so the
    /// wake-driven hot path never needs to poll `due()` across all
    /// shards — the calendar tells it exactly when a shard can next
    /// become due. The wake is scheduled in *both* hot-path modes to
    /// keep the two event streams identical.
    pub(crate) fn apply_pool_resize(
        &mut self,
        now: Time,
        sid: u32,
        q: &mut EventQueue<SchedEvent>,
    ) {
        let ledger = &self.ledger;
        let cluster = &self.cluster;
        let index = self.engine.index();
        let Some(p) = self.pool.as_mut() else { return };
        let sid = sid as usize;
        if sid >= p.fleet.shards.len() {
            return;
        }
        let shape = p.fleet.shards[sid].shape;
        let mut delta: i64 = 0;
        match p.fleet.shards[sid].decision() {
            Resize::Grow(k) => {
                let mut grown = 0usize;
                let mut acquired = 0usize;
                for _ in 0..k {
                    // 1) Borrow a free node from a sibling shard
                    // (never one carrying a reservation hold — a
                    // planted forecast hold must stay with its shard).
                    if p.fleet.borrow_into(sid, &|n| ledger.hold_on(n).is_none()).is_some() {
                        acquired += 1;
                        continue;
                    }
                    // 2) Lease an idle batch node of the right capacity
                    // class (no holds, not owned by any shard). The
                    // *narrowest* fitting node wins (lowest id on ties,
                    // so homogeneous clusters keep the historical
                    // order) — wide nodes stay available for shards
                    // that actually need them.
                    let idle_cand: Option<NodeId> = {
                        let fl = &p.fleet;
                        let mut best: Option<(NodeId, u32)> = None;
                        for n in index.partition_nodes_iter(0) {
                            let fits = !fl.in_pool(n)
                                && ledger.hold_on(n).is_none()
                                && shape.node_fits(fl.capacity(n))
                                && cluster
                                    .node(n)
                                    .map(|x| x.state() == NodeState::Up && x.is_idle())
                                    .unwrap_or(false);
                            if !fits {
                                continue;
                            }
                            let cap = fl.capacity(n);
                            if best.map(|(_, bc)| cap < bc).unwrap_or(true) {
                                best = Some((n, cap));
                            }
                        }
                        best.map(|(n, _)| n)
                    };
                    if let Some(n) = idle_cand {
                        if p.fleet.shards[sid].nodes.lease(n) {
                            grown += 1;
                            acquired += 1;
                        }
                        continue;
                    }
                    // 3) No idle batch node: drain the busy one
                    // expected to free soonest — it joins the shard
                    // when its running tasks release.
                    let drain_cand: Option<NodeId> = {
                        let fl = &p.fleet;
                        let mut best: Option<(NodeId, Time)> = None;
                        for n in index.partition_nodes_iter(0) {
                            if fl.in_pool(n)
                                || ledger.hold_on(n).is_some()
                                || !shape.node_fits(fl.capacity(n))
                            {
                                continue;
                            }
                            let busy = cluster
                                .node(n)
                                .map(|x| x.state() == NodeState::Up && !x.is_idle())
                                .unwrap_or(false);
                            if !busy {
                                continue;
                            }
                            let frees_at = ledger.expected_free(n, now);
                            if best.map(|(_, t)| frees_at < t).unwrap_or(true) {
                                best = Some((n, frees_at));
                            }
                        }
                        best.map(|(n, _)| n)
                    };
                    match drain_cand {
                        Some(n) => {
                            if p.fleet.shards[sid].nodes.begin_drain(n) {
                                grown += 1;
                                acquired += 1;
                            }
                        }
                        None => break, // nothing left to take
                    }
                }
                if grown > 0 {
                    p.fleet.shards[sid].manager.record_grow(grown);
                }
                delta = grown as i64;
                // A fruitless grow gates the starving-shard cooldown
                // bypass until the next batch or sibling release.
                p.fleet.shards[sid].grow_blocked = acquired == 0;
            }
            Resize::Shrink(k) => {
                let mut shrunk = 0usize;
                let sh = &mut p.fleet.shards[sid];
                for _ in 0..k {
                    if sh.nodes.return_free().is_some() {
                        shrunk += 1;
                    } else if let Some(n) = sh.nodes.any_draining() {
                        // Prefer cancelling a pending drain over
                        // returning capacity the shard actually uses.
                        if sh.nodes.cancel_drain(n) {
                            shrunk += 1;
                        }
                    } else {
                        break;
                    }
                }
                delta = -(shrunk as i64);
                if shrunk > 0 {
                    sh.manager.record_shrink(shrunk);
                    // Returned nodes are batch capacity again: let the
                    // blocked head retry against a fresh cycle.
                    self.hol_blocked = false;
                    self.cycle_budget = 0;
                }
            }
            Resize::Hold => {}
        }
        p.fleet.shards[sid].manager.note_resize(now);
        p.fleet.note_peak();
        if p.fleet.check_conservation().is_err() {
            p.fleet.violated = true;
        }
        let cooldown = p.fleet.shards[sid].manager.cooldown;
        p.wakes_pending[sid] += 1;
        // A resize can move nodes between batch and any shard (borrows
        // touch the donor; `any_pooled` gates fleet-wide fences), so
        // every shard — and the batch backfill scans — re-evaluate.
        p.mark_all();
        // Wait-cause marker: the shard has queued work but this resize
        // delivered no new capacity (cooldown/hysteresis hold, blocked
        // grow, or a shrink) — the head keeps waiting on pool cold
        // start (code 1).
        let starved = if delta <= 0 { p.fleet.shards[sid].pending.front().copied() } else { None };
        self.backfill_dirty = true;
        self.trace(TraceKind::PoolResize, sid as u32, delta.unsigned_abs(), now, delta);
        if let Some(front) = starved {
            self.trace(TraceKind::WaitCause, 1, front, now, 0);
        }
        q.at(now + cooldown, SchedEvent::ShardWake(sid as u32));
    }

    /// The preemptive-backfill scan: for every hold that has come due,
    /// kill backfilled tasks on its node that have overstayed their
    /// walltime estimate (real schedulers terminate jobs past their
    /// declared walltime). Signals go through the ordinary preempt path
    /// — `Op::PreemptSignal`, then cleanup — so the ledger release
    /// hooks run unchanged. Scans the bounded live-backfill set, not
    /// the append-only log.
    pub(crate) fn signal_overdue_backfills(&mut self, now: Time) {
        if !self.ledger.has_holds() || self.live_backfills.is_empty() {
            return;
        }
        // Same reused scratch buffer as the hold-ready scan in
        // `pick_next` (the two run sequentially, never nested) — this
        // scan fires on every blocked pick under `preempt_overdue`, so
        // a per-call clone would be hot-loop garbage.
        let mut holds = std::mem::take(&mut self.hold_scratch);
        holds.clear();
        holds.extend_from_slice(self.ledger.holds());
        let startup = self.task_model.startup;
        let mut kills: Vec<TaskId> = Vec::new();
        for h in &holds {
            if now < h.start {
                continue;
            }
            kills.clear();
            for &(task, node) in &self.live_backfills {
                if node != h.node {
                    continue;
                }
                let slot = &self.tasks[task as usize];
                if slot.record.state != TaskState::Running || slot.kill_signalled {
                    continue;
                }
                let est_end = slot.record.start_t.unwrap_or(now) + startup + slot.est_duration;
                if now + 1e-9 >= est_end {
                    kills.push(task);
                }
            }
            for &tid in &kills {
                self.tasks[tid as usize].kill_signalled = true;
                self.preempt_q.push_back(tid);
            }
        }
        self.hold_scratch = holds;
    }

    // ---- fault & churn layer -------------------------------------------
    //
    // The plan itself (what breaks when) lives in `crate::fault`; these
    // methods are the scheduler-side application of one planned event,
    // run as server ops off the fault queue. Every mutation flows
    // through the existing machinery — kills take the preempt path,
    // releases the cleanup path, evictions the pool mutators — so the
    // fault layer adds no second bookkeeping scheme to keep consistent.
    // All of it is unreachable while fault injection is off, which
    // keeps fault-off runs bit-for-bit identical (pinned by
    // `rust/tests/fault_properties.rs`).

    /// A node goes down hard. Running tasks on it (batch or pooled) are
    /// marked and killed through the preempt path, its pooled lease is
    /// evicted, any reservation hold fencing it is void, and the node
    /// leaves the placement index until its recovery event.
    pub(crate) fn apply_node_fail(
        &mut self,
        now: Time,
        node: NodeId,
        reason: FaultReason,
        q: &mut EventQueue<SchedEvent>,
    ) {
        match self.cluster.node(node).map(|n| n.state()) {
            Ok(NodeState::Up) | Ok(NodeState::Draining) => {}
            // Unknown node, or already down: overlapping failure
            // processes (MTBF + reclaim) are idempotent.
            _ => return,
        }
        self.fault_stats.node_failures += 1;
        self.audit.push(now, AuditEvent::NodeFailed { node }, reason);
        // 1) Mark running tasks for the kill *before* the lease
        // teardown detaches pool tasks from the node.
        let mut kills: Vec<TaskId> = Vec::new();
        for slot in self.tasks.iter_mut() {
            if slot.record.state != TaskState::Running {
                continue;
            }
            let on_node = slot.placement.as_ref().map(|p| p.node == node).unwrap_or(false)
                || slot.pool_node.map(|(_, n)| n == node).unwrap_or(false);
            if on_node && slot.fault_node.is_none() {
                slot.fault_node = Some(node);
                slot.killed_at = now;
                kills.push(slot.record.task);
            }
        }
        self.trace(TraceKind::FaultCascade, node, kills.len() as u64, now, 0);
        // 2) Pool membership teardown (evict the lease, reroute queued
        // completions, wake the owning shard so it can re-grow).
        self.pool_evict(now, node, q);
        // 3) Fence the node out of placement. De-indexing is immediate;
        // later releases of placements still held on the dead node stay
        // safe — the index only updates its cached free count for a
        // de-indexed node, and re-inserts with the final value at
        // recovery.
        self.engine.set_node_state(&mut self.cluster, node, NodeState::Down);
        self.down_since[node as usize] = now;
        // 4) A reservation hold fencing the dead node is void.
        let held = self.ledger.hold_on(node).map(|h| h.task);
        if let Some(task) = held {
            self.ledger.clear_hold(task);
            self.audit
                .push(now, AuditEvent::HoldCleared { node, task }, FaultReason::Cascade);
        }
        // 5) Kill the marked tasks through the ordinary preempt path
        // (signal op → PREEMPTED → cleanup → retry policy).
        for tid in kills {
            self.preempt_q.push_back(tid);
        }
        // Holds moved and fences changed: the scans must re-run.
        self.backfill_dirty = true;
        if let Some(p) = self.pool.as_mut() {
            p.mark_all();
        }
    }

    /// A down or draining node returns to service: back into the
    /// placement index (with its still-cached free count — allocations
    /// survive downtime until their cleanup releases them), and every
    /// blocked consumer of capacity gets another look.
    pub(crate) fn apply_node_recover(&mut self, now: Time, node: NodeId) {
        match self.cluster.node(node).map(|n| n.state()) {
            Ok(NodeState::Down) | Ok(NodeState::Draining) => {}
            _ => return, // unknown node, or already back up
        }
        self.engine.set_node_state(&mut self.cluster, node, NodeState::Up);
        self.fault_stats.node_recoveries += 1;
        let since = self.down_since[node as usize];
        if since.is_finite() {
            self.fault_stats.recovery_s += (now - since).max(0.0);
            self.fault_stats.recovery_n += 1;
            self.down_since[node as usize] = f64::NAN;
        }
        self.audit
            .push(now, AuditEvent::NodeRecovered { node }, FaultReason::Recovery);
        self.trace(TraceKind::FaultCascade, node, 0, now, 1);
        // Fresh capacity: the blocked head retries against a fresh
        // cycle, the backfill scans re-run, and every shard may have a
        // grow candidate again.
        self.hol_blocked = false;
        self.cycle_budget = 0;
        self.backfill_dirty = true;
        if let Some(p) = self.pool.as_mut() {
            for sh in p.fleet.shards.iter_mut() {
                sh.grow_blocked = false;
            }
            p.mark_all();
        }
    }

    /// A spot reclamation wave: every node in the plan's wave fails at
    /// this instant, in plan order (deterministic — the audit log
    /// records the wave header, then each node's failure cascade).
    pub(crate) fn apply_reclaim_wave(
        &mut self,
        now: Time,
        wave: u32,
        q: &mut EventQueue<SchedEvent>,
    ) {
        let members: Vec<NodeId> = match self.fault_plan.as_ref() {
            Some(plan) if (wave as usize) < plan.n_waves() => plan.wave(wave).to_vec(),
            _ => return,
        };
        self.fault_stats.reclaim_waves += 1;
        self.audit.push(
            now,
            AuditEvent::ReclaimWave { wave, nodes: members.len() },
            FaultReason::SpotReclaim,
        );
        self.trace(TraceKind::FaultCascade, wave, members.len() as u64, now, 2);
        for node in members {
            self.apply_node_fail(now, node, FaultReason::SpotReclaim, q);
        }
    }

    /// A maintenance drain starts: graceful — running work finishes and
    /// releases normally, but the node takes no new work (out of the
    /// index) and a pooled lease ends now, since the shard must not
    /// dispatch onto a node leaving service.
    pub(crate) fn apply_drain_node(
        &mut self,
        now: Time,
        node: NodeId,
        q: &mut EventQueue<SchedEvent>,
    ) {
        match self.cluster.node(node).map(|n| n.state()) {
            Ok(NodeState::Up) => {}
            _ => return, // down or already draining: nothing to start
        }
        self.fault_stats.drains += 1;
        self.audit
            .push(now, AuditEvent::NodeDrained { node }, FaultReason::Maintenance);
        self.trace(TraceKind::FaultCascade, node, 0, now, 3);
        self.pool_evict(now, node, q);
        self.engine.set_node_state(&mut self.cluster, node, NodeState::Draining);
        self.down_since[node as usize] = now;
        let held = self.ledger.hold_on(node).map(|h| h.task);
        if let Some(task) = held {
            self.ledger.clear_hold(task);
            self.audit
                .push(now, AuditEvent::HoldCleared { node, task }, FaultReason::Cascade);
        }
        self.backfill_dirty = true;
        if let Some(p) = self.pool.as_mut() {
            p.mark_all();
        }
    }

    /// Tear down a node's pool lease because the node is leaving
    /// service. Pool tasks bound to the lease are detached first —
    /// running ones will release through the batch cleanup queue
    /// (killed or not), and completions already queued for the O(1)
    /// shard release are rerouted there too, since after the eviction
    /// the shard no longer owns the node and the shard release would be
    /// a conservation violation. Returns `false` if no shard owned the
    /// node.
    fn pool_evict(&mut self, now: Time, node: NodeId, q: &mut EventQueue<SchedEvent>) -> bool {
        let Some(sid) = self.pool.as_ref().and_then(|p| p.fleet.owner(node)) else {
            return false;
        };
        let mut reroute: Vec<TaskId> = Vec::new();
        for slot in self.tasks.iter_mut() {
            if slot.pool_node.map(|(_, n)| n == node).unwrap_or(false) {
                slot.pool_node = None;
                if slot.record.state == TaskState::Completing {
                    reroute.push(slot.record.task);
                }
            }
        }
        if !reroute.is_empty() {
            if let Some(p) = self.pool.as_mut() {
                p.completions.retain(|&(_, t)| !reroute.contains(&t));
            }
            for t in reroute {
                self.completions.push_back(t);
            }
            self.note_backlog();
        }
        let p = self.pool.as_mut().expect("owner implies a pool");
        if !p.fleet.shards[sid].nodes.evict(node) {
            p.fleet.violated = true;
        }
        p.fleet.note_release(sid, node);
        // The fleet lost capacity: clear every grow latch and schedule
        // the evicted shard's wake so its manager can re-grow past the
        // dead node (the same wake pattern as a resize apply).
        for sh in p.fleet.shards.iter_mut() {
            sh.grow_blocked = false;
        }
        let cooldown = p.fleet.shards[sid].manager.cooldown;
        p.wakes_pending[sid] += 1;
        p.mark_all();
        q.at(now + cooldown, SchedEvent::ShardWake(sid as u32));
        self.audit.push(
            now,
            AuditEvent::PoolEvicted { node, shard: sid },
            FaultReason::Cascade,
        );
        self.trace(TraceKind::FaultCascade, node, sid as u64, now, 4);
        true
    }

    /// A launch landing on a task with a pending restart stamp closes
    /// the kill-to-restart latency measurement. No-op (NaN stamp) for
    /// every task that was never fault-killed.
    pub(crate) fn note_restart(&mut self, now: Time, tid: TaskId) {
        let killed_at = self.tasks[tid as usize].killed_at;
        if killed_at.is_finite() {
            self.fault_stats.requeue_delay_s += (now - killed_at).max(0.0);
            self.fault_stats.requeue_n += 1;
            self.tasks[tid as usize].killed_at = f64::NAN;
        }
    }

    /// The retry-policy decision for one fault-killed task, taken at
    /// its cleanup: requeue after exponential backoff, or declare it
    /// lost once the attempts are spent.
    fn schedule_retry(&mut self, now: Time, tid: TaskId, q: &mut EventQueue<SchedEvent>) {
        let retries = {
            let slot = &mut self.tasks[tid as usize];
            slot.fault_node = None;
            slot.retries
        };
        if retries >= self.fault_cfg.retry.max_retries {
            self.tasks[tid as usize].killed_at = f64::NAN;
            self.fault_stats.tasks_lost += 1;
            self.audit.push(
                now,
                AuditEvent::TaskLost { task: tid, attempts: retries },
                FaultReason::RetryExhausted,
            );
            return;
        }
        let delay = self.fault_cfg.retry.delay(retries);
        // Wait-cause marker: the task sits out its retry backoff
        // (code 3; detail = the backoff delay in nanoseconds).
        self.trace(TraceKind::WaitCause, 3, tid, now, (delay * 1e9) as i64);
        q.at(now + delay, SchedEvent::Requeue(tid));
    }

    /// A retry backoff expired: reset the task's record to PENDING and
    /// put it back on the queue it belongs to — the same routing as a
    /// fresh registration, so a short whole-node task returns to its
    /// shard and everything else to the batch queue.
    pub(crate) fn requeue_task(&mut self, now: Time, tid: TaskId) {
        let prio = {
            let slot = &mut self.tasks[tid as usize];
            debug_assert_eq!(slot.record.state, TaskState::Done, "requeue of live task");
            if slot.record.state != TaskState::Done {
                return;
            }
            slot.retries += 1;
            slot.record.state = TaskState::Pending;
            slot.record.start_t = None;
            slot.record.end_t = None;
            slot.record.cleanup_t = None;
            slot.record.cores = 0;
            slot.backfilled = false;
            slot.kill_signalled = false;
            slot.enqueued_at = now;
            slot.priority
        };
        self.not_done += 1;
        self.fault_stats.tasks_requeued += 1;
        let attempt = self.tasks[tid as usize].retries;
        self.audit.push(
            now,
            AuditEvent::TaskRequeued { task: tid, attempt },
            FaultReason::Cascade,
        );
        if let Some(sid) = self.route_to_pool(tid) {
            let p = self.pool.as_mut().expect("routing implies a pool");
            p.fleet.shards[sid].pending.push_back(tid);
            p.mark(sid);
        } else {
            self.pending.push(tid, prio, now);
            self.backfill_dirty = true;
        }
    }
}
