//! The pending queue: priority-then-FIFO ordering over scheduling tasks,
//! with optional queue aging.
//!
//! Within one array job all tasks share a priority, so dispatch order is
//! array order (Slurm behaves the same). Across jobs, higher priority goes
//! first; spot jobs ride at negative priority.
//!
//! With an [`AgingPolicy`] installed, a pending entry's *effective*
//! priority rises with its wait time (configurable slope, capped), so a
//! low-priority whole-node job stuck behind a sustained high-priority
//! stream eventually outranks fresh arrivals and reaches the head —
//! the cross-priority starvation fix the backfill reservations alone
//! cannot provide. With no policy installed the queue behaves exactly
//! like the static priority-then-FIFO discipline (same pop order, same
//! scan order), which the equivalence properties in
//! `rust/tests/fairness_properties.rs` pin down.

use crate::scheduler::job::TaskId;
use crate::sim::Time;
use std::collections::VecDeque;

/// Queue-aging policy: effective priority = static priority +
/// `min(cap, floor(slope × wait))`.
///
/// The floor keeps effective priorities integral, so aging never breaks
/// FIFO ties within a class faster than one priority point at a time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AgingPolicy {
    /// Priority points gained per second of wait (> 0 to have effect).
    pub slope: f64,
    /// Maximum boost above the static priority (≥ 0).
    pub cap: i32,
}

impl AgingPolicy {
    /// Convenience constructor.
    pub fn new(slope: f64, cap: i32) -> AgingPolicy {
        AgingPolicy { slope, cap }
    }

    /// The boost earned after `wait` seconds (0 for non-positive wait).
    pub fn boost(&self, wait: Time) -> i64 {
        if self.slope <= 0.0 || wait <= 0.0 {
            return 0;
        }
        // `as` saturates, so pathological slopes cannot overflow.
        ((self.slope * wait) as i64).min(self.cap.max(0) as i64)
    }

    /// Effective priority of a `base`-priority entry after `wait` seconds.
    pub fn effective(&self, base: i32, wait: Time) -> i64 {
        base as i64 + self.boost(wait)
    }

    /// Wait after which a `base`-priority entry outranks a fresh entry
    /// of priority `other` (the bound the fairness properties use);
    /// `None` when the cap is too small to ever close the gap.
    pub fn overtake_wait(&self, base: i32, other: i32) -> Option<Time> {
        let gap = (other as i64 - base as i64) + 1;
        if gap <= 0 {
            return Some(0.0);
        }
        if self.slope <= 0.0 || gap > self.cap.max(0) as i64 {
            return None;
        }
        Some((gap as f64 + 1.0) / self.slope)
    }
}

/// One pending entry.
#[derive(Debug, Clone, Copy, PartialEq)]
struct Entry {
    task: TaskId,
    priority: i32,
    seq: u64,
    /// When the entry first joined the queue. Head-of-line reinsertions
    /// ([`PendingQueue::push_front`]) carry the *original* timestamp —
    /// re-stamping would silently reset aging credit on every failed
    /// placement retry, un-fixing the starvation aging exists to fix.
    enqueued_at: Time,
}

/// Priority + FIFO pending queue with O(buckets) pop and O(log n)-ish
/// insert (bucketed by priority; priorities in practice are a handful of
/// values). Aging, when enabled, reranks buckets by their *front* entry's
/// effective priority — within a bucket the front is the oldest entry, so
/// it is also the bucket's best under any non-negative slope.
#[derive(Debug, Default)]
pub struct PendingQueue {
    /// Buckets sorted by descending static priority; each bucket FIFO.
    buckets: Vec<(i32, VecDeque<Entry>)>,
    seq: u64,
    len: usize,
    aging: Option<AgingPolicy>,
}

impl PendingQueue {
    pub fn new() -> PendingQueue {
        PendingQueue::default()
    }

    /// Install (or remove) the aging policy. `None` restores the static
    /// priority-then-FIFO discipline bit-for-bit.
    pub fn set_aging(&mut self, aging: Option<AgingPolicy>) {
        self.aging = aging;
    }

    /// The installed aging policy.
    pub fn aging(&self) -> Option<AgingPolicy> {
        self.aging
    }

    /// Enqueue a task at a priority, timestamped `now` for aging.
    pub fn push(&mut self, task: TaskId, priority: i32, now: Time) {
        self.seq += 1;
        self.len += 1;
        let e = Entry {
            task,
            priority,
            seq: self.seq,
            enqueued_at: now,
        };
        match self.buckets.binary_search_by(|(p, _)| priority.cmp(p)) {
            Ok(i) => self.buckets[i].1.push_back(e),
            Err(i) => {
                let mut q = VecDeque::new();
                q.push_back(e);
                self.buckets.insert(i, (priority, q));
            }
        }
    }

    /// Put a task back at the *front* of its priority bucket (head-of-line
    /// retry after a failed placement). `enqueued_at` must be the entry's
    /// original enqueue time so the retry keeps its aging credit.
    ///
    /// With aging on, the re-entry is inserted *in stamp order* rather
    /// than blindly at the front: a backfill-race requeue can carry a
    /// younger stamp than the bucket's current head (`pop_where` extracts
    /// from the middle), and a plain front insert would break the
    /// oldest-first invariant `best_front`/`scan_order` rely on —
    /// the acknowledged aging-order hole. The common case (the retry is
    /// the oldest entry) still lands at the front. Without aging, order
    /// within a bucket carries no priority meaning, so the historical
    /// plain front insert is kept bit-for-bit.
    pub fn push_front(&mut self, task: TaskId, priority: i32, enqueued_at: Time) {
        self.len += 1;
        let e = Entry {
            task,
            priority,
            seq: 0, // front of bucket
            enqueued_at,
        };
        match self.buckets.binary_search_by(|(p, _)| priority.cmp(p)) {
            Ok(i) => {
                let q = &mut self.buckets[i].1;
                if self.aging.is_some() {
                    // First slot whose stamp is not older — the retry
                    // goes ahead of every same-or-younger entry. The
                    // bucket is non-decreasing in `enqueued_at` (this
                    // insert rule plus monotone `push` stamps), so a
                    // binary search is sound.
                    let pos = q.partition_point(|x| x.enqueued_at < enqueued_at);
                    q.insert(pos, e);
                } else {
                    q.push_front(e);
                }
            }
            Err(i) => {
                let mut q = VecDeque::new();
                q.push_back(e);
                self.buckets.insert(i, (priority, q));
            }
        }
    }

    /// Effective priority of an entry at `now`.
    fn effective(&self, e: &Entry, now: Time) -> i64 {
        match self.aging {
            None => e.priority as i64,
            Some(a) => a.effective(e.priority, now - e.enqueued_at),
        }
    }

    /// The bucket whose *front* entry ranks first in dispatch order at
    /// `now` (the allocation-free core of `pop`/`peek`, the scheduler's
    /// hottest queue op). With no aging this is the first non-empty
    /// bucket, exactly the historical walk.
    fn best_front(&self, now: Time) -> Option<usize> {
        if self.aging.is_none() {
            return self.buckets.iter().position(|(_, q)| !q.is_empty());
        }
        let mut best: Option<(usize, i64)> = None;
        for (i, (_, q)) in self.buckets.iter().enumerate() {
            if let Some(front) = q.front() {
                let eff = self.effective(front, now);
                // Strict `>` keeps the earlier bucket (higher static
                // priority) on effective-priority ties.
                let better = match best {
                    None => true,
                    Some((_, b)) => eff > b,
                };
                if better {
                    best = Some((i, eff));
                }
            }
        }
        best.map(|(i, _)| i)
    }

    /// `(bucket, position)` pairs in dispatch order — effective priority
    /// descending, higher static priority then FIFO on ties — at most
    /// `max` of them. A k-way merge over bucket cursors: within a bucket
    /// entries sit oldest-first (head-of-line retries re-enter in stamp
    /// order via [`PendingQueue::push_front`]'s ordered insert, so even
    /// a backfill-race requeue cannot front a younger entry), so
    /// effective priority never increases along a cursor and the merge
    /// order is globally correct. With no aging this degenerates to the
    /// static bucket-then-FIFO walk, taken as a merge-free fast path.
    fn scan_order(&self, now: Time, max: usize) -> Vec<(usize, usize)> {
        if self.aging.is_none() {
            let mut out = Vec::new();
            'buckets: for (i, (_, q)) in self.buckets.iter().enumerate() {
                for p in 0..q.len() {
                    if out.len() >= max {
                        break 'buckets;
                    }
                    out.push((i, p));
                }
            }
            return out;
        }
        let mut cursors = vec![0usize; self.buckets.len()];
        let mut out = Vec::new();
        while out.len() < max {
            let mut best: Option<(usize, i64)> = None;
            for (i, (_, q)) in self.buckets.iter().enumerate() {
                if cursors[i] < q.len() {
                    let eff = self.effective(&q[cursors[i]], now);
                    // Strict `>` keeps the earlier bucket (higher static
                    // priority) on effective-priority ties.
                    let better = match best {
                        None => true,
                        Some((_, b)) => eff > b,
                    };
                    if better {
                        best = Some((i, eff));
                    }
                }
            }
            match best {
                Some((i, _)) => {
                    out.push((i, cursors[i]));
                    cursors[i] += 1;
                }
                None => break,
            }
        }
        out
    }

    /// Peek the next task at `now` without removing it.
    pub fn peek(&self, now: Time) -> Option<TaskId> {
        let bi = self.best_front(now)?;
        self.buckets[bi].1.front().map(|e| e.task)
    }

    /// Pop the effectively-highest-priority, oldest task at `now`.
    pub fn pop(&mut self, now: Time) -> Option<TaskId> {
        let bi = self.best_front(now)?;
        let e = self.buckets[bi].1.pop_front().expect("best bucket is non-empty");
        self.len -= 1;
        self.prune(bi);
        Some(e.task)
    }

    /// Drop bucket `bi` if its deque emptied, so `best_front` and
    /// `scan_order` never walk dead buckets (a workload with many
    /// distinct priorities would otherwise accumulate them forever).
    fn prune(&mut self, bi: usize) {
        if self.buckets[bi].1.is_empty() {
            self.buckets.remove(bi);
        }
    }

    /// Number of live priority buckets (test / diagnostics hook).
    pub fn bucket_count(&self) -> usize {
        self.buckets.len()
    }

    /// Pop the first task (effective-priority dispatch order at `now`)
    /// satisfying `pred`, inspecting at most `max_scan` entries — the
    /// backfill lookahead.
    ///
    /// The bound keeps the scan cheap on deep queues *and* bounds
    /// priority inversion: a backfill candidate can only jump entries
    /// inside the lookahead window, so ahead-of-it tasks age out of
    /// reach after at most `max_scan` backfills.
    pub fn pop_where(
        &mut self,
        max_scan: usize,
        now: Time,
        mut pred: impl FnMut(TaskId) -> bool,
    ) -> Option<TaskId> {
        for (bi, pos) in self.scan_order(now, max_scan) {
            let task = self.buckets[bi].1[pos].task;
            if pred(task) {
                let _ = self.buckets[bi].1.remove(pos);
                self.len -= 1;
                self.prune(bi);
                return Some(task);
            }
        }
        None
    }

    /// The first `max` tasks in dispatch order at `now`, without
    /// removing anything — the multi-hold planner's candidate window.
    pub fn iter_ordered(&self, now: Time, max: usize) -> Vec<TaskId> {
        self.scan_order(now, max)
            .into_iter()
            .map(|(b, p)| self.buckets[b].1[p].task)
            .collect()
    }

    /// Remove an arbitrary task (job cancellation); O(n).
    pub fn remove(&mut self, task: TaskId) -> bool {
        for bi in 0..self.buckets.len() {
            if let Some(pos) = self.buckets[bi].1.iter().position(|e| e.task == task) {
                self.buckets[bi].1.remove(pos);
                self.len -= 1;
                self.prune(bi);
                return true;
            }
        }
        false
    }

    /// Whether the task is currently queued; O(n). The withdraw path
    /// uses this to prove a job is wholly parked in queues (a task can
    /// be `Pending`-state yet *out* of every queue while its dispatch
    /// op is in flight — such a job must not be withdrawn).
    pub fn contains(&self, task: TaskId) -> bool {
        self.buckets
            .iter()
            .any(|(_, q)| q.iter().any(|e| e.task == task))
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_within_priority() {
        let mut q = PendingQueue::new();
        q.push(1, 0, 0.0);
        q.push(2, 0, 0.0);
        q.push(3, 0, 0.0);
        assert_eq!(q.pop(0.0), Some(1));
        assert_eq!(q.pop(0.0), Some(2));
        assert_eq!(q.pop(0.0), Some(3));
        assert_eq!(q.pop(0.0), None);
    }

    #[test]
    fn priority_order_across_buckets() {
        let mut q = PendingQueue::new();
        q.push(10, -5, 0.0); // spot
        q.push(11, 0, 0.0); // normal
        q.push(12, 5, 0.0); // interactive
        q.push(13, 0, 0.0);
        assert_eq!(q.pop(1.0), Some(12));
        assert_eq!(q.pop(1.0), Some(11));
        assert_eq!(q.pop(1.0), Some(13));
        assert_eq!(q.pop(1.0), Some(10));
    }

    #[test]
    fn push_front_retries_first() {
        let mut q = PendingQueue::new();
        q.push(1, 0, 0.0);
        q.push(2, 0, 0.0);
        let t = q.pop(0.0).unwrap();
        q.push_front(t, 0, 0.0);
        assert_eq!(q.pop(0.0), Some(1), "retried task pops first again");
    }

    #[test]
    fn peek_does_not_remove() {
        let mut q = PendingQueue::new();
        q.push(7, 1, 0.0);
        assert_eq!(q.peek(0.0), Some(7));
        assert_eq!(q.len(), 1);
        assert_eq!(q.pop(0.0), Some(7));
        assert!(q.is_empty());
    }

    #[test]
    fn remove_specific() {
        let mut q = PendingQueue::new();
        q.push(1, 0, 0.0);
        q.push(2, 0, 0.0);
        q.push(3, 1, 0.0);
        assert!(q.remove(2));
        assert!(!q.remove(99));
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop(0.0), Some(3));
        assert_eq!(q.pop(0.0), Some(1));
    }

    #[test]
    fn push_front_into_missing_bucket_creates_it() {
        // A head-of-line retry at a priority with no live bucket must
        // create the bucket in sorted position, not panic or misorder.
        let mut q = PendingQueue::new();
        q.push(1, 0, 0.0);
        q.push_front(2, 5, 0.0); // no priority-5 bucket exists yet
        q.push_front(3, -5, 0.0); // nor a -5 one
        assert_eq!(q.len(), 3);
        assert_eq!(q.pop(0.0), Some(2), "highest priority first");
        assert_eq!(q.pop(0.0), Some(1));
        assert_eq!(q.pop(0.0), Some(3));
        assert_eq!(q.pop(0.0), None);
        assert_eq!(q.len(), 0);
    }

    #[test]
    fn push_front_ordering_within_existing_bucket() {
        let mut q = PendingQueue::new();
        q.push(1, 0, 0.0);
        q.push(2, 0, 0.0);
        q.push_front(9, 0, 0.0);
        q.push_front(8, 0, 0.0);
        // Most recent retry pops first, then the earlier retry, then FIFO.
        assert_eq!(q.pop(0.0), Some(8));
        assert_eq!(q.pop(0.0), Some(9));
        assert_eq!(q.pop(0.0), Some(1));
        assert_eq!(q.pop(0.0), Some(2));
    }

    #[test]
    fn remove_maintains_len_invariants() {
        let mut q = PendingQueue::new();
        for t in 0..10u64 {
            q.push(t, (t % 2) as i32, 0.0);
        }
        assert_eq!(q.len(), 10);
        // Remove from the middle, the head, and a push_front entry.
        assert!(q.remove(4));
        assert!(q.remove(1));
        q.push_front(99, 1, 0.0);
        assert!(q.remove(99));
        assert_eq!(q.len(), 8);
        // Double-remove and unknown ids leave len untouched.
        assert!(!q.remove(4));
        assert!(!q.remove(1234));
        assert_eq!(q.len(), 8);
        // Drain: count must match len, ids must be the surviving ones.
        let mut drained = Vec::new();
        while let Some(t) = q.pop(0.0) {
            drained.push(t);
        }
        assert_eq!(drained.len(), 8);
        assert!(!drained.contains(&4) && !drained.contains(&1) && !drained.contains(&99));
        assert!(q.is_empty());
        assert_eq!(q.len(), 0);
    }

    #[test]
    fn remove_then_push_front_roundtrip() {
        // The scheduler's failed-dispatch path: pop, fail, push_front,
        // preemption removes it. len must stay exact throughout.
        let mut q = PendingQueue::new();
        q.push(7, 0, 0.0);
        let t = q.pop(0.0).unwrap();
        assert_eq!(q.len(), 0);
        q.push_front(t, 0, 0.0);
        assert_eq!(q.len(), 1);
        assert!(q.remove(t));
        assert!(q.is_empty());
        assert_eq!(q.peek(0.0), None);
        assert_eq!(q.pop(0.0), None);
    }

    #[test]
    fn pop_where_scans_in_order_and_respects_bound() {
        let mut q = PendingQueue::new();
        q.push(1, 0, 0.0);
        q.push(2, 0, 0.0);
        q.push(3, 5, 0.0); // higher priority, scanned first
        q.push(4, 0, 0.0);
        // First even task in priority-FIFO order: 3 is odd, then 1 odd,
        // then 2.
        assert_eq!(q.pop_where(10, 0.0, |t| t % 2 == 0), Some(2));
        assert_eq!(q.len(), 3);
        // Bound: scanning only 2 entries (3, then 1) finds no even task.
        assert_eq!(q.pop_where(2, 0.0, |t| t % 2 == 0), None);
        assert_eq!(q.len(), 3, "failed scan removes nothing");
        // Remaining order is untouched.
        assert_eq!(q.pop(0.0), Some(3));
        assert_eq!(q.pop(0.0), Some(1));
        assert_eq!(q.pop(0.0), Some(4));
    }

    #[test]
    fn pop_where_never_matches_leaves_queue_intact() {
        let mut q = PendingQueue::new();
        for t in 0..5u64 {
            q.push(t, 0, 0.0);
        }
        assert_eq!(q.pop_where(100, 0.0, |_| false), None);
        assert_eq!(q.len(), 5);
        let drained: Vec<_> = std::iter::from_fn(|| q.pop(0.0)).collect();
        assert_eq!(drained, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn interleaved_priorities_stay_fifo() {
        let mut q = PendingQueue::new();
        for i in 0..100u64 {
            q.push(i, (i % 3) as i32, 0.0);
        }
        let mut last_by_prio = [None::<u64>; 3];
        let mut prio_seen = Vec::new();
        while let Some(t) = q.pop(0.0) {
            let p = (t % 3) as usize;
            if let Some(prev) = last_by_prio[p] {
                assert!(t > prev, "FIFO violated within priority {p}");
            }
            last_by_prio[p] = Some(t);
            prio_seen.push(p);
        }
        // All priority-2 tasks must come before any priority-1, etc.
        let first_1 = prio_seen.iter().position(|&p| p == 1).unwrap();
        let last_2 = prio_seen.iter().rposition(|&p| p == 2).unwrap();
        assert!(last_2 < first_1);
    }

    // ---- aging ----

    #[test]
    fn aging_boost_is_monotone_and_capped() {
        let a = AgingPolicy::new(0.5, 10);
        assert_eq!(a.boost(-5.0), 0, "no credit before enqueue");
        assert_eq!(a.boost(0.0), 0);
        assert_eq!(a.boost(1.9), 0, "floor: below one point");
        assert_eq!(a.boost(2.0), 1);
        let mut prev = 0;
        for w in 0..200 {
            let b = a.boost(w as f64);
            assert!(b >= prev, "boost must be monotone in wait");
            assert!(b <= 10, "boost must respect the cap");
            prev = b;
        }
        assert_eq!(a.boost(1e9), 10, "cap binds for arbitrarily long waits");
        assert_eq!(a.effective(-5, 30.0), -5 + 10);
        // Degenerate slopes never boost.
        assert_eq!(AgingPolicy::new(0.0, 10).boost(100.0), 0);
        assert_eq!(AgingPolicy::new(-1.0, 10).boost(100.0), 0);
        // Saturating cast: absurd slopes cannot overflow.
        assert_eq!(AgingPolicy::new(1e300, i32::MAX).boost(1e300), i32::MAX as i64);
    }

    #[test]
    fn overtake_wait_bounds_the_gap() {
        let a = AgingPolicy::new(0.5, 100);
        let w = a.overtake_wait(-5, 10).unwrap();
        assert!(a.effective(-5, w) > 10, "after w the entry outranks a fresh 10");
        assert_eq!(a.overtake_wait(10, -5), Some(0.0), "already ahead");
        // Cap smaller than the gap: never overtakes.
        assert_eq!(AgingPolicy::new(0.5, 3).overtake_wait(-5, 10), None);
        assert_eq!(AgingPolicy::new(0.0, 100).overtake_wait(0, 1), None);
    }

    #[test]
    fn aged_low_priority_overtakes_fresh_high_priority() {
        let mut q = PendingQueue::new();
        q.set_aging(Some(AgingPolicy::new(1.0, 100)));
        q.push(1, 0, 0.0); // old, low priority
        q.push(2, 10, 15.0); // fresh, high priority
        // At t = 16: eff(1) = 0 + 16 = 16 beats eff(2) = 10 + 1 = 11.
        assert_eq!(q.peek(16.0), Some(1));
        assert_eq!(q.pop(16.0), Some(1), "aged entry pops first");
        assert_eq!(q.pop(16.0), Some(2));
        // Same queue without aging: static priority wins.
        let mut q = PendingQueue::new();
        q.push(1, 0, 0.0);
        q.push(2, 10, 15.0);
        assert_eq!(q.pop(16.0), Some(2), "no aging: high priority first");
    }

    #[test]
    fn aging_cap_stops_the_climb() {
        let mut q = PendingQueue::new();
        q.set_aging(Some(AgingPolicy::new(1.0, 3)));
        q.push(1, 0, 0.0);
        q.push(2, 10, 0.0);
        // Even after forever, 0 + 3 < 10 + boost: high priority holds.
        assert_eq!(q.pop(1e6), Some(2));
        assert_eq!(q.pop(1e6), Some(1));
    }

    #[test]
    fn pop_where_respects_aged_priority() {
        let mut q = PendingQueue::new();
        q.set_aging(Some(AgingPolicy::new(1.0, 100)));
        q.push(1, 0, 0.0); // old, low priority
        q.push(2, 10, 15.0); // fresh, high priority
        q.push(3, 10, 15.5);
        // Scan order at t = 16 is [1, 2, 3]; the bound must count the
        // aged entry first.
        assert_eq!(q.iter_ordered(16.0, 10), vec![1, 2, 3]);
        assert_eq!(q.pop_where(1, 16.0, |t| t != 1), None, "window holds only the aged head");
        assert_eq!(q.pop_where(10, 16.0, |t| t != 1), Some(2));
        assert_eq!(q.len(), 2);
        // At t = 0 relative ordering is static (nobody has credit yet).
        let mut q = PendingQueue::new();
        q.set_aging(Some(AgingPolicy::new(1.0, 100)));
        q.push(1, 0, 0.0);
        q.push(2, 10, 0.0);
        assert_eq!(q.iter_ordered(0.0, 10), vec![2, 1]);
    }

    #[test]
    fn push_front_roundtrip_preserves_aging_credit() {
        // Regression: a head-of-line retry must keep its original
        // enqueue timestamp. Re-stamping would reset the aged entry's
        // credit and let the fresh high-priority entry overtake it.
        let mut q = PendingQueue::new();
        q.set_aging(Some(AgingPolicy::new(1.0, 1000)));
        q.push(1, 0, 0.0);
        q.push(2, 5, 8.0);
        // At t = 10: eff(1) = 10 > eff(2) = 7.
        let head = q.pop(10.0).unwrap();
        assert_eq!(head, 1);
        // Failed placement: back to the front with the ORIGINAL stamp.
        q.push_front(head, 0, 0.0);
        assert_eq!(
            q.pop(10.0),
            Some(1),
            "retry keeps its age; a fresh stamp would rank it 0 < 7 and pop 2"
        );
        // And the aged order persists across repeated retries.
        q.push_front(1, 0, 0.0);
        q.push_front(1, 0, 0.0); // remove + retry churn
        q.remove(1);
        assert_eq!(q.pop(10.0), Some(1));
        assert_eq!(q.pop(10.0), Some(2));
    }

    #[test]
    fn requeue_then_scan_keeps_global_dispatch_order() {
        // Regression for the aging-order hole: a backfill-race requeue
        // (`pop_where` extracts from the middle, the placement fails,
        // `push_front` puts it back) used to land the younger entry at
        // the bucket front, breaking the oldest-first invariant that
        // `best_front` and `scan_order`'s k-way merge rely on.
        let mut q = PendingQueue::new();
        q.set_aging(Some(AgingPolicy::new(1.0, 100)));
        q.push(1, 0, 0.0); // old entry, lots of aging credit
        q.push(2, 0, 8.0); // younger sibling in the same bucket
        // Backfill pulls the younger entry out of the middle…
        assert_eq!(q.pop_where(10, 8.0, |t| t == 2), Some(2));
        // …fails to place it, and requeues it head-of-line.
        q.push_front(2, 0, 8.0);
        q.push(3, 3, 6.0); // a third bucket to force a real merge
        // At t = 10: eff(1) = 0+10, eff(3) = 3+4, eff(2) = 0+2.
        // The broken front insert hid 1 behind 2, yielding [3, 2, 1]
        // and popping 3 first.
        assert_eq!(q.iter_ordered(10.0, 10), vec![1, 3, 2]);
        assert_eq!(q.pop(10.0), Some(1));
        assert_eq!(q.pop(10.0), Some(3));
        assert_eq!(q.pop(10.0), Some(2));
    }

    #[test]
    fn emptied_buckets_are_pruned() {
        // Every removal path (`pop`, `pop_where`, `remove`) must drop a
        // bucket when it empties; a workload cycling through many
        // distinct priorities would otherwise leave `best_front` and
        // `scan_order` walking dead buckets forever.
        let mut q = PendingQueue::new();
        for p in 0..32 {
            q.push(p as u64, p, 0.0);
        }
        assert_eq!(q.bucket_count(), 32);
        // pop drains the highest bucket and prunes it.
        assert_eq!(q.pop(0.0), Some(31));
        assert_eq!(q.bucket_count(), 31);
        // pop_where extracting a bucket's only entry prunes it too.
        assert_eq!(q.pop_where(64, 0.0, |t| t == 5), Some(5));
        assert_eq!(q.bucket_count(), 30);
        // remove (cancellation) likewise.
        assert!(q.remove(17));
        assert_eq!(q.bucket_count(), 29);
        // A multi-entry bucket survives until its last entry leaves.
        q.push(100, 0, 1.0);
        assert_eq!(q.bucket_count(), 29);
        assert_eq!(q.pop(1.0), Some(30));
        while q.pop(1.0).is_some() {}
        assert_eq!(q.bucket_count(), 0, "drained queue holds no buckets");
        assert!(q.is_empty());
    }

    #[test]
    fn aging_off_matches_static_discipline_exactly() {
        // The same operation sequence against an aging queue with no
        // policy and the static queue must produce identical orders.
        let mut with = PendingQueue::new();
        with.set_aging(None);
        let mut without = PendingQueue::new();
        for i in 0..50u64 {
            let prio = (i % 5) as i32 - 2;
            with.push(i, prio, i as f64);
            without.push(i, prio, 0.0);
        }
        let mut a = Vec::new();
        let mut b = Vec::new();
        while let Some(t) = with.pop(1e6) {
            a.push(t);
        }
        while let Some(t) = without.pop(0.0) {
            b.push(t);
        }
        assert_eq!(a, b);
    }
}
