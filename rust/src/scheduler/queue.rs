//! The pending queue: priority-then-FIFO ordering over scheduling tasks.
//!
//! Within one array job all tasks share a priority, so dispatch order is
//! array order (Slurm behaves the same). Across jobs, higher priority goes
//! first; spot jobs ride at negative priority.

use crate::scheduler::job::TaskId;
use std::collections::VecDeque;

/// One pending entry.
#[derive(Debug, Clone, Copy, PartialEq)]
struct Entry {
    task: TaskId,
    priority: i32,
    seq: u64,
}

/// Priority + FIFO pending queue with O(1) pop and O(log n)-ish insert
/// (bucketed by priority; priorities in practice are a handful of values).
#[derive(Debug, Default)]
pub struct PendingQueue {
    /// Buckets sorted by descending priority; each bucket FIFO.
    buckets: Vec<(i32, VecDeque<Entry>)>,
    seq: u64,
    len: usize,
}

impl PendingQueue {
    pub fn new() -> PendingQueue {
        PendingQueue::default()
    }

    /// Enqueue a task at a priority.
    pub fn push(&mut self, task: TaskId, priority: i32) {
        self.seq += 1;
        self.len += 1;
        let e = Entry {
            task,
            priority,
            seq: self.seq,
        };
        match self.buckets.binary_search_by(|(p, _)| priority.cmp(p)) {
            Ok(i) => self.buckets[i].1.push_back(e),
            Err(i) => {
                let mut q = VecDeque::new();
                q.push_back(e);
                self.buckets.insert(i, (priority, q));
            }
        }
    }

    /// Peek the next task without removing it.
    pub fn peek(&self) -> Option<TaskId> {
        self.buckets
            .iter()
            .find(|(_, q)| !q.is_empty())
            .and_then(|(_, q)| q.front().map(|e| e.task))
    }

    /// Pop the highest-priority, oldest task.
    pub fn pop(&mut self) -> Option<TaskId> {
        for (_, q) in self.buckets.iter_mut() {
            if let Some(e) = q.pop_front() {
                self.len -= 1;
                return Some(e.task);
            }
        }
        None
    }

    /// Put a task back at the *front* of its priority bucket (head-of-line
    /// retry after a failed placement).
    pub fn push_front(&mut self, task: TaskId, priority: i32) {
        self.len += 1;
        let e = Entry {
            task,
            priority,
            seq: 0, // front of bucket
        };
        match self.buckets.binary_search_by(|(p, _)| priority.cmp(p)) {
            Ok(i) => self.buckets[i].1.push_front(e),
            Err(i) => {
                let mut q = VecDeque::new();
                q.push_back(e);
                self.buckets.insert(i, (priority, q));
            }
        }
    }

    /// Pop the first task (priority-then-FIFO order) satisfying `pred`,
    /// scanning at most `max_scan` entries — the backfill lookahead.
    ///
    /// The bound keeps the scan cheap on deep queues *and* bounds
    /// priority inversion: a backfill candidate can only jump entries
    /// inside the lookahead window, so ahead-of-it tasks age out of
    /// reach after at most `max_scan` backfills.
    pub fn pop_where(
        &mut self,
        max_scan: usize,
        mut pred: impl FnMut(TaskId) -> bool,
    ) -> Option<TaskId> {
        let mut scanned = 0usize;
        for (_, q) in self.buckets.iter_mut() {
            let budget = max_scan - scanned;
            if let Some(pos) = q.iter().take(budget).position(|e| pred(e.task)) {
                let task = q[pos].task;
                let _ = q.remove(pos);
                self.len -= 1;
                return Some(task);
            }
            scanned += q.len().min(budget);
            if scanned >= max_scan {
                return None;
            }
        }
        None
    }

    /// Remove an arbitrary task (job cancellation); O(n).
    pub fn remove(&mut self, task: TaskId) -> bool {
        for (_, q) in self.buckets.iter_mut() {
            if let Some(pos) = q.iter().position(|e| e.task == task) {
                q.remove(pos);
                self.len -= 1;
                return true;
            }
        }
        false
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_within_priority() {
        let mut q = PendingQueue::new();
        q.push(1, 0);
        q.push(2, 0);
        q.push(3, 0);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn priority_order_across_buckets() {
        let mut q = PendingQueue::new();
        q.push(10, -5); // spot
        q.push(11, 0); // normal
        q.push(12, 5); // interactive
        q.push(13, 0);
        assert_eq!(q.pop(), Some(12));
        assert_eq!(q.pop(), Some(11));
        assert_eq!(q.pop(), Some(13));
        assert_eq!(q.pop(), Some(10));
    }

    #[test]
    fn push_front_retries_first() {
        let mut q = PendingQueue::new();
        q.push(1, 0);
        q.push(2, 0);
        let t = q.pop().unwrap();
        q.push_front(t, 0);
        assert_eq!(q.pop(), Some(1), "retried task pops first again");
    }

    #[test]
    fn peek_does_not_remove() {
        let mut q = PendingQueue::new();
        q.push(7, 1);
        assert_eq!(q.peek(), Some(7));
        assert_eq!(q.len(), 1);
        assert_eq!(q.pop(), Some(7));
        assert!(q.is_empty());
    }

    #[test]
    fn remove_specific() {
        let mut q = PendingQueue::new();
        q.push(1, 0);
        q.push(2, 0);
        q.push(3, 1);
        assert!(q.remove(2));
        assert!(!q.remove(99));
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop(), Some(3));
        assert_eq!(q.pop(), Some(1));
    }

    #[test]
    fn push_front_into_missing_bucket_creates_it() {
        // A head-of-line retry at a priority with no live bucket must
        // create the bucket in sorted position, not panic or misorder.
        let mut q = PendingQueue::new();
        q.push(1, 0);
        q.push_front(2, 5); // no priority-5 bucket exists yet
        q.push_front(3, -5); // nor a -5 one
        assert_eq!(q.len(), 3);
        assert_eq!(q.pop(), Some(2), "highest priority first");
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(3));
        assert_eq!(q.pop(), None);
        assert_eq!(q.len(), 0);
    }

    #[test]
    fn push_front_ordering_within_existing_bucket() {
        let mut q = PendingQueue::new();
        q.push(1, 0);
        q.push(2, 0);
        q.push_front(9, 0);
        q.push_front(8, 0);
        // Most recent retry pops first, then the earlier retry, then FIFO.
        assert_eq!(q.pop(), Some(8));
        assert_eq!(q.pop(), Some(9));
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn remove_maintains_len_invariants() {
        let mut q = PendingQueue::new();
        for t in 0..10u64 {
            q.push(t, (t % 2) as i32);
        }
        assert_eq!(q.len(), 10);
        // Remove from the middle, the head, and a push_front entry.
        assert!(q.remove(4));
        assert!(q.remove(1));
        q.push_front(99, 1);
        assert!(q.remove(99));
        assert_eq!(q.len(), 8);
        // Double-remove and unknown ids leave len untouched.
        assert!(!q.remove(4));
        assert!(!q.remove(1234));
        assert_eq!(q.len(), 8);
        // Drain: count must match len, ids must be the surviving ones.
        let mut drained = Vec::new();
        while let Some(t) = q.pop() {
            drained.push(t);
        }
        assert_eq!(drained.len(), 8);
        assert!(!drained.contains(&4) && !drained.contains(&1) && !drained.contains(&99));
        assert!(q.is_empty());
        assert_eq!(q.len(), 0);
    }

    #[test]
    fn remove_then_push_front_roundtrip() {
        // The scheduler's failed-dispatch path: pop, fail, push_front,
        // preemption removes it. len must stay exact throughout.
        let mut q = PendingQueue::new();
        q.push(7, 0);
        let t = q.pop().unwrap();
        assert_eq!(q.len(), 0);
        q.push_front(t, 0);
        assert_eq!(q.len(), 1);
        assert!(q.remove(t));
        assert!(q.is_empty());
        assert_eq!(q.peek(), None);
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn pop_where_scans_in_order_and_respects_bound() {
        let mut q = PendingQueue::new();
        q.push(1, 0);
        q.push(2, 0);
        q.push(3, 5); // higher priority, scanned first
        q.push(4, 0);
        // First even task in priority-FIFO order: 3 is odd, then 1 odd,
        // then 2.
        assert_eq!(q.pop_where(10, |t| t % 2 == 0), Some(2));
        assert_eq!(q.len(), 3);
        // Bound: scanning only 2 entries (3, then 1) finds no even task.
        assert_eq!(q.pop_where(2, |t| t % 2 == 0), None);
        assert_eq!(q.len(), 3, "failed scan removes nothing");
        // Remaining order is untouched.
        assert_eq!(q.pop(), Some(3));
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(4));
    }

    #[test]
    fn pop_where_never_matches_leaves_queue_intact() {
        let mut q = PendingQueue::new();
        for t in 0..5u64 {
            q.push(t, 0);
        }
        assert_eq!(q.pop_where(100, |_| false), None);
        assert_eq!(q.len(), 5);
        let drained: Vec<_> = std::iter::from_fn(|| q.pop()).collect();
        assert_eq!(drained, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn interleaved_priorities_stay_fifo() {
        let mut q = PendingQueue::new();
        for i in 0..100u64 {
            q.push(i, (i % 3) as i32);
        }
        let mut last_by_prio = [None::<u64>; 3];
        let mut prio_seen = Vec::new();
        while let Some(t) = q.pop() {
            let p = (t % 3) as usize;
            if let Some(prev) = last_by_prio[p] {
                assert!(t > prev, "FIFO violated within priority {p}");
            }
            last_by_prio[p] = Some(t);
            prio_seen.push(p);
        }
        // All priority-2 tasks must come before any priority-1, etc.
        let first_1 = prio_seen.iter().position(|&p| p == 1).unwrap();
        let last_2 = prio_seen.iter().rposition(|&p| p == 2).unwrap();
        assert!(last_2 < first_1);
    }
}
