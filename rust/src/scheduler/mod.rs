//! The Slurm-like centralized scheduler substrate.
//!
//! The paper measures how a production scheduler (Slurm on TX-Green)
//! behaves when a single array job carries 2048–32768 scheduling tasks
//! (multi-level / per-core aggregation) versus 32–512 (node-based
//! aggregation). We rebuild the relevant scheduler anatomy:
//!
//! * a **job/task state machine** (`PENDING → RUNNING → COMPLETING →
//!   DONE`) with full per-task timestamps ([`job`], [`accounting`]),
//! * a **single-threaded scheduler server** that serializes submission
//!   registration, dispatch RPCs and completion cleanup transactions —
//!   the serialization is what collapses at 512-node scale. The façade
//!   and public types live in [`core`]; the op loop and service
//!   discipline in [`server`]; task state transitions, placement (via
//!   [`crate::placement`]) and cleanup in [`lifecycle`],
//! * a **calibrated cost model** for each server operation
//!   ([`costmodel`]), including the array-size-dependent cleanup cost the
//!   paper observed ("releasing the completed tasks takes significantly
//!   longer than dispatching"),
//! * a **pending queue** with FIFO + priority ordering ([`queue`]), and
//! * a **background-load (production noise) process** reproducing the
//!   paper's production-vs-dedicated distinction ([`noise`]).

pub mod accounting;
pub mod core;
pub mod costmodel;
pub mod job;
pub mod lifecycle;
pub mod noise;
pub mod queue;
pub mod server;

pub use accounting::{JobStats, TaskRecord};
pub use self::core::{HotPath, SchedEvent, SchedulerSim, SimOutcome};
pub use costmodel::CostModel;
pub use job::{ComputeBatch, JobId, JobSpec, ResourceRequest, SchedTaskSpec, TaskId, TaskState};
pub use queue::{AgingPolicy, PendingQueue};
