//! The scheduler server: the op loop, the work-conserving service
//! discipline, and the DES event handler.
//!
//! Everything here models *what the single-threaded server spends its
//! time on*. The rules that produce the paper's 512-node collapse:
//!
//! 1. one operation at a time (registration, cycle scan, dispatch,
//!    cleanup, noise burst, preempt signal), each with a calibrated
//!    virtual-time cost ([`crate::scheduler::costmodel`]);
//! 2. service order: background noise → preempt signals → cleanups
//!    (with a bounded dispatch interleave) → cycle-batched dispatches;
//! 3. cleanups cost more than dispatches and grow with array size, so
//!    once completions flood in, dispatch starves.
//!
//! What happens when an operation *completes* (state transitions,
//! placement, resource release) lives in
//! [`crate::scheduler::lifecycle`].

use crate::scheduler::accounting::TaskRecord;
use crate::scheduler::core::{JobMeta, Op, SchedEvent, SchedulerSim, TaskSlot};
use crate::scheduler::job::{ResourceRequest, TaskId, TaskState};
use crate::sim::{self, EventQueue, Time};

impl SchedulerSim {
    /// If the server is idle, pick the next operation and start it.
    pub(crate) fn kick(&mut self, now: Time, q: &mut EventQueue<SchedEvent>) {
        if self.server_busy {
            return;
        }
        if let Some((op, cost)) = self.pick_next() {
            self.server_busy = true;
            self.busy_since = now;
            q.after(cost, SchedEvent::ServerDone(op));
        }
    }

    /// Work-conserving service discipline (see module docs):
    /// noise → preempt signals → cleanups (with bounded dispatch
    /// interleave) → dispatches (cycle-batched).
    pub(crate) fn pick_next(&mut self) -> Option<(Op, Time)> {
        let s = self.op_scale;
        if let Some(demand) = self.noise_q.pop_front() {
            return Some((Op::Noise(demand), demand * s));
        }
        if let Some(t) = self.preempt_q.pop_front() {
            return Some((Op::PreemptSignal(t), self.cost.preempt_signal * s));
        }
        let can_dispatch = !self.pending.is_empty() && !self.hol_blocked;
        if !self.completions.is_empty() {
            let must_interleave =
                can_dispatch && self.cleanups_since_dispatch >= self.cost.cleanup_interleave;
            if !must_interleave {
                let tid = self.completions.pop_front().expect("checked non-empty");
                self.cleanups_since_dispatch += 1;
                let array = self.jobs[self.tasks[tid as usize].record.job as usize].array_size;
                return Some((Op::Cleanup(tid), self.cost.cleanup(array) * s));
            }
        }
        if can_dispatch {
            if self.cycle_budget == 0 {
                return Some((Op::Cycle, self.cost.cycle(self.pending.len()) * s));
            }
            let tid = self.pending.pop().expect("checked non-empty");
            self.cleanups_since_dispatch = 0;
            self.cycle_budget -= 1;
            let node_level =
                self.tasks[tid as usize].spec.request == ResourceRequest::WholeNode;
            return Some((Op::Dispatch(tid), self.cost.dispatch(node_level) * s));
        }
        None
    }

    /// Account a finished operation and apply its effects.
    pub(crate) fn apply_op(&mut self, now: Time, op: Op, q: &mut EventQueue<SchedEvent>) {
        match op {
            Op::Register(job) => {
                self.busy.register +=
                    self.cost.submit(self.jobs[job as usize].array_size) * self.op_scale;
                // Materialized at Submit; now they become schedulable.
                let prio = self.jobs[job as usize].priority;
                let ids: Vec<TaskId> = self
                    .tasks
                    .iter()
                    .filter(|t| t.record.job == job && t.record.state == TaskState::Pending)
                    .map(|t| t.record.task)
                    .collect();
                for tid in ids {
                    self.pending.push(tid, prio);
                }
            }
            Op::Cycle => {
                self.busy.cycle += self.cost.cycle(self.pending.len()) * self.op_scale;
                self.cycle_budget = self.cost.dispatch_cycle_batch;
            }
            Op::Dispatch(tid) => {
                let node_level =
                    self.tasks[tid as usize].spec.request == ResourceRequest::WholeNode;
                self.busy.dispatch += self.cost.dispatch(node_level) * self.op_scale;
                self.try_place(now, tid, q);
            }
            Op::Cleanup(tid) => {
                let array = self.jobs[self.tasks[tid as usize].record.job as usize].array_size;
                self.busy.cleanup += self.cost.cleanup(array) * self.op_scale;
                self.finish_cleanup(now, tid);
            }
            Op::Noise(d) => {
                self.busy.noise += d * self.op_scale;
            }
            Op::PreemptSignal(tid) => {
                self.busy.preempt += self.cost.preempt_signal * self.op_scale;
                self.apply_preempt_signal(now, tid);
            }
        }
    }
}

impl sim::Actor for SchedulerSim {
    type Event = SchedEvent;

    fn handle(&mut self, now: Time, ev: SchedEvent, q: &mut EventQueue<SchedEvent>) {
        match ev {
            SchedEvent::Submit(id) => {
                if self.server_busy {
                    // The server is mid-operation: retry a tick later so
                    // registration serializes behind it (nothing is
                    // materialized yet, so there is nothing to roll back).
                    q.after(sim::TICK, SchedEvent::Submit(id));
                    return;
                }
                let spec = self.specs[id as usize].take().expect("double submit");
                // Largest node's core count, cached by the placement
                // index (no O(nodes) walk per submission).
                let cores_per_node = self.engine.index().cores_per_node();
                spec.validate(cores_per_node).expect("invalid job spec submitted");
                let meta = JobMeta {
                    id,
                    name: spec.name.clone(),
                    array_size: spec.array_size(),
                    reservation: spec.reservation.clone(),
                    priority: spec.priority,
                    preemptable: spec.preemptable,
                    submit_t: now,
                };
                // Materialize task slots (records in PENDING).
                for t in &spec.tasks {
                    let tid = self.tasks.len() as TaskId;
                    self.tasks.push(TaskSlot {
                        spec: t.clone(),
                        record: TaskRecord {
                            task: tid,
                            job: id,
                            state: TaskState::Pending,
                            submit_t: now,
                            start_t: None,
                            end_t: None,
                            cleanup_t: None,
                            cores: 0,
                        },
                        placement: None,
                        priority: spec.priority,
                    });
                }
                while self.jobs.len() <= id as usize {
                    // placeholder ordering safety (ids are dense by construction)
                    self.jobs.push(meta.clone());
                }
                self.jobs[id as usize] = meta;
                // Registration is server work.
                let cost = self.cost.submit(spec.array_size());
                self.server_busy = true;
                self.busy_since = now;
                q.after(cost * self.op_scale, SchedEvent::ServerDone(Op::Register(id)));
            }
            SchedEvent::ServerDone(op) => {
                self.apply_op(now, op, q);
                self.server_busy = false;
                // Background bursts do not count as *scheduler* saturation:
                // the unusable-in-production guard measures the load this
                // job itself puts on the server, matching the paper's
                // observation about multi-level runs.
                let is_noise = matches!(op, Op::Noise(_));
                let stretch_started = if is_noise { now } else { self.busy_since };
                let stretch = now - stretch_started;
                if stretch > self.longest_busy_stretch {
                    self.longest_busy_stretch = stretch;
                }
                self.kick(now, q);
                if self.server_busy {
                    // The server went straight back to work: this is one
                    // continuous saturated stretch, so keep its start time.
                    self.busy_since = stretch_started;
                }
            }
            SchedEvent::TaskEnded(tid) => {
                self.finish_task(now, tid);
                self.kick(now, q);
            }
            SchedEvent::NoiseSmall => {
                if let Some((gap, demand)) = self.noise.next_small(&mut self.rng) {
                    self.noise_q.push_back(demand);
                    // Only keep the process alive while user work exists;
                    // otherwise the sim would never terminate.
                    if self.has_outstanding_work() {
                        q.after(gap, SchedEvent::NoiseSmall);
                    }
                }
                self.kick(now, q);
            }
            SchedEvent::NoiseLarge => {
                if let Some((gap, demand)) = self.noise.next_large(&mut self.rng) {
                    self.noise_q.push_back(demand);
                    if self.has_outstanding_work() {
                        q.after(gap, SchedEvent::NoiseLarge);
                    }
                }
                self.kick(now, q);
            }
            SchedEvent::Preempt(job) => {
                self.preempt_job(now, job);
                self.kick(now, q);
            }
        }
    }
}
