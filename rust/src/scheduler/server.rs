//! The scheduler server: the op loop, the work-conserving service
//! discipline, and the DES event handler.
//!
//! Everything here models *what the single-threaded server spends its
//! time on*. The rules that produce the paper's 512-node collapse:
//!
//! 1. one operation at a time (registration, cycle scan, dispatch,
//!    cleanup, noise burst, preempt signal, backfill dispatch), each
//!    with a calibrated virtual-time cost
//!    ([`crate::scheduler::costmodel`]);
//! 2. service order: background noise → preempt signals → cleanups
//!    (with a bounded dispatch interleave) → cycle-batched dispatches →
//!    backfill (only when the head of the queue is blocked);
//! 3. cleanups cost more than dispatches and grow with array size, so
//!    once completions flood in, dispatch starves.
//!
//! With backfill enabled ([`SchedulerSim::with_backfill`]) a blocked
//! whole-node head holds an earliest-start reservation
//! ([`crate::placement::backfill`]); the backfill branch then admits
//! small core-level tasks from a bounded lookahead window, provided the
//! placement engine can put them somewhere that cannot delay the hold.
//!
//! What happens when an operation *completes* (state transitions,
//! placement, resource release) lives in
//! [`crate::scheduler::lifecycle`].

use crate::cluster::NodeState;
use crate::fault::audit::FaultReason;
use crate::obs::TraceKind;
use crate::pool::Resize;
use crate::scheduler::accounting::TaskRecord;
use crate::scheduler::core::{HotPath, JobMeta, Op, SchedEvent, SchedulerSim, TaskSlot};
use crate::scheduler::job::{ResourceRequest, TaskId, TaskState};
use crate::sim::{self, EventQueue, Time};

/// The `(branch-code, subject-id)` pair a picked op contributes to its
/// `Pick` trace record. Codes follow the service-discipline order and
/// are part of the exporter vocabulary (see `docs/observability.md`).
fn op_trace_key(op: &Op) -> (u32, u64) {
    match *op {
        Op::Register(j) => (0, j),
        Op::Cycle => (1, 0),
        Op::Dispatch(t) => (2, t),
        Op::Backfill(t) => (3, t),
        Op::Cleanup(t) => (4, t),
        Op::Noise(_) => (5, 0),
        Op::PreemptSignal(t) => (6, t),
        Op::PoolDispatch(_, t) => (7, t),
        Op::PoolRelease(_, t) => (8, t),
        Op::PoolResize(s) => (9, u64::from(s)),
        Op::NodeFail(n) => (10, u64::from(n)),
        Op::NodeRecover(n) => (11, u64::from(n)),
        Op::ReclaimWave(w) => (12, u64::from(w)),
        Op::DrainNode(n) => (13, u64::from(n)),
    }
}

impl SchedulerSim {
    /// If the server is idle, pick the next operation and start it.
    pub(crate) fn kick(&mut self, now: Time, q: &mut EventQueue<SchedEvent>) {
        if self.server_busy {
            return;
        }
        let picked = if self.obs.is_some() {
            self.pick_next_traced(now)
        } else {
            self.pick_next(now)
        };
        if let Some((op, cost)) = picked {
            self.server_busy = true;
            self.busy_since = now;
            q.after(cost, SchedEvent::ServerDone(op));
        }
    }

    /// `pick_next` under the flight recorder: the branch taken becomes
    /// a `Pick` record, the decision feeds the queue-depth and
    /// decision-latency histograms, and in self-profiling mode the
    /// invocation's host-side time accumulates against the cost model's
    /// simulated charge. The recorder only observes — it draws no
    /// randomness and changes no decision — so recorder-on schedules
    /// are bit-for-bit the recorder-off ones (pinned by
    /// `rust/tests/obs_properties.rs`).
    fn pick_next_traced(&mut self, now: Time) -> Option<(Op, Time)> {
        let profiling = self.obs.as_ref().is_some_and(|o| o.profiling());
        let t0 = if profiling { Some(std::time::Instant::now()) } else { None };
        let depth = self.pending.len();
        let picked = self.pick_next(now);
        let obs = self.obs.as_mut().expect("traced pick implies a recorder");
        if let Some(t0) = t0 {
            let sim_cost = picked.map(|(_, c)| c).unwrap_or(0.0);
            obs.profile_pick(t0.elapsed().as_nanos() as u64, sim_cost);
        }
        if let Some((op, cost)) = picked {
            obs.registry.queue_depth.observe(depth as f64);
            obs.registry.decision_latency.observe(cost);
            let (branch, id) = op_trace_key(&op);
            obs.record(TraceKind::Pick, branch, id, now, (cost * 1e9) as i64);
        }
        picked
    }

    /// Work-conserving service discipline (see module docs):
    /// noise → preempt signals → cleanups (with bounded dispatch
    /// interleave) → dispatches (cycle-batched) → backfill.
    pub(crate) fn pick_next(&mut self, now: Time) -> Option<(Op, Time)> {
        let s = self.op_scale;
        // Fault events outrank everything: a dead node must stop taking
        // work before any dispatch decision looks at it. The queue is
        // empty in every fault-off run, so this adds nothing there.
        if let Some(op) = self.fault_q.pop_front() {
            return Some((op, self.cost.fault_handle * s));
        }
        if let Some(demand) = self.noise_q.pop_front() {
            return Some((Op::Noise(demand), demand * s));
        }
        if let Some(t) = self.preempt_q.pop_front() {
            return Some((Op::PreemptSignal(t), self.cost.preempt_signal * s));
        }
        // Rapid-launch fleet service, ahead of the batch machinery (the
        // pool is the fast path): releases first (cheap, free nodes for
        // the next volley), then any shard's due resize, then free-list
        // dispatch shard by shard. With one shard this is exactly the
        // PR 4 single-pool service order.
        let wake_driven = self.hot_path == HotPath::WakeDriven;
        if let Some(p) = self.pool.as_mut() {
            if let Some((sid, tid)) = p.completions.pop_front() {
                return Some((Op::PoolRelease(sid, tid), self.cost.pool_release * s));
            }
            // An empty shard with queued work bypasses the resize
            // cooldown: with no leases there may be no future event to
            // re-kick the server once the cooldown expires, and waiting
            // would strand the queue. `grow_blocked` (set when a grow
            // found nothing to take — no sibling-free node, no batch
            // node — and cleared on the next batch or sibling release)
            // keeps the bypass from spinning on a cluster with nothing
            // left to lease.
            //
            // Wake-driven skip rule: a shard is evaluated only while its
            // attention flag is set (every relevant state transition
            // sets it) — except that an in-flight cooldown wake whose
            // instant has arrived keeps the due check live, because a
            // lower-seq event at the exact expiry instant pops first and
            // must see the shard due, just as a polled pick would.
            for (sid, sh) in p.fleet.shards.iter().enumerate() {
                if wake_driven
                    && !p.attention[sid]
                    && !(p.wakes_pending[sid] > 0 && sh.manager.due(now))
                {
                    continue;
                }
                let starving =
                    !sh.pending.is_empty() && !sh.nodes.any_pooled() && !sh.grow_blocked;
                if (sh.manager.due(now) || starving) && sh.decision() != Resize::Hold {
                    return Some((Op::PoolResize(sid as u32), self.cost.pool_resize * s));
                }
            }
            // No shard had a resize to run: a shard with no dispatchable
            // work either has nothing to do at all now, so its attention
            // flag drops until the next transition or wake re-marks it.
            for sid in 0..p.fleet.shards.len() {
                if wake_driven && !p.attention[sid] {
                    continue;
                }
                let sh = &mut p.fleet.shards[sid];
                if !sh.pending.is_empty() && sh.nodes.n_free() > 0 {
                    let tid = sh.pending.pop_front().expect("checked non-empty");
                    let cost = self.cost.pool_dispatch * s;
                    return Some((Op::PoolDispatch(sid as u32, tid), cost));
                }
                if wake_driven {
                    p.attention[sid] = false;
                }
            }
        }
        let can_dispatch = !self.pending.is_empty() && !self.hol_blocked;
        if !self.completions.is_empty() {
            let must_interleave =
                can_dispatch && self.cleanups_since_dispatch >= self.cost.cleanup_interleave;
            if !must_interleave {
                let tid = self.completions.pop_front().expect("checked non-empty");
                self.cleanups_since_dispatch += 1;
                let array = self.jobs[self.tasks[tid as usize].record.job as usize].array_size;
                return Some((Op::Cleanup(tid), self.cost.cleanup(array) * s));
            }
        }
        if can_dispatch {
            if self.cycle_budget == 0 {
                return Some((Op::Cycle, self.cost.cycle(self.pending.len()) * s));
            }
            let tid = self.pending.pop(now).expect("checked non-empty");
            self.cleanups_since_dispatch = 0;
            self.cycle_budget -= 1;
            let node_level =
                self.tasks[tid as usize].spec.request == ResourceRequest::WholeNode;
            return Some((Op::Dispatch(tid), self.cost.dispatch(node_level) * s));
        }
        // Backfill machinery: only runs while the head of the queue is
        // blocked (otherwise normal dispatch above is work-conserving).
        if self.backfill && self.hol_blocked {
            // Preemptive backfill: a hold that has come due no longer
            // waits for overdue backfilled tasks on its node — they
            // overstayed their declared walltime, so they are killed
            // through the ordinary preempt path (opt-in).
            if self.preempt_overdue {
                self.signal_overdue_backfills(now);
                if let Some(t) = self.preempt_q.pop_front() {
                    return Some((Op::PreemptSignal(t), self.cost.preempt_signal * s));
                }
            }
            // Wake-driven gate: hold readiness is purely state-driven
            // (a node drains, a hold is planted or cleared, a pool
            // lease returns) and a backfill admission window only
            // *shrinks* as the clock advances, so once both scans come
            // up empty nothing can become admissible until a marked
            // transition sets `backfill_dirty` again. Aging is the one
            // exception — it reorders the lookahead window with time —
            // so an installed aging policy keeps the scans unconditional.
            let scan = self.hot_path == HotPath::Polled
                || self.backfill_dirty
                || self.aging.is_some();
            if !scan {
                return None;
            }
            // A held node came wholly idle: dispatch its reservation's
            // own task out of order, wherever it sits in the queue —
            // without this, a blocked higher-priority head would let the
            // held node idle while the reserved job starves behind it.
            // With multi-hold every active hold is checked; whichever
            // reserved node drained first launches first. A hold planted
            // on a still-pool-owned node (the fleet's drain forecast
            // path) is not ready: the node looks idle to the cluster
            // model but the batch fence keeps placement off it until
            // the owning shard actually returns it.
            //
            // The holds are iterated out of a reused scratch buffer (the
            // ledger cannot be borrowed across `pending.remove`), so the
            // hot loop never allocates — the historical code cloned the
            // hold list on every blocked pick.
            let mut holds = std::mem::take(&mut self.hold_scratch);
            holds.clear();
            holds.extend_from_slice(self.ledger.holds());
            let mut picked: Option<TaskId> = None;
            for h in &holds {
                let ready = self
                    .cluster
                    .node(h.node)
                    .map(|n| n.state() == NodeState::Up && n.is_idle())
                    .unwrap_or(false)
                    && self
                        .pool
                        .as_ref()
                        .map(|p| !p.fleet.in_pool(h.node))
                        .unwrap_or(true);
                if !ready {
                    continue;
                }
                if self.pending.remove(h.task) {
                    picked = Some(h.task);
                    break;
                }
                // Hold task no longer pending (cancelled): unfence.
                self.ledger.clear_hold(h.task);
            }
            self.hold_scratch = holds;
            if let Some(task) = picked {
                self.cleanups_since_dispatch = 0;
                return Some((Op::Dispatch(task), self.cost.dispatch(true) * s));
            }
            if let Some(tid) = self.find_backfill(now) {
                self.cleanups_since_dispatch = 0;
                return Some((Op::Backfill(tid), self.cost.dispatch(false) * s));
            }
            // Both scans empty: gate them off until state moves again.
            self.backfill_dirty = false;
        }
        None
    }

    /// Scan the lookahead window of the pending queue for a core-level
    /// task the placement engine can admit without delaying the active
    /// hold. Pops (and returns) the first such task.
    fn find_backfill(&mut self, now: Time) -> Option<TaskId> {
        // The dispatch op lands `dispatch_core × op_scale` later; fold
        // that into the completion estimate so the admission decision
        // made here is exactly the one the placement re-check sees.
        let dispatch_at = now + self.cost.dispatch(false) * self.op_scale;
        let startup = self.task_model.startup;
        let tasks = &self.tasks;
        let jobs = &self.jobs;
        let engine = &self.engine;
        let cluster = &self.cluster;
        let ledger = &self.ledger;
        let pool = self.pool.as_ref().map(|p| &p.fleet);
        self.pending.pop_where(self.backfill_lookahead, now, |tid| {
            let slot = &tasks[tid as usize];
            let (cores, mem_mib) = match slot.spec.request {
                ResourceRequest::Cores { cores, mem_mib } => (cores, mem_mib),
                ResourceRequest::WholeNode => return false,
            };
            // Admission plans from the walltime *estimate*: exact under
            // WalltimeError::None, noisy otherwise (a real scheduler
            // only knows the declared walltime).
            let est_end = dispatch_at + startup + slot.est_duration;
            let res = jobs[slot.record.job as usize].reservation.as_deref();
            engine
                .peek_cores_where(cluster, res, cores, mem_mib, &|n| {
                    ledger.allows_backfill(n, est_end)
                        && pool.map(|pn| !pn.in_pool(n)).unwrap_or(true)
                })
                .is_some()
        })
    }

    /// Account a finished operation and apply its effects.
    pub(crate) fn apply_op(&mut self, now: Time, op: Op, q: &mut EventQueue<SchedEvent>) {
        match op {
            Op::Register(job) => {
                self.busy.register +=
                    self.cost.submit(self.jobs[job as usize].array_size) * self.op_scale;
                // Materialized at Submit; now they become schedulable.
                // The job's slots are one contiguous arena range, so
                // registration walks exactly its own tasks. (The state
                // check stays: a preempt can cancel a task between
                // materialization and registration completing.)
                let prio = self.jobs[job as usize].priority;
                // The span layer's queue-entry anchor and job→task
                // mapping: one record per job, carrying its contiguous
                // arena range (unit = task count, detail = first task).
                let (range_first, range_count) = {
                    let m = &self.jobs[job as usize];
                    (m.first_task, m.task_count)
                };
                self.trace(TraceKind::JobQueued, range_count, job, now, range_first as i64);
                if self.legacy_register {
                    // Bench-only: the pre-arena whole-arena scan, kept
                    // so the speedup is measurable against the same
                    // schedule (`SchedulerSim::with_legacy_register`).
                    let ids: Vec<TaskId> = self
                        .tasks
                        .iter()
                        .filter(|t| t.record.job == job && t.record.state == TaskState::Pending)
                        .map(|t| t.record.task)
                        .collect();
                    for tid in ids {
                        self.enqueue_registered(now, tid, prio);
                    }
                } else {
                    let (first, count) = {
                        let m = &self.jobs[job as usize];
                        (m.first_task, m.task_count)
                    };
                    for tid in first..first + count as TaskId {
                        if self.tasks[tid as usize].record.state == TaskState::Pending {
                            self.enqueue_registered(now, tid, prio);
                        }
                    }
                }
            }
            Op::Cycle => {
                self.busy.cycle += self.cost.cycle(self.pending.len()) * self.op_scale;
                self.cycle_budget = self.cost.dispatch_cycle_batch;
            }
            Op::Dispatch(tid) => {
                let node_level =
                    self.tasks[tid as usize].spec.request == ResourceRequest::WholeNode;
                self.busy.dispatch += self.cost.dispatch(node_level) * self.op_scale;
                self.try_place(now, tid, q);
            }
            Op::Backfill(tid) => {
                self.busy.dispatch += self.cost.dispatch(false) * self.op_scale;
                self.try_place_backfill(now, tid, q);
            }
            Op::Cleanup(tid) => {
                let array = self.jobs[self.tasks[tid as usize].record.job as usize].array_size;
                self.busy.cleanup += self.cost.cleanup(array) * self.op_scale;
                self.finish_cleanup(now, tid, q);
            }
            Op::Noise(d) => {
                self.busy.noise += d * self.op_scale;
            }
            Op::PreemptSignal(tid) => {
                self.busy.preempt += self.cost.preempt_signal * self.op_scale;
                self.apply_preempt_signal(now, tid);
            }
            Op::PoolDispatch(sid, tid) => {
                self.busy.pool += self.cost.pool_dispatch * self.op_scale;
                self.pool_launch(now, sid, tid, q);
            }
            Op::PoolRelease(sid, tid) => {
                self.busy.pool += self.cost.pool_release * self.op_scale;
                self.finish_pool_release(now, sid, tid);
            }
            Op::PoolResize(sid) => {
                self.busy.pool += self.cost.pool_resize * self.op_scale;
                self.apply_pool_resize(now, sid, q);
            }
            Op::NodeFail(node) => {
                self.busy.fault += self.cost.fault_handle * self.op_scale;
                self.apply_node_fail(now, node, FaultReason::Mtbf, q);
            }
            Op::NodeRecover(node) => {
                self.busy.fault += self.cost.fault_handle * self.op_scale;
                self.apply_node_recover(now, node);
            }
            Op::ReclaimWave(w) => {
                self.busy.fault += self.cost.fault_handle * self.op_scale;
                self.apply_reclaim_wave(now, w, q);
            }
            Op::DrainNode(node) => {
                self.busy.fault += self.cost.fault_handle * self.op_scale;
                self.apply_drain_node(now, node, q);
            }
        }
    }

    /// Enqueue one freshly-registered task: short whole-node tasks
    /// route to the shard whose shape matches them (FIFO per shard; one
    /// class of work per shard by design); everything else takes the
    /// batch pending queue.
    fn enqueue_registered(&mut self, now: Time, tid: TaskId, prio: i32) {
        self.tasks[tid as usize].enqueued_at = now;
        match self.route_to_pool(tid) {
            Some(sid) => {
                let p = self.pool.as_mut().expect("routing implies a pool");
                p.fleet.shards[sid].pending.push_back(tid);
                p.mark(sid);
                self.trace(TraceKind::RegisterRoute, sid as u32, tid, now, 1);
            }
            None => {
                self.pending.push(tid, prio, now);
                self.backfill_dirty = true;
                self.trace(TraceKind::RegisterRoute, u32::MAX, tid, now, 0);
            }
        }
    }
}

impl sim::Actor for SchedulerSim {
    type Event = SchedEvent;

    fn handle(&mut self, now: Time, ev: SchedEvent, q: &mut EventQueue<SchedEvent>) {
        match ev {
            SchedEvent::Submit(id) => {
                if self.server_busy {
                    // The server is mid-operation: retry a tick later so
                    // registration serializes behind it (nothing is
                    // materialized yet, so there is nothing to roll back).
                    q.after(sim::TICK, SchedEvent::Submit(id));
                    return;
                }
                let spec = self.specs[id as usize].take().expect("double submit");
                // Largest node's core count, cached by the placement
                // index (no O(nodes) walk per submission).
                let cores_per_node = self.engine.index().cores_per_node();
                spec.validate(cores_per_node).expect("invalid job spec submitted");
                let meta = JobMeta {
                    id,
                    name: spec.name.clone(),
                    array_size: spec.array_size(),
                    reservation: spec.reservation.clone(),
                    priority: spec.priority,
                    preemptable: spec.preemptable,
                    submit_t: now,
                    // Task slots are materialized as one contiguous
                    // arena block right below.
                    first_task: self.tasks.len() as TaskId,
                    task_count: spec.tasks.len() as u32,
                };
                // Materialize task slots (records in PENDING). The
                // walltime estimate is sampled here, once per task, from
                // the dedicated estimate stream: the declared walltime is
                // fixed at submission, like a real batch script's.
                for t in &spec.tasks {
                    let tid = self.tasks.len() as TaskId;
                    let est_duration = t.duration * self.walltime.factor(&mut self.walltime_rng);
                    // Straggler stretch (fault layer): the *actual*
                    // occupancy runs longer, while the declared walltime
                    // — and hence `est_duration` above — keeps the
                    // submitted value. The factor is a pure hash of
                    // (fault seed, task id): no stream draws, so
                    // straggler-off runs are bit-for-bit unchanged.
                    let mut spec_t = t.clone();
                    if let Some(plan) = self.fault_plan.as_ref() {
                        let f = plan.straggler_factor(tid);
                        if f > 1.0 {
                            spec_t.duration *= f;
                            spec_t.batch.each *= f;
                        }
                    }
                    self.tasks.push(TaskSlot {
                        spec: spec_t,
                        est_duration,
                        enqueued_at: now,
                        pool_node: None,
                        backfilled: false,
                        kill_signalled: false,
                        retries: 0,
                        fault_node: None,
                        killed_at: f64::NAN,
                        record: TaskRecord {
                            task: tid,
                            job: id,
                            state: TaskState::Pending,
                            submit_t: now,
                            start_t: None,
                            end_t: None,
                            cleanup_t: None,
                            cores: 0,
                            pool_shard: None,
                        },
                        placement: None,
                        priority: spec.priority,
                    });
                }
                self.not_done += spec.tasks.len();
                // Ids are dense by construction; the resize covers the
                // (test-only) case of out-of-order first submissions
                // without cloning real metadata into filler slots.
                if self.jobs.len() <= id as usize {
                    self.jobs.resize_with(id as usize + 1, JobMeta::placeholder);
                }
                self.jobs[id as usize] = meta;
                // Registration is server work. It bypasses `pick_next`
                // (the op is scheduled directly), so its Pick record —
                // branch code 0 — is emitted here.
                let cost = self.cost.submit(spec.array_size());
                self.server_busy = true;
                self.busy_since = now;
                self.trace(TraceKind::Pick, 0, id, now, (cost * self.op_scale * 1e9) as i64);
                q.after(cost * self.op_scale, SchedEvent::ServerDone(Op::Register(id)));
            }
            SchedEvent::ServerDone(op) => {
                self.apply_op(now, op, q);
                self.server_busy = false;
                // Background bursts do not count as *scheduler* saturation:
                // the unusable-in-production guard measures the load this
                // job itself puts on the server, matching the paper's
                // observation about multi-level runs.
                let is_noise = matches!(op, Op::Noise(_));
                let stretch_started = if is_noise { now } else { self.busy_since };
                let stretch = now - stretch_started;
                if stretch > self.longest_busy_stretch {
                    self.longest_busy_stretch = stretch;
                }
                self.kick(now, q);
                if self.server_busy {
                    // The server went straight back to work: this is one
                    // continuous saturated stretch, so keep its start time.
                    self.busy_since = stretch_started;
                }
            }
            SchedEvent::TaskEnded(tid) => {
                self.finish_task(now, tid);
                self.kick(now, q);
            }
            SchedEvent::NoiseSmall => {
                if let Some((gap, demand)) = self.noise.next_small(&mut self.rng) {
                    self.noise_q.push_back(demand);
                    // Only keep the process alive while user work exists;
                    // otherwise the sim would never terminate.
                    if self.has_outstanding_work() {
                        q.after(gap, SchedEvent::NoiseSmall);
                    }
                }
                self.kick(now, q);
            }
            SchedEvent::NoiseLarge => {
                if let Some((gap, demand)) = self.noise.next_large(&mut self.rng) {
                    self.noise_q.push_back(demand);
                    if self.has_outstanding_work() {
                        q.after(gap, SchedEvent::NoiseLarge);
                    }
                }
                self.kick(now, q);
            }
            SchedEvent::Preempt(job) => {
                self.preempt_job(now, job);
                self.kick(now, q);
            }
            SchedEvent::ShardWake(sid) => {
                // Cooldown expiry marker. It only re-arms the shard's
                // attention flag — it never kicks the server, so no
                // resize happens at an instant the polled discipline
                // would not also have acted on (the decision still
                // waits for the next natural op boundary). This keeps
                // the wake-driven schedule bit-for-bit the polled one.
                if let Some(p) = self.pool.as_mut() {
                    if let Some(w) = p.wakes_pending.get_mut(sid as usize) {
                        *w = w.saturating_sub(1);
                    }
                    p.mark(sid as usize);
                }
            }
            SchedEvent::Fault(op) => {
                self.fault_q.push_back(op);
                self.kick(now, q);
            }
            SchedEvent::Requeue(tid) => {
                self.requeue_task(now, tid);
                self.kick(now, q);
            }
        }
    }
}
