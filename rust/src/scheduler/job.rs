//! Jobs, scheduling tasks and their state machine.
//!
//! Terminology follows the paper: a *compute task* is one unit of user
//! work (e.g. a 1-second simulation); a *scheduling task* is what the
//! scheduler actually places and tracks. The aggregation mode decides how
//! many compute tasks ride inside one scheduling task.

use crate::cluster::affinity::CoreMask;
use crate::cluster::node::NodeId;
use crate::error::{Error, Result};
use crate::sim::Time;

/// Job identifier.
pub type JobId = u64;
/// Scheduling-task identifier (global, dense).
pub type TaskId = u64;

/// Scheduling-task lifecycle, mirroring Slurm's visible states.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TaskState {
    /// In the pending queue, not yet placed.
    Pending,
    /// Placed and running on its resources.
    Running,
    /// Work finished; waiting for the scheduler's cleanup transaction.
    /// Resources are *held* until cleanup completes (the paper's
    /// "releasing the completed tasks takes significantly longer" effect).
    Completing,
    /// Cleaned up; resources released.
    Done,
    /// Killed by preemption (spot jobs) before finishing.
    Preempted,
}

impl TaskState {
    /// Valid transitions. Everything else is a state-machine bug.
    pub fn can_transition_to(self, next: TaskState) -> bool {
        use TaskState::*;
        matches!(
            (self, next),
            (Pending, Running)
                | (Running, Completing)
                | (Running, Preempted)
                | (Completing, Done)
                | (Preempted, Done)
        )
    }
}

/// What a scheduling task asks the scheduler for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ResourceRequest {
    /// `cores` cores on a single node plus memory (per-task / multi-level).
    Cores { cores: u32, mem_mib: u64 },
    /// One whole node (node-based scheduling).
    WholeNode,
}

impl ResourceRequest {
    /// Cores this request occupies on a node with `cores_per_node` cores.
    pub fn cores_on(&self, cores_per_node: u32) -> u32 {
        match self {
            ResourceRequest::Cores { cores, .. } => *cores,
            ResourceRequest::WholeNode => cores_per_node,
        }
    }
}

/// A compact batch of identical compute tasks (the DES representation; at
/// 512 nodes × 1 s tasks a job has ~7.9 M compute tasks, so we never
/// materialize them individually on the simulation path).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ComputeBatch {
    /// Number of compute tasks in the batch.
    pub count: u64,
    /// Duration of each compute task, seconds.
    pub each: f64,
}

impl ComputeBatch {
    /// Total serial work in the batch.
    pub fn total(&self) -> f64 {
        self.count as f64 * self.each
    }
}

/// One scheduling task, as submitted.
#[derive(Debug, Clone)]
pub struct SchedTaskSpec {
    /// Resources requested from the scheduler.
    pub request: ResourceRequest,
    /// How long the task occupies its resources (for aggregated tasks this
    /// is the serial per-core work, e.g. n × t = T_job).
    pub duration: Time,
    /// The compute tasks aggregated inside, as (per-core batch, lanes).
    /// `lanes` is the number of parallel streams (1 for per-core tasks,
    /// `cores_per_node` for node tasks).
    pub batch: ComputeBatch,
    pub lanes: u32,
}

impl SchedTaskSpec {
    /// Total compute tasks carried by this scheduling task.
    pub fn compute_tasks(&self) -> u64 {
        self.batch.count * self.lanes as u64
    }
}

/// A job: an array of scheduling tasks plus submission metadata.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub name: String,
    pub tasks: Vec<SchedTaskSpec>,
    /// Submit into a named reservation (paper: benchmark slice).
    pub reservation: Option<String>,
    /// Priority (higher dispatches first); spot jobs use low priority.
    pub priority: i32,
    /// Spot jobs are preemptable.
    pub preemptable: bool,
}

impl JobSpec {
    /// Array size (number of scheduling tasks) — the scheduler-visible
    /// load, the quantity the paper's contribution minimizes.
    pub fn array_size(&self) -> u64 {
        self.tasks.len() as u64
    }

    /// Total compute tasks across the array.
    pub fn total_compute_tasks(&self) -> u64 {
        self.tasks.iter().map(|t| t.compute_tasks()).sum()
    }

    /// Basic sanity checks before submission.
    pub fn validate(&self, cores_per_node: u32) -> Result<()> {
        if self.tasks.is_empty() {
            return Err(Error::Rejected("empty job".into()));
        }
        for t in &self.tasks {
            if t.duration <= 0.0 {
                return Err(Error::Rejected("non-positive task duration".into()));
            }
            if let ResourceRequest::Cores { cores, .. } = t.request {
                if cores == 0 || cores > cores_per_node {
                    return Err(Error::Rejected(format!(
                        "request of {cores} cores does not fit a {cores_per_node}-core node"
                    )));
                }
            }
        }
        Ok(())
    }
}

/// Where a running task was placed.
#[derive(Debug, Clone)]
pub struct Placement {
    pub node: NodeId,
    pub mask: CoreMask,
    pub mem_mib: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state_machine_legal_paths() {
        use TaskState::*;
        assert!(Pending.can_transition_to(Running));
        assert!(Running.can_transition_to(Completing));
        assert!(Completing.can_transition_to(Done));
        assert!(Running.can_transition_to(Preempted));
        assert!(Preempted.can_transition_to(Done));
    }

    #[test]
    fn state_machine_illegal_paths() {
        use TaskState::*;
        assert!(!Pending.can_transition_to(Completing));
        assert!(!Pending.can_transition_to(Done));
        assert!(!Done.can_transition_to(Pending));
        assert!(!Completing.can_transition_to(Running));
        assert!(!Pending.can_transition_to(Preempted));
    }

    #[test]
    fn batch_totals() {
        let b = ComputeBatch { count: 240, each: 1.0 };
        assert_eq!(b.total(), 240.0);
    }

    #[test]
    fn spec_counts() {
        let node_task = SchedTaskSpec {
            request: ResourceRequest::WholeNode,
            duration: 240.0,
            batch: ComputeBatch { count: 48, each: 5.0 },
            lanes: 64,
        };
        assert_eq!(node_task.compute_tasks(), 48 * 64);
        let job = JobSpec {
            name: "j".into(),
            tasks: vec![node_task; 32],
            reservation: None,
            priority: 0,
            preemptable: false,
        };
        assert_eq!(job.array_size(), 32);
        assert_eq!(job.total_compute_tasks(), 32 * 48 * 64);
    }

    #[test]
    fn validation() {
        let mut job = JobSpec {
            name: "j".into(),
            tasks: vec![],
            reservation: None,
            priority: 0,
            preemptable: false,
        };
        assert!(job.validate(64).is_err(), "empty job");
        job.tasks.push(SchedTaskSpec {
            request: ResourceRequest::Cores { cores: 65, mem_mib: 0 },
            duration: 1.0,
            batch: ComputeBatch { count: 1, each: 1.0 },
            lanes: 1,
        });
        assert!(job.validate(64).is_err(), "oversized core request");
        job.tasks[0].request = ResourceRequest::Cores { cores: 1, mem_mib: 0 };
        assert!(job.validate(64).is_ok());
        job.tasks[0].duration = 0.0;
        assert!(job.validate(64).is_err(), "zero duration");
    }

    #[test]
    fn request_core_counts() {
        assert_eq!(ResourceRequest::WholeNode.cores_on(64), 64);
        assert_eq!(
            ResourceRequest::Cores { cores: 3, mem_mib: 0 }.cores_on(64),
            3
        );
    }
}
