//! Per-task records and job-level statistics — the "scheduler log" the
//! paper reads its measurements from (§III.B: runtime is "the time between
//! the start time of the first task and the end time of the last task").

use crate::scheduler::job::{JobId, TaskId, TaskState};
use crate::sim::Time;

/// Timestamps of one scheduling task's life cycle.
#[derive(Debug, Clone)]
pub struct TaskRecord {
    pub task: TaskId,
    pub job: JobId,
    pub state: TaskState,
    /// When the job containing the task was submitted.
    pub submit_t: Time,
    /// Dispatch (= start) time in the scheduler log.
    pub start_t: Option<Time>,
    /// When the task's work finished (enters COMPLETING).
    pub end_t: Option<Time>,
    /// When the scheduler finished the cleanup transaction (resources
    /// actually released).
    pub cleanup_t: Option<Time>,
    /// Cores the task occupied while running.
    pub cores: u32,
    /// The pool-fleet shard this task launched through, if it took the
    /// node-based dispatch path (`None` for batch-placed tasks). The
    /// durable per-task launch attribution — the fleet itself keeps only
    /// counters and a bounded recent-launch ring.
    pub pool_shard: Option<u32>,
}

impl TaskRecord {
    /// Resource-hold time beyond useful work (end → cleanup).
    pub fn hold_after_end(&self) -> Option<Time> {
        Some(self.cleanup_t? - self.end_t?)
    }
}

/// Aggregated statistics for one job, computed from its task records.
#[derive(Debug, Clone)]
pub struct JobStats {
    pub job: JobId,
    pub array_size: u64,
    /// First task start (scheduler-log convention).
    pub first_start: Time,
    /// Last task end.
    pub last_end: Time,
    /// Last cleanup (job fully released).
    pub last_cleanup: Time,
    /// The paper's "job run time": last_end − first_start.
    pub runtime: Time,
    /// Overhead vs the job time per processor T_job: runtime − T_job.
    pub overhead: Time,
    /// Overhead normalized by T_job (Fig 1's vertical axis).
    pub norm_overhead: f64,
    /// Time from first to last dispatch (machine fill time).
    pub dispatch_span: Time,
    /// Time from first task end to last cleanup (release span — the
    /// paper's "releasing the completed tasks takes significantly longer").
    pub release_span: Time,
}

impl JobStats {
    /// Compute stats over the records of one job. `t_job` is the job time
    /// per processor (Table I: 240 s). Returns `None` if any task of the
    /// job is unfinished.
    pub fn compute(job: JobId, records: &[TaskRecord], t_job: Time) -> Option<JobStats> {
        let recs: Vec<&TaskRecord> = records.iter().filter(|r| r.job == job).collect();
        if recs.is_empty() || recs.iter().any(|r| r.cleanup_t.is_none()) {
            return None;
        }
        let first_start = recs.iter().map(|r| r.start_t.unwrap()).fold(f64::INFINITY, f64::min);
        let last_start = recs.iter().map(|r| r.start_t.unwrap()).fold(0.0, f64::max);
        let first_end = recs.iter().map(|r| r.end_t.unwrap()).fold(f64::INFINITY, f64::min);
        let last_end = recs.iter().map(|r| r.end_t.unwrap()).fold(0.0, f64::max);
        let last_cleanup = recs.iter().map(|r| r.cleanup_t.unwrap()).fold(0.0, f64::max);
        let runtime = last_end - first_start;
        Some(JobStats {
            job,
            array_size: recs.len() as u64,
            first_start,
            last_end,
            last_cleanup,
            runtime,
            overhead: runtime - t_job,
            norm_overhead: (runtime - t_job) / t_job,
            dispatch_span: last_start - first_start,
            release_span: last_cleanup - first_end,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(job: JobId, task: TaskId, start: Time, end: Time, cleanup: Time) -> TaskRecord {
        TaskRecord {
            task,
            job,
            state: TaskState::Done,
            submit_t: 0.0,
            start_t: Some(start),
            end_t: Some(end),
            cleanup_t: Some(cleanup),
            cores: 1,
            pool_shard: None,
        }
    }

    #[test]
    fn stats_from_simple_job() {
        let records = vec![
            rec(1, 0, 10.0, 250.0, 251.0),
            rec(1, 1, 12.0, 252.0, 260.0),
            rec(1, 2, 14.0, 254.0, 255.0),
        ];
        let s = JobStats::compute(1, &records, 240.0).unwrap();
        assert_eq!(s.array_size, 3);
        assert_eq!(s.first_start, 10.0);
        assert_eq!(s.last_end, 254.0);
        assert_eq!(s.runtime, 244.0);
        assert!((s.overhead - 4.0).abs() < 1e-12);
        assert!((s.norm_overhead - 4.0 / 240.0).abs() < 1e-12);
        assert_eq!(s.dispatch_span, 4.0);
        assert_eq!(s.release_span, 260.0 - 250.0);
    }

    #[test]
    fn unfinished_job_yields_none() {
        let mut records = vec![rec(1, 0, 1.0, 2.0, 3.0)];
        records.push(TaskRecord {
            cleanup_t: None,
            ..rec(1, 1, 1.0, 2.0, 3.0)
        });
        assert!(JobStats::compute(1, &records, 240.0).is_none());
    }

    #[test]
    fn other_jobs_ignored() {
        let records = vec![rec(1, 0, 0.0, 240.0, 241.0), rec(2, 1, 50.0, 400.0, 401.0)];
        let s = JobStats::compute(1, &records, 240.0).unwrap();
        assert_eq!(s.runtime, 240.0);
        assert_eq!(s.array_size, 1);
    }

    #[test]
    fn missing_job_yields_none() {
        let records = vec![rec(1, 0, 0.0, 1.0, 2.0)];
        assert!(JobStats::compute(9, &records, 240.0).is_none());
    }

    #[test]
    fn hold_after_end() {
        let r = rec(1, 0, 0.0, 240.0, 250.0);
        assert_eq!(r.hold_after_end(), Some(10.0));
    }
}
