//! Production background-load model.
//!
//! The paper ran node-based benchmarks on the *production* system (other
//! users' jobs compete for the scheduler) but had to move multi-level
//! 256/512-node runs to a *dedicated* system. We model production load as
//! bursts of extraneous scheduler work:
//!
//! * **small bursts** — steady drizzle of other users' submissions, RPCs
//!   and queries; keeps the server ~40 % occupied on average, stretching
//!   all scheduler operations by ~1.7× (matches the production-vs-
//!   dedicated gap between the 128- and 256-node multi-level rows of
//!   Table III), and
//! * **rare large bursts** — another user launching a big array job or an
//!   admin operation wedging the scheduler for minutes; these produce the
//!   occasional heavy-tail runs the paper attributes to "the other jobs
//!   being served at the time" (e.g. node-based 512-node runs of 391 s and
//!   489 s against a 242 s norm).

use crate::sim::Time;
use crate::util::rng::Rng;

/// Parameters of the background-load process.
#[derive(Debug, Clone)]
pub struct NoiseModel {
    /// Mean gap between small bursts (exponential), seconds.
    pub small_gap_mean: Time,
    /// Mean small-burst service demand, seconds (exponential).
    pub small_burst_mean: Time,
    /// Mean gap between large bursts, seconds.
    pub large_gap_mean: Time,
    /// Large-burst demand range (uniform), seconds.
    pub large_burst: (Time, Time),
}

impl NoiseModel {
    /// Calibrated production drizzle: ~40 % average server load with a
    /// heavy tail (see module docs).
    pub fn production() -> NoiseModel {
        NoiseModel {
            small_gap_mean: 2.0,
            small_burst_mean: 0.8,
            large_gap_mean: 2500.0,
            large_burst: (40.0, 160.0),
        }
    }

    /// Dedicated system: no background work at all.
    pub fn dedicated() -> NoiseModel {
        NoiseModel {
            small_gap_mean: f64::INFINITY,
            small_burst_mean: 0.0,
            large_gap_mean: f64::INFINITY,
            large_burst: (0.0, 0.0),
        }
    }

    /// Average fraction of server time consumed by background load.
    pub fn mean_load(&self) -> f64 {
        let small = if self.small_gap_mean.is_finite() {
            self.small_burst_mean / (self.small_gap_mean + self.small_burst_mean)
        } else {
            0.0
        };
        let large = if self.large_gap_mean.is_finite() {
            let mean_burst = 0.5 * (self.large_burst.0 + self.large_burst.1);
            mean_burst / (self.large_gap_mean + mean_burst)
        } else {
            0.0
        };
        (small + large).min(1.0)
    }

    /// Sample the next `(gap, demand)` small-burst pair.
    pub fn next_small(&self, rng: &mut Rng) -> Option<(Time, Time)> {
        if !self.small_gap_mean.is_finite() {
            return None;
        }
        let gap = rng.exponential(1.0 / self.small_gap_mean);
        let demand = rng.exponential(1.0 / self.small_burst_mean.max(1e-12));
        Some((gap, demand))
    }

    /// Sample the next `(gap, demand)` large-burst pair.
    pub fn next_large(&self, rng: &mut Rng) -> Option<(Time, Time)> {
        if !self.large_gap_mean.is_finite() {
            return None;
        }
        let gap = rng.exponential(1.0 / self.large_gap_mean);
        let demand = rng.range_f64(self.large_burst.0, self.large_burst.1);
        Some((gap, demand))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dedicated_is_silent() {
        let n = NoiseModel::dedicated();
        let mut rng = Rng::new(1);
        assert!(n.next_small(&mut rng).is_none());
        assert!(n.next_large(&mut rng).is_none());
        assert_eq!(n.mean_load(), 0.0);
    }

    #[test]
    fn production_load_near_forty_percent() {
        let n = NoiseModel::production();
        let load = n.mean_load();
        assert!((0.3..0.55).contains(&load), "load {load}");
    }

    #[test]
    fn sampled_means_match_parameters() {
        let n = NoiseModel::production();
        let mut rng = Rng::new(42);
        let k = 20_000;
        let (mut gaps, mut demands) = (0.0, 0.0);
        for _ in 0..k {
            let (g, d) = n.next_small(&mut rng).unwrap();
            gaps += g;
            demands += d;
        }
        let mg = gaps / k as f64;
        let md = demands / k as f64;
        assert!((mg - 2.0).abs() < 0.1, "gap mean {mg}");
        assert!((md - 0.8).abs() < 0.05, "demand mean {md}");
    }

    #[test]
    fn large_bursts_in_range() {
        let n = NoiseModel::production();
        let mut rng = Rng::new(7);
        for _ in 0..1000 {
            let (_, d) = n.next_large(&mut rng).unwrap();
            assert!((40.0..160.0).contains(&d), "{d}");
        }
    }
}
