//! The scheduler cost model — the calibrated substrate knob set.
//!
//! Every operation the single-threaded scheduler server performs has a
//! virtual-time cost. The constants below are calibrated against Table III
//! of the paper (see EXPERIMENTS.md §Calibration): with them, the
//! simulated multi-level runs land on the paper's medians (≈283 s at 32
//! nodes → ≈2750 s at 512 nodes) and node-based runs stay at ≈242–312 s,
//! while the *mechanism* — dispatch serialized against array-size-dependent
//! completion cleanup — is the one the paper describes.
//!
//! Key structural facts the model encodes:
//!
//! 1. **Dispatch** costs ~12 ms of scheduler time per scheduling task
//!    (placement + RPC + bookkeeping). 16384 tasks ⇒ ~202 s to fill the
//!    machine — exactly the paper's 256-node multi-level overhead.
//! 2. **Cleanup** of a finished scheduling task is *more expensive than
//!    dispatch* and grows with the job's array size (per-completion
//!    bookkeeping touches the array's task set / accounting records):
//!    `cleanup = base + coeff × array_size`. At 32768 tasks this is
//!    ~108 ms/task — the "scheduler unresponsive while clearing finished
//!    tasks" pathology.
//! 3. The server prioritizes completion processing over new dispatches
//!    (with a bounded interleave), so once completions start flooding in,
//!    dispatch starves. At ≤256 nodes the machine fills before the first
//!    completion (dispatch time < T_job) and nothing happens; at 512 nodes
//!    dispatch time (~400 s) crosses T_job = 240 s and the feedback cliff
//!    appears — the paper's "could not dispatch some compute tasks until
//!    after the 2500 second mark".

use crate::sim::Time;

/// Cost (virtual seconds of scheduler-server time) of each operation.
#[derive(Debug, Clone)]
pub struct CostModel {
    /// Registering a submitted job: fixed part.
    pub submit_base: Time,
    /// Registering a submitted job: per scheduling task.
    pub submit_per_task: Time,
    /// Dispatching one core-level scheduling task (placement + RPC).
    pub dispatch_core: Time,
    /// Dispatching one whole-node scheduling task.
    pub dispatch_node: Time,
    /// Scan cost charged once per dispatch *cycle* (per
    /// [`CostModel::dispatch_cycle_batch`] dispatches): fixed part.
    pub cycle_base: Time,
    /// Scan cost per pending task in the queue at cycle start.
    pub cycle_per_pending: Time,
    /// How many dispatches one scheduling cycle may perform.
    pub dispatch_cycle_batch: u32,
    /// Cleanup transaction for one finished scheduling task: fixed part.
    pub cleanup_base: Time,
    /// Cleanup: additional cost per task in the owning job's array
    /// (the super-linear term behind the 512-node collapse).
    pub cleanup_per_array_task: Time,
    /// Process at most this many cleanups before allowing one dispatch
    /// through (bounded starvation; Slurm still runs periodic sched
    /// cycles while draining completion RPCs).
    pub cleanup_interleave: u32,
    /// Preemption signal cost per scheduling task (spot release path).
    pub preempt_signal: Time,
    /// Pool dispatch of one short whole-node job: pop the free list,
    /// notify the node. Bypasses placement and per-core bookkeeping, so
    /// it is far below [`CostModel::dispatch_core`] — the paper's
    /// node-based launch-cost structure.
    pub pool_dispatch: Time,
    /// Pool release of one finished job: push the node back on the free
    /// list. Constant — unlike [`CostModel::cleanup`] it does not grow
    /// with the owning array's size.
    pub pool_release: Time,
    /// One pool-resize operation (lease / drain / return bookkeeping).
    pub pool_resize: Time,
    /// Handling one fault event (node state flip, hold/lease teardown,
    /// kill fan-out bookkeeping). Node failures are rare but their
    /// handling still serializes through the scheduler server.
    pub fault_handle: Time,
}

impl CostModel {
    /// Calibrated to TX-Green/Slurm behaviour in Table III
    /// (see EXPERIMENTS.md §Calibration for the fitting procedure).
    pub fn slurm_like_tx_green() -> CostModel {
        CostModel {
            submit_base: 0.5,
            submit_per_task: 20e-6,
            dispatch_core: 12.3e-3,
            dispatch_node: 12.3e-3,
            cycle_base: 0.8e-3,
            cycle_per_pending: 0.05e-6,
            dispatch_cycle_batch: 100,
            cleanup_base: 8e-3,
            cleanup_per_array_task: 2.15e-6,
            cleanup_interleave: 2,
            preempt_signal: 4e-3,
            pool_dispatch: 0.3e-3,
            pool_release: 0.5e-3,
            pool_resize: 2e-3,
            fault_handle: 2e-3,
        }
    }

    /// An idealized zero-overhead scheduler (ablation baseline: what the
    /// runtime would be if scheduling were free).
    pub fn ideal() -> CostModel {
        CostModel {
            submit_base: 0.0,
            submit_per_task: 0.0,
            dispatch_core: 0.0,
            dispatch_node: 0.0,
            cycle_base: 0.0,
            cycle_per_pending: 0.0,
            dispatch_cycle_batch: u32::MAX,
            cleanup_base: 0.0,
            cleanup_per_array_task: 0.0,
            cleanup_interleave: u32::MAX,
            preempt_signal: 0.0,
            pool_dispatch: 0.0,
            pool_release: 0.0,
            pool_resize: 0.0,
            fault_handle: 0.0,
        }
    }

    /// Submission registration cost for an array of `n` tasks.
    pub fn submit(&self, n: u64) -> Time {
        self.submit_base + self.submit_per_task * n as f64
    }

    /// Dispatch cost for one task (`node_level` = whole-node request).
    pub fn dispatch(&self, node_level: bool) -> Time {
        if node_level {
            self.dispatch_node
        } else {
            self.dispatch_core
        }
    }

    /// Scheduling-cycle scan cost with `pending` tasks queued.
    pub fn cycle(&self, pending: usize) -> Time {
        self.cycle_base + self.cycle_per_pending * pending as f64
    }

    /// Cleanup cost for one finished task of a job with `array_size` tasks.
    pub fn cleanup(&self, array_size: u64) -> Time {
        self.cleanup_base + self.cleanup_per_array_task * array_size as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_magnitudes() {
        let c = CostModel::slurm_like_tx_green();
        // 16384 dispatches must land near the paper's 256-node overhead
        // (~202 s) — the machine fills just before T_job.
        let fill_256 = 16384.0 * c.dispatch_core;
        assert!((195.0..215.0).contains(&fill_256), "{fill_256}");
        // 32768 dispatches must exceed T_job = 240 s (the cliff trigger).
        assert!(32768.0 * c.dispatch_core > 240.0);
        // Cleanup at 512-node array size must dominate dispatch.
        let cl = c.cleanup(32768);
        assert!(cl > 5.0 * c.dispatch_core, "cleanup {cl} too cheap");
        assert!((0.06..0.16).contains(&cl), "cleanup {cl} out of band");
    }

    #[test]
    fn node_based_overhead_is_small() {
        let c = CostModel::slurm_like_tx_green();
        // 512 node-level dispatches: a few seconds, not minutes.
        let t = 512.0 * c.dispatch_node + c.submit(512);
        assert!(t < 10.0, "{t}");
    }

    #[test]
    fn ideal_model_is_free() {
        let c = CostModel::ideal();
        assert_eq!(c.submit(1_000_000), 0.0);
        assert_eq!(c.dispatch(true), 0.0);
        assert_eq!(c.cleanup(1 << 20), 0.0);
        assert_eq!(c.cycle(1 << 20), 0.0);
    }

    #[test]
    fn pool_path_is_an_order_of_magnitude_cheaper() {
        let c = CostModel::slurm_like_tx_green();
        // The paper's cost structure: node-based pool launch + release
        // must beat full dispatch + cleanup by ≥ 10× per short job.
        let pooled = c.pool_dispatch + c.pool_release;
        let batch = c.dispatch(true) + c.cleanup(1000);
        assert!(batch > 10.0 * pooled, "batch {batch} vs pooled {pooled}");
        assert!(c.pool_resize < c.dispatch_core, "resize stays cheap");
    }

    #[test]
    fn cleanup_grows_with_array() {
        let c = CostModel::slurm_like_tx_green();
        assert!(c.cleanup(32768) > c.cleanup(2048));
        assert!(c.cleanup(512) < 2.0 * c.dispatch_core, "node-based cleanup stays cheap");
    }
}
