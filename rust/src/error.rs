//! Library-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls: the offline build has no
//! `thiserror`, and the PJRT bindings are stubbed (see
//! [`crate::runtime::stub`]), so the error surface stays dependency-free.

use std::fmt;

/// Errors surfaced by the llsched library.
#[derive(Debug)]
pub enum Error {
    /// A job or task referenced an id that does not exist.
    UnknownId { kind: &'static str, id: u64 },

    /// A resource request cannot ever be satisfied by the cluster.
    Infeasible(String),

    /// Configuration file / value errors.
    Config(String),

    /// The scheduler refused the submission (e.g. responsiveness guard).
    Rejected(String),

    /// Invalid state transition in a job/task/node state machine.
    InvalidTransition(String),

    /// PJRT / XLA runtime errors.
    Runtime(String),

    /// I/O errors (artifact loading, report writing).
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::UnknownId { kind, id } => write!(f, "unknown {kind} id {id}"),
            Error::Infeasible(m) => write!(f, "infeasible request: {m}"),
            Error::Config(m) => write!(f, "config error: {m}"),
            Error::Rejected(m) => write!(f, "submission rejected: {m}"),
            Error::InvalidTransition(m) => write!(f, "invalid transition: {m}"),
            Error::Runtime(m) => write!(f, "runtime error: {m}"),
            Error::Io(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<crate::runtime::stub::XlaError> for Error {
    fn from(e: crate::runtime::stub::XlaError) -> Self {
        Error::Runtime(e.to_string())
    }
}

/// Convenience result alias used across the crate.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats() {
        assert_eq!(
            Error::UnknownId { kind: "node", id: 5 }.to_string(),
            "unknown node id 5"
        );
        assert_eq!(Error::Config("bad".into()).to_string(), "config error: bad");
        assert!(Error::Infeasible("x".into()).to_string().contains("infeasible"));
    }

    #[test]
    fn io_errors_convert() {
        let e: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(matches!(e, Error::Io(_)));
        assert!(e.to_string().contains("gone"));
        use std::error::Error as _;
        assert!(e.source().is_some());
    }
}
