//! Library-wide error type.

use thiserror::Error;

/// Errors surfaced by the llsched library.
#[derive(Error, Debug)]
pub enum Error {
    /// A job or task referenced an id that does not exist.
    #[error("unknown {kind} id {id}")]
    UnknownId { kind: &'static str, id: u64 },

    /// A resource request cannot ever be satisfied by the cluster.
    #[error("infeasible request: {0}")]
    Infeasible(String),

    /// Configuration file / value errors.
    #[error("config error: {0}")]
    Config(String),

    /// The scheduler refused the submission (e.g. responsiveness guard).
    #[error("submission rejected: {0}")]
    Rejected(String),

    /// Invalid state transition in a job/task/node state machine.
    #[error("invalid transition: {0}")]
    InvalidTransition(String),

    /// PJRT / XLA runtime errors.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// I/O errors (artifact loading, report writing).
    #[error(transparent)]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(e.to_string())
    }
}

/// Convenience result alias used across the crate.
pub type Result<T> = std::result::Result<T, Error>;
